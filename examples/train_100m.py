"""End-to-end training driver: a ~100M-parameter dense LM on the synthetic
pipeline with checkpoint/restart, straggler monitoring, and loss logging.

Default runs a reduced step count for CPU; pass --steps 300 for the full
few-hundred-step run (see EXPERIMENTS.md for a recorded run).

  PYTHONPATH=src python examples/train_100m.py [--steps N] [--ckpt DIR]
"""
import argparse
import dataclasses
import time

from repro.configs.base import ModelConfig
from repro.launch.train import train
from repro.optim.adamw import AdamWConfig

# ~100M params: 12L x 512d x 8H, 50k vocab -> 88.9M
CONFIG_100M = ModelConfig(
    name="demo-100m", family="dense", num_layers=12, d_model=512,
    num_heads=8, num_kv_heads=4, d_ff=2048, vocab_size=50304,
    dtype="float32", remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = CONFIG_100M
    print(f"model: {cfg.param_count() / 1e6:.1f}M params")
    t0 = time.time()
    _, _, info = train(cfg, steps=args.steps, global_batch=args.batch,
                       seq_len=args.seq, ckpt_dir=args.ckpt, save_every=20,
                       opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=10,
                                           total_steps=args.steps))
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"\n{args.steps} steps, {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.0f} tok/s)")
    print(f"loss: {info['losses'][0]:.3f} -> {info['losses'][-1]:.3f}")
    print(f"stragglers flagged: {info['stragglers']}")


if __name__ == "__main__":
    main()
