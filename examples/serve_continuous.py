"""Continuous batching vs BSP batch serving — the Atos scheduler on LLM
requests with skewed output lengths (the serving convoy experiment).

  PYTHONPATH=src python examples/serve_continuous.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import smoke_config
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving.engine import ContinuousBatchingEngine, Request


def main():
    cfg = smoke_config("minitron-4b")
    params = init_params(T.model_spec(cfg), jax.random.PRNGKey(0),
                         jnp.float32)
    rng = np.random.default_rng(0)
    # heavy-tailed output lengths: most requests short, a few long
    reqs = [Request(uid=i, prompt=[int(rng.integers(1, cfg.vocab_size))],
                    max_new_tokens=int(rng.choice([2, 3, 3, 16])))
            for i in range(16)]

    for mode in ["bsp", "continuous"]:
        trace = []
        eng = ContinuousBatchingEngine(cfg, params, num_slots=4, max_len=64,
                                       mode=mode)
        res = eng.run(list(reqs), trace=trace)
        st = res["stats"]
        print(f"\nmode={mode}")
        print(f"  wavefronts      : {st.wavefronts}")
        print(f"  mean occupancy  : {st.mean_occupancy:.3f}")
        print(f"  active-slot trace: {trace}")
    print("\ncontinuous admits into freed slots every wavefront "
          "(relaxed barrier) -> fewer wavefronts for the same tokens.")


if __name__ == "__main__":
    main()
