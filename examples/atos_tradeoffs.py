"""The paper's analysis experiments, reproduced:

  1. Fig-4 analogue — runtime heatmap over (num_workers x fetch_size);
  2. section 6.4 — vertex-ID permutation vs graph-coloring overwork;
  3. kernel strategy — persistent vs discrete round/dispatch counts.

  PYTHONPATH=src python examples/atos_tradeoffs.py
"""
import time

import numpy as np

from repro.algorithms.bfs import bfs_speculative
from repro.algorithms.coloring import coloring_async
from repro.core import SchedulerConfig
from repro.graph import grid2d, permute_vertices, rmat


def heatmap():
    print("=== Fig 4 analogue: BFS runtime (ms) over workers x fetch ===")
    g = rmat(9, 8, seed=1)
    print(f"{'':>8}" + "".join(f"fetch={f:<6}" for f in [1, 4, 16]))
    for w in [4, 16, 64]:
        cells = []
        for f in [1, 4, 16]:
            cfg = SchedulerConfig(num_workers=w, fetch_size=f,
                                  persistent=True, max_rounds=1 << 20)
            bfs_speculative(g, 0, cfg)  # warm
            t0 = time.perf_counter()
            bfs_speculative(g, 0, cfg)
            cells.append(f"{(time.perf_counter() - t0) * 1e3:8.1f}    ")
        print(f"w={w:<6}" + "".join(cells))


def permutation():
    print("\n=== section 6.4: vertex-ID permutation vs coloring overwork ===")
    g = grid2d(24, 24)
    perm = np.random.default_rng(0).permutation(g.num_vertices).astype(np.int32)
    gp = permute_vertices(g, perm)
    cfg = SchedulerConfig(num_workers=16, fetch_size=8, persistent=True,
                          max_rounds=1 << 20)
    for name, gg in [("sorted IDs  ", g), ("permuted IDs", gp)]:
        _, info = coloring_async(gg, cfg)
        print(f"  {name}: work/|V| = {info['work'] / gg.num_vertices:.3f}")


def kernel_strategy():
    print("\n=== kernel strategy: persistent vs discrete (BFS, mesh) ===")
    g = grid2d(32, 32)
    for persistent in [True, False]:
        cfg = SchedulerConfig(num_workers=16, fetch_size=2,
                              persistent=persistent, max_rounds=1 << 20)
        t0 = time.perf_counter()
        _, info = bfs_speculative(g, 0, cfg)
        dt = (time.perf_counter() - t0) * 1e3
        kind = "persistent" if persistent else "discrete  "
        n_dispatch = 1 if persistent else info["rounds"]
        print(f"  {kind}: rounds={info['rounds']:5d} wall={dt:7.1f} ms "
              f"({n_dispatch} host dispatches)")


if __name__ == "__main__":
    heatmap()
    permutation()
    kernel_strategy()
