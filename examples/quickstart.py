"""Quickstart: the Atos task-parallel scheduler on the paper's three case
studies (BFS / PageRank / graph coloring), BSP vs relaxed-barrier.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.algorithms.bfs import bfs_bsp, bfs_speculative
from repro.algorithms.coloring import coloring_async, coloring_bsp, \
    validate_coloring
from repro.algorithms.pagerank import pagerank_async, pagerank_bsp, \
    pagerank_reference
from repro.core import SchedulerConfig
from repro.graph import degree_stats, grid2d, rmat


def main():
    for name, g in [("scale-free (R-MAT)", rmat(9, 8, seed=1)),
                    ("mesh-like (grid)", grid2d(32, 32))]:
        print(f"\n=== {name}: {degree_stats(g)}")
        cfg = SchedulerConfig(num_workers=16, fetch_size=4, persistent=True,
                              max_rounds=1 << 20)

        dist, info_b = bfs_bsp(g, 0)
        dist_a, info_a = bfs_speculative(g, 0, cfg, strategy="merge_path")
        same = bool((np.asarray(dist) == np.asarray(dist_a)).all())
        print(f"BFS       BSP levels={info_b['levels']:4d} | Atos rounds="
              f"{info_a['rounds']:4d} work={info_a['work']} exact={same}")

        ref = pagerank_reference(g, iters=200)
        _, pb = pagerank_bsp(g, eps=1e-6)
        ra, pa = pagerank_async(g, cfg, eps=1e-6)
        err = float(np.max(np.abs(np.asarray(ra) - np.asarray(ref))))
        print(f"PageRank  BSP work={pb['work']:7d} | Atos work="
              f"{pa['work']:7d} (ratio {pa['work'] / pb['work']:.2f}) "
              f"err={err:.1e}")

        cb, ib = coloring_bsp(g)
        ca, ia = coloring_async(g, cfg)
        print(f"Coloring  BSP work/|V|={ib['work'] / g.num_vertices:.2f} | "
              f"Atos work/|V|={ia['work'] / g.num_vertices:.2f} "
              f"valid={validate_coloring(g, ca)} "
              f"colors={int(np.max(np.asarray(ca))) + 1}")


if __name__ == "__main__":
    main()
