"""End-to-end trainer: data -> sharded train_step -> checkpoint/restart.

Runs at any scale: smoke configs on 1 CPU device (tests, examples) up to the
production mesh.  Fault tolerance wiring: StepMonitor (straggler flags),
CheckpointManager (atomic + async), resume-from-latest on start.

Usage (CPU example):
  PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b --smoke \
      --steps 60 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..configs.registry import get_config, smoke_config
from ..data.pipeline import DataConfig, SyntheticLM
from ..distributed import sharding as SH
from ..distributed.fault import StepMonitor
from ..models import transformer as T
from ..models.params import init_params
from ..optim import adamw, adafactor
from .mesh import make_local_mesh


def train(cfg, *, steps: int, global_batch: int, seq_len: int,
          ckpt_dir: str | None = None, save_every: int = 20,
          data_seed: int = 0, opt_cfg=None, log_every: int = 10,
          mesh=None, pc=None, grad_compression: str = "none",
          log=print):
    pc = pc or SH.ParallelConfig(fsdp_axis=(), tp_axis=())
    mesh = mesh or make_local_mesh(1, 1)
    dtype = jnp.dtype(cfg.dtype)

    spec = T.model_spec(cfg)
    params = init_params(spec, jax.random.PRNGKey(0), dtype)
    opt_state = (adafactor.init(params) if cfg.use_adafactor
                 else adamw.init(params))
    if opt_cfg is None:
        opt_cfg = (adafactor.AdafactorConfig() if cfg.use_adafactor
                   else adamw.AdamWConfig(total_steps=steps))
    step_fn = jax.jit(SH.make_train_step(cfg, opt_cfg,
                                         grad_compression=grad_compression))

    data = SyntheticLM(DataConfig(cfg.vocab_size, seq_len, global_batch,
                                  seed=data_seed))
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start = 0
    if mgr and mgr.latest_step() is not None:
        start = mgr.latest_step()
        state = mgr.restore(start, {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        log(f"resumed from step {start}")

    monitor = StepMonitor()
    losses = []
    with mesh:
        for i in range(start, steps):
            batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            if cfg.family == "vlm":
                batch["patch_emb"] = jnp.zeros(
                    (global_batch, cfg.frontend_len, cfg.d_model), dtype)
            if cfg.family == "encdec":
                rng = np.random.default_rng(i)
                batch["frames"] = jnp.asarray(rng.standard_normal(
                    (global_batch, cfg.frontend_len, cfg.d_model)), dtype)
            monitor.start()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            straggler = monitor.stop(i)
            losses.append(float(metrics["loss"]))
            if (i + 1) % log_every == 0 or i == start:
                log(f"step {i + 1:5d} loss {losses[-1]:.4f} "
                    f"{'STRAGGLER' if straggler else ''}")
            if mgr and (i + 1) % save_every == 0:
                mgr.save(i + 1, {"params": params, "opt": opt_state},
                         blocking=False)
    if mgr:
        mgr.wait()
    return params, opt_state, {"losses": losses,
                               "stragglers": monitor.straggler_steps}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    t0 = time.time()
    _, _, info = train(cfg, steps=args.steps, global_batch=args.batch,
                       seq_len=args.seq, ckpt_dir=args.ckpt_dir)
    print(f"done in {time.time() - t0:.1f}s; "
          f"loss {info['losses'][0]:.3f} -> {info['losses'][-1]:.3f}")


if __name__ == "__main__":
    main()
