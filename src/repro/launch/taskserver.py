"""Multi-tenant task-server driver: N concurrent graph jobs, one scheduler.

  PYTHONPATH=src python -m repro.launch.taskserver --jobs 8 --policy weighted
  PYTHONPATH=src python -m repro.launch.taskserver --jobs 12 --lanes 4 \
      --autotune --compare-sequential
  PYTHONPATH=src python -m repro.launch.taskserver --jobs 8 --backend pallas

Builds one scale-free (R-MAT) and one mesh (2-D grid) graph — the paper's
two dataset regimes — submits a mixed batch of BFS / PageRank / coloring
jobs against them, and drains everything through a single TaskServer,
printing per-job telemetry (latency, rounds, occupancy, overwork) and the
server totals.  ``--compare-sequential`` also runs the tenant-at-a-time
baseline to show the fused-wavefront round savings.
"""
from __future__ import annotations

import argparse
import logging
import subprocess

from ..core.scheduler import SchedulerConfig
from ..graph.generators import grid2d, rmat
from ..runtime.policy import POLICY_GRID, parse_policy
from ..server import (Autotuner, JobRegistry, JobSpec, TaskServer,
                      serve_sequential)

ALGO_CYCLE = ("bfs", "pagerank", "coloring")


def git_sha() -> str:
    """Best-effort provenance stamp for the trace meta block."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=5, check=True).stdout.strip()
    except Exception:
        return "unknown"


def build_registry(scale: int, grid_side: int, seed: int) -> JobRegistry:
    reg = JobRegistry()
    reg.register_graph("rmat", rmat(scale, edge_factor=8, seed=seed))
    reg.register_graph("grid", grid2d(grid_side, grid_side, seed=seed))
    return reg


def mixed_specs(n_jobs: int, registry: JobRegistry, eps: float,
                seed: int, shards: int = 1,
                stream: int = 0, stream_batch: int = 32,
                snapshot_every: int = 0, checkpoint_dir: str | None = None,
                resume: bool = False, compact_every: int = 0,
                overlay_slack: float = 0.25) -> list[JobSpec]:
    """Round-robin over algorithms x graphs, sources spread over vertices.

    With ``shards > 1`` the BFS jobs become sharded single-tenant jobs (the
    exchange-heavy workload benefits most from the mesh) while PageRank and
    coloring stay in the fused multi-tenant rounds — one batch exercising
    both serving modes.

    With ``stream > 0`` the BFS jobs become *streaming* jobs: each gets a
    deterministic seeded delta log (``graph/generators.edge_delta_stream``,
    ``stream`` batches of ``stream_batch`` edge ops) committed batch by
    batch with incremental recompute between drains; snapshot/resume
    posture per ``snapshot_every`` / ``checkpoint_dir`` / ``resume``
    (per-job subdirectories under ``checkpoint_dir``).
    """
    from ..graph.generators import edge_delta_stream
    from ..stream import StreamSpec

    specs = []
    graphs = registry.graph_names
    for i in range(n_jobs):
        algorithm = ALGO_CYCLE[i % len(ALGO_CYCLE)]
        gname = graphs[(i // len(ALGO_CYCLE)) % len(graphs)]
        n = registry.graph(gname).num_vertices
        params = {}
        if algorithm == "bfs":
            params["source"] = (seed + 7919 * i) % n
        elif algorithm == "pagerank":
            params["eps"] = eps
        stream_spec = None
        if stream > 0 and algorithm == "bfs":
            deltas = edge_delta_stream(registry.graph(gname), stream,
                                       stream_batch, seed=seed + i)
            job_dir = (f"{checkpoint_dir}/job_{i}"
                       if checkpoint_dir else None)
            stream_spec = StreamSpec(
                deltas=tuple(deltas),
                snapshot_every=snapshot_every if job_dir else 0,
                checkpoint_dir=job_dir, resume=resume and job_dir is not None,
                compact_every=compact_every, overlay_slack=overlay_slack)
        specs.append(JobSpec(algorithm, gname, params,
                             weight=1.0 + (i % 3),
                             shards=shards if algorithm == "bfs" else 1,
                             stream=stream_spec))
    return specs


def print_telemetry(result) -> None:
    hdr = (f"{'job':>3} {'algorithm':<9} {'graph':<5} {'lat(rounds)':>11} "
           f"{'active':>6} {'items':>7} {'occ':>6} {'overwork':>8} "
           f"{'drops':>5} {'bp':>3}")
    print(hdr)
    print("-" * len(hdr))
    for job_id in sorted(result.telemetry):
        t = result.telemetry[job_id]
        print(f"{job_id:>3} {t.algorithm:<9} {t.graph:<5} "
              f"{t.latency_rounds:>11} {t.rounds_active:>6} "
              f"{t.items_processed:>7} {t.occupancy:>6.3f} "
              f"{t.overwork:>8.2f} {t.dropped:>5} "
              f"{t.backpressure_events:>3}")
    s = result.stats
    print(f"server: rounds={s.rounds} occupancy={s.occupancy:.3f} "
          f"wall={s.wall_seconds:.2f}s "
          f"backpressure={s.backpressure_events} "
          f"deferred_admissions={s.deferred_admissions}")
    if s.sharded_jobs:
        print(f"sharded phases: {s.sharded_jobs} jobs, "
              f"{s.sharded_rounds} device rounds")
    if s.streaming_jobs:
        print(f"streaming phases: {s.streaming_jobs} jobs, "
              f"{s.stream_batches} delta batches")


def print_stream_records(server) -> None:
    """Per-batch breakdown of every streaming job's drains."""
    for job in server._jobs:
        if job.stream_result is None:
            continue
        res = job.stream_result
        print(f"streaming job {job.job_id}: {res.info['batches_run']} "
              f"batches (incremental={res.info['incremental']})")
        for r in res.batches:
            mode = "incr" if r.incremental else "full"
            print(f"  batch {r.batch:>3} [{mode}] ops={r.effective_ops:>4} "
                  f"seeds={r.seeds:>5} rounds={r.rounds:>5} "
                  f"work={r.work:>7} touched={r.touched_rows:>4} "
                  f"ovl={r.overlay:>4}{' compact' if r.compacted else ''}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--jobs", type=int, default=8)
    ap.add_argument("--lanes", type=int, default=8)
    ap.add_argument("--policy", default="weighted",
                    choices=["weighted", "round_robin",
                             "longest_queue_first"])
    ap.add_argument("--workers", type=int, default=64)
    ap.add_argument("--fetch", type=int, default=1)
    ap.add_argument("--exec-policy", default="auto",
                    help="execution policy "
                         "('<topology>.<kernel>[.g<width>]', DESIGN.md "
                         "sections 11-12, 14): e.g. fused.discrete drains "
                         "through a packed MultiQueue lane with a host "
                         "loop, sharded.persistent.g4 adds width-4 chunk "
                         "tasks, single.megakernel fuses a drain loop "
                         "into ONE Pallas kernel launch — an "
                         "interpret-mode prototype (no Mosaic lowering "
                         "yet, so it runs emulated even on TPU), honored "
                         "by streaming jobs' per-batch drains; the "
                         "multi-tenant server rounds themselves stay "
                         "host-driven and warn.  auto keeps the config "
                         "defaults (single topology, persistent "
                         "kernel).  Known cells: "
                         + ", ".join(str(p) for p in POLICY_GRID))
    ap.add_argument("--granularity", type=int, default=1,
                    help="max task chunk width G (core/task.py, DESIGN.md "
                         "section 12): each queue slot carries up to G "
                         "consecutive CSR rows; 1 = classic single-vertex "
                         "tasks.  A .g<width> suffix on --exec-policy "
                         "overrides this.")
    ap.add_argument("--split-threshold", type=int, default=0,
                    help="chunk degree-sum cap at formation time (0 = "
                         "bounded by the merge-path work budget only) — "
                         "the paper's level-of-balancing dial")
    ap.add_argument("--backend", default="auto",
                    choices=["jnp", "pallas", "auto"],
                    help="kernel backend: jnp reference, Pallas TPU kernels "
                         "(interpret mode off-TPU), or auto-detect "
                         "(ignored under --autotune, which searches the "
                         "backend axis itself)")
    ap.add_argument("--shards", type=int, default=1,
                    help="run the BFS jobs as sharded single-tenant drains "
                         "over an N-device ('shard',) mesh (repro/shard); "
                         "needs N visible devices — on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--mesh", type=int, nargs=2, default=None,
                    metavar=("R", "C"),
                    help="shard the BFS jobs over a 2-D ('row', 'col') "
                         "R x C device mesh instead of the 1-D ring "
                         "(DESIGN.md section 16): the routed exchange "
                         "decomposes into two per-axis all_to_alls; "
                         "implies --shards R*C")
    ap.add_argument("--overlap", action="store_true",
                    help="hide the exchange: stage routed task deliveries "
                         "one round (defer_rounds=1) so the collective "
                         "overlaps the next round's compute — results "
                         "unchanged (tasks are idempotent re-checks), "
                         "schedule may differ from strict delivery")
    ap.add_argument("--compress", action="store_true",
                    help="delta-compress exchange payloads on the wire "
                         "(sorted-run delta + zigzag bit-packing, "
                         "shard/codec.py); lossless, raw fallback when a "
                         "batch is incompressible")
    ap.add_argument("--stream", type=int, default=0, metavar="N",
                    help="turn the BFS jobs into streaming jobs over N "
                         "delta batches (repro/stream): each batch commits "
                         "edge inserts/deletes against the job's graph and "
                         "incrementally recomputes from the dirty frontier")
    ap.add_argument("--stream-batch", type=int, default=32, metavar="K",
                    help="edge operations per delta batch (mixed "
                         "inserts/deletes, both directions emitted)")
    ap.add_argument("--compact-every", type=int, default=0, metavar="B",
                    help="re-pack the slotted CSR's slabs every B delta "
                         "batches (graph/slotted.py; 0 = compact only on "
                         "overlay occupancy / slab-slack triggers).  "
                         "Commits stay O(touched rows) either way; "
                         "compaction amortizes the overlay away")
    ap.add_argument("--overlay-slack", type=float, default=0.25, metavar="F",
                    help="compact when the edge-log overlay exceeds F * m "
                         "live edges (default 0.25); smaller = tighter "
                         "slabs and more frequent O(m) re-packs")
    ap.add_argument("--snapshot-every", type=int, default=0, metavar="R",
                    help="write a crash-consistent mid-drain snapshot every "
                         "R rounds of a streaming drain (0 = batch "
                         "boundaries only; needs --checkpoint-dir)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="directory for streaming snapshots (per-job "
                         "subdirectories); enables snapshots and --resume")
    ap.add_argument("--resume", action="store_true",
                    help="resume each streaming job from its newest "
                         "snapshot under --checkpoint-dir (bit-identical "
                         "to the uninterrupted run)")
    ap.add_argument("--scale", type=int, default=8,
                    help="R-MAT scale (2**scale vertices)")
    ap.add_argument("--grid-side", type=int, default=16)
    ap.add_argument("--eps", type=float, default=1e-4,
                    help="PageRank convergence threshold")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Perfetto-loadable Chrome trace of every "
                         "round (server lanes, sharded phases, streaming "
                         "drains) to PATH — enables the in-trace ring "
                         "buffer (repro/obs, DESIGN.md section 15)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the canonical metrics JSONL (server/job "
                         "summaries, per-job latency histograms with exact "
                         "p50/p95/p99, per-round records) to PATH")
    ap.add_argument("--trace-capacity", type=int, default=0, metavar="N",
                    help="trace ring capacity in rounds per drain (0 = "
                         "default; oldest rounds are overwritten on "
                         "wraparound and counted as truncated)")
    ap.add_argument("--autotune", action="store_true",
                    help="pick the SchedulerConfig via the autotuner")
    ap.add_argument("--autotune-cache", default=".atos_autotune.json")
    ap.add_argument("--compare-sequential", action="store_true")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args()

    logging.basicConfig(
        level=logging.INFO if args.verbose else logging.WARNING,
        format="%(name)s: %(message)s")

    mesh_shape = tuple(args.mesh) if args.mesh else None
    if mesh_shape:
        rows, cols = mesh_shape
        if args.shards > 1 and args.shards != rows * cols:
            ap.error(f"--shards {args.shards} contradicts "
                     f"--mesh {rows} {cols} (= {rows * cols} shards)")
        args.shards = rows * cols
    if args.shards > 1:
        from .mesh import require_devices

        require_devices(args.shards, purpose=f"--shards {args.shards}")
    if args.resume and not args.checkpoint_dir:
        ap.error("--resume requires --checkpoint-dir")
    if args.snapshot_every and not args.checkpoint_dir:
        ap.error("--snapshot-every requires --checkpoint-dir")
    registry = build_registry(args.scale, args.grid_side, args.seed)
    specs = mixed_specs(args.jobs, registry, args.eps, args.seed,
                        shards=args.shards, stream=args.stream,
                        stream_batch=args.stream_batch,
                        snapshot_every=args.snapshot_every,
                        checkpoint_dir=args.checkpoint_dir,
                        resume=args.resume,
                        compact_every=args.compact_every,
                        overlay_slack=args.overlay_slack)

    granularity = args.granularity
    if args.exec_policy == "auto":
        topology, kernel, persistent = "auto", "auto", True
    else:
        policy = parse_policy(args.exec_policy)
        topology, kernel = policy.topology, policy.kernel
        persistent = policy.persistent
        # an explicit granularity segment — including .g1 — wins over
        # --granularity, as the flag's help promises
        if len(args.exec_policy.split(".")) == 3:
            granularity = policy.granularity
    if args.autotune and (mesh_shape or args.overlap or args.compress):
        # the tuner searches launch shapes, not exchange posture; the mesh
        # knobs would be silently dropped from its chosen config
        ap.error("--mesh/--overlap/--compress need an explicit config; "
                 "drop --autotune")
    config = None if args.autotune else SchedulerConfig(
        num_workers=args.workers, fetch_size=args.fetch,
        backend=args.backend, topology=topology, persistent=persistent,
        kernel=kernel, granularity=granularity,
        split_threshold=args.split_threshold,
        mesh_shape=mesh_shape, defer_rounds=1 if args.overlap else 0,
        compress=args.compress)
    autotuner = (Autotuner(cache_path=args.autotune_cache)
                 if args.autotune else None)

    trace = None
    if args.trace_out or args.metrics_out:
        from ..obs import DEFAULT_CAPACITY, Trace

        trace = Trace(capacity=args.trace_capacity or DEFAULT_CAPACITY,
                      meta={"git_sha": git_sha()})

    server = TaskServer(registry, num_lanes=args.lanes, config=config,
                        policy=args.policy, autotuner=autotuner,
                        trace=trace)
    for spec in specs:
        server.submit(spec)
    print(f"submitted {len(specs)} jobs to {args.lanes} lanes "
          f"(policy={args.policy})")
    result = server.run()
    print_telemetry(result)
    if args.stream > 0:
        print_stream_records(server)
    if trace is not None:
        trace.write(args.trace_out, args.metrics_out)
        lat = trace.histograms.get("job_latency_rounds")
        if lat is not None and lat.count:
            print(f"job latency (rounds): p50={lat.percentile(50)} "
                  f"p95={lat.percentile(95)} p99={lat.percentile(99)} "
                  f"over {lat.count} jobs")
        for path, what in ((args.trace_out, "chrome trace"),
                           (args.metrics_out, "metrics jsonl")):
            if path:
                print(f"wrote {what}: {path} "
                      f"({len(trace.records)} round records, "
                      f"{trace.truncated} truncated)")

    if args.compare_sequential:
        seq_config = config
        if seq_config is None and autotuner is not None:
            seq_config = autotuner.recommend_for_mix(
                [(s.algorithm, registry.graph(s.graph)) for s in specs])
        seq = serve_sequential(registry, specs, config=seq_config)
        print(f"sequential: rounds={seq.stats.rounds} "
              f"occupancy={seq.stats.occupancy:.3f} "
              f"wall={seq.stats.wall_seconds:.2f}s")
        print(f"fused/sequential rounds: {result.stats.rounds}"
              f"/{seq.stats.rounds} "
              f"({result.stats.rounds / max(seq.stats.rounds, 1):.2f}x)")


if __name__ == "__main__":
    main()
