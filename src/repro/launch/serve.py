"""Serving driver: Atos continuous batching over a synthetic request trace.

  PYTHONPATH=src python -m repro.launch.serve --arch olmoe-1b-7b --smoke \
      --requests 16 --slots 4 --mode continuous
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.registry import get_config, smoke_config
from ..models import transformer as T
from ..models.params import init_params
from ..serving.engine import ContinuousBatchingEngine, Request


def synthetic_requests(n: int, vocab: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [
        Request(uid=i,
                prompt=list(rng.integers(0, vocab, rng.integers(2, 6))),
                max_new_tokens=int(rng.integers(2, 10)))
        for i in range(n)
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--mode", default="continuous",
                    choices=["continuous", "bsp"])
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(T.model_spec(cfg), jax.random.PRNGKey(0),
                         jnp.dtype(cfg.dtype))
    reqs = synthetic_requests(args.requests, cfg.vocab_size)
    engine = ContinuousBatchingEngine(cfg, params, num_slots=args.slots,
                                      max_len=args.max_len, mode=args.mode,
                                      dtype=jnp.dtype(cfg.dtype))
    t0 = time.time()
    res = engine.run(reqs)
    dt = time.time() - t0
    st = res["stats"]
    total_toks = sum(len(v) for v in res["outputs"].values())
    print(f"mode={args.mode} requests={args.requests} slots={args.slots}")
    print(f"wavefronts={st.wavefronts} mean_occupancy={st.mean_occupancy:.3f}")
    print(f"tokens={total_toks} wall={dt:.2f}s tok/s={total_toks / dt:.1f}")


if __name__ == "__main__":
    main()
