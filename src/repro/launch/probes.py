"""Scan-body probes for roofline composition.

XLA cost analysis counts a ``lax.scan`` body once, so the dry-run compiles
each scanned layer body *separately* (same shardings as inside the step) and
the analyzer composes:  total = full_step + sum_probes (trips - counted) x
probe  (+ the analytic SSM time-recurrence correction).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as PS

from ..configs.base import ModelConfig, ShapeConfig
from ..distributed import sharding as SH
from ..models import transformer as T
from ..models.params import abstract_params


@dataclasses.dataclass
class Probe:
    name: str
    fn: Callable            # jit-able
    args: tuple             # ShapeDtypeStructs
    trips: int              # scan length in the real model
    counted: int            # how many bodies the full artifact already counts


def _x_spec(cfg, mesh, pc, batch: int, t: int):
    from .specs import _batch_axes, _fit
    b_ax = _batch_axes(mesh, pc)
    return jax.ShapeDtypeStruct(
        (batch, t, cfg.d_model), jnp.dtype(cfg.dtype),
        sharding=NamedSharding(mesh, PS(_fit(mesh, batch, b_ax),
                                        _fit(mesh, t, pc.seq_axis), None)))


def _block_params_spec(cfg, mesh, pc, kind: str):
    resolve = SH.make_resolver(mesh, pc)
    return abstract_params(T.block_spec(cfg, kind), jnp.dtype(cfg.dtype),
                           resolve)


def _train_probe_fn(cfg, kind: str, enc_kv=None, attn_impl="xla"):
    def apply(p, x, *rest):
        if kind == "moe":
            y, _, _ = T._apply_moe_block(p, cfg, x, attn_impl=attn_impl)
        elif kind == "mamba":
            y, _ = T._apply_mamba_block(p, cfg, x)
        elif kind == "encdec_dec":
            y, _ = T._apply_xattn_block(p, cfg, x, rest[0])
        else:
            y, _ = T._apply_dense_block(p, cfg, x, attn_impl=attn_impl)
        return jnp.sum(jnp.square(y.astype(jnp.float32)))

    def fwd_bwd(p, x, *rest):
        body = T._remat(cfg, lambda p, x: apply(p, x, *rest))
        _, grads = jax.value_and_grad(body, argnums=(0, 1))(p, x)
        return grads

    return fwd_bwd


def _fwd_probe_fn(cfg, kind: str, attn_impl="xla"):
    def apply(p, x, *rest):
        if kind == "moe":
            y, _, _ = T._apply_moe_block(p, cfg, x, attn_impl=attn_impl)
        elif kind == "mamba":
            y, _ = T._apply_mamba_block(p, cfg, x)
        elif kind == "encdec_dec":
            y, _ = T._apply_xattn_block(p, cfg, x, rest[0])
        else:
            y, _ = T._apply_dense_block(p, cfg, x, attn_impl=attn_impl)
        return y

    return apply


def _decode_probe_fn(cfg, kind: str):
    def apply(p, x, kv_or_ssm, clen, *rest):
        if kind == "moe":
            y, _, _ = T._apply_moe_block(p, cfg, x, kv_cache=kv_or_ssm,
                                         cache_len=clen)
        elif kind == "mamba":
            y, _ = T._apply_mamba_block(p, cfg, x, cache=kv_or_ssm)
        elif kind == "encdec_dec":
            y, _ = T._apply_xattn_block(p, cfg, x, rest[0],
                                        kv_cache=kv_or_ssm, cache_len=clen)
        else:
            y, _ = T._apply_dense_block(p, cfg, x, kv_cache=kv_or_ssm,
                                        cache_len=clen)
        return y

    return apply


def make_probes(cfg: ModelConfig, shape: ShapeConfig, mesh,
                pc: SH.ParallelConfig, attn_impl: str = "xla") -> List[Probe]:
    """Probes matching the scan structure of the step for this (cfg, shape)."""
    from .specs import _batch_axes, input_specs

    B = shape.global_batch
    fam = cfg.family
    probes: List[Probe] = []
    b_ax = _batch_axes(mesh, pc)
    kvh, hd = cfg.num_kv_heads, cfg.hd

    def enc_kv_spec(s_len):
        from .specs import _fit
        sh = NamedSharding(mesh, PS(_fit(mesh, B, b_ax), None, None, None))
        return (jax.ShapeDtypeStruct((B, s_len, kvh, hd),
                                     jnp.dtype(cfg.dtype), sharding=sh),) * 2

    if shape.kind in ("train", "prefill"):
        t = shape.seq_len
        if fam == "vlm":
            t = max(shape.seq_len - cfg.frontend_len, 128) + cfg.frontend_len
        x = _x_spec(cfg, mesh, pc, B, t)
        mk = ((lambda c, k, enc_kv=None: _train_probe_fn(c, k, attn_impl=attn_impl))
              if shape.kind == "train" else
              (lambda c, k, enc_kv=None: _fwd_probe_fn(c, k, attn_impl=attn_impl)))
        if fam in ("dense", "vlm"):
            p = _block_params_spec(cfg, mesh, pc, "dense")
            probes.append(Probe("layer", mk(cfg, "dense"), (p, x),
                                cfg.num_layers, 1))
        elif fam == "moe":
            p = _block_params_spec(cfg, mesh, pc, "moe")
            probes.append(Probe("layer", mk(cfg, "moe"), (p, x),
                                cfg.num_layers, 1))
        elif fam == "ssm":
            p = _block_params_spec(cfg, mesh, pc, "mamba")
            probes.append(Probe("layer", mk(cfg, "mamba"), (p, x),
                                cfg.num_layers, 1))
        elif fam == "hybrid":
            every = cfg.attn_every or cfg.num_layers
            g = cfg.num_layers // every
            p = _block_params_spec(cfg, mesh, pc, "mamba")
            probes.append(Probe("mamba_layer", mk(cfg, "mamba"), (p, x),
                                cfg.num_layers, g))
        elif fam == "encdec":
            pe = _block_params_spec(cfg, mesh, pc, "dense")
            xe = _x_spec(cfg, mesh, pc, B, cfg.frontend_len)
            probes.append(Probe("enc_layer", mk(cfg, "dense"), (pe, xe),
                                cfg.encoder_layers, 1))
            pd = _block_params_spec(cfg, mesh, pc, "encdec_dec")
            ekv = enc_kv_spec(cfg.frontend_len)
            dec_fn = (_train_probe_fn(cfg, "encdec_dec")
                      if shape.kind == "train"
                      else _fwd_probe_fn(cfg, "encdec_dec"))
            probes.append(Probe("dec_layer", dec_fn, (pd, x, ekv),
                                cfg.num_layers, 1))
        return probes

    # ---- decode probes
    specs = input_specs(cfg, shape, mesh, pc)
    cache = specs["cache"]
    x = _x_spec(cfg, mesh, pc, B, 1)
    clen = jax.ShapeDtypeStruct((B,), jnp.int32,
                                sharding=SH.replicated(mesh))
    if fam in ("dense", "vlm", "moe"):
        kind = "moe" if fam == "moe" else "dense"
        p = _block_params_spec(cfg, mesh, pc, kind)
        kv = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype,
                                           sharding=_drop_lead(s.sharding)),
            cache.kv)
        probes.append(Probe("layer", _decode_probe_fn(cfg, kind),
                            (p, x, kv, clen), cfg.num_layers, 1))
    elif fam == "ssm":
        p = _block_params_spec(cfg, mesh, pc, "mamba")
        ssm = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype,
                                           sharding=_drop_lead(s.sharding)),
            cache.ssm)
        probes.append(Probe("layer", _decode_probe_fn(cfg, "mamba"),
                            (p, x, ssm, clen), cfg.num_layers, 1))
    elif fam == "hybrid":
        every = cfg.attn_every or cfg.num_layers
        g = cfg.num_layers // every
        p = _block_params_spec(cfg, mesh, pc, "mamba")
        ssm = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype,
                                           sharding=_drop_lead(s.sharding)),
            cache.ssm)
        probes.append(Probe("mamba_layer", _decode_probe_fn(cfg, "mamba"),
                            (p, x, ssm, clen), cfg.num_layers, g))
    elif fam == "encdec":
        p = _block_params_spec(cfg, mesh, pc, "encdec_dec")
        kv = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype,
                                           sharding=_drop_lead(s.sharding)),
            cache.kv)
        probes.append(Probe("layer", _decode_probe_fn(cfg, "encdec_dec"),
                            (p, x, kv, clen, cache.enc),
                            cfg.num_layers, 1))
    return probes


def _drop_lead(sharding):
    return NamedSharding(sharding.mesh, PS(*sharding.spec[1:]))


def ssm_analytic_correction(cfg: ModelConfig, shape: ShapeConfig):
    """FLOPs/bytes the inner time-scan hides from cost analysis."""
    if cfg.family not in ("ssm", "hybrid") or shape.kind == "decode":
        return 0.0, 0.0
    t = shape.seq_len
    b = shape.global_batch
    step_flops = 8.0 * b * cfg.d_inner * cfg.ssm_state
    step_bytes = 8.0 * b * cfg.d_inner * cfg.ssm_state  # h read+write f32
    mult = 3.0 if shape.kind == "train" else 1.0        # fwd+bwd recompute
    missing = (t - 1) * cfg.num_layers * mult
    return step_flops * missing, step_bytes * missing
