"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape, mesh, pc)`` returns the exact pytree the
train/prefill/decode step consumes, shard-annotated, weak-type-correct —
the multi-pod dry-run lowers against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as PS

from ..configs.base import ModelConfig, ShapeConfig
from ..distributed import sharding as SH
from ..models import transformer as T


def _batch_axes(mesh, pc):
    has_pod = "pod" in mesh.axis_names
    ax = (("pod",) if has_pod else ()) + tuple(pc.batch_axes)
    return ax if len(ax) > 1 else (ax[0] if ax else None)


def _axis_size(mesh, ax) -> int:
    if ax is None:
        return 1
    n = 1
    for a in (ax if isinstance(ax, tuple) else (ax,)):
        n *= mesh.shape[a]
    return n


def _fit(mesh, dim: int, ax):
    """Axis if divisible, else None (replicate small dims, e.g. batch=1)."""
    return ax if (ax is not None and dim % _axis_size(mesh, ax) == 0) else None


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh,
                pc: SH.ParallelConfig) -> dict:
    b_ax = _batch_axes(mesh, pc)
    B = shape.global_batch

    def tok_spec(t):
        return jax.ShapeDtypeStruct(
            (B, t), jnp.int32,
            sharding=NamedSharding(mesh, PS(_fit(mesh, B, b_ax),
                                            _fit(mesh, t, pc.seq_axis))))

    def emb_spec(t):
        return jax.ShapeDtypeStruct(
            (B, t, cfg.d_model), jnp.dtype(cfg.dtype),
            sharding=NamedSharding(mesh, PS(_fit(mesh, B, b_ax),
                                            _fit(mesh, t, pc.seq_axis),
                                            None)))

    if shape.kind in ("train", "prefill"):
        t_text = shape.seq_len
        batch = {}
        if cfg.family == "vlm":
            t_text = max(shape.seq_len - cfg.frontend_len, 128)
            batch["patch_emb"] = emb_spec(cfg.frontend_len)
        if cfg.family == "encdec":
            batch["frames"] = emb_spec(cfg.frontend_len)
        batch["tokens"] = tok_spec(t_text)
        if shape.kind == "train":
            batch["labels"] = tok_spec(t_text)
        return batch

    # decode: (cache, tokens[B, 1])
    cache = jax.eval_shape(
        lambda: T.init_cache(cfg, B, shape.seq_len, jnp.dtype(cfg.dtype)))
    cache_sh = SH.cache_shardings(cfg, mesh, pc, cache)
    cache = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        cache, cache_sh)
    tokens = jax.ShapeDtypeStruct(
        (B, 1), jnp.int32,
        sharding=NamedSharding(mesh, PS(_fit(mesh, B, b_ax), None)))
    return {"cache": cache, "tokens": tokens}
