"""Production meshes.  A FUNCTION (not a module constant) so importing this
module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests/examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


def require_devices(n: int, purpose: str = "a sharded run") -> None:
    """Assert ``n`` devices are visible, with an actionable message.

    TPU pods expose the devices naturally; on CPU the XLA host-platform
    override must be set *before* jax initializes, which is why the shard
    tests and the ``multidevice`` CI job export it in the environment.
    """
    have = jax.device_count()
    if have < n:
        raise RuntimeError(
            f"{purpose} needs {n} devices but only {have} "
            f"{'is' if have == 1 else 'are'} visible.  On CPU, relaunch "
            f"with XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"set before the first jax import (tests/test_shard.py and the "
            f"CI 'multidevice' job do exactly this); on TPU, check that "
            f"the requested shard count does not exceed the slice size."
        )


def make_shard_mesh(n: int):
    """1-D ``("shard",)`` mesh for the sharded task scheduler (repro/shard).

    One mesh axis, ``n`` devices: each device owns one vertex block, one
    queue replica, and one lane of every collective (task all-to-all,
    replica merge, steal ppermute).  Raises with the ``XLA_FLAGS`` host
    override hint when fewer than ``n`` devices exist.
    """
    require_devices(n, purpose=f"make_shard_mesh({n})")
    return jax.make_mesh((n,), ("shard",))


def make_shard_mesh2d(rows: int, cols: int):
    """2-D ``("row", "col")`` mesh for the sharded scheduler (DESIGN.md §16).

    Same ownership model as the 1-D mesh — shard ids stay *linear*
    (``id = row * cols + col``, exactly the row-major order jax linearizes
    tuple-axis collectives in), so partitioning, steal halos, and the
    replica merge are unchanged — but the routed task exchange decomposes
    into two per-axis all_to_alls (a column hop inside each row, then a row
    hop inside each column: dimension-ordered routing) instead of one
    global ``num_shards``-wide collective.  On a torus interconnect each
    hop crosses only ``cols`` (resp. ``rows``) devices.
    """
    if rows < 1 or cols < 1:
        raise ValueError(
            f"mesh_shape must be positive, got ({rows}, {cols})")
    require_devices(rows * cols, purpose=f"make_shard_mesh2d({rows}, {cols})")
    return jax.make_mesh((rows, cols), ("row", "col"))
