import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first init).  Do not move them.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  * jit the train/prefill/serve step with the production shardings,
    ``.lower(**input_specs)`` and ``.compile()`` — success proves the
    distribution config is coherent (no sharding mismatch / unsupported
    collective / compile-time OOM);
  * record ``memory_analysis()`` + ``cost_analysis()`` + parsed collective
    bytes, compose scan-body probes (see probes.py), and emit one JSON per
    cell for EXPERIMENTS.md and the roofline benchmark.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-110b \
      --shape train_4k [--multi-pod] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs.base import SHAPES, supports_shape
from ..configs.registry import ARCH_IDS, get_config
from ..distributed import sharding as SH
from . import probes as PR
from . import roofline as RL
from .mesh import make_production_mesh
from .specs import input_specs


def _step_fn(cfg, shape, attn_impl="xla"):
    if shape.kind == "train":
        return SH.make_train_step(cfg, attn_impl=attn_impl)
    if shape.kind == "prefill":
        return SH.make_prefill_step(cfg, attn_impl=attn_impl)
    serve = SH.make_serve_step(cfg, attn_impl=attn_impl)
    return lambda params, cache, tokens: serve(params, cache, tokens)


def _lower_full(cfg, shape, mesh, pc, attn_impl="xla"):
    specs = input_specs(cfg, shape, mesh, pc)
    params, opt = SH.abstract_train_state(cfg, mesh, pc)
    fn = _step_fn(cfg, shape, attn_impl)
    with mesh:
        if shape.kind == "train":
            lowered = jax.jit(fn).lower(params, opt, specs)
        elif shape.kind == "prefill":
            lowered = jax.jit(fn).lower(params, specs)
        else:
            lowered = jax.jit(fn).lower(params, specs["cache"],
                                        specs["tokens"])
        compiled = lowered.compile()
    return lowered, compiled


def _analyze(cfg, shape, mesh, pc, compiled, attn_impl="xla"):
    chips = mesh.devices.size
    total = RL.cost_terms(compiled)
    probe_detail = []
    for probe in PR.make_probes(cfg, shape, mesh, pc, attn_impl=attn_impl):
        with mesh:
            pc_compiled = jax.jit(probe.fn).lower(*probe.args).compile()
        terms = RL.cost_terms(pc_compiled)
        extra = terms.scaled(probe.trips - probe.counted)
        total = total + extra
        probe_detail.append({
            "name": probe.name, "trips": probe.trips,
            "counted": probe.counted,
            "flops_per_body": terms.flops,
            "bytes_per_body": terms.bytes,
            "coll_bytes_per_body": terms.coll_bytes,
        })
    ssm_f, ssm_b = PR.ssm_analytic_correction(cfg, shape)
    total = total + RL.CostTerms(ssm_f / chips, ssm_b / chips, 0.0, {})
    roof = RL.make_roofline(total, chips,
                            RL.model_flops_estimate(cfg, shape))

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                mem[attr] = int(v)
    except Exception as e:  # CPU backend may not implement it
        mem["error"] = str(e)
    return total, roof, mem, probe_detail


def state_bytes_per_device(cfg, mesh, pc) -> float:
    """Analytic params+optimizer bytes per chip from the shardings."""
    params, opt = SH.abstract_train_state(cfg, mesh, pc)
    n_dev = mesh.devices.size

    def bytes_of(t):
        total = 0
        for leaf in jax.tree.leaves(t):
            total += leaf.size * leaf.dtype.itemsize
        return total

    return (bytes_of(params) + bytes_of(opt)) / n_dev


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             pc: SH.ParallelConfig | None = None, out_dir: str | None = None,
             tag: str = "baseline", attn_impl: str = "xla",
             cfg_overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    if not supports_shape(cfg, shape):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "full-attention arch; long_500k undefined "
                          "(DESIGN.md section 5)"}
    pc = pc or SH.ParallelConfig()
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered, compiled = _lower_full(cfg, shape, mesh, pc, attn_impl)
    compile_s = time.time() - t0
    total, roof, mem, probe_detail = _analyze(cfg, shape, mesh, pc, compiled,
                                              attn_impl)
    rec_chips = int(mesh.devices.size)
    rec = {
        "arch": arch, "shape": shape_name, "tag": tag,
        "attn_impl": attn_impl,
        "cfg_overrides": cfg_overrides or {},
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "multi_pod": multi_pod,
        "chips": int(mesh.devices.size),
        "compile_s": round(compile_s, 1),
        "parallel": dataclasses.asdict(pc),
        "hlo_flops": total.flops,
        "hlo_bytes": total.bytes,
        "convert_bytes": total.conv_bytes,
        # CPU backend upcasts bf16 dot operands to f32 (no native bf16
        # matmul); the TPU MXU reads bf16 directly, so at least the convert
        # writes vanish on hardware (conservative 1x subtraction — the f32
        # re-reads inside fusions are partially counted already):
        "t_memory_tpu_adj_s": max(total.bytes - total.conv_bytes, 0.0)
        / RL.HBM_BW,
        "collective_bytes": total.coll_bytes,
        "collective_by_kind": total.coll_by_kind,
        "t_compute_s": roof.t_compute,
        "t_memory_s": roof.t_memory,
        "t_collective_s": roof.t_collective,
        "dominant": roof.dominant,
        "model_flops": roof.model_flops,
        "usefulness": roof.usefulness,
        "roofline_fraction": roof.roofline_fraction,
        "roofline_fraction_tpu_adj": (
            roof.model_flops / (rec_chips * RL.PEAK_FLOPS)
            / max(roof.t_compute,
                  max(total.bytes - total.conv_bytes, 0.0) / RL.HBM_BW,
                  roof.t_collective)
            if max(roof.t_compute, roof.t_collective) > 0 or total.bytes
            else 0.0),
        "state_bytes_per_device": state_bytes_per_device(cfg, mesh, pc),
        "memory_analysis": mem,
        "probes": probe_detail,
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        pod = "pod2" if multi_pod else "pod1"
        path = os.path.join(out_dir, f"{arch}__{shape_name}__{pod}__{tag}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    for arch, shape in cells:
        try:
            rec = run_cell(arch, shape, args.multi_pod, out_dir=args.out,
                           tag=args.tag)
            if rec.get("skipped"):
                print(f"SKIP {arch} {shape}: {rec['reason']}", flush=True)
                continue
            print(f"OK   {arch:22s} {shape:12s} mesh={rec['mesh']} "
                  f"compile={rec['compile_s']}s dominant={rec['dominant']} "
                  f"tC={rec['t_compute_s']:.3e} tM={rec['t_memory_s']:.3e} "
                  f"tN={rec['t_collective_s']:.3e} "
                  f"frac={rec['roofline_fraction']:.3f}", flush=True)
        except Exception:
            print(f"FAIL {arch} {shape}", flush=True)
            traceback.print_exc()


if __name__ == "__main__":
    main()
