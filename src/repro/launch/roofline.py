"""Roofline extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), hardware = TPU v5e:

    T_compute    = HLO_FLOPs / (chips * 197e12)        [bf16 MXU peak]
    T_memory     = HLO_bytes / (chips * 819e9)         [HBM]
    T_collective = collective_bytes / (chips * 45e9)   [ICI per link]

XLA's cost analysis counts ``lax.scan`` bodies ONCE (verified empirically),
so totals are *composed*: the full step artifact plus (trip_count - 1) x the
separately-compiled scan-body probe for every scanned layer stack
(DESIGN.md section 7).  The SSM time-recurrence contributes an analytic
correction (its scan body is elementwise; projections dominate).

Collective bytes are parsed from the compiled HLO text — operand bytes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 45e9            # bytes/s / link (~50 GB/s nominal)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVE_RE = re.compile(
    r"=\s+(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum *result* bytes per collective kind (per device).

    HLO lines read ``%op = f32[SHAPE]{layout} all-gather(%operand), ...`` —
    operands carry no type in optimized HLO text, so we take the result
    shape.  For all-reduce / all-to-all / collective-permute the result
    equals the operand; for all-gather the result is the fully gathered
    buffer (~= bytes received per device on a ring); for reduce-scatter it
    under-counts by the shard factor (noted in EXPERIMENTS.md — RS traffic
    in our steps is a small share).  ``-done`` ops are skipped.
    """
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        kind = m.group(2).lower()
        bytes_ = sum(_shape_bytes(d, s)
                     for d, s in _SHAPE_RE.findall(m.group(1)))
        out[kind] = out.get(kind, 0) + bytes_
    return out


_CONVERT_RE = re.compile(
    r"=\s+([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+convert\(")


def convert_bytes_from_hlo(hlo_text: str) -> int:
    """Result bytes of ``convert`` ops — on the CPU backend every bf16 dot
    upcasts its operands to f32 (no native bf16 matmul), traffic that does
    NOT exist on the TPU MXU.  Recorded so EXPERIMENTS.md can report a
    TPU-adjusted memory term alongside the raw one."""
    total = 0
    for line in hlo_text.splitlines():
        m = _CONVERT_RE.search(line)
        if m:
            total += sum(_shape_bytes(d, s)
                         for d, s in _SHAPE_RE.findall(m.group(1)))
    return total


@dataclasses.dataclass
class CostTerms:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)
    conv_bytes: float = 0.0

    def scaled(self, k: float) -> "CostTerms":
        return CostTerms(self.flops * k, self.bytes * k, self.coll_bytes * k,
                         {kk: v * k for kk, v in self.coll_by_kind.items()},
                         self.conv_bytes * k)

    def __add__(self, o: "CostTerms") -> "CostTerms":
        kinds = dict(self.coll_by_kind)
        for k, v in o.coll_by_kind.items():
            kinds[k] = kinds.get(k, 0) + v
        return CostTerms(self.flops + o.flops, self.bytes + o.bytes,
                         self.coll_bytes + o.coll_bytes, kinds,
                         self.conv_bytes + o.conv_bytes)


def cost_terms(compiled) -> CostTerms:
    """NOTE: XLA analyzes the *partitioned* module — all values returned here
    are PER-DEVICE (verified against analytic counts in EXPERIMENTS.md)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bytes_ = float(ca.get("bytes accessed", 0.0))
    text = compiled.as_text()
    coll = collective_bytes_from_hlo(text)
    return CostTerms(flops, bytes_, sum(coll.values()), coll,
                     float(convert_bytes_from_hlo(text)))


@dataclasses.dataclass
class Roofline:
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops: float
    hlo_flops: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def usefulness(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs, both per-device."""
        per_dev = self.model_flops / self.chips
        return per_dev / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak sustained if the dominant term is the wall:
        useful compute time / max(terms)."""
        t_useful = self.model_flops / (self.chips * PEAK_FLOPS)
        wall = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / wall if wall else 0.0


def make_roofline(total: CostTerms, chips: int, model_flops: float) -> Roofline:
    """``total`` is per-device (see cost_terms), so terms divide by ONE
    chip's peak — the global formula HLO_FLOPs_global/(chips*peak) is
    identical since HLO_FLOPs_global = chips * per-device."""
    return Roofline(
        t_compute=total.flops / PEAK_FLOPS,
        t_memory=total.bytes / HBM_BW,
        t_collective=total.coll_bytes / ICI_BW,
        model_flops=model_flops,
        hlo_flops=total.flops,
        chips=chips,
    )


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill/decode), N = active."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token/seq
