"""Tiny HLO profiler: attribute cost-analysis bytes to op kinds.

The dry-run has no wall-clock profile; this is the "profile" the perf loop
iterates on (DESIGN.md section 7): group every HLO op's result bytes by
opcode and by source op_name metadata, descending.
"""
from __future__ import annotations

import re
from collections import defaultdict

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*"
    r"((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"([\w\-]+)\(")
_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|"
                       r"pred)\[([0-9,]*)\]")
_META_RE = re.compile(r'op_name="([^"]+)"')
_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
          "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
          "pred": 1}


def _shape_bytes(txt: str) -> int:
    return sum(int(_prod(dims)) * _BYTES[d]
               for d, dims in _SHAPE_RE.findall(txt))


def _prod(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def bytes_by(hlo_text: str, key: str = "opcode", top: int = 20):
    """key: 'opcode' or 'opname' (jax-level op metadata)."""
    acc = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        b = _shape_bytes(m.group(1))
        if key == "opcode":
            acc[m.group(2)] += b
        else:
            meta = _META_RE.search(line)
            name = meta.group(1) if meta else "<none>"
            # strip indices for grouping
            name = re.sub(r"[0-9]+", "#", name)[:90]
            acc[name] += b
    return sorted(acc.items(), key=lambda kv: -kv[1])[:top]


def report(compiled, top: int = 15):
    txt = compiled.as_text()
    print("--- bytes by opcode")
    for k, v in bytes_by(txt, "opcode", top):
        print(f"  {v / 1e9:10.1f} GB  {k}")
    print("--- bytes by op_name")
    for k, v in bytes_by(txt, "opname", top):
        print(f"  {v / 1e9:10.1f} GB  {k}")
