"""Pallas TPU kernels (pl.pallas_call + BlockSpec) + their jnp oracles.

frontier_expand -- merge-path load-balancing search (Atos CTA-worker LB);
                   hot path of ``core.frontier.expand_merge_path`` under
                   ``backend="pallas"`` (core/backend.py, DESIGN.md §9)
queue_compact   -- prefix-sum slot reservation / stream compaction; hot
                   path of ``core.queue.TaskQueue.push`` under
                   ``backend="pallas"``
flash_attention -- tiled online-softmax attention (LM stack; reference-only
                   in the Atos hot path — see its ops.py)

All kernels compile on TPU and fall back to interpret mode elsewhere
(``core.backend.resolve_interpret``), so tests validate the real kernel
schedule on any host.
"""
