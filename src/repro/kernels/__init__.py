"""Pallas TPU kernels (pl.pallas_call + BlockSpec), validated interpret=True.

frontier_expand -- merge-path load-balancing search (Atos CTA-worker LB)
queue_compact   -- prefix-sum slot reservation / stream compaction
flash_attention -- tiled online-softmax attention (LM hot path)
"""
