"""jit'd public wrapper: full CSR wavefront expansion via the LBS kernel.

Call paths (wired by the backend layer, ``core/backend.py``):

  * ``core/frontier.expand_merge_path(..., backend="pallas"|"auto")``
    dispatches here — which makes this kernel the hot path of the
    merge-path strategy in ``algorithms/bfs.py`` and
    ``algorithms/pagerank.py``, of every server job built from their
    runtime program factories (``server/jobs.JobRegistry.build``), and of
    any autotuner candidate with ``SchedulerConfig(backend="pallas")``.
  * ``benchmarks/bench_kernels.py`` times it against the jnp reference and
    emits the comparison to ``BENCH_kernels.json``.

``interpret=None`` defers to :func:`repro.core.backend.resolve_interpret`:
compiled on TPU, interpreter elsewhere — a real-TPU run never silently
interprets.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.backend import resolve_interpret
from ...core.frontier import Expansion
from .kernel import lbs_pallas


@functools.partial(jax.jit, static_argnames=("budget", "interpret"))
def frontier_expand(items, valid, row_ptr, col_idx, budget: int,
                    interpret: bool | None = None) -> Expansion:
    """Drop-in replacement for ``core.frontier.expand_merge_path`` that runs
    the merge-path search as a Pallas TPU kernel.

    Bit-identical to the reference by construction (same masking, same
    owner/rank definitions) — asserted by ``tests/test_kernels.py`` and,
    end-to-end, by the backend-parity tests in ``tests/test_algorithms.py``.
    """
    interpret = resolve_interpret(interpret)
    safe = jnp.where(valid, items, 0)
    deg = jnp.where(valid, row_ptr[safe + 1] - row_ptr[safe], 0)
    scan = jnp.cumsum(deg)
    total = scan[-1] if scan.shape[0] > 0 else jnp.int32(0)

    owner, rank = lbs_pallas(scan, budget, interpret=interpret)
    owner = jnp.clip(owner, 0, items.shape[0] - 1)
    src = safe[owner]
    k = jnp.arange(budget, dtype=jnp.int32)
    in_range = k < total
    edge = row_ptr[src] + rank
    nbr = col_idx[jnp.clip(edge, 0, col_idx.shape[0] - 1)]
    return Expansion(
        src=jnp.where(in_range, src, 0),
        nbr=jnp.where(in_range, nbr, 0),
        owner=jnp.where(in_range, owner, 0),
        valid=in_range,
        total=total,
    )
