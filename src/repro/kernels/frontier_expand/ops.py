"""jit'd public wrapper: full CSR wavefront expansion via the LBS kernel.

Call paths (wired by the backend layer, ``core/backend.py``):

  * ``core/frontier.expand_merge_path(..., backend="pallas"|"auto")``
    dispatches here — which makes this kernel the hot path of the
    merge-path strategy in ``algorithms/bfs.py`` and
    ``algorithms/pagerank.py``, of every server job built from their
    runtime program factories (``server/jobs.JobRegistry.build``), and of
    any autotuner candidate with ``SchedulerConfig(backend="pallas")``.
  * ``benchmarks/bench_kernels.py`` times it against the jnp reference and
    emits the comparison to ``BENCH_kernels.json``.

``interpret=None`` defers to :func:`repro.core.backend.resolve_interpret`:
compiled on TPU, interpreter elsewhere — a real-TPU run never silently
interprets.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.backend import resolve_interpret
from ...core.frontier import (Expansion, chunk_degrees, chunk_row_of,
                              gather_neighbors)
from .kernel import lbs_pallas


@functools.partial(jax.jit,
                   static_argnames=("budget", "interpret", "max_width"))
def frontier_expand(items, valid, row_ptr, col_idx, budget: int,
                    interpret: bool | None = None,
                    widths=None, max_width: int = 1,
                    overlay=None) -> Expansion:
    """Drop-in replacement for ``core.frontier.expand_merge_path`` that runs
    the merge-path search as a Pallas TPU kernel.

    Bit-identical to the reference by construction (same masking, same
    owner/rank definitions) — asserted by ``tests/test_kernels.py`` and,
    end-to-end, by the backend-parity tests in ``tests/test_algorithms.py``.

    Chunked wavefronts (``widths`` + static ``max_width``; core/task.py)
    feed the kernel the *chunk degree-sum* scan — the LBS itself is
    granularity-agnostic, it balances whatever scan it is given — and each
    work unit's member row is recovered afterwards by the shared
    :func:`~repro.core.frontier.chunk_row_of` compare-count (O(max_width)
    broadcast compares, the same VPU shape as the kernel's owner count), so
    both backends stay bit-identical at every granularity.
    """
    interpret = resolve_interpret(interpret)
    safe = jnp.where(valid, items, 0)
    deg = chunk_degrees(items, widths, valid, row_ptr)
    scan = jnp.cumsum(deg)
    total = scan[-1] if scan.shape[0] > 0 else jnp.int32(0)

    owner, rank = lbs_pallas(scan, budget, interpret=interpret)
    owner = jnp.clip(owner, 0, items.shape[0] - 1)
    head = safe[owner]
    src = (head if widths is None else
           chunk_row_of(row_ptr, head, rank, widths[owner], max_width))
    k = jnp.arange(budget, dtype=jnp.int32)
    in_range = k < total
    edge = row_ptr[head] + rank
    # the LBS kernel only computes (owner, rank); the gather lives out here,
    # so a slotted graph just swaps the flat read for the two-level one
    nbr = gather_neighbors(row_ptr, col_idx, src, edge, overlay=overlay)
    return Expansion(
        src=jnp.where(in_range, src, 0),
        nbr=jnp.where(in_range, nbr, 0),
        owner=jnp.where(in_range, owner, 0),
        valid=in_range,
        total=total,
    )
