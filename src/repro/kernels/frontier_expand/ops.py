"""jit'd public wrapper: full CSR wavefront expansion via the LBS kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.frontier import Expansion
from .kernel import lbs_pallas


@functools.partial(jax.jit, static_argnames=("budget", "interpret"))
def frontier_expand(items, valid, row_ptr, col_idx, budget: int,
                    interpret: bool = True) -> Expansion:
    """Drop-in replacement for ``core.frontier.expand_merge_path`` that runs
    the merge-path search as a Pallas TPU kernel."""
    safe = jnp.where(valid, items, 0)
    deg = jnp.where(valid, row_ptr[safe + 1] - row_ptr[safe], 0)
    scan = jnp.cumsum(deg)
    total = scan[-1] if scan.shape[0] > 0 else jnp.int32(0)

    owner, rank = lbs_pallas(scan, budget, interpret=interpret)
    owner = jnp.clip(owner, 0, items.shape[0] - 1)
    src = safe[owner]
    k = jnp.arange(budget, dtype=jnp.int32)
    in_range = k < total
    edge = row_ptr[src] + rank
    nbr = col_idx[jnp.clip(edge, 0, col_idx.shape[0] - 1)]
    return Expansion(
        src=jnp.where(in_range, src, 0),
        nbr=jnp.where(in_range, nbr, 0),
        owner=jnp.where(in_range, owner, 0),
        valid=in_range,
        total=total,
    )
