"""Pallas TPU kernel: merge-path load-balancing search (LBS).

This is the compute hot spot of Atos's CTA-worker expansion (paper section
3.3, after Merrill/Baxter's load-balancing search): given the inclusive scan
of the popped rows' degrees, every flattened work unit k must find its owner
row  owner(k) = first j with scan[j] > k  and its rank within the row
rank(k) = k - scan[owner-1].

GPU implementations binary-search the scan per thread (branchy, divergent).
TPU adaptation: the VPU has no efficient per-lane gather but eats 8x128
broadcast compares — so we replace the binary search with a dense
compare-count:

    owner(k) = sum_j [scan[j] <= k]          (count of rows fully before k)
    excl(k)  = max_j  scan[j] * [scan[j] <= k]  (monotone scan -> running max)

Both are [TILE, W] broadcast ops + a reduction: O(TILE*W) VPU work with zero
gathers/branches, vs O(TILE*log W) gathers for the binary search.  For
wavefronts W <= 4096 the compare-count is faster on the VPU than serialized
gathers by napkin math (a [1024, 2048] i32 compare+reduce is ~2 Mop against
~11 serial gather rounds with 8-deep dependency chains).

Block layout: the scan (padded to a lane multiple) is VMEM-resident and
shared by every grid step; each grid step produces one TILE of (owner, rank).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.backend import resolve_interpret

TILE = 1024  # work units per grid step (8 sublanes x 128 lanes)


def _lbs_kernel(scan_ref, owner_ref, rank_ref, *, w: int):
    """One TILE of the load-balancing search.

    scan_ref:  [1, W]    inclusive degree scan (padded with last value)
    owner_ref: [1, TILE] int32 owner row per work unit
    rank_ref:  [1, TILE] int32 rank within the owner row
    """
    t = pl.program_id(0)
    k = t * TILE + jax.lax.broadcasted_iota(jnp.int32, (1, TILE), 1)
    scan = scan_ref[...]  # [1, W]
    # [TILE, W] broadcast compare: row i <=> work unit k_i
    le = (scan <= k.reshape(TILE, 1)).astype(jnp.int32)        # [TILE, W]
    owner = jnp.sum(le, axis=1, dtype=jnp.int32)               # [TILE]
    excl = jnp.max(scan * le, axis=1)                          # [TILE]
    owner_ref[...] = owner.reshape(1, TILE)
    rank_ref[...] = (k.reshape(TILE) - excl).reshape(1, TILE)


@functools.partial(jax.jit, static_argnames=("budget", "interpret"))
def lbs_pallas(scan: jax.Array, budget: int, interpret: bool | None = None):
    """Run the LBS kernel. ``scan``: [W] int32 inclusive scan of degrees.

    Returns (owner[budget], rank[budget]) int32.  ``interpret=None`` defers
    to the backend layer: compiled on TPU, interpreter elsewhere.
    """
    interpret = resolve_interpret(interpret)
    w = scan.shape[0]
    w_pad = max(128, -(-w // 128) * 128)
    # pad with the last scan value so padded rows own zero work units
    last = scan[-1] if w > 0 else jnp.int32(0)
    scan_p = jnp.full((1, w_pad), last, jnp.int32).at[0, :w].set(scan)
    budget_pad = -(-budget // TILE) * TILE
    grid = (budget_pad // TILE,)
    owner, rank = pl.pallas_call(
        functools.partial(_lbs_kernel, w=w_pad),
        grid=grid,
        in_specs=[pl.BlockSpec((1, w_pad), lambda t: (0, 0))],
        out_specs=[
            pl.BlockSpec((1, TILE), lambda t: (0, t)),
            pl.BlockSpec((1, TILE), lambda t: (0, t)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, budget_pad), jnp.int32),
            jax.ShapeDtypeStruct((1, budget_pad), jnp.int32),
        ],
        interpret=interpret,
    )(scan_p)
    return owner[0, :budget], rank[0, :budget]
