"""Pure-jnp oracle for the load-balancing-search kernel.

The search is granularity-agnostic: ``scan`` may be the inclusive scan of
per-row degrees (fine-grained tasks) or of per-chunk degree *sums*
(core/task.py); ``owner`` is then the chunk index and ``rank`` the edge
offset within the chunk, localized to a member row by
``core.frontier.chunk_row_of``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lbs_ref(scan: jax.Array, budget: int):
    """owner(k) = first j with scan[j] > k; rank(k) = k - scan[owner-1]."""
    k = jnp.arange(budget, dtype=jnp.int32)
    owner = jnp.searchsorted(scan, k, side="right").astype(jnp.int32)
    excl = jnp.where(owner > 0, scan[jnp.maximum(owner - 1, 0)], 0)
    return owner, k - excl
