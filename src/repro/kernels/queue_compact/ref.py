"""Pure-jnp oracle for stream compaction."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compact_ref(items: jax.Array, mask: jax.Array):
    """Stable compaction: ([N], [N]bool) -> ([N] compacted then zeros, count)."""
    n = items.shape[0]
    mask_i = mask.astype(jnp.int32)
    pos = jnp.cumsum(mask_i) - mask_i
    out = jnp.zeros((n,), jnp.int32).at[jnp.where(mask, pos, n)].set(
        jnp.where(mask, items, 0), mode="drop")
    return out, jnp.sum(mask_i)
