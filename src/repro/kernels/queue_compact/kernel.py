"""Pallas TPU kernel: stream compaction (the queue's push-slot reservation).

Atos pushes with an atomic ticket counter; the TPU-native equivalent is a
two-phase stream compaction (DESIGN.md section 2):

  phase 1 (this kernel) — per-tile *local* compaction + a per-tile count.
    Within a tile, the scatter "item i -> slot pos(i)" is expressed as a
    one-hot [TILE, TILE] mask contraction — scatters become a dense
    compare + masked reduce that the VPU executes without any dynamic
    addressing (the TPU answer to CUDA's shared-memory scatter).
  phase 2 (ops.py, jnp) — a tiny exclusive scan over the per-tile counts
    stitches tiles into the final contiguous output.

The sequential TPU grid plays the role of the GPU's atomic ticket: tile t's
global offset is fully determined by tiles 0..t-1, no contention possible.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.backend import resolve_interpret

TILE = 256


def _compact_kernel(items_ref, mask_ref, out_ref, cnt_ref):
    """items/mask: [1, TILE] -> out: [1, TILE] locally compacted, cnt: [1, 1]."""
    items = items_ref[...].reshape(TILE)
    mask = mask_ref[...].reshape(TILE).astype(jnp.int32)
    pos = jnp.cumsum(mask) - mask                       # exclusive scan
    j = jax.lax.broadcasted_iota(jnp.int32, (TILE, TILE), 1)
    # onehot[i, j] = item i lands in slot j
    onehot = (pos.reshape(TILE, 1) == j) & (mask.reshape(TILE, 1) > 0)
    compacted = jnp.sum(jnp.where(onehot, items.reshape(TILE, 1), 0), axis=0)
    out_ref[...] = compacted.reshape(1, TILE)
    cnt_ref[...] = jnp.sum(mask).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def compact_tiles_pallas(items: jax.Array, mask: jax.Array,
                         interpret: bool | None = None):
    """[N] items + [N] mask -> ([n_tiles, TILE] local, [n_tiles] counts)."""
    interpret = resolve_interpret(interpret)
    n = items.shape[0]
    n_pad = -(-n // TILE) * TILE
    items_p = jnp.zeros((1, n_pad), jnp.int32).at[0, :n].set(items)
    mask_p = jnp.zeros((1, n_pad), jnp.int32).at[0, :n].set(
        mask.astype(jnp.int32))
    grid = (n_pad // TILE,)
    local, counts = pl.pallas_call(
        _compact_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, TILE), lambda t: (0, t)),
            pl.BlockSpec((1, TILE), lambda t: (0, t)),
        ],
        out_specs=[
            pl.BlockSpec((1, TILE), lambda t: (0, t)),
            pl.BlockSpec((1, 1), lambda t: (0, t)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, n_pad), jnp.int32),
            jax.ShapeDtypeStruct((1, n_pad // TILE), jnp.int32),
        ],
        interpret=interpret,
    )(items_p, mask_p)
    return local.reshape(-1, TILE), counts.reshape(-1)
