"""jit'd public wrapper: global stream compaction via the Pallas tile kernel.

Call paths (wired by the backend layer, ``core/backend.py``):

  * ``core/queue.TaskQueue.push(..., backend="pallas"|"auto")`` uses
    :func:`compact` as its slot-reservation engine — which makes this kernel
    the push hot path of the scheduler (``core/scheduler.wavefront_step``),
    of every ``MultiQueue`` lane the task server drives
    (``server/engine.TaskServer``), and of any autotuner candidate with
    ``SchedulerConfig(backend="pallas")``.  All three case-study algorithms
    (BFS / PageRank / coloring) push through it under that config.
  * ``benchmarks/bench_kernels.py`` times it against the jnp reference and
    emits the comparison to ``BENCH_kernels.json``.

``interpret=None`` defers to :func:`repro.core.backend.resolve_interpret`:
compiled on TPU, interpreter elsewhere — a real-TPU run never silently
interprets.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.backend import resolve_interpret
from .kernel import TILE, compact_tiles_pallas


@functools.partial(jax.jit, static_argnames=("interpret",))
def compact(items: jax.Array, mask: jax.Array,
            interpret: bool | None = None):
    """([N], [N]bool) -> ([N] compacted-then-zeros, count) — kernel-backed.

    Stable (order-preserving) and bit-identical to
    ``kernels/queue_compact/ref.compact_ref`` — asserted per-tile by
    ``tests/test_kernels.py`` and end-to-end against ``TaskQueue``'s
    prefix-sum reservation by ``tests/test_backend.py``.
    """
    interpret = resolve_interpret(interpret)
    n = items.shape[0]
    local, counts = compact_tiles_pallas(items, mask, interpret=interpret)
    n_tiles = local.shape[0]
    tile_offs = jnp.cumsum(counts) - counts            # phase 2: global stitch
    # element (t, j) for j < counts[t] lands at tile_offs[t] + j
    j = jnp.arange(TILE, dtype=jnp.int32)
    dst = tile_offs[:, None] + j[None, :]
    live = j[None, :] < counts[:, None]
    out = jnp.zeros((n_tiles * TILE,), jnp.int32).at[
        jnp.where(live, dst, n_tiles * TILE)
    ].set(jnp.where(live, local, 0), mode="drop")
    return out[:n], jnp.sum(counts)
