"""jit'd public wrapper: global stream compaction via the Pallas tile kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import TILE, compact_tiles_pallas


@functools.partial(jax.jit, static_argnames=("interpret",))
def compact(items: jax.Array, mask: jax.Array, interpret: bool = True):
    """([N], [N]bool) -> ([N] compacted-then-zeros, count) — kernel-backed."""
    n = items.shape[0]
    local, counts = compact_tiles_pallas(items, mask, interpret=interpret)
    n_tiles = local.shape[0]
    tile_offs = jnp.cumsum(counts) - counts            # phase 2: global stitch
    # element (t, j) for j < counts[t] lands at tile_offs[t] + j
    j = jnp.arange(TILE, dtype=jnp.int32)
    dst = tile_offs[:, None] + j[None, :]
    live = j[None, :] < counts[:, None]
    out = jnp.zeros((n_tiles * TILE,), jnp.int32).at[
        jnp.where(live, dst, n_tiles * TILE)
    ].set(jnp.where(live, local, 0), mode="drop")
    return out[:n], jnp.sum(counts)
