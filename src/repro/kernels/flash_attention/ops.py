"""jit'd public wrapper around the flash-attention kernel.

Accepts the model-layer layout [B, S, H, D] (+ GQA KV [B, S, KVH, D]) and
dispatches to the Pallas kernel or to the jnp reference (``impl='xla'``).

Call paths: unlike ``kernels/frontier_expand`` and ``kernels/queue_compact``
— which the backend layer (``core/backend.py``) wires into the Atos
scheduler hot path — this kernel is **reference-only** today: the model
stack (``models/transformer.py``, dry-run/roofline) calls ``impl='xla'`` so
XLA cost analysis can see the FLOPs (DESIGN.md section 7), and nothing in
the task-server hot path dispatches to it.  ``impl='pallas'`` is exercised
by ``tests/test_kernels.py`` and ``benchmarks/bench_kernels.py`` only.

``interpret=None`` defers to :func:`repro.core.backend.resolve_interpret`:
compiled on TPU, interpreter elsewhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.backend import resolve_interpret
from .kernel import flash_attention_pallas
from .ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "impl",
                                             "interpret"))
def multihead_attention(q, k, v, *, causal: bool = True, window: int = 0,
                        impl: str = "xla", interpret: bool | None = None):
    """q: [B, Sq, H, D], k/v: [B, Skv, KVH, D] -> [B, Sq, H, D]."""
    b, s_q, h, d = q.shape
    kvh = k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s_q, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, k.shape[1], d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, v.shape[1], d)
    if impl == "pallas":
        out = flash_attention_pallas(qf, kf, vf, causal=causal, window=window,
                                     interpret=resolve_interpret(interpret))
    else:
        out = attention_ref(qf, kf, vf, causal=causal, window=window)
    return out.reshape(b, h, s_q, d).transpose(0, 2, 1, 3)
