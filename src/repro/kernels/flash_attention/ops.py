"""jit'd public wrapper around the flash-attention kernel.

Accepts the model-layer layout [B, S, H, D] (+ GQA KV [B, S, KVH, D]) and
dispatches to the Pallas kernel (TPU target; interpret=True on CPU) or to the
jnp reference (``impl='xla'``).  The dry-run/roofline path uses 'xla' so XLA
cost analysis can see the FLOPs (DESIGN.md section 7); 'pallas' is the
hardware hot path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "impl",
                                             "interpret"))
def multihead_attention(q, k, v, *, causal: bool = True, window: int = 0,
                        impl: str = "xla", interpret: bool = True):
    """q: [B, Sq, H, D], k/v: [B, Skv, KVH, D] -> [B, Sq, H, D]."""
    b, s_q, h, d = q.shape
    kvh = k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s_q, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, k.shape[1], d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, v.shape[1], d)
    if impl == "pallas":
        out = flash_attention_pallas(qf, kf, vf, causal=causal, window=window,
                                     interpret=interpret)
    else:
        out = attention_ref(qf, kf, vf, causal=causal, window=window)
    return out.reshape(b, h, s_q, d).transpose(0, 2, 1, 3)
