"""Pallas TPU kernel: tiled causal attention with online softmax (flash).

The LM-side compute hot spot.  Grid (bh, q_tile, kv_tile): kv_tile is the
innermost (sequential) dimension, so the running max / normalizer / weighted
accumulator live in VMEM scratch across kv steps — the classic flash
schedule, laid out for the MXU:

  * q/k/v tiles are [TILE, D] with D and TILE multiples of 128/8 so both
    q @ k^T and p @ v hit the 128x128 systolic array without padding;
  * the m/l online-softmax carries are [TILE_Q, 1] f32 in VMEM scratch;
  * causal + sliding-window masking happens on the [TILE_Q, TILE_KV] logits
    tile; fully-masked kv tiles still run (a `pl.when` skip would be the next
    optimization on hardware — grid pruning is done by the wrapper instead).

GQA is handled by the BlockSpec index maps: the kv block index is derived
from the q-head block index (h // group), so KV heads are never materialized
per-q-head in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.backend import resolve_interpret

DEFAULT_TILE_Q = 128
DEFAULT_TILE_KV = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int,
                  tile_q: int, tile_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)          # [TQ, D]
    k = k_ref[0].astype(jnp.float32)          # [TK, D]
    v = v_ref[0].astype(jnp.float32)          # [TK, D]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    q_pos = qi * tile_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * tile_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                        # [TQ, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                     # [TQ, TK]
    correction = jnp.exp(m_prev - m_new)       # [TQ, 1]
    l_ref[...] = l_ref[...] * correction + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * correction + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)     # rows fully masked -> zeros
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "tile_q", "tile_kv", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,          # [BH, S_q, D]
    k: jax.Array,          # [BKV, S_kv, D]
    v: jax.Array,          # [BKV, S_kv, D]
    *,
    causal: bool = True,
    window: int = 0,       # 0 = unlimited; >0 = sliding window
    tile_q: int = DEFAULT_TILE_Q,
    tile_kv: int = DEFAULT_TILE_KV,
    interpret: bool | None = None,
):
    interpret = resolve_interpret(interpret)
    bh, s_q, d = q.shape
    bkv, s_kv, _ = k.shape
    assert bh % bkv == 0, "q heads must be a multiple of kv heads"
    group = bh // bkv
    assert s_q % tile_q == 0 and s_kv % tile_kv == 0
    scale = 1.0 / (d ** 0.5)

    grid = (bh, s_q // tile_q, s_kv // tile_kv)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        tile_q=tile_q, tile_kv=tile_kv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, tile_kv, d), lambda b, qi, ki: (b // group, ki, 0)),
            pl.BlockSpec((1, tile_kv, d), lambda b, qi, ki: (b // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_q, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tile_q, 1), jnp.float32),
            pltpu.VMEM((tile_q, 1), jnp.float32),
            pltpu.VMEM((tile_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
