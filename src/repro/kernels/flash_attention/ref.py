"""Pure-jnp oracle for flash attention (materializes the full logits)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: [BH, Sq, D]; k/v: [BKV, Skv, D] with BH % BKV == 0."""
    bh, s_q, d = q.shape
    bkv = k.shape[0]
    group = bh // bkv
    k = jnp.repeat(k, group, axis=0)
    v = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    q_pos = jnp.arange(s_q)[:, None]
    k_pos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((s_q, k.shape[1]), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
