"""The persistent Pallas megakernel: one launch per drain (DESIGN.md §14).

The paper's persistent strategy keeps workers resident in a single kernel
that claims tasks until the queue is globally empty.  Our ``persistent``
kernel value approximates that with a jitted ``lax.while_loop`` — zero
host round-trips, but every round still re-enters the expand/push kernels.
This package fuses the *whole* drain loop — claim → expand → apply → push →
global-empty check — into one ``pallas_call``:

  * :func:`~repro.kernels.drain_loop.kernel.fused_drain_pallas` traces any
    ``(step, cond, carry)`` while-loop into a jaxpr, hoists its closed-over
    constants (the CSR arrays, budgets, codecs) into explicit kernel
    inputs, and evaluates it inside a single kernel body;
  * :mod:`~repro.kernels.drain_loop.csr_stream` feeds the in-kernel
    expansion: per-chunk CSR row slices are DMA'd HBM→VMEM through a
    double-buffered scratch so the copy of round ``i+1`` overlaps the
    gather of round ``i``;
  * :func:`~repro.kernels.drain_loop.ops.megakernel_drive` is the driver
    the scheduler dispatches to for ``ExecutionPolicy(kernel="megakernel")``
    — with an optional round ``limit`` so the streaming snapshot layer can
    segment a drain at the exact same boundaries as the other strategies.

Unlike the leaf kernels in this tree, the fused drain body is an
**interpret-mode prototype**: its jaxpr contains a nested ``pallas_call``
(the DMA stream) and whole-array operands that Mosaic has no in-kernel
lowering for, so ``fused_drain_pallas`` ALWAYS runs through the Pallas
interpreter — on a real TPU (where ``core.backend.resolve_interpret``
would compile) it warns and falls back, and an explicit
``interpret=False`` raises ``NotImplementedError``.  The
parity/property/fault tests therefore exercise the real fused loop on any
host; a compiled Mosaic lowering (explicit HBM memory spaces for the CSR
operands, in-kernel DMA instead of the nested expansion call) is future
work (DESIGN.md §14).
"""
from .csr_stream import expand_stream, stream_row_slices
from .kernel import fused_drain_pallas, make_fused_drain
from .ops import make_megakernel_segment, megakernel_drive

__all__ = ["expand_stream", "fused_drain_pallas", "make_fused_drain",
           "make_megakernel_segment", "megakernel_drive",
           "stream_row_slices"]
