"""The persistent Pallas megakernel: one launch per drain (DESIGN.md §14).

The paper's persistent strategy keeps workers resident in a single kernel
that claims tasks until the queue is globally empty.  Our ``persistent``
kernel value approximates that with a jitted ``lax.while_loop`` — zero
host round-trips, but every round still re-enters the expand/push kernels.
This package fuses the *whole* drain loop — claim → expand → apply → push →
global-empty check — into one ``pallas_call``:

  * :func:`~repro.kernels.drain_loop.kernel.fused_drain_pallas` traces any
    ``(step, cond, carry)`` while-loop into a jaxpr, hoists its closed-over
    constants (the CSR arrays, budgets, codecs) into explicit kernel
    inputs, and evaluates it inside a single kernel body;
  * :mod:`~repro.kernels.drain_loop.csr_stream` feeds the in-kernel
    expansion: per-chunk CSR row slices are DMA'd HBM→VMEM through a
    double-buffered scratch so the copy of round ``i+1`` overlaps the
    gather of round ``i``;
  * :func:`~repro.kernels.drain_loop.ops.megakernel_drive` is the driver
    the scheduler dispatches to for ``ExecutionPolicy(kernel="megakernel")``
    — with an optional round ``limit`` so the streaming snapshot layer can
    segment a drain at the exact same boundaries as the other strategies.

Like every kernel in this tree it compiles on TPU and falls back to
interpret mode elsewhere (``core.backend.resolve_interpret``), so the
parity/property/fault tests exercise the real fused loop on any host.
"""
from .csr_stream import expand_stream, stream_row_slices
from .kernel import fused_drain_pallas
from .ops import megakernel_drive

__all__ = ["expand_stream", "fused_drain_pallas", "megakernel_drive",
           "stream_row_slices"]
