"""``megakernel_drive`` — the drain driver behind ``kernel="megakernel"``.

The third point of the kernel-strategy axis (persistent | discrete |
megakernel): where ``persistent_drive`` hands the step/cond pair to
``lax.while_loop`` and ``discrete_drive`` to a host loop, this driver
hands them to :func:`~repro.kernels.drain_loop.kernel.fused_drain_pallas`
— the whole drain becomes ONE kernel launch.

``limit`` serves the streaming snapshot layer (stream/driver.py): a
segmented megakernel drain folds ``rounds < limit`` into the loop
condition, so segment boundaries are absolute round numbers and a resumed
drain takes exactly the same steps as an uninterrupted one — the same
invariant the persistent segments rely on, proved under SIGKILL by
tests/test_megakernel.py.
"""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import fused_drain_pallas


def megakernel_drive(step, cond, carry0, *, limit=None, interpret=None):
    """Drive ``carry0 = (queue, state, rounds, processed)`` to its fixed
    point (or to round ``limit``) in a single fused kernel launch."""
    if limit is not None:
        limit = jnp.int32(limit)
        inner = cond
        cond = lambda c: inner(c) & (c[2] < limit)
    return fused_drain_pallas(step, cond, carry0, interpret=interpret)
