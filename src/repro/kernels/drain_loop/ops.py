"""``megakernel_drive`` — the drain driver behind ``kernel="megakernel"``.

The third point of the kernel-strategy axis (persistent | discrete |
megakernel): where ``persistent_drive`` hands the step/cond pair to
``lax.while_loop`` and ``discrete_drive`` to a host loop, this driver
hands them to :func:`~repro.kernels.drain_loop.kernel.fused_drain_pallas`
— the whole drain becomes ONE kernel launch.

``limit`` serves the streaming snapshot layer (stream/driver.py): a
segmented megakernel drain folds ``rounds < limit`` into the loop
condition, so segment boundaries are absolute round numbers and a resumed
drain takes exactly the same steps as an uninterrupted one — the same
invariant the persistent segments rely on, proved under SIGKILL by
tests/test_megakernel.py.  Segmented callers should hold a
:func:`make_megakernel_segment` runner: the limit rides as a *kernel
operand* (an extra carry leaf), so ONE traced jaxpr / pallas_call serves
every segment instead of retracing the whole fused drain per snapshot
window.
"""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import fused_drain_pallas, make_fused_drain


def make_megakernel_segment(step, cond, example_carry, *, interpret=None):
    """Build the round-limited fused drain ONCE; return ``seg(carry,
    limit)``.

    The limit is appended to the carry as one more leaf and conjoined into
    the in-kernel condition as ``rounds < limit`` (rounds live at
    ``carry[2]``, the repo-wide drain-carry convention), so it reaches the
    kernel as a plain operand — calling ``seg`` with a new limit reuses
    the same traced jaxpr and jitted ``pallas_call``, mirroring the
    persistent branch's single jitted segment function.
    """

    def seg_cond(c):
        return cond(tuple(c[:-1])) & (c[2] < c[-1])

    def seg_step(c):
        return (*step(tuple(c[:-1])), c[-1])

    run = make_fused_drain(seg_step, seg_cond,
                           (*tuple(example_carry), jnp.int32(0)),
                           interpret=interpret)

    def seg(carry, limit):
        out = run((*tuple(carry), jnp.asarray(limit, jnp.int32)))
        return tuple(out[:-1])

    return seg


def megakernel_drive(step, cond, carry0, *, limit=None, interpret=None):
    """Drive ``carry0 = (queue, state, rounds, processed)`` to its fixed
    point (or to round ``limit``) in a single fused kernel launch."""
    if limit is not None:
        return make_megakernel_segment(step, cond, carry0,
                                       interpret=interpret)(carry0, limit)
    return fused_drain_pallas(step, cond, carry0, interpret=interpret)
