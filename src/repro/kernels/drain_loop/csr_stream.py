"""Double-buffered DMA of CSR row slices — the megakernel's expansion feed.

Outside the megernel, ``expand_merge_path`` gathers each work unit's
neighbor id straight out of the full ``col_idx`` array; inside a resident
kernel the CSR lives in HBM and the win comes from *streaming* exactly the
row slices the claimed chunks need into VMEM, with the copy for chunk
``i+1`` in flight while chunk ``i``'s slice is being written out — the
classic two-deep DMA pipeline.

``stream_row_slices`` is that pipeline: for each popped chunk it issues
``make_async_copy(col_idx[start_i : start_i + budget]) -> scratch[slot]``
against a ``[2, budget]`` VMEM scratch and a 2-lane DMA semaphore, waits
the previous slot, and lands the slice in row ``i`` of the output.

``expand_stream`` is the merge-path expansion rebuilt on top of it: the
degree scan, owner search, and intra-chunk row recovery are shared with
``core.frontier`` (imported, not copied), and only the neighbor gather
changes — ``nbr[k] = slices[owner_k, k - excl[owner_k]]``.  The merge-path
layout makes the two gathers *provably identical*: work unit ``k``'s edge
index is ``row_ptr[head_owner] + rank`` with ``rank < budget``, i.e. it
always falls inside its owner's streamed slice.  Dispatched as the
internal ``backend="stream"`` value of ``expand_merge_path``
(core/backend.py), which the runtime selects for megakernel bodies.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.backend import resolve_interpret
from ...core.frontier import (Expansion, chunk_degrees, chunk_row_of,
                              searchsorted_right)
from ...graph.slotted import SLAB_SLACK

_N_BUFFERS = 2  # double buffering: one slice landing, one in flight


def _stream_kernel(n_items, budget, starts_ref, hbm_ref, out_ref,
                   scratch, sem):
    """Copy ``hbm[starts[i] : starts[i]+budget]`` into ``out[i]`` for every
    ``i``, two DMAs deep.  ``starts`` rides in SMEM (scalar loop bounds),
    ``hbm_ref`` stays unblocked in ANY/HBM — only the slices touch VMEM.
    ``n_items`` is static and positive: ``stream_row_slices`` short-circuits
    an empty wavefront before the launch, so the prologue DMA below never
    reads ``starts_ref[0]`` out of bounds."""

    def dma(slot, i):
        return pltpu.make_async_copy(
            hbm_ref.at[pl.ds(starts_ref[i], budget)],
            scratch.at[slot], sem.at[slot])

    dma(0, 0).start()

    def body(i, carry):
        slot = jax.lax.rem(i, _N_BUFFERS)

        @pl.when(i + 1 < n_items)
        def _():
            dma(jax.lax.rem(i + 1, _N_BUFFERS), i + 1).start()

        dma(slot, i).wait()
        out_ref[pl.ds(i, 1), :] = scratch[slot].reshape(1, budget)
        return carry

    jax.lax.fori_loop(0, n_items, body, 0)


def stream_row_slices(col_idx: jax.Array, starts: jax.Array, budget: int,
                      *, interpret=None) -> jax.Array:
    """``[n_items, budget]`` — ``col_idx[starts[i] : starts[i]+budget]``
    per item, streamed HBM→VMEM through the double-buffered pipeline.

    ``col_idx`` is padded by ``budget`` zeros so a slice starting near the
    tail never reads out of bounds (padding lanes are masked off by the
    caller's ``in_range``); DMA lengths must be static on TPU, only the
    starts may be dynamic.
    """
    n_items = int(starts.shape[0])
    if n_items == 0:
        # static: no items, no launch — the kernel's prologue DMA would
        # read starts_ref[0] out of bounds (and a zero-row output block
        # cannot be padded at all)
        return jnp.zeros((0, budget), col_idx.dtype)
    padded = jnp.concatenate(
        [col_idx, jnp.zeros((budget,), col_idx.dtype)])
    starts = jnp.clip(jnp.asarray(starts, jnp.int32), 0, col_idx.shape[0])
    return pl.pallas_call(
        functools.partial(_stream_kernel, n_items, budget),
        out_shape=jax.ShapeDtypeStruct((n_items, budget), col_idx.dtype),
        in_specs=[pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.SMEM),
                  pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.VMEM),
        scratch_shapes=[pltpu.VMEM((_N_BUFFERS, budget), col_idx.dtype),
                        pltpu.SemaphoreType.DMA((_N_BUFFERS,))],
        interpret=resolve_interpret(interpret),
    )(starts, padded)


def expand_stream(
    items: jax.Array,
    valid: jax.Array,
    row_ptr: jax.Array,
    col_idx: jax.Array,
    work_budget: int,
    widths: jax.Array | None = None,
    max_width: int = 1,
    overlay=None,
    *,
    interpret=None,
) -> Expansion:
    """Merge-path expansion over DMA-streamed row slices.

    Bit-identical to the jnp reference in ``core.frontier``: the LBS
    schedule (degree scan, owner search, chunk-row recovery) is the shared
    code, and for every in-range work unit ``rank = k - excl[owner]``
    satisfies ``rank < deg_owner <= budget`` — the streamed slice
    ``col_idx[row_ptr[head_owner] :+ budget]`` therefore contains exactly
    the edge the flat gather would read.  Out-of-range lanes are zeroed on
    both paths.

    Traffic note: because DMA lengths must be static, every popped item
    streams a FULL ``work_budget``-length slice — ``n_items x
    work_budget`` elements per expansion regardless of the chunks' actual
    degrees, so on low-degree frontiers the streamed byte volume can
    exceed the flat gather's touched footprint by a large factor.  The
    roofline section of ``benchmarks/bench_megakernel.py`` accounts for
    this term explicitly (DESIGN.md §14).
    """
    safe = jnp.where(valid, items, 0)
    deg = chunk_degrees(items, widths, valid, row_ptr)
    scan = jnp.cumsum(deg)
    total = scan[-1] if scan.shape[0] > 0 else jnp.int32(0)

    k = jnp.arange(work_budget, dtype=jnp.int32)
    owner = searchsorted_right(scan, k)
    owner = jnp.clip(owner, 0, items.shape[0] - 1)
    excl = scan - deg
    rank = k - excl[owner]
    head = safe[owner]
    src = (head if widths is None else
           chunk_row_of(row_ptr, head, rank, widths[owner], max_width))
    in_range = k < total
    if overlay is None:
        slices = stream_row_slices(col_idx, row_ptr[safe], work_budget,
                                   interpret=interpret)
        nbr = slices[owner, jnp.clip(rank, 0, work_budget - 1)]
    else:
        # Slotted graph (graph/slotted.py): ``col_idx`` is the flat slab
        # array.  A chunk's slab span is bounded by the slab-slack
        # invariant: sum(cap_r) <= 4 * sum(max(1, deg_r)) <= 4 *
        # (degree_sum + width) <= 4 * (work_budget + max_width), so one
        # static-length DMA per chunk starting at ``slab_ptr[head]`` covers
        # every member row's slab.  The extra over-fetch (4x on top of the
        # full-budget slice above) is the price of in-place commits; the
        # overlay tail is tiny and compaction-bounded, so it reads straight
        # from its own flat array instead of the stream.
        slab_budget = SLAB_SLACK * (work_budget + max_width)
        slices = stream_row_slices(col_idx, overlay.slab_ptr[safe],
                                   slab_budget, interpret=interpret)
        edge = row_ptr[head] + rank
        off = edge - row_ptr[src]
        s_idx = overlay.slab_ptr[src] + off - overlay.slab_ptr[head]
        s_val = slices[owner, jnp.clip(s_idx, 0, slab_budget - 1)]
        o_idx = overlay.ovl_ptr[src] + off - overlay.slab_len[src]
        o_val = overlay.ovl_col[jnp.clip(o_idx, 0,
                                         overlay.ovl_col.shape[0] - 1)]
        nbr = jnp.where(off < overlay.slab_len[src], s_val, o_val)
    return Expansion(
        src=jnp.where(in_range, src, 0),
        nbr=jnp.where(in_range, nbr, 0),
        owner=jnp.where(in_range, owner, 0),
        valid=in_range,
        total=total,
    )
