"""``fused_drain_pallas`` — run a whole while-loop inside one pallas_call.

The megakernel problem is *generality*: the drain's step function is an
arbitrary program body (BFS relaxations, PageRank residue scatters,
coloring conflict checks) closing over arbitrary graph state, and Pallas
kernels may not capture traced constants.  ``jax.closure_convert`` does
not help — it hoists only inexact-dtype (differentiable) constants, and a
CSR graph is int32.  So we hoist by hand:

  1. flatten the carry pytree and trace ``while_loop(cond, step, ·)`` over
     the leaves with ``jax.make_jaxpr`` — every closed-over array
     (row_ptr, col_idx, budgets, chunk codecs) lands in ``jaxpr.consts``;
  2. pass ``consts + carry leaves`` as explicit kernel operands (0-d
     scalars lifted to shape ``(1,)`` — TPU refs are >= 1-d);
  3. the kernel body re-evaluates the jaxpr with ``jax.core.eval_jaxpr``
     on the loaded values and stores the loop's outputs.

Because the kernel evaluates the *identical jaxpr* the persistent driver
would hand to ``lax.while_loop``, the fused drain is bit-identical to the
persistent strategy by construction — the parity matrix in
tests/test_megakernel.py pins that, and the property battery drives the
claim/push protocol through this same entry point.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.backend import resolve_interpret


def fused_drain_pallas(step, cond, carry0, *, interpret=None):
    """Run ``while cond(c): c = step(c)`` to its fixed point in ONE kernel.

    ``carry0`` may be any pytree of arrays (the drain carry is
    ``(queue, state, rounds, processed)``; the property tests thread
    scripted op tapes through here).  ``step``/``cond`` may close over
    anything traceable — constants are hoisted into kernel operands.
    Returns the final carry with the input tree structure.  ``interpret``
    follows the repo-wide rule: ``None`` = interpret iff no TPU attached.
    """
    flat0, treedef = jax.tree.flatten(carry0)
    flat0 = [jnp.asarray(x) for x in flat0]

    def flat_drain(*leaves):
        carry = jax.tree.unflatten(treedef, list(leaves))
        out = jax.lax.while_loop(cond, step, carry)
        return tuple(jax.tree.leaves(out))

    closed = jax.make_jaxpr(flat_drain)(*flat0)
    consts = [jnp.asarray(c) for c in closed.consts]
    inputs = consts + flat0
    # TPU refs are >= 1-d; lift 0-d scalars (round counters, cursors) and
    # reshape back on load so the jaxpr sees its original avals.
    lifted = [x.reshape(1) if x.ndim == 0 else x for x in inputs]
    out_avals = closed.out_avals
    n_in, n_const = len(lifted), len(consts)

    def kernel(*refs):
        in_refs, out_refs = refs[:n_in], refs[n_in:]
        vals = [r[...].reshape(x.shape) for r, x in zip(in_refs, inputs)]
        outs = jax.core.eval_jaxpr(closed.jaxpr, vals[:n_const],
                                   *vals[n_const:])
        for o_ref, o in zip(out_refs, outs):
            o_ref[...] = o.reshape(o_ref.shape)

    outs = pl.pallas_call(
        kernel,
        out_shape=tuple(
            jax.ShapeDtypeStruct(a.shape if a.ndim else (1,), a.dtype)
            for a in out_avals),
        interpret=resolve_interpret(interpret),
    )(*lifted)
    outs = [o.reshape(a.shape) for o, a in zip(outs, out_avals)]
    return jax.tree.unflatten(treedef, outs)
