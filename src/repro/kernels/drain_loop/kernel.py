"""``fused_drain_pallas`` — run a whole while-loop inside one pallas_call.

The megakernel problem is *generality*: the drain's step function is an
arbitrary program body (BFS relaxations, PageRank residue scatters,
coloring conflict checks) closing over arbitrary graph state, and Pallas
kernels may not capture traced constants.  ``jax.closure_convert`` does
not help — it hoists only inexact-dtype (differentiable) constants, and a
CSR graph is int32.  So we hoist by hand:

  1. flatten the carry pytree and trace ``while_loop(cond, step, ·)`` over
     the leaves with ``jax.make_jaxpr`` — every closed-over array
     (row_ptr, col_idx, budgets, chunk codecs) lands in ``jaxpr.consts``;
  2. pass ``consts + carry leaves`` as explicit kernel operands (0-d
     scalars lifted to shape ``(1,)`` — TPU refs are >= 1-d);
  3. the kernel body re-evaluates the jaxpr with ``jax.core.eval_jaxpr``
     on the loaded values and stores the loop's outputs.

Because the kernel evaluates the *identical jaxpr* the persistent driver
would hand to ``lax.while_loop``, the fused drain is bit-identical to the
persistent strategy by construction — the parity matrix in
tests/test_megakernel.py pins that, and the property battery drives the
claim/push protocol through this same entry point.

**TPU status: interpret-mode prototype.**  The fused body has no Mosaic
lowering today: the drain jaxpr contains a *nested* ``pallas_call`` (the
``backend.STREAM`` expansion, csr_stream.py), ``lax.while_loop``, and
arbitrary gather/scatter — none of which Mosaic can lower from inside a
kernel body — and the operands here get default whole-array BlockSpecs,
which contradicts HBM-resident CSR state on a real chip.  So this entry
point ALWAYS runs through the Pallas interpreter: with ``interpret=None``
on a TPU (where the repo-wide rule would compile) it warns and falls back
to interpret mode, and an explicit ``interpret=False`` raises rather than
hand Mosaic a program it cannot lower.  The launch-structure collapse and
every correctness claim hold in interpret mode; a compiled lowering
(explicit HBM memory spaces for the CSR operands, in-kernel DMA instead
of the nested expansion call) is future work — see DESIGN.md §14.

Tracing the drain is the expensive part, so it happens ONCE per
:func:`make_fused_drain` — the returned runner reuses the jaxpr, the
hoisted constants, and one jitted ``pallas_call`` across every invocation
with like-shaped carries (the streaming snapshot layer calls it once per
segment).
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.backend import resolve_interpret

_NO_LOWERING = (
    "kernel='megakernel' is an interpret-mode prototype: the fused drain "
    "body evaluates the whole while-loop jaxpr in-kernel — including a "
    "nested pallas_call expansion (kernels/drain_loop/csr_stream) and "
    "whole-array operands — which Mosaic has no lowering for"
)


def _resolve_fused_interpret(interpret) -> bool:
    """The megakernel's own interpret rule: ALWAYS interpret (see module
    docstring).  ``None`` on a real TPU — where the repo-wide rule would
    compile — warns before falling back; an explicit ``interpret=False``
    (a demand to compile) raises."""
    if interpret is not None and not interpret:
        raise NotImplementedError(
            f"{_NO_LOWERING}; interpret=False cannot be honored.  Use the "
            "default (interpret=None) to run through the Pallas "
            "interpreter, or kernel='persistent' for a compiled "
            "device-resident drain.")
    if interpret is None and not resolve_interpret(None):
        warnings.warn(
            f"{_NO_LOWERING}; falling back to the Pallas interpreter on "
            "this TPU.  The drain still collapses to one kernel entry, "
            "but it runs emulated — use kernel='persistent' for compiled "
            "TPU speed.", stacklevel=3)
    return True


def make_fused_drain(step, cond, example_carry, *, interpret=None):
    """Build the fused ``while cond(c): c = step(c)`` kernel ONCE; return a
    runner for it.

    ``example_carry`` supplies shapes/dtypes only — the returned
    ``run(carry)`` accepts any carry with the same pytree structure and
    avals, reusing the traced jaxpr, the hoisted constants, and a single
    jitted ``pallas_call`` (no per-call retrace — the streaming snapshot
    layer drives one runner through O(num_segments) calls).  ``step`` /
    ``cond`` may close over anything traceable — constants are hoisted
    into kernel operands.  ``interpret`` follows the megakernel gate
    (:func:`_resolve_fused_interpret`): always interpret, warn on TPU,
    reject an explicit compile request.
    """
    interpret = _resolve_fused_interpret(interpret)
    flat0, treedef = jax.tree.flatten(example_carry)
    flat0 = [jnp.asarray(x) for x in flat0]

    def flat_drain(*leaves):
        carry = jax.tree.unflatten(treedef, list(leaves))
        out = jax.lax.while_loop(cond, step, carry)
        return tuple(jax.tree.leaves(out))

    closed = jax.make_jaxpr(flat_drain)(*flat0)
    consts = [jnp.asarray(c) for c in closed.consts]
    # TPU refs are >= 1-d; lift 0-d scalars (round counters, cursors) and
    # reshape back on load so the jaxpr sees its original avals.
    shapes = [x.shape for x in consts + flat0]
    out_avals = closed.out_avals
    n_in, n_const = len(shapes), len(consts)

    def kernel(*refs):
        in_refs, out_refs = refs[:n_in], refs[n_in:]
        vals = [r[...].reshape(s) for r, s in zip(in_refs, shapes)]
        outs = jax.core.eval_jaxpr(closed.jaxpr, vals[:n_const],
                                   *vals[n_const:])
        for o_ref, o in zip(out_refs, outs):
            o_ref[...] = o.reshape(o_ref.shape)

    call = pl.pallas_call(
        kernel,
        out_shape=tuple(
            jax.ShapeDtypeStruct(a.shape if a.ndim else (1,), a.dtype)
            for a in out_avals),
        interpret=interpret,
    )
    lifted_consts = [c.reshape(1) if c.ndim == 0 else c for c in consts]

    @jax.jit
    def run(carry):
        leaves = [jnp.asarray(x) for x in jax.tree.leaves(carry)]
        lifted = lifted_consts + [x.reshape(1) if x.ndim == 0 else x
                                  for x in leaves]
        outs = call(*lifted)
        outs = [o.reshape(a.shape) for o, a in zip(outs, out_avals)]
        return jax.tree.unflatten(treedef, outs)

    return run


def fused_drain_pallas(step, cond, carry0, *, interpret=None):
    """Run ``while cond(c): c = step(c)`` to its fixed point in ONE kernel.

    One-shot wrapper over :func:`make_fused_drain` — builds the fused
    kernel for ``carry0``'s shapes and runs it once.  ``carry0`` may be
    any pytree of arrays (the drain carry is ``(queue, state, rounds,
    processed)``; the property tests thread scripted op tapes through
    here).  Returns the final carry with the input tree structure.
    Callers that drive many like-shaped drains (the segmented snapshot
    path) should hold a :func:`make_fused_drain` runner instead.
    """
    return make_fused_drain(step, cond, carry0, interpret=interpret)(carry0)
