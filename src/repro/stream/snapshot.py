"""Crash-consistent mid-drain snapshots for streaming jobs (DESIGN.md §13).

A snapshot is one pytree written through the checkpoint layer's atomic
tmp-then-rename commit (``checkpoint/manager.py``, ``prefix="snap"`` so
drain snapshots and train checkpoints can share a directory without
retention interference):

    cursor      — batch index, rounds/processed so far, the per-batch
                  record's baselines (pre-drain work, seed/effective-op
                  counts): everything host-side the resumed driver needs
    fingerprint — (n, m, row-sum, col-sum, delta-log position) of the graph
                  the drain was running on: resume re-derives that graph by
                  replaying the delta-log prefix, and the fingerprint check
                  catches a caller handing back a different base graph or
                  log
    queue       — the live queue pytree (TaskQueue / MultiQueue / stacked
                  sharded MultiQueue)
    state       — the program state pytree

Consistency argument: the drain is a pure function of the carry, and the
driver only snapshots *between* rounds (segment boundaries), so the carry
on disk is exactly the carry the uninterrupted run had at that round.  A
resumed run replays the delta log to rebuild the (bit-identical) graph and
program, restores the carry, and continues with the same segment schedule
— every subsequent round computes on identical inputs, so the final state
is bit-identical to the uninterrupted run.  A SIGKILL mid-write never
corrupts the newest snapshot (atomic commit); it merely loses the tail
segment, which the resume recomputes.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import numpy as np

from ..checkpoint.manager import CheckpointManager

#: host-side scalars carried per snapshot (all int32 in the tree)
CURSOR_FIELDS = ("batch", "rounds", "processed", "pre_work", "pre_splits",
                 "seeds", "eff")


def graph_fingerprint(graph, num_deltas: int) -> dict:
    """Cheap int64 digest of (graph, delta-log position).

    Representation independent: a slotted graph (``graph/slotted.py`` —
    anything exposing an ``overlay``) digests its live slab prefixes plus
    overlay tail, which is the same multiset of (row, col) pairs the
    canonical ``col_idx`` holds, and the canonical ``row_ptr`` both carry —
    so a snapshot taken against a :class:`SlottedView` restores against
    the replayed-and-recommitted slotted graph *or* its canonical
    materialization interchangeably.
    """
    rp = np.asarray(graph.row_ptr, dtype=np.int64)
    if getattr(graph, "overlay", None) is not None:
        slab_ptr = np.asarray(graph.slab_ptr, dtype=np.int64)
        slab_len = np.asarray(graph.slab_len, dtype=np.int64)
        slab_col = np.asarray(graph.slab_col, dtype=np.int64)
        ovl_ptr = np.asarray(graph.ovl_ptr, dtype=np.int64)
        ovl_col = np.asarray(graph.ovl_col, dtype=np.int64)
        # sum of each row's live slab prefix, via cumsum differences
        cs = np.concatenate([[0], np.cumsum(slab_col)])
        col_sum = int((cs[slab_ptr[:-1] + slab_len] - cs[slab_ptr[:-1]]).sum())
        col_sum += int(ovl_col[:int(ovl_ptr[-1])].sum())
        m = int(rp[-1])
    else:
        ci = np.asarray(graph.col_idx, dtype=np.int64)
        col_sum = int(ci.sum())
        m = int(ci.size)
    return {
        "n": np.int64(graph.num_vertices),
        "m": np.int64(m),
        "row_sum": np.int64(rp.sum()),
        "col_sum": np.int64(col_sum),
        "deltas": np.int64(num_deltas),
    }


class SnapshotManager:
    """Thin streaming-flavored wrapper over :class:`CheckpointManager`."""

    def __init__(self, directory: str, keep: int = 3):
        self.mgr = CheckpointManager(directory, keep=keep, prefix="snap")

    @property
    def dir(self) -> str:
        return self.mgr.dir

    # --------------------------------------------------------------- save
    def save(self, tick: int, *, cursor: dict, graph, num_deltas: int,
             queue: Any, state: Any, blocking: bool = True):
        missing = set(CURSOR_FIELDS) - set(cursor)
        if missing:
            raise ValueError(f"snapshot cursor missing {sorted(missing)}")
        tree = {
            "cursor": {k: np.int32(cursor[k]) for k in CURSOR_FIELDS},
            "fingerprint": graph_fingerprint(graph, num_deltas),
            "queue": queue,
            "state": state,
        }
        self.mgr.save(tick, tree, blocking=blocking)

    def wait(self):
        self.mgr.wait()

    # ------------------------------------------------------------ inspect
    def latest(self) -> Optional[int]:
        return self.mgr.latest_step()

    def peek(self, tick: int) -> dict:
        """Read only the cursor + fingerprint of a snapshot — the resume
        path must learn *which* batch (hence which graph to replay) before
        it can build the full restore template."""
        d = os.path.join(self.mgr.dir, f"{self.mgr.prefix}_{tick}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)["arrays"]
        out: dict = {"fingerprint": {}}
        for key, meta in manifest.items():
            names = re.findall(r"\['([^']+)'\]", key)
            if len(names) == 2 and names[0] == "cursor":
                out[names[1]] = int(np.load(os.path.join(d, meta["file"])))
            elif len(names) == 2 and names[0] == "fingerprint":
                out["fingerprint"][names[1]] = int(
                    np.load(os.path.join(d, meta["file"])))
        return out

    # ------------------------------------------------------------ restore
    def restore(self, tick: int, *, queue_template: Any, state_template: Any,
                graph, num_deltas: int) -> dict:
        """Load a snapshot into deterministically rebuilt templates.

        ``graph`` must be the replayed batch graph; a fingerprint mismatch
        means the caller's base graph or delta log differs from the one the
        snapshot was taken under, and resuming would silently corrupt the
        run — refuse instead.
        """
        want = {k: int(v) for k, v in
                graph_fingerprint(graph, num_deltas).items()}
        got = self.peek(tick)["fingerprint"]  # host-side: int64-exact
        if got != want:
            raise ValueError(
                f"snapshot {tick} fingerprint {got} does not match the "
                f"replayed graph {want}: different base graph or delta log")
        # the template omits the fingerprint on purpose: restore loads only
        # the template's keys, and the device round-trip would truncate the
        # int64 digests anyway — they were already verified above.
        like = {
            "cursor": {k: np.int32(0) for k in CURSOR_FIELDS},
            "queue": queue_template,
            "state": state_template,
        }
        return self.mgr.restore(tick, like)
