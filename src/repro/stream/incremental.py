"""Incremental recompute rules: which seeds does a delta batch dirty?

Each rule is a host-side function ``(applied, state, ...) -> (state',
seeds)``; the algorithm factories close it over their chunking bundle and
install it as ``AtosProgram.dirty_seeds``, so the stream driver never
branches on the algorithm.  Seeds are ordinary chunk-coded tasks
(``core/task.chunk_seeds``) — the incremental drain rides the existing
queue/frontier/chunk machinery unchanged (DESIGN.md §13).

Rules (correctness arguments in DESIGN.md §13):

* **BFS** — inserts: seed the finite-dist source endpoints of inserted
  edges (their relaxation cascades any improvement).  Deletes: compute the
  invalidation level ``L`` = min level of a deleted tree edge's target
  (``dist[v] == dist[u] + 1``); all levels ``< L`` are provably still
  exact, so reset every ``dist >= L`` to INF and seed the finite-dist
  boundary (vertices with an INF out-neighbor).  Monotone re-relaxation
  from exact-or-INF upper bounds reproduces the from-scratch hop distances
  bit-for-bit (they are unique).
* **PageRank** — the push invariant ``residue = (1-d)·1 + d·AᵀD⁻¹rank -
  rank`` *defines* residue given rank, so restore it densely on the new
  graph from the carried rank: only vertices whose in-neighborhood (or
  degree) changed move off ``<= eps``.  Deleted edges can leave *negative*
  residues the positive-push drain would never clean (its stop is
  ``max(residue) <= eps`` and the rescan enqueues ``> eps`` only), so
  negative mass is decayed host-side by the same harvest/push sweep the
  dense BSP kernel uses (mass shrinks ×damping per sweep).  Seeds = the
  ``> eps`` frontier; the drained result matches a from-scratch drain
  within the usual eps slack.
* **Coloring** — ``"conflicts"`` mode keeps the carried colors and seeds
  one assign task per *losing* endpoint of every inserted same-colored
  edge (the ``(hash, id)`` priority tie-break the conflict kernel uses);
  deletes never invalidate a proper coloring.  The result is a valid
  coloring for strictly less work than recoloring, but not bit-identical
  to a from-scratch drain — ``"recolor"`` mode (``dirty_seeds=None``,
  i.e. the conservative full reseed) is the bit-identical option.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.task import ChunkCodec, chunk_seeds
from .ingest import AppliedDelta

BFS_INF = 0x7FFFFFFF


def reseed(program, applied: AppliedDelta, state,
           incremental: bool = True) -> Tuple[Any, Any]:
    """The stream driver's dispatch: the program's incremental rule when it
    has one (and the caller wants it), else the conservative full reseed
    via ``init()`` — always correct, never cheaper."""
    if incremental and program.dirty_seeds is not None:
        return program.dirty_seeds(applied, state)
    return program.init()


def _csr_host(graph):
    rp = np.asarray(graph.row_ptr, dtype=np.int64)
    ci = np.asarray(graph.col_idx, dtype=np.int64)
    return rp, ci


def _chunked(verts: np.ndarray, codec: ChunkCodec, row_ptr,
             split_threshold, owner_block) -> np.ndarray:
    """Sorted unique dirty vertices -> chunk-coded seed tasks."""
    verts = np.unique(np.asarray(verts, dtype=np.int64)).astype(np.int32)
    return np.asarray(chunk_seeds(verts, codec, row_ptr,
                                  split_threshold=split_threshold,
                                  owner_block=owner_block))


# ---------------------------------------------------------------------- BFS
def _row_access(applied: AppliedDelta):
    """``(row_ptr64, neighbors_fn, symmetric)`` for host-side rules.

    Slotted commits answer per-row queries in O(degree) straight out of the
    slabs and carry the tracked symmetry flag; a canonical CSR gets slice
    access plus an O(m log m) symmetry check (that path was O(m) anyway).
    """
    if applied.slotted is not None:
        s = applied.slotted
        return s.row_ptr64(), s.row_neighbors, s.symmetric
    g = applied.new_graph
    n = g.num_vertices
    rp = np.asarray(g.row_ptr, dtype=np.int64)
    ci = np.asarray(g.col_idx, dtype=np.int32)

    def nbrs(r):
        return ci[rp[r]:rp[r + 1]]

    src = np.repeat(np.arange(n, dtype=np.int64), np.diff(rp))
    keys = src * n + ci
    sym = bool(np.array_equal(keys, np.sort(ci.astype(np.int64) * n + src)))
    return rp, nbrs, sym


def bfs_dirty_seeds(applied: AppliedDelta, state, *, codec: ChunkCodec,
                    split_threshold, owner_block):
    """Region-pruned delete invalidation (Ramalingam/Reps deletion phase).

    The conservative rule below resets *every* level >= the lowest deleted
    tree edge — on low-diameter graphs one early delete re-drains most of
    the graph (the 0.92x work ratio in BENCH_stream.json).  This rule
    instead walks only the truly disconnected region: candidates are the
    deleted tree edges' targets, processed in ascending old level; a
    candidate at level L is *supported* (keeps its distance) iff it still
    has an unaffected neighbor at L-1, else it is affected and its old
    tree children (neighbors at L+1) become candidates.  Level-order
    processing finalizes every L-1 verdict before any L check, so supports
    are never read stale.  Affected vertices reset to INF and the region's
    finite fringe reseeds; monotone re-relaxation restores the (unique)
    hop distances bit-for-bit.

    The support/fringe scans read *out*-neighbors as in-neighbors, which
    is only sound on symmetric graphs — the streaming workload contract
    (``graph/generators.edge_delta_stream`` emits both directions).  The
    slotted representation tracks symmetry per commit; asymmetric or
    unknown cases fall back to :func:`bfs_dirty_seeds_conservative`
    (always correct, never cheaper).
    """
    import dataclasses
    import heapq

    rp, nbrs, symmetric = _row_access(applied)
    if not symmetric:
        return bfs_dirty_seeds_conservative(
            applied, state, codec=codec, split_threshold=split_threshold,
            owner_block=owner_block)
    n = rp.shape[0] - 1
    dist = np.asarray(state.dist).astype(np.int64)

    affected = np.zeros(n, dtype=bool)
    seed_mask = np.zeros(n, dtype=bool)
    if applied.del_src.size:
        du = dist[applied.del_src]
        dv = dist[applied.del_dst]
        on_tree = (du < BFS_INF) & (dv == du + 1)
        heap = [(int(l), int(v)) for l, v in
                zip(dv[on_tree], applied.del_dst[on_tree])]
        heapq.heapify(heap)
        while heap:
            L, v = heapq.heappop(heap)
            if affected[v]:
                continue
            nb = nbrs(v)
            dn = dist[nb]
            if np.any((dn == L - 1) & ~affected[nb]):
                continue  # supported: an intact parent remains
            affected[v] = True
            for w in nb[dn == L + 1].tolist():
                if not affected[w]:
                    heapq.heappush(heap, (L + 1, int(w)))
    if affected.any():
        # regional boundary: the affected region's finite, unaffected
        # fringe relaxes back in (exact because the carried state was a
        # drained fixed point: any other finite->INF edge would have
        # relaxed already)
        for v in np.flatnonzero(affected).tolist():
            nb = nbrs(v)
            seed_mask[nb[(dist[nb] < BFS_INF) & ~affected[nb]]] = True
        dist[affected] = BFS_INF
    if applied.ins_src.size:
        iu = applied.ins_src[dist[applied.ins_src] < BFS_INF]
        seed_mask[iu] = True

    seeds = _chunked(np.flatnonzero(seed_mask), codec, rp,
                     split_threshold, owner_block)
    new_state = dataclasses.replace(
        state, dist=jnp.asarray(dist.astype(np.int32)))
    return new_state, jnp.asarray(seeds, jnp.int32)


def bfs_dirty_seeds_conservative(applied: AppliedDelta, state, *,
                                 codec: ChunkCodec, split_threshold,
                                 owner_block):
    """Monotone re-relaxation with level-cut invalidation (see module doc).

    The regression oracle for :func:`bfs_dirty_seeds` (and its fallback on
    asymmetric graphs): resets every level >= the lowest deleted tree
    edge's target, always a superset of the region-pruned reset.
    """
    import dataclasses

    g = applied.csr()
    n = g.num_vertices
    rp, ci = _csr_host(g)
    dist = np.asarray(state.dist).astype(np.int64)

    invalidated = False
    if applied.del_src.size:
        du = dist[applied.del_src]
        dv = dist[applied.del_dst]
        # an edge can lie on a shortest path only if dv == du + 1 exactly
        on_tree = (du < BFS_INF) & (dv == du + 1)
        if on_tree.any():
            L = int(dv[on_tree].min())
            dist = np.where(dist >= L, BFS_INF, dist)
            invalidated = True

    seed_mask = np.zeros(n, dtype=bool)
    if invalidated:
        # boundary of the intact region: finite vertices that can relax
        # into the reset (INF) region on the NEW graph
        src = np.repeat(np.arange(n, dtype=np.int64), np.diff(rp))
        to_inf = dist[ci] == BFS_INF
        has_inf_nbr = np.bincount(src[to_inf], minlength=n) > 0
        seed_mask |= (dist < BFS_INF) & has_inf_nbr
    if applied.ins_src.size:
        iu = applied.ins_src[dist[applied.ins_src] < BFS_INF]
        seed_mask[iu] = True

    seeds = _chunked(np.flatnonzero(seed_mask), codec, rp,
                     split_threshold, owner_block)
    new_state = dataclasses.replace(
        state, dist=jnp.asarray(dist.astype(np.int32)))
    return new_state, jnp.asarray(seeds, jnp.int32)


# ----------------------------------------------------------------- PageRank
def pagerank_dirty_seeds(applied: AppliedDelta, state, *, damping: float,
                         eps: float, codec: ChunkCodec, split_threshold,
                         owner_block, max_sweeps: int = 400):
    """Invariant restoration + negative-residue decay (see module doc)."""
    import dataclasses

    g = applied.csr()
    n = g.num_vertices
    rp, ci = _csr_host(g)
    src = np.repeat(np.arange(n, dtype=np.int64), np.diff(rp))
    rank = np.asarray(state.rank, dtype=np.float64)
    deg = np.maximum(np.diff(rp), 1).astype(np.float64)

    # residue := (1-d)·1 + d·Σ_{u->v} rank[u]/deg(u) − rank[v] on the NEW
    # graph — the exact error of the carried rank as a solution here.
    contrib = damping * rank / deg
    residue = (1.0 - damping) + np.bincount(
        ci, weights=contrib[src], minlength=n) - rank

    # decay negative mass (deleted in-edges): harvest into rank, push the
    # damped share along out-edges; total |negative| shrinks ×damping per
    # sweep, so convergence to eps is geometric.
    for _ in range(max_sweeps):
        neg = residue < -eps
        if not neg.any():
            break
        res_neg = np.where(neg, residue, 0.0)
        rank = rank + res_neg
        residue = np.where(neg, 0.0, residue)
        residue += np.bincount(ci, weights=(damping * res_neg / deg)[src],
                               minlength=n)

    rank32 = rank.astype(np.float32)
    residue32 = residue.astype(np.float32)
    over = residue32 > eps
    seeds = _chunked(np.flatnonzero(over), codec, rp,
                     split_threshold, owner_block)
    new_state = dataclasses.replace(
        state,
        rank=jnp.asarray(rank32),
        residue=jnp.asarray(residue32),
        in_queue=jnp.asarray(over),
    )
    return new_state, jnp.asarray(seeds, jnp.int32)


# ----------------------------------------------------------------- coloring
def _priority_host(v: np.ndarray) -> np.ndarray:
    """numpy mirror of ``algorithms.coloring._priority`` (uint32 wraps)."""
    v = v.astype(np.uint32)
    h = (v * np.uint32(2654435761)) ^ np.uint32(0x9E3779B9)
    h = (h ^ (h >> np.uint32(13))) * np.uint32(0x85EBCA6B)
    return h ^ (h >> np.uint32(16))


def coloring_dirty_seeds(applied: AppliedDelta, state, *, codec: ChunkCodec,
                         split_threshold, owner_block):
    """Conflict-endpoint recoloring (``"conflicts"`` mode; see module doc)."""
    g = applied.new_graph       # row_ptr only — any representation works
    rp = np.asarray(g.row_ptr, dtype=np.int64)
    colors = np.asarray(state.colors)

    dirty = []
    u, v = applied.ins_src, applied.ins_dst
    if u.size:
        conflict = (colors[u] >= 0) & (colors[u] == colors[v])
        if conflict.any():
            cu, cv = u[conflict], v[conflict]
            pu, pv = _priority_host(cu), _priority_host(cv)
            # the endpoint with the HIGHER (hash, id) priority recolors —
            # exactly _conflicts's "neighbor wins ties by lower priority"
            u_loses = (pv < pu) | ((pv == pu) & (cv < cu))
            dirty.append(np.where(u_loses, cu, cv))
    uncolored = np.flatnonzero(colors < 0)  # defensive: partial prior state
    if uncolored.size:
        dirty.append(uncolored)

    verts = (np.concatenate(dirty) if dirty
             else np.empty(0, dtype=np.int64))
    # assign tasks: +(chunk code + 1) — the coloring sign convention
    seeds = _chunked(verts, codec, rp, split_threshold, owner_block) + 1 \
        if verts.size else np.empty(0, dtype=np.int32)
    return state, jnp.asarray(seeds, jnp.int32)
