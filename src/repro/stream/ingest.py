"""Delta ingestion: commit an :class:`EdgeDelta` batch against the graph.

The canonical edge set is sorted unique directed ``(src, dst)`` pairs with
self-loops dropped (``graph/csr.from_edges``).  Two commit paths produce
it:

* **reference** (:func:`apply_delta` on a :class:`~repro.graph.csr.
  CSRGraph`): set algebra on the int64 pair keys and a full ``from_edges``
  rebuild — O(m) per batch, kept as the oracle;
* **slotted** (:func:`apply_delta` on a :class:`~repro.graph.slotted.
  SlottedCSR`, or :func:`commit` which adds the compaction schedule):
  in-place slab insert/delete plus overlay append — O(touched rows) per
  batch, the production path (DESIGN.md §17).  The materialized edge set
  is bit-identical to the reference at *every* commit, before and after
  compaction (the property battery in tests/test_slotted.py).

Effective-op semantics are shared: inserts already present and deletes of
absent edges are no-ops, which is what makes canonical batches idempotent.

Sharded rebuild: the per-device :class:`~repro.shard.partition.ShardedCSR`
keeps the *global* vertex index space, and ownership is a pure function of
``(n, num_shards)`` — deltas change edges, never ``n`` — so each committed
batch maps to a **per-owner patch**: only the shards owning a touched row
(plus their ring successors, which replicate that block as a steal halo)
are rewritten; clean shards keep their device buffers untouched
(:func:`reshard` with ``parts=``/``touched_rows=``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..graph.csr import CSRGraph, from_edges
from ..graph.slotted import SlottedCSR
from .deltas import EdgeDelta


@dataclasses.dataclass(frozen=True, eq=False)
class AppliedDelta:
    """A committed batch: the graphs on both sides plus the *effective*
    ops (no-ops filtered out) — what the dirty-seed rules key off.

    On the slotted path ``new_graph`` is a device
    :class:`~repro.graph.slotted.SlottedView`; host rules that need a flat
    ``col_idx`` call :meth:`csr` (materialized lazily, valid until the
    *next* commit mutates the underlying :attr:`slotted` — the driver
    reseeds immediately after each commit, inside that window).
    ``touched_rows`` / ``compacted`` are the commit-cost meters the stream
    records export (O(delta) evidence: touched rows stay far below n/m).
    """

    old_graph: object     # CSRGraph | SlottedView before the batch
    new_graph: object     # CSRGraph | SlottedView after the batch
    ins_src: np.ndarray   # int32 [ki] effective inserts
    ins_dst: np.ndarray
    del_src: np.ndarray   # int32 [kd] effective deletes
    del_dst: np.ndarray
    slotted: SlottedCSR | None = None
    touched_rows: int = 0        # rows rewritten in place (0 = full rebuild)
    compacted: bool = False
    _csr_cache: list = dataclasses.field(default_factory=list, repr=False)

    @property
    def num_effective(self) -> int:
        return int(self.ins_src.size + self.del_src.size)

    def csr(self) -> CSRGraph:
        """Canonical host-facing materialization of ``new_graph``."""
        if self.slotted is None:
            return self.new_graph
        if not self._csr_cache:
            self._csr_cache.append(self.slotted.to_csr())
        return self._csr_cache[0]


def _edge_keys(graph: CSRGraph) -> np.ndarray:
    """Sorted int64 ``src * n + dst`` keys of the CSR's directed edges."""
    n = graph.num_vertices
    rp = np.asarray(graph.row_ptr, dtype=np.int64)
    ci = np.asarray(graph.col_idx, dtype=np.int64)
    src = np.repeat(np.arange(n, dtype=np.int64), np.diff(rp))
    return src * n + ci  # CSR order = sorted by (src, dst) already


def _check_n(graph, delta: EdgeDelta) -> int:
    n = graph.num_vertices
    if delta.num_vertices != n:
        raise ValueError(
            f"delta is for {delta.num_vertices} vertices, graph has {n}")
    return n


def apply_delta(graph, delta: EdgeDelta) -> AppliedDelta:
    """Commit one canonical batch; returns the :class:`AppliedDelta`.

    Dispatches on the representation: a :class:`CSRGraph` takes the O(m)
    reference rebuild, a :class:`SlottedCSR` the O(touched rows) in-place
    path (mutating it; no compaction here — see :func:`commit`).
    """
    if isinstance(graph, SlottedCSR):
        n = _check_n(graph, delta)
        old_view = graph.view()
        ins_s, ins_d, del_s, del_d = graph.apply(
            delta.src, delta.dst, delta.insert)
        return AppliedDelta(
            old_graph=old_view, new_graph=graph.view(),
            ins_src=ins_s, ins_dst=ins_d, del_src=del_s, del_dst=del_d,
            slotted=graph, touched_rows=graph.last_touched)
    n = _check_n(graph, delta)
    old_keys = _edge_keys(graph)
    dkeys = delta.src.astype(np.int64) * n + delta.dst.astype(np.int64)
    ins_keys = dkeys[delta.insert]
    del_keys = dkeys[~delta.insert]
    eff_ins = ins_keys[~np.isin(ins_keys, old_keys)]
    eff_del = del_keys[np.isin(del_keys, old_keys)]
    new_keys = np.union1d(np.setdiff1d(old_keys, eff_del), eff_ins)
    new_graph = from_edges(n, new_keys // n, new_keys % n)
    return AppliedDelta(
        old_graph=graph,
        new_graph=new_graph,
        ins_src=(eff_ins // n).astype(np.int32),
        ins_dst=(eff_ins % n).astype(np.int32),
        del_src=(eff_del // n).astype(np.int32),
        del_dst=(eff_del % n).astype(np.int32),
    )


def commit(slotted: SlottedCSR, delta: EdgeDelta, batch_index: int,
           compact_every: int = 0,
           overlay_slack: float = 0.25) -> AppliedDelta:
    """One full slotted commit: in-place apply + the compaction schedule.

    The compaction decision is a pure function of the delta-log prefix and
    the two knobs (``--compact-every`` / ``--overlay-slack``), so a resumed
    run replaying ``deltas[:b]`` through this same function lands on the
    identical slab layout — what keeps SIGKILL-and-resume bit-exact at the
    representation level, not just the edge-set level.
    """
    applied = apply_delta(slotted, delta)
    slotted.last_compacted = False
    if slotted.should_compact(batch_index, compact_every, overlay_slack):
        slotted.compact()
        slotted.last_compacted = True
        applied = dataclasses.replace(applied, new_graph=slotted.view(),
                                      compacted=True)
    return applied


def replay(graph: CSRGraph, deltas) -> CSRGraph:
    """Fold a delta-log prefix into the graph (deterministic: the resume
    path rebuilds the batch-``b`` graph by replaying ``deltas[:b]``)."""
    for d in deltas:
        graph = apply_delta(graph, d).new_graph
    return graph


def replay_commits(slotted: SlottedCSR, deltas, compact_every: int = 0,
                   overlay_slack: float = 0.25,
                   first_batch: int = 1) -> SlottedCSR:
    """Fold a delta-log prefix through the *slotted* commit path (resume):
    same per-batch :func:`commit` calls, same batch indices, therefore the
    same compaction schedule and final slab layout as the original run."""
    for i, d in enumerate(deltas):
        commit(slotted, d, first_batch + i, compact_every, overlay_slack)
    return slotted


def reshard(graph, num_shards: int, halo: bool = True, *,
            parts=None, touched_rows=None):
    """Owner-aware sharded (re)build of a committed graph.

    Without ``parts`` this is the full ``partition_graph`` build (ownership
    blocks are a function of ``(n, num_shards)`` only, so re-partitioning
    the post-delta graph preserves every row's owner and steal halos).

    With ``parts`` (the previous :class:`~repro.shard.partition.ShardedCSR`)
    and ``touched_rows`` (the rows the commit rewrote) and a
    :class:`SlottedCSR` source, only the **dirty** shards — owners of
    touched rows, plus their ring successors when halos are on (the
    successor replicates the owner's block as its steal halo) — are
    re-extracted and patched into the device stacks; clean shards keep
    their buffers untouched.  If a dirty shard outgrows the stack's edge
    padding, the build falls back to a full restack with monotonically
    grown padding (shapes never shrink, so downstream shard traces are
    reused).
    """
    from ..shard.partition import (block_bounds, block_size,
                                   partition_graph)  # lazy: shard -> runtime

    import jax.numpy as jnp

    if parts is None or touched_rows is None or \
            not isinstance(graph, SlottedCSR):
        source = graph.to_csr() if isinstance(graph, SlottedCSR) else graph
        return partition_graph(source, num_shards, halo=halo)

    touched = np.unique(np.asarray(touched_rows, dtype=np.int64))
    if touched.size == 0:
        return parts
    n = graph.num_vertices
    use_halo = parts.halo
    owners = np.unique(np.clip(touched // block_size(n, num_shards),
                               0, num_shards - 1))
    dirty = set(owners.tolist())
    if use_halo:
        dirty |= {(d + 1) % num_shards for d in owners.tolist()}

    rp = graph.row_ptr64()
    e_pad = int(parts.col_idx.shape[1])
    patches = {}
    owned_edges = list(parts.edges_per_shard)
    for d in sorted(dirty):
        own_lo, own_hi = block_bounds(d, n, num_shards)
        e_lo, e_hi = int(rp[own_lo]), int(rp[own_hi])
        owned_edges[d] = e_hi - e_lo
        lrp = np.zeros(n + 1, dtype=np.int32)
        if use_halo and d > 0:
            pre_lo, _ = block_bounds(d - 1, n, num_shards)
            ep_lo = int(rp[pre_lo])
            lcol = graph.range_cols(pre_lo, own_hi)
            lrp[pre_lo:own_hi + 1] = rp[pre_lo:own_hi + 1] - ep_lo
        elif use_halo:
            pre_lo, pre_hi = block_bounds(num_shards - 1, n, num_shards)
            ep_lo, ep_hi = int(rp[pre_lo]), int(rp[pre_hi])
            lcol = np.concatenate([graph.range_cols(own_lo, own_hi),
                                   graph.range_cols(pre_lo, pre_hi)])
            lrp[own_lo:own_hi + 1] = rp[own_lo:own_hi + 1] - e_lo
            lrp[pre_lo:pre_hi + 1] = (e_hi - e_lo) + (rp[pre_lo:pre_hi + 1]
                                                      - ep_lo)
        else:
            lcol = graph.range_cols(own_lo, own_hi)
            lrp[own_lo:own_hi + 1] = rp[own_lo:own_hi + 1] - e_lo
        if len(lcol) > e_pad:
            # overflow: full restack, padding grown monotonically so the
            # [S, E_pad] operand shapes downstream never shrink
            full = partition_graph(graph.to_csr(), num_shards, halo=halo)
            new_pad = max(e_pad, int(full.col_idx.shape[1]))
            col = jnp.zeros((num_shards, new_pad), jnp.int32)
            col = col.at[:, :full.col_idx.shape[1]].set(full.col_idx)
            return dataclasses.replace(full, col_idx=col)
        patches[d] = (lrp, lcol)

    row_ptr, col_idx = parts.row_ptr, parts.col_idx
    for d, (lrp, lcol) in patches.items():
        row_ptr = row_ptr.at[d].set(jnp.asarray(lrp))
        pad = np.zeros(e_pad, dtype=np.int32)
        pad[:len(lcol)] = lcol
        col_idx = col_idx.at[d].set(jnp.asarray(pad))
    return dataclasses.replace(parts, row_ptr=row_ptr, col_idx=col_idx,
                               edges_per_shard=tuple(owned_edges))
