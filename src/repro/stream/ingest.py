"""Delta ingestion: commit an :class:`EdgeDelta` batch against a CSR graph.

The CSR is the canonical edge set — sorted unique directed ``(src, dst)``
pairs with self-loops dropped (``graph/csr.from_edges``).  Application is
set algebra on the int64 pair keys: effective inserts are the batch's
inserts not already present, effective deletes its deletes that are;
inserting an existing edge or deleting an absent one is a no-op (which is
what makes canonical batches idempotent).  The rebuilt graph goes through
``from_edges`` itself, so a streamed graph is bit-identical to building
the post-delta edge list from scratch — the round-trip property the
hypothesis suite checks against a dense-adjacency oracle.

Sharded rebuild: the per-device :class:`~repro.shard.partition.ShardedCSR`
keeps the *global* vertex index space, and ownership is a pure function of
``(n, num_shards)`` — deltas change edges, never ``n`` — so
:func:`reshard` (= ``partition_graph`` on the committed graph) *is* the
owner-aware rebuild: every row lands on the shard that owned it before the
delta, and the ring-predecessor steal halos are rebuilt from the fresh
edge slices (DESIGN.md §13).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..graph.csr import CSRGraph, from_edges
from .deltas import EdgeDelta


@dataclasses.dataclass(frozen=True, eq=False)
class AppliedDelta:
    """A committed batch: the graphs on both sides plus the *effective*
    ops (no-ops filtered out) — what the dirty-seed rules key off."""

    old_graph: CSRGraph
    new_graph: CSRGraph
    ins_src: np.ndarray   # int32 [ki] effective inserts
    ins_dst: np.ndarray
    del_src: np.ndarray   # int32 [kd] effective deletes
    del_dst: np.ndarray

    @property
    def num_effective(self) -> int:
        return int(self.ins_src.size + self.del_src.size)


def _edge_keys(graph: CSRGraph) -> np.ndarray:
    """Sorted int64 ``src * n + dst`` keys of the CSR's directed edges."""
    n = graph.num_vertices
    rp = np.asarray(graph.row_ptr, dtype=np.int64)
    ci = np.asarray(graph.col_idx, dtype=np.int64)
    src = np.repeat(np.arange(n, dtype=np.int64), np.diff(rp))
    return src * n + ci  # CSR order = sorted by (src, dst) already


def apply_delta(graph: CSRGraph, delta: EdgeDelta) -> AppliedDelta:
    """Commit one canonical batch; returns the :class:`AppliedDelta`."""
    n = graph.num_vertices
    if delta.num_vertices != n:
        raise ValueError(
            f"delta is for {delta.num_vertices} vertices, graph has {n}")
    old_keys = _edge_keys(graph)
    dkeys = delta.src.astype(np.int64) * n + delta.dst.astype(np.int64)
    ins_keys = dkeys[delta.insert]
    del_keys = dkeys[~delta.insert]
    eff_ins = ins_keys[~np.isin(ins_keys, old_keys)]
    eff_del = del_keys[np.isin(del_keys, old_keys)]
    new_keys = np.union1d(np.setdiff1d(old_keys, eff_del), eff_ins)
    new_graph = from_edges(n, new_keys // n, new_keys % n)
    return AppliedDelta(
        old_graph=graph,
        new_graph=new_graph,
        ins_src=(eff_ins // n).astype(np.int32),
        ins_dst=(eff_ins % n).astype(np.int32),
        del_src=(eff_del // n).astype(np.int32),
        del_dst=(eff_del % n).astype(np.int32),
    )


def replay(graph: CSRGraph, deltas) -> CSRGraph:
    """Fold a delta-log prefix into the graph (deterministic: the resume
    path rebuilds the batch-``b`` graph by replaying ``deltas[:b]``)."""
    for d in deltas:
        graph = apply_delta(graph, d).new_graph
    return graph


def reshard(graph: CSRGraph, num_shards: int, halo: bool = True):
    """Owner-aware sharded rebuild of a committed graph.

    Thin, named front door over ``partition_graph``: ownership blocks are a
    function of ``(n, num_shards)`` only, so re-partitioning the post-delta
    graph preserves every row's owner and rebuilds the steal halos — the
    invariant the streaming sharded drain relies on.
    """
    from ..shard.partition import partition_graph  # lazy: shard -> runtime

    return partition_graph(graph, num_shards, halo=halo)
