"""Streaming-graph subsystem: delta ingestion, incremental recompute, and
crash-consistent mid-drain checkpoint/resume (DESIGN.md §13).

Front doors: :func:`repro.runtime.stream_execute` (programmatic),
``launch/taskserver --stream`` (CLI), ``server/jobs.JobSpec(stream=...)``
(multi-tenant).  The pieces:

  * :mod:`deltas`      — canonical edge-delta batches (validate + dedup)
  * :mod:`ingest`      — commit a batch against the CSR / sharded CSR
  * :mod:`incremental` — per-algorithm dirty-seed rules
  * :mod:`snapshot`    — crash-consistent mid-drain snapshots
  * :mod:`driver`      — the batch-by-batch streaming drain loop
"""
from .deltas import EdgeDelta, make_delta, symmetrized
from .driver import (BatchRecord, StreamResult, StreamSpec, run_stream)
from .incremental import reseed
from .ingest import (AppliedDelta, apply_delta, commit, replay,
                     replay_commits, reshard)
from .snapshot import SnapshotManager, graph_fingerprint

__all__ = [
    "EdgeDelta", "make_delta", "symmetrized",
    "AppliedDelta", "apply_delta", "commit", "replay", "replay_commits",
    "reshard",
    "reseed",
    "SnapshotManager", "graph_fingerprint",
    "BatchRecord", "StreamResult", "StreamSpec", "run_stream",
]
