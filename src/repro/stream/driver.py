"""The streaming drain driver: delta batches x incremental recompute x
crash-consistent snapshots (DESIGN.md §13).

``run_stream`` turns any registered :class:`~repro.runtime.program.
AtosProgram` into a long-running job over a mutating graph.  The timeline
is a sequence of **batches**: batch 0 drains the base graph from
``program.init()``; each batch ``b >= 1`` commits ``deltas[b-1]`` against
the current CSR (``stream/ingest``), re-seeds via the program's
``dirty_seeds`` rule (``stream/incremental``; or the conservative full
reseed), rebuilds the program on the new graph — its body closes over the
CSR — and drains again under whatever execution policy the config
resolves to.  The per-batch drains reuse the existing engines unchanged:
``runtime/api._shared_setup`` for the single/fused topologies,
``shard.run_sharded`` for the device mesh.

Snapshots segment a drain at round boundaries: rounds and processed
counts live *in the carry*, so a segmented drain takes exactly the same
steps as an unsegmented one, and a resumed run — replay the delta log,
rebuild the program, restore the carry, keep the same segment schedule —
is bit-identical to the uninterrupted run (tests/test_checkpoint_fault.py
proves this under SIGKILL).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.queue import make_multiqueue, make_queue
from ..core.scheduler import (SchedulerConfig, megakernel_drive,
                              megakernel_segment, persistent_drive)
from ..graph.slotted import SlottedCSR
from ..obs import Trace
from ..runtime.api import _shared_setup, instrument_step, \
    shared_queue_capacity
from ..runtime.policy import policy_of
from ..runtime.programs import build_program
from .deltas import EdgeDelta
from .incremental import reseed
from .ingest import commit, replay_commits, reshard
from .snapshot import SnapshotManager


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """Streaming attachment for a server job (``server/jobs.JobSpec``)."""

    deltas: Tuple[EdgeDelta, ...]
    incremental: bool = True
    snapshot_every: int = 0
    checkpoint_dir: Optional[str] = None
    resume: bool = False
    compact_every: int = 0        # 0 = occupancy/slack triggers only
    overlay_slack: float = 0.25   # compact when overlay > slack * m

    def __post_init__(self):
        object.__setattr__(self, "deltas", tuple(self.deltas))
        if self.snapshot_every < 0:
            raise ValueError("snapshot_every must be >= 0")
        if ((self.snapshot_every > 0 or self.resume)
                and not self.checkpoint_dir):
            raise ValueError(
                "snapshot_every/resume require a checkpoint_dir")
        if self.compact_every < 0:
            raise ValueError("compact_every must be >= 0")
        if not self.overlay_slack > 0:
            raise ValueError("overlay_slack must be > 0")


@dataclasses.dataclass
class BatchRecord:
    """Per-batch outcome (work/rounds are schedule-deterministic)."""

    batch: int
    incremental: bool     # did a dirty-seed rule produce the seeds?
    seeds: int            # seed tasks enqueued for this batch's drain
    effective_ops: int    # delta ops that actually changed the edge set
    rounds: int
    processed: int
    work: int             # program work-counter delta over this batch
    splits: int
    dropped: int
    touched_rows: int = 0     # slab rows rewritten by this batch's commit
    overlay: int = 0          # overlay occupancy after the commit
    compacted: bool = False   # did this commit trigger a compaction?
    commit_seconds: float = 0.0   # apply(+compaction) wall time


@dataclasses.dataclass
class StreamResult:
    state: Any            # final program state (last batch's graph)
    result: Any           # program.result(state)
    batches: List[BatchRecord]
    info: dict

    def as_dict(self) -> dict:
        """Serialize into the canonical ``stream`` doc (obs/schema)."""
        from ..obs.schema import metric_doc  # lazy: obs is a leaf layer

        return metric_doc(
            "stream",
            **{k: v for k, v in self.info.items() if v is not None})


def _drive_shared(step, cond, carry, kernel: str, every: int, cb):
    """Drive a single/fused carry to its fixed point, calling ``cb(carry)``
    at every ``every``-th round (0 = never).  Rounds live in ``carry[2]``,
    so the boundaries are absolute round numbers — a resumed drain lands on
    the same boundaries the uninterrupted one did.  ``kernel`` is the
    resolved strategy name (``policy.kernel``); a segmented megakernel
    drain bakes the same ``rounds < limit`` term into its in-kernel loop
    condition, so it snapshots at the identical boundaries."""
    if kernel == "megakernel":
        if every <= 0:
            return megakernel_drive(step, cond, carry)
        # build the fused segment ONCE: the round limit rides as a kernel
        # operand, so every snapshot window reuses the same traced jaxpr /
        # pallas_call instead of retracing the whole drain per segment
        seg = megakernel_segment(step, cond, carry)
        keep_going = jax.jit(cond)
        while bool(keep_going(carry)):
            carry = seg(carry, jnp.int32(int(carry[2]) + every))
            cb(carry)
        return carry
    if kernel == "persistent":
        if every <= 0:
            return persistent_drive(step, cond, carry)
        seg = jax.jit(lambda c, limit: jax.lax.while_loop(
            lambda cc: cond(cc) & (cc[2] < limit), step, c))
        keep_going = jax.jit(cond)
        while bool(keep_going(carry)):
            carry = seg(carry, jnp.int32(int(carry[2]) + every))
            cb(carry)
        return carry
    round_jit = jax.jit(step)
    while bool(cond(carry)):
        carry = round_jit(carry)
        if every > 0 and int(carry[2]) % every == 0:
            cb(carry)
    return carry


def _drive_sharded(program, graph, cfg: SchedulerConfig, capacity: int,
                   mq, state, rounds: int, processed: int, every: int, cb,
                   route_width, mesh, trace=None, trace_engine=None,
                   trace_round_offset: int = 0, parts=None):
    """Segmented sharded drain: each segment is one ``run_sharded`` call
    with its round budget clamped to the next snapshot boundary.  The
    host-side continuation replicates the in-loop ``keep_going`` exactly
    (queue mass for ``empty_means_done`` programs, then ``stop``)."""
    from .. import shard as _shard
    from ..shard.driver import _queue_sizes

    extra = {"exchanged": 0, "donated": 0, "steal_rounds": 0,
             "mis_routed": 0, "route_dropped": 0}

    def more() -> bool:
        if rounds >= cfg.max_rounds:
            return False
        if program.empty_means_done and \
                int(np.asarray(_queue_sizes(mq)).sum()) == 0:
            return False
        if program.stop is not None and bool(program.stop(state)):
            return False
        return True

    while more():
        budget = cfg.max_rounds - rounds
        if every > 0:
            at_boundary = rounds % every
            budget = min(budget, every - at_boundary if at_boundary else every)
        scfg = dataclasses.replace(cfg, max_rounds=budget)
        fq: list = []
        state, st = _shard.run_sharded(
            program, graph, scfg, queue_capacity=capacity,
            route_width=route_width, mesh=mesh, trace=trace,
            trace_engine=trace_engine,
            trace_round_offset=trace_round_offset + rounds,
            initial_queues=mq, initial_state=state, final_queues=fq,
            parts=parts)
        mq = fq[0]
        rounds += st.rounds
        processed += st.items_processed
        extra["exchanged"] += st.exchanged
        extra["donated"] += st.donated
        extra["steal_rounds"] += st.steal_rounds
        extra["mis_routed"] += st.mis_routed
        extra["route_dropped"] += st.route_dropped
        if every > 0:
            cb(mq, state, rounds, processed)
        if st.rounds == 0:  # defensive: never spin on a no-progress segment
            break
    dropped = int(np.asarray(mq.lanes.dropped).sum()) + extra["route_dropped"]
    return mq, state, rounds, processed, dropped, extra


def run_stream(
    algorithm: str,
    graph,
    deltas,
    cfg: SchedulerConfig,
    *,
    params: Optional[dict] = None,
    queue_capacity: Optional[int] = None,
    incremental: bool = True,
    snapshot_every: int = 0,
    checkpoint_dir: Optional[str] = None,
    keep: int = 3,
    resume: bool = False,
    route_width: Optional[int] = None,
    mesh=None,
    snapshot_hook=None,
    trace: Optional[Trace] = None,
    trace_engine: Optional[str] = None,
    compact_every: int = 0,
    overlay_slack: float = 0.25,
) -> StreamResult:
    """Run ``algorithm`` over ``graph`` + a delta log, batch by batch.

    See :func:`repro.runtime.api.stream_execute` (the front door) for the
    argument contract.  ``snapshot_hook(tick, batch)``, if given, fires
    after every committed snapshot — the fault-injection tests kill the
    process inside it.  On resume, records for batches that completed
    before the restored snapshot are not re-synthesized; the final state
    and result are nevertheless bit-identical to an uninterrupted run.

    ``trace`` (an :class:`~repro.obs.Trace`) threads a fresh device ring
    through every batch's drain — snapshots never see it (the save hooks
    receive only queue + state), so segmented and resumed runs stay
    bit-identical — draining each batch under ``trace_engine`` with
    absolute (cross-batch) round numbers, and registers the canonical
    ``stream`` summary doc at the end.
    """
    policy = policy_of(cfg)
    deltas = list(deltas)
    params = dict(params or {})
    total = len(deltas) + 1
    snap = SnapshotManager(checkpoint_dir, keep=keep) if checkpoint_dir \
        else None
    if (snapshot_every > 0 or resume) and snap is None:
        raise ValueError("snapshot_every/resume require a checkpoint_dir")

    tick = 0
    start_batch = 0
    resume_tick = None
    if resume:
        resume_tick = snap.latest()
        if resume_tick is not None:
            start_batch = snap.peek(resume_tick)["batch"]
            tick = resume_tick + 1
    resumed = resume_tick is not None

    # ONE slotted CSR lives across the whole stream (graph/slotted.py):
    # batch commits mutate it in place, O(touched rows) instead of the old
    # per-batch from_edges rebuild.  Resume replays the committed prefix
    # through the SAME commit path — identical compaction schedule, hence
    # identical slab layout and snapshot fingerprints (the deltas and the
    # knobs fully determine both).
    slotted = SlottedCSR.from_csr(graph)
    if start_batch:
        replay_commits(slotted, deltas[:start_batch], compact_every,
                       overlay_slack)
    cur_graph = slotted.view()
    parts = None  # sharded: long-lived partition, patched per owner below
    state = None
    records: List[BatchRecord] = []
    totals = {"rounds": 0, "processed": 0, "work": 0, "dropped": 0}
    program = None

    for b in range(start_batch, total):
        restoring = resumed and b == start_batch
        applied = None
        commit_s = 0.0
        if b > 0 and not restoring:
            t_commit = time.perf_counter()
            applied = commit(slotted, deltas[b - 1], b, compact_every,
                             overlay_slack)
            commit_s = time.perf_counter() - t_commit
            cur_graph = applied.new_graph
        # the body closes over the adjacency view, so the program is
        # rebuilt per batch (fresh chunk codec, budgets, and dirty-seed
        # closure for the committed graph)
        program = build_program(algorithm, cur_graph, cfg,
                                params=dict(params),
                                queue_capacity=queue_capacity)
        was_incremental = bool(b > 0 and incremental
                               and program.dirty_seeds is not None)
        n = cur_graph.num_vertices
        sharded = policy.topology == "sharded"
        if sharded:
            # owner-aware patch: only shards owning an effectively changed
            # row (plus their halo successors) are rewritten; batch 0 (or a
            # fresh resume) pays the one full build
            t_commit = time.perf_counter()
            halo = cfg.steal_threshold > 0
            if parts is None:
                parts = reshard(slotted, cfg.num_shards, halo=halo)
            elif applied is not None:
                parts = reshard(
                    slotted, cfg.num_shards, halo=halo, parts=parts,
                    touched_rows=np.concatenate([applied.ins_src,
                                                 applied.del_src]))
            commit_s += time.perf_counter() - t_commit
        capacity = (queue_capacity or max(4 * n, 1024)) if sharded else \
            shared_queue_capacity(program, queue_capacity)

        restored = None
        if restoring:
            state_template, _ = program.init()
            if sharded:
                from ..shard.driver import seed_queues
                q_template = seed_queues(program, jnp.zeros((0,), jnp.int32),
                                         n, cfg.num_shards, capacity)
            elif policy.topology == "single":
                q_template = make_queue(capacity)
            else:
                q_template = make_multiqueue(capacity, 1)
            tree = snap.restore(resume_tick, queue_template=q_template,
                                state_template=state_template,
                                graph=cur_graph, num_deltas=b)
            cur = {k: int(v) for k, v in tree["cursor"].items()}
            restored = (tree["queue"], cur["rounds"], cur["processed"])
            state = tree["state"]
            seeds = jnp.zeros((0,), jnp.int32)
            seeds_count, eff = cur["seeds"], cur["eff"]
            pre_work, pre_splits = cur["pre_work"], cur["pre_splits"]
        else:
            if b == 0:
                state, seeds = program.init()
                eff = 0
            else:
                state, seeds = reseed(program, applied, state,
                                      incremental=incremental)
                eff = applied.num_effective
            seeds = jnp.asarray(seeds, jnp.int32)
            seeds_count = int(seeds.shape[0])
            pre_work = program.work_of(state)
            pre_splits = program.splits_of(state)

        def save_snapshot(queue_tree, st, r, p):
            nonlocal tick
            snap.save(tick, cursor={
                "batch": b, "rounds": r, "processed": p,
                "pre_work": pre_work, "pre_splits": pre_splits,
                "seeds": seeds_count, "eff": eff,
            }, graph=cur_graph, num_deltas=b, queue=queue_tree, state=st)
            t, tick = tick, tick + 1
            if snapshot_hook is not None:
                snapshot_hook(t, b)

        every = snapshot_every if snap is not None else 0
        engine = trace_engine or f"stream.{algorithm}"
        # cross-batch round offset: batches tile one absolute timeline in
        # the exported trace (in-batch rounds restart at r0 per batch)
        batch_offset = totals["rounds"]
        if not sharded:
            init_arg = (state, seeds)
            queue_in = restored[0] if restored is not None else None
            queue, state0, ops, step, cond, dropped_of = _shared_setup(
                program, cur_graph, cfg, policy, queue_capacity,
                init=init_arg, queue=queue_in)
            r0 = restored[1] if restored is not None else 0
            p0 = restored[2] if restored is not None else 0
            carry = (queue, state0, jnp.int32(r0), jnp.int32(p0))
            if trace is not None:
                # fresh ring per batch, riding LAST in the carry — the
                # snapshot hooks below only ever see c[0]/c[1], so the
                # ring never reaches a checkpoint
                step, cond = instrument_step(step, cond, ops, program)
                carry = carry + (trace.ring(),)
            if snap is not None and restored is None:
                save_snapshot(carry[0], carry[1], 0, 0)
            cb = (lambda c: save_snapshot(c[0], c[1], int(c[2]), int(c[3])))
            carry = _drive_shared(step, cond, carry, policy.kernel,
                                  every, cb)
            queue, state, rounds_a, processed_a = carry[:4]
            if trace is not None:
                trace.drain(carry[4], engine=engine,
                            round_offset=batch_offset - r0)
            rounds, processed = int(rounds_a), int(processed_a)
            dropped = int(dropped_of(queue))
            extra = {}
        else:
            from ..shard.driver import seed_queues
            if restored is None:
                mq = seed_queues(program, seeds, n, cfg.num_shards, capacity)
                r0 = p0 = 0
            else:
                mq, r0, p0 = restored
            if snap is not None and restored is None:
                save_snapshot(mq, state, 0, 0)
            _, state, rounds, processed, dropped, extra = _drive_sharded(
                program, cur_graph, cfg, capacity, mq, state, r0, p0, every,
                lambda q, st, r, p: save_snapshot(q, st, r, p),
                route_width, mesh, trace=trace, trace_engine=engine,
                trace_round_offset=batch_offset - r0, parts=parts)

        records.append(BatchRecord(
            batch=b, incremental=was_incremental, seeds=seeds_count,
            effective_ops=eff, rounds=rounds, processed=processed,
            work=program.work_of(state) - pre_work,
            splits=program.splits_of(state) - pre_splits,
            dropped=dropped,
            # a restoring batch's commit happened inside replay_commits —
            # the slotted counters still hold exactly that batch's numbers
            touched_rows=(applied.touched_rows if applied is not None
                          else (slotted.last_touched if b > 0 else 0)),
            overlay=slotted.overlay_size,
            compacted=(applied.compacted if applied is not None
                       else (slotted.last_compacted if b > 0 else False)),
            commit_seconds=commit_s,
        ))
        totals["rounds"] += rounds
        totals["processed"] += processed
        totals["work"] += records[-1].work
        totals["dropped"] += dropped
        for k, v in extra.items():
            totals[k] = totals.get(k, 0) + v

    if snap is not None:
        snap.wait()
    info = dict(totals)
    info.update({
        "batches": total,
        "batches_run": total - start_batch,
        "resumed_at": start_batch if resumed else None,
        "incremental": incremental,
        "topology": policy.topology,
        # commit-cost meters (cumulative over the whole delta log,
        # including any resume-replayed prefix — same totals as an
        # uninterrupted run)
        "touched_rows": slotted.touched_rows,
        "compactions": slotted.compactions,
        "commit_seconds": round(sum(r.commit_seconds for r in records), 6),
    })
    out = StreamResult(state=state, result=program.result(state),
                       batches=records, info=info)
    if trace is not None:
        trace.add_metric(out.as_dict())
    return out
