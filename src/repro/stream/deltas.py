"""Edge deltas — the streaming subsystem's wire format (DESIGN.md §13).

A delta batch is a set of directed edge operations against a CSR graph:
``(src, dst, insert)`` triples where ``insert=True`` adds the edge and
``False`` removes it.  :func:`make_delta` is the validating front door: it
rejects out-of-range endpoints and self-loops (the CSR builder drops
self-loops, so accepting one here would silently do nothing) and
canonicalizes the batch — **last-wins de-duplication** per directed pair,
then a sort by ``(src, dst)`` — so a batch is a *function* from edge to
final operation.  Canonical batches make delta application idempotent
(applying a batch twice equals once) and order-insensitive within the
batch, the two properties the hypothesis suite pins down.

The repo's generators emit symmetric graphs; symmetric *deltas* are the
caller's contract (``graph/generators.edge_delta_stream`` emits both
directions of every pair).  Nothing here requires symmetry — directed
streams are legal — but the per-algorithm dirty-seed rules inherit the
base algorithms' assumptions about the graphs they run on.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True, eq=False)
class EdgeDelta:
    """One canonical batch of directed edge inserts/deletes.

    Arrays are host numpy (deltas are ingested host-side, like CSR
    construction); ``insert[i]`` tells whether ``(src[i], dst[i])`` is added
    or removed.  Construct via :func:`make_delta` — the constructor itself
    performs no validation.
    """

    num_vertices: int
    src: np.ndarray      # int32 [k]
    dst: np.ndarray      # int32 [k]
    insert: np.ndarray   # bool  [k]

    @property
    def num_ops(self) -> int:
        return int(self.src.shape[0])

    @property
    def num_inserts(self) -> int:
        return int(np.count_nonzero(self.insert))

    @property
    def num_deletes(self) -> int:
        return self.num_ops - self.num_inserts


def make_delta(num_vertices: int, src, dst, insert) -> EdgeDelta:
    """Validate + canonicalize a raw op list into an :class:`EdgeDelta`.

    Canonical form: at most one op per directed ``(src, dst)`` pair — the
    *last* occurrence in the input wins (a stream that inserts then deletes
    the same edge within a batch nets to a delete) — sorted by ``(src,
    dst)``.  Raises ``ValueError`` on shape mismatch, out-of-range
    endpoints, or self-loops.
    """
    n = int(num_vertices)
    if n <= 0:
        raise ValueError(f"num_vertices must be positive, got {n}")
    src = np.asarray(src, dtype=np.int64).ravel()
    dst = np.asarray(dst, dtype=np.int64).ravel()
    ins = np.asarray(insert, dtype=bool).ravel()
    if not (src.shape == dst.shape == ins.shape):
        raise ValueError(
            f"delta arrays disagree: src {src.shape}, dst {dst.shape}, "
            f"insert {ins.shape}")
    if src.size:
        if src.min() < 0 or src.max() >= n or dst.min() < 0 or dst.max() >= n:
            raise ValueError(
                f"delta endpoint out of range for {n} vertices")
        loops = src == dst
        if loops.any():
            v = int(src[loops][0])
            raise ValueError(
                f"delta contains self-loop ({v}, {v}); the CSR builder "
                f"drops self-loops, so the op would be a silent no-op")
    # last-wins dedup: unique over the reversed key stream keeps, for each
    # directed pair, the index of its last occurrence in the original order;
    # np.unique aligns those indices to ascending key order, which IS the
    # canonical (src, dst) sort.
    key = src * n + dst
    _, rev_idx = np.unique(key[::-1], return_index=True)
    idx = src.size - 1 - rev_idx
    return EdgeDelta(
        num_vertices=n,
        src=src[idx].astype(np.int32),
        dst=dst[idx].astype(np.int32),
        insert=ins[idx],
    )


def symmetrized(delta: EdgeDelta) -> EdgeDelta:
    """Mirror every op: the undirected-stream helper (both directions get
    the same operation; re-canonicalized, so duplicates collapse)."""
    return make_delta(
        delta.num_vertices,
        np.concatenate([delta.src, delta.dst]),
        np.concatenate([delta.dst, delta.src]),
        np.concatenate([delta.insert, delta.insert]),
    )
