"""Fairness policies: how one wavefront's budget is split across job lanes.

Each scheduling round the server has a budget of ``W = num_workers x
fetch_size`` pop slots (one Atos wavefront).  A policy turns the observed
per-lane queue sizes into per-lane *quotas* summing to at most W:

  * ``round_robin``        — the whole wavefront goes to the next non-empty
    lane in rotation: Atos's ``num_queues`` behaviour, one tenant per round.
  * ``weighted``           — weighted max-min fair sharing (water-filling):
    every non-empty lane gets a share proportional to its job weight, and
    budget a lane cannot use (small frontier) spills to hungrier lanes.
    This is the policy that *fuses* tenants into one wavefront and converts
    the paper's small-frontier underutilization into cross-job occupancy.
  * ``longest_queue_first` — the whole wavefront to the fullest lane; drains
    hot tenants first (throughput-greedy, latency-unfair).

Backpressure hook: lanes flagged ``boosted`` (their ``dropped`` counter grew
last round, i.e. pushes overflowed) are served before any policy logic, with
as much budget as they can use — draining is the only action that relieves a
full ring buffer (DESIGN.md section 8).

Policies are host-side (NumPy): quota selection is scheduling control flow,
which in the discrete-kernel regime lives between device dispatches exactly
like Atos's host-side launch loop.
"""
from __future__ import annotations

import numpy as np


class FairnessPolicy:
    """Base: pre-serves backpressured lanes, then delegates to ``_allocate``."""

    name = "base"

    def allocate(self, sizes, weights, boosted, wavefront: int) -> np.ndarray:
        sizes = np.asarray(sizes, dtype=np.int64)
        weights = np.asarray(weights, dtype=np.float64)
        boosted = np.asarray(boosted, dtype=bool)
        quotas = np.zeros_like(sizes)
        budget = int(wavefront)
        # drain-boost: backpressured lanes are served first, up to demand
        for lane in np.flatnonzero(boosted & (sizes > 0)):
            give = min(int(sizes[lane]), budget)
            quotas[lane] = give
            budget -= give
            if budget == 0:
                return quotas
        rest = self._allocate(sizes - quotas, weights, budget)
        return quotas + rest

    def _allocate(self, sizes, weights, budget: int) -> np.ndarray:
        raise NotImplementedError


class RoundRobin(FairnessPolicy):
    """Whole budget to the next non-empty lane in rotation (Atos classic)."""

    name = "round_robin"

    def __init__(self) -> None:
        self.cursor = 0

    def _allocate(self, sizes, weights, budget):
        quotas = np.zeros_like(sizes)
        num_lanes = len(sizes)
        if budget <= 0 or num_lanes == 0:
            return quotas
        for off in range(num_lanes):
            lane = (self.cursor + off) % num_lanes
            if sizes[lane] > 0:
                quotas[lane] = min(int(sizes[lane]), budget)
                self.cursor = (lane + 1) % num_lanes
                break
        return quotas


class WeightedShare(FairnessPolicy):
    """Weighted max-min fairness via integer water-filling.

    The in-order distribution is rotated by one lane per round: when the
    budget is smaller than the number of hungry lanes, truncation otherwise
    always hits the same high-index lanes (unbounded starvation).
    """

    name = "weighted"

    def __init__(self) -> None:
        self.rotation = 0

    def _allocate(self, sizes, weights, budget):
        quotas = np.zeros_like(sizes)
        demand = sizes.copy()
        rotation, self.rotation = self.rotation, self.rotation + 1
        while budget > 0:
            hungry = np.flatnonzero(demand > 0)
            if len(hungry) == 0:
                break
            hungry = np.roll(hungry, -(rotation % len(hungry)))
            w = weights[hungry]
            w = w / w.sum() if w.sum() > 0 else np.full(len(hungry),
                                                        1.0 / len(hungry))
            # proportional shares, at least 1 slot each while budget lasts
            shares = np.maximum(1, np.floor(budget * w)).astype(np.int64)
            gave = 0
            for lane, share in zip(hungry, shares):
                give = min(int(share), int(demand[lane]), budget - gave)
                quotas[lane] += give
                demand[lane] -= give
                gave += give
                if gave == budget:
                    break
            if gave == 0:
                break
            budget -= gave
        return quotas


class LongestQueueFirst(FairnessPolicy):
    """Whole budget to the fullest lane (throughput-greedy)."""

    name = "longest_queue_first"

    def _allocate(self, sizes, weights, budget):
        quotas = np.zeros_like(sizes)
        if budget <= 0 or len(sizes) == 0 or sizes.max(initial=0) <= 0:
            return quotas
        lane = int(np.argmax(sizes))
        quotas[lane] = min(int(sizes[lane]), budget)
        return quotas


_POLICIES = {
    "round_robin": RoundRobin,
    "weighted": WeightedShare,
    "longest_queue_first": LongestQueueFirst,
}


def make_policy(name: str) -> FairnessPolicy:
    if name not in _POLICIES:
        raise ValueError(f"unknown policy {name!r}; "
                         f"expected one of {sorted(_POLICIES)}")
    return _POLICIES[name]()
