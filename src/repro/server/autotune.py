"""Scheduler configuration autotuner — the paper's selection guidelines, live.

Atos section 7 distills when each launch configuration wins: persistent
kernels when frontiers are small (launch fixed cost dominates), discrete
when rounds are few and fat; more workers / larger FETCH_SIZE for
heavy-tailed frontiers, narrow wavefronts for meshes.  Instead of shipping
those guidelines as prose, the autotuner *measures* a small candidate grid
over ``SchedulerConfig = (persistent, num_workers, fetch_size, backend,
topology, granularity)`` on a calibration workload and caches the winner
per ``(algorithm, graph_class)`` (DESIGN.md section 8).

The fourth axis, ``backend`` (DESIGN.md section 9), selects the kernel
implementation — jnp reference vs the Pallas TPU kernels
(``kernels/frontier_expand`` LBS + ``kernels/queue_compact`` push).  Results
are bit-identical across backends, so the tuner may pick freely on wall time
alone: on TPU the Pallas candidates compile to Mosaic and typically win; on
CPU they run in interpret mode and lose honestly.  The chosen backend is
persisted in the JSON cache like every other axis.

The fifth axis, ``topology`` (DESIGN.md section 11), is the execution-
policy dimension of the runtime layer: the same AtosProgram drains through
a plain TaskQueue (``single``) or a packed MultiQueue lane (``fused``) with
bit-identical results, so — like the backend — the tuner may pick freely on
wall time.  ``sharded`` is excluded from the default grid (it needs a
device mesh and competes on capacity, not calibration wall time) but tuned
caches that record it parse fine.

The kernel-strategy axis gained a third value in DESIGN.md section 14:
``megakernel`` candidates (``MEGAKERNEL_GRID``) fuse the whole drain into
one Pallas launch.  Results stay bit-identical, so the tuner again picks
on wall time — on TPU the fused loop removes every per-round kernel entry;
on CPU it pays the Pallas interpreter and loses honestly, exactly like the
``pallas`` backend candidates.

The sixth axis, ``granularity`` (DESIGN.md section 12), is the paper's
task-parallel granularity control: the maximum chunk width a queue slot
carries (core/task.py).  Results are preserved at every width (exact for
BFS/coloring, eps-converged for PageRank), so the tuner again picks on
wall time — coarse chunks tend to win on mesh-like graphs (fewer rounds,
uniform degree-sums) and fine chunks on scale-free ones (hub-bearing
chunks fight the load-balancing budget); the measured grid turns that
guideline into a cached decision.

Graph class is the paper's two-regime split: ``scale_free`` (heavy-tailed
degrees, low diameter) vs ``mesh`` (bounded degree, high diameter), decided
from degree statistics so one tuned decision covers every graph of the same
shape.  The default config is always in the candidate set, so the chosen
config is never slower than the default *on the calibration measurements*.
Decisions are cached to JSON (survives processes) and logged.

Since DESIGN.md section 16 the default search is **successive halving
seeded by a graph-statistics cost model** rather than the exhaustive grid:
:func:`graph_stats` distills the calibration graph into a handful of
features (degree CV, a hub-clipped frontier-growth estimate, a diameter
proxy), :func:`predict_cost` turns the paper's selection guidelines into a
closed-form relative-cost score per candidate, and ``tune`` measures only
the predicted-cheapest ``max(2, N // 4)`` cells (the default config always
force-included), halving the survivor set between measurement rounds.  The
exhaustive behaviour is preserved behind ``search="grid"``.  Cache entries
carry ``schema = AUTOTUNE_SCHEMA`` plus the cost-model provenance; entries
written by older schema-less runs keep loading unchanged.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import logging
import math
import os
import statistics
import tempfile
import time
import zlib
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..core.scheduler import SchedulerConfig
from ..graph.csr import CSRGraph
from ..runtime.policy import policy_of

log = logging.getLogger("repro.server.autotune")

#: curated launch shapes: both kernel strategies, narrow->wide wavefronts.
#: The plain ``SchedulerConfig()`` default is first — it must always be
#: measured.
_BASE_GRID: Tuple[SchedulerConfig, ...] = (
    SchedulerConfig(),                                       # the default
    SchedulerConfig(num_workers=16, fetch_size=1),
    SchedulerConfig(num_workers=64, fetch_size=4),
    SchedulerConfig(num_workers=256, fetch_size=1),
    SchedulerConfig(num_workers=16, fetch_size=1, persistent=False),
    SchedulerConfig(num_workers=64, fetch_size=1, persistent=False),
)

#: the searched backends — the resolved axis values only ("auto" would just
#: alias one of them and waste calibration runs).
BACKEND_GRID: Tuple[str, ...] = ("jnp", "pallas")

#: the searched execution topologies (DESIGN.md section 11).  ``sharded``
#: is deliberately absent: it needs a device mesh the calibration host may
#: not have, and its win condition is capacity, not wall time.
TOPOLOGY_GRID: Tuple[str, ...] = ("single", "fused")

#: the searched task granularities (DESIGN.md section 12) — the sixth grid
#: axis.  Chunk width is a results-preserving scheduling knob (BFS and
#: coloring are exact at every G, PageRank converges to the same eps), so
#: like backend and topology the tuner picks on wall time alone; the grid
#: stays small because each extra width multiplies the calibration budget.
GRANULARITY_GRID: Tuple[int, ...] = (1, 4)

#: full candidate grid: every launch shape crossed with every backend,
#: topology, and granularity.  The granularity-1 single-topology jnp block
#: keeps ``topology="auto"`` (which resolves to ``single`` off-mesh) and
#: comes first so ``DEFAULT_CANDIDATES[0] == SchedulerConfig()``.
#: the megakernel kernel strategy (DESIGN.md section 14) joins the search
#: as a small dedicated block rather than a full cross: inside the fused
#: drain the expansion always DMA-streams CSR slices and the queue ops run
#: on the jnp reference, so crossing it with the ``backend`` axis would
#: only duplicate cells.  ``persistent=True`` is the documented mirror for
#: code that reads the legacy bool.
MEGAKERNEL_GRID: Tuple[SchedulerConfig, ...] = tuple(
    SchedulerConfig(num_workers=w, kernel="megakernel",
                    topology="auto" if t == "single" else t, granularity=g)
    for g in GRANULARITY_GRID
    for t in TOPOLOGY_GRID
    for w in (16, 64)
)

DEFAULT_CANDIDATES: Tuple[SchedulerConfig, ...] = tuple(
    dataclasses.replace(c, backend=b,
                        topology="auto" if t == "single" else t,
                        granularity=g)
    for g in GRANULARITY_GRID
    for t in TOPOLOGY_GRID
    for b in BACKEND_GRID
    for c in _BASE_GRID
) + MEGAKERNEL_GRID


def graph_class(graph: CSRGraph) -> str:
    """Two-regime split from degree statistics (paper's dataset taxonomy)."""
    deg = graph.degrees()
    max_deg = float(jnp.max(deg))
    avg_deg = float(jnp.mean(deg))
    return "scale_free" if max_deg >= 4.0 * avg_deg + 8.0 else "mesh"


#: cache schema: 1 = pre-cost-model grid entries (no "schema" field — those
#: still parse), 2 = adds search/cells_total/cells_measured/cost_model.
AUTOTUNE_SCHEMA = 2


@dataclasses.dataclass(frozen=True)
class GraphStats:
    """Degree-derived features the cost model sees (DESIGN.md section 16).

    ``frontier_growth`` is a hub-clipped branching-factor estimate: the mean
    degree after clipping at the 90th percentile, because a hub's edges fan
    out once — they do not multiply the frontier round after round the way
    the raw mean would suggest.  ``diameter_proxy`` is the expected number
    of drain rounds: ``log(n)/log(branching)`` in the scale-free regime
    (CV >= 1), ``sqrt(n)`` in the bounded-degree mesh regime.
    """

    num_vertices: int
    num_edges: int
    avg_degree: float
    degree_cv: float
    frontier_growth: float
    diameter_proxy: float


def graph_stats(graph: CSRGraph) -> GraphStats:
    """Distill one calibration graph into the cost model's features."""
    deg = jnp.asarray(graph.degrees(), jnp.float32)
    n = int(graph.num_vertices)
    avg = float(jnp.mean(deg))
    cv = float(jnp.std(deg)) / max(avg, 1e-9)
    clip = float(jnp.quantile(deg, 0.9))
    growth = float(jnp.mean(jnp.minimum(deg, clip)))
    if cv >= 1.0:
        diam = math.log(max(n, 2)) / math.log(max(growth, 2.0))
    else:
        diam = math.sqrt(max(n, 1))
    return GraphStats(num_vertices=n, num_edges=int(graph.num_edges),
                      avg_degree=avg, degree_cv=cv,
                      frontier_growth=max(growth, 1.0),
                      diameter_proxy=max(diam, 1.0))


#: per-round fixed costs, arbitrary units: a discrete drain re-enters a
#: kernel every round, a persistent drain pays only the in-loop collective,
#: the megakernel amortizes even that into one launch.
_ROUND_COST = {"discrete": 8.0, "persistent": 1.0, "megakernel": 0.25}


#: per-round latency charge per launched lane: a wider kernel is a slower
#: kernel even when most lanes carry EMPTY masks.
_WIDTH_COST = 0.01


def predict_cost(cfg: SchedulerConfig, stats: GraphStats) -> float:
    """Relative drain-cost score for one candidate (arbitrary units).

    This is the paper's section-7 guidelines as arithmetic, used only to
    *rank* candidates when seeding successive halving — it never replaces a
    measurement.  Wall time is rounds x per-round latency: the round count
    is a frontier ramp (the diameter proxy) plus a drain phase retiring at
    most ``lanes`` tasks per round out of a rescan-inflated vertex budget,
    and each round costs its kernel-strategy fixed entry, one parallel
    expansion (~avg degree), and a width penalty for launched-but-masked
    lanes.  High diameter favors persistent narrow shapes (fixed cost
    dominates); heavy tails inflate the budget and favor wide launches.
    """
    lanes = float(cfg.num_workers * cfg.fetch_size * max(cfg.granularity, 1))
    rescan = 1.0 + 0.5 * stats.degree_cv
    budget = stats.num_vertices * rescan
    rounds = stats.diameter_proxy + budget / lanes
    per_round = (_ROUND_COST[policy_of(cfg).kernel]
                 + max(stats.avg_degree, 1.0) + _WIDTH_COST * lanes)
    return rounds * per_round


def structural_cost_runner(algorithm: str, graph: CSRGraph,
                           cfg: SchedulerConfig) -> float:
    """Deterministic drop-in for the calibration runner: returns a
    structural cost instead of executing anything, so benches and CI can
    compare the grid and successive-halving searches reproducibly (a wall
    clock would make the checked-in agreement artifact machine-dependent).

    Finer than :func:`predict_cost`: it simulates the drain round by round
    with the same per-round wall model — the frontier starts at one task,
    each round retires at most ``lanes`` of it (one kernel-strategy fixed
    entry + one parallel expansion + the masked-width penalty), and the
    remainder grows by the hub-clipped branching factor until the
    rescan-inflated vertex budget is spent.  Where the closed form guesses
    the ramp from the diameter proxy, the simulation walks the actual
    growth trajectory.  Algorithm multipliers model rescan breadth
    (PageRank re-ranks, coloring re-bids).  A CRC-derived epsilon breaks
    exact ties deterministically so grid and SH agree on tie-heavy
    candidate sets.
    """
    stats = graph_stats(graph)
    lanes = float(cfg.num_workers * cfg.fetch_size * max(cfg.granularity, 1))
    rescan = 1.0 + 0.5 * stats.degree_cv
    budget = stats.num_vertices * rescan
    per_round = (_ROUND_COST[policy_of(cfg).kernel]
                 + max(stats.avg_degree, 1.0) + _WIDTH_COST * lanes)
    frontier, cost = 1.0, 0.0
    for _ in range(100_000):
        if budget <= 0.0 or frontier <= 0.0:
            break
        take = min(frontier, lanes, budget)
        cost += per_round
        budget -= take
        frontier = min(frontier - take + take * stats.frontier_growth,
                       budget)
    mult = {"bfs": 1.0, "coloring": 1.5, "pagerank": 2.5}.get(algorithm, 1.0)
    tiebreak = 1.0 + (zlib.crc32(_config_key(cfg).encode()) % 997) * 1e-9
    return cost * mult * tiebreak


def _config_key(cfg: SchedulerConfig) -> str:
    # the key's leading segment is the resolved kernel-strategy name; the
    # legacy two names keep their exact pre-megakernel spelling so every
    # cached trial written before the third strategy existed stays valid.
    kind = policy_of(cfg).kernel
    key = (f"{kind}|workers={cfg.num_workers}|fetch={cfg.fetch_size}"
           f"|backend={cfg.backend}")
    topology = policy_of(cfg).topology
    # the default single topology is omitted so pre-topology cache keys
    # stay valid and their trials comparable with new single candidates.
    if topology != "single":
        key += f"|topology={topology}"
    # likewise the default granularity 1 (pre-granularity caches)
    if cfg.granularity != 1:
        key += f"|granularity={cfg.granularity}"
    return key


def _config_dict(cfg: SchedulerConfig) -> dict:
    return {"num_workers": cfg.num_workers, "fetch_size": cfg.fetch_size,
            "persistent": cfg.persistent, "backend": cfg.backend,
            "topology": policy_of(cfg).topology,
            "granularity": cfg.granularity,
            "kernel": cfg.kernel}


def _load_topology(stored: Optional[str]) -> str:
    # "single" and "auto" resolve identically off-mesh; normalize loads to
    # "auto" so reloaded configs compare equal to the default candidates.
    return "auto" if stored in (None, "single") else str(stored)


def _config_from_dict(d: dict) -> SchedulerConfig:
    # cache entries written before the backend / topology / granularity
    # axes existed lack those fields; they were measured on the jnp
    # reference's single topology at the fine (width-1) granularity.
    return SchedulerConfig(num_workers=int(d["num_workers"]),
                           fetch_size=int(d["fetch_size"]),
                           persistent=bool(d["persistent"]),
                           backend=str(d.get("backend", "jnp")),
                           topology=_load_topology(d.get("topology")),
                           granularity=int(d.get("granularity", 1)),
                           kernel=str(d.get("kernel", "auto")))


def _default_runner(algorithm: str, graph: CSRGraph,
                    cfg: SchedulerConfig) -> None:
    """One complete calibration run (result discarded; wall time is the
    signal).  Imported lazily to keep autotune importable standalone."""
    from ..algorithms import bfs, coloring, pagerank

    if algorithm == "bfs":
        dist, _ = bfs.bfs_speculative(graph, 0, cfg)
        jax.block_until_ready(dist)
    elif algorithm == "pagerank":
        rank, _ = pagerank.pagerank_async(graph, cfg, eps=1e-4)
        jax.block_until_ready(rank)
    elif algorithm == "coloring":
        colors, _ = coloring.coloring_async(graph, cfg)
        jax.block_until_ready(colors)
    else:
        raise ValueError(f"unknown algorithm {algorithm!r}")


class Autotuner:
    """Measure-once, reuse-everywhere config selection.

    ``tune`` returns the winning :class:`SchedulerConfig` for one
    ``(algorithm, graph_class)``; ``recommend_for_mix`` aggregates the cached
    trials across a job mix and picks the config minimizing total
    calibration wall time — the server's single shared launch configuration.

    ``search`` selects the measurement strategy: ``"sh"`` (default) is
    cost-model-seeded successive halving — only the predicted-cheapest
    ``max(2, N // 4)`` candidates are measured (default force-included),
    survivors re-measured and halved until one remains; ``"grid"`` measures
    every candidate (the pre-section-16 behaviour).  ``runner`` may return
    a float to be used as the measurement instead of its wall time (see
    :func:`structural_cost_runner`).
    """

    def __init__(
        self,
        cache_path: Optional[str | Path] = None,
        candidates: Sequence[SchedulerConfig] = DEFAULT_CANDIDATES,
        warmup: int = 1,
        iters: int = 2,
        runner=_default_runner,
        search: str = "sh",
    ) -> None:
        if search not in ("sh", "grid"):
            raise ValueError(f"unknown search {search!r}; want 'sh'|'grid'")
        self.search = search
        self.cache_path = Path(cache_path) if cache_path else None
        self.candidates = list(candidates)
        if not any(c == SchedulerConfig() for c in self.candidates):
            # the acceptance bar is "no worse than default": always measure it
            self.candidates.insert(0, SchedulerConfig())
        self.warmup = warmup
        self.iters = iters
        self.runner = runner
        self._cache: Dict[str, dict] = {}
        if self.cache_path and self.cache_path.exists():
            self._cache = json.loads(self.cache_path.read_text())
            log.info("autotune cache loaded: %d entries from %s",
                     len(self._cache), self.cache_path)

    # ------------------------------------------------------------- plumbing
    def _save(self) -> None:
        # atomic write-temp-then-rename: concurrent jobs autotuning the same
        # graph class race on this file, and a torn half-written JSON would
        # poison every later run's cache load.  os.replace is atomic on
        # POSIX and Windows for same-directory renames; last writer wins
        # with a complete document either way.
        if self.cache_path:
            self.cache_path.parent.mkdir(parents=True, exist_ok=True)
            fd, tmp = tempfile.mkstemp(
                dir=self.cache_path.parent,
                prefix=self.cache_path.name + ".", suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    f.write(json.dumps(self._cache, indent=2,
                                       sort_keys=True))
                os.replace(tmp, self.cache_path)
            except BaseException:
                with contextlib.suppress(OSError):
                    os.unlink(tmp)
                raise

    def _measure(self, algorithm: str, graph: CSRGraph,
                 cfg: SchedulerConfig) -> float:
        for _ in range(self.warmup):
            self.runner(algorithm, graph, cfg)
        walls = []
        for _ in range(self.iters):
            t0 = time.perf_counter()
            returned = self.runner(algorithm, graph, cfg)
            wall = time.perf_counter() - t0
            # a runner may return its own deterministic cost (e.g.
            # structural_cost_runner); wall time is the default signal
            walls.append(float(returned) if returned is not None else wall)
        return statistics.median(walls)

    @staticmethod
    def cache_key(algorithm: str, graph: CSRGraph) -> str:
        return f"{algorithm}|{graph_class(graph)}"

    # ------------------------------------------------------------------ api
    def tune(self, algorithm: str, graph: CSRGraph) -> SchedulerConfig:
        """Winning config for (algorithm, class-of-graph); cached."""
        key = self.cache_key(algorithm, graph)
        if key in self._cache:
            entry = self._cache[key]
            log.info("autotune cache hit %s -> %s", key, entry["chosen"])
            return _config_from_dict(entry["config"])

        stats = graph_stats(graph)
        predicted = {_config_key(c): predict_cost(c, stats)
                     for c in self.candidates}
        if self.search == "grid":
            measured = list(self.candidates)
        else:
            # cost-model-seeded successive halving: measure only the
            # predicted-cheapest quarter (floor 2), default force-included
            budget = max(2, len(self.candidates) // 4)
            ranked = sorted(self.candidates,
                            key=lambda c: predicted[_config_key(c)])
            measured = []
            for cfg in [SchedulerConfig(), *ranked]:
                if cfg not in measured:
                    measured.append(cfg)
                if len(measured) >= budget:
                    break

        samples: Dict[str, List[float]] = {_config_key(c): []
                                           for c in measured}
        trials: Dict[str, float] = {}

        def _round(survivors: List[SchedulerConfig]) -> None:
            for cfg in survivors:
                wall = self._measure(algorithm, graph, cfg)
                samples[_config_key(cfg)].append(wall)
                log.info("autotune %s: %s -> %.4fs", key, _config_key(cfg),
                         wall)
            trials.update({ck: statistics.median(v)
                           for ck, v in samples.items() if v})

        if self.search == "grid":
            _round(measured)
            best = min(measured, key=lambda c: trials[_config_key(c)])
        else:
            survivors = list(measured)
            if len(survivors) == 1:
                _round(survivors)
            while len(survivors) > 1:
                _round(survivors)
                survivors = sorted(
                    survivors,
                    key=lambda c: trials[_config_key(c)])[:(len(survivors)
                                                            + 1) // 2]
            best = survivors[0]

        entry = {
            "schema": AUTOTUNE_SCHEMA,
            "chosen": _config_key(best),
            "config": _config_dict(best),
            "trials": trials,
            "default_wall": trials[_config_key(SchedulerConfig())],
            "calibration_graph": {"n": graph.num_vertices,
                                  "m": graph.num_edges},
            "search": self.search,
            "cells_total": len(self.candidates),
            "cells_measured": len(measured),
            "cost_model": {"stats": dataclasses.asdict(stats),
                           "predicted": {ck: predicted[ck]
                                         for ck in samples}},
        }
        self._cache[key] = entry
        self._save()
        log.info(
            "autotune decision %s: chose %s (%.4fs) vs default %s (%.4fs)",
            key, entry["chosen"], trials[entry["chosen"]],
            _config_key(SchedulerConfig()), entry["default_wall"])
        return best

    def recommend_for_mix(
        self, pairs: Iterable[Tuple[str, CSRGraph]]
    ) -> SchedulerConfig:
        """One shared config for a mixed job batch: tune each distinct
        (algorithm, graph-class), then pick the candidate whose *summed*
        calibration wall across the mix is smallest."""
        distinct: Dict[str, CSRGraph] = {}
        for algorithm, graph in pairs:
            distinct.setdefault(self.cache_key(algorithm, graph),
                                graph)
        entries: List[dict] = []
        for key, graph in distinct.items():
            algorithm = key.split("|", 1)[0]
            self.tune(algorithm, graph)  # fills the cache
            entries.append(self._cache[key])
        if not entries:
            return SchedulerConfig()
        # only candidates measured for every workload are comparable
        shared = set(entries[0]["trials"])
        for e in entries[1:]:
            shared &= set(e["trials"])
        if not shared:
            # cache entries from runs with disjoint candidate lists: no
            # cross-workload comparison possible — fall back to the most
            # commonly chosen per-workload winner instead of crashing.
            chosen = [e["chosen"] for e in entries]
            best_key = max(chosen, key=chosen.count)
            log.warning(
                "autotune mix: cached trials share no candidates; falling "
                "back to majority per-workload winner %s", best_key)
            return _parse_config_key(best_key)
        totals = {ck: sum(e["trials"][ck] for e in entries) for ck in shared}
        best_key = min(totals, key=totals.get)
        log.info("autotune mix recommendation: %s (total %.4fs)",
                 best_key, totals[best_key])
        return _parse_config_key(best_key)


def _parse_config_key(key: str) -> SchedulerConfig:
    # pre-backend caches wrote 3-field keys, pre-topology caches 4-field
    # ones, pre-granularity caches omit the granularity segment; those runs
    # used the jnp path's single topology at width-1 granularity.
    kind, workers, fetch, *rest = key.split("|")
    extras = dict(part.split("=", 1) for part in rest)
    return SchedulerConfig(
        num_workers=int(workers.split("=")[1]),
        fetch_size=int(fetch.split("=")[1]),
        # megakernel keys are new (no pre-megakernel cache can hold one);
        # the legacy bool mirrors "device-resident" for both such kinds
        persistent=(kind != "discrete"),
        kernel=("megakernel" if kind == "megakernel" else "auto"),
        backend=extras.get("backend", "jnp"),
        topology=_load_topology(extras.get("topology")),
        granularity=int(extras.get("granularity", 1)),
    )
