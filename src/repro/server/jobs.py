"""Job abstraction: what a tenant submits and how it runs on the server.

A **JobSpec** is the wire-level request ("run PageRank on graph 'web' with
damping 0.85, weight 2.0").  The **JobRegistry** owns the named graphs and
compiles a spec into a **Program** — the job-parameterized bundle of pure
callables the scheduler drives:

    init()                -> (state, seed natural tasks)
    wavefront_fn(i, v, s) -> (out, mask, s')     # the algorithm's expansion
    on_empty(s)           -> optional refill step (PageRank's re-scan)
    stop(s)               -> optional convergence predicate
    result(s)             -> the job's answer (dist / rank / colors)

Programs are exactly the reusable wavefront components the algorithms
export (``bfs.make_wavefront_fn`` etc.) — the server adds no algorithmic
logic of its own, it only routes, packs, and meters (DESIGN.md section 8).

Kernel backends (DESIGN.md section 9): ``build(..., backend=...)`` threads
the server's kernel-backend axis into each bundle, so under
``SchedulerConfig(backend="pallas")`` every BFS/PageRank tenant's merge-path
expansion runs the Pallas LBS kernel (``kernels/frontier_expand``) and every
tenant's queue push runs the Pallas compaction kernel
(``kernels/queue_compact``) via the engine's step.  ``backend`` is part of
the kernel-cache key: bundles are shared only between jobs that agree on it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..algorithms import bfs as _bfs
from ..algorithms import coloring as _coloring
from ..algorithms import pagerank as _pagerank
from ..algorithms.common import default_work_budget
from ..graph.csr import CSRGraph
from .encoding import check_job_fits

ALGORITHMS = ("bfs", "pagerank", "coloring")


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """A tenant's request.  ``weight`` feeds the weighted fairness policy.

    ``shards > 1`` asks for a *sharded single-tenant* drain: instead of a
    lane in the fused multi-tenant wavefront, the job gets the whole
    ``shards``-device mesh to itself for the duration of its drain
    (repro/shard), and the server runs such jobs as device-wide phases
    before the fused rounds (DESIGN.md section 10).
    """

    algorithm: str                 # one of ALGORITHMS
    graph: str                     # name registered with the JobRegistry
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    weight: float = 1.0
    shards: int = 1                # >1 = sharded single-tenant job

    def __post_init__(self):
        if self.algorithm not in ALGORITHMS:
            raise ValueError(f"unknown algorithm {self.algorithm!r}; "
                             f"expected one of {ALGORITHMS}")
        if self.weight <= 0:
            raise ValueError("job weight must be positive")
        if self.shards < 1:
            raise ValueError("job shards must be >= 1")


@dataclasses.dataclass(frozen=True)
class Program:
    """Compiled form of a JobSpec: pure callables the scheduler drives."""

    algorithm: str
    graph_name: str
    graph: CSRGraph
    init: Callable[[], Tuple[Any, jax.Array]]
    wavefront_fn: Callable
    result: Callable[[Any], jax.Array]
    work: Callable[[Any], jax.Array]
    ideal_work: int
    on_empty: Optional[Callable] = None
    stop: Optional[Callable] = None


# init-only params: they shape a job's initial state but NOT its wavefront
# kernel, so jobs differing only in these share one compiled kernel bundle.
_INIT_ONLY = {"bfs": ("source",), "pagerank": (), "coloring": ()}


def _kernel_bundle(spec: JobSpec, graph: CSRGraph, wavefront: int,
                   num_workers: int, backend: str) -> Dict[str, Any]:
    """Build the cacheable (init-independent) callables for one spec.

    ``backend`` picks the kernel implementations inside the bundle (jnp
    reference vs Pallas); results are bit-identical across backends.
    """
    n = graph.num_vertices
    p = {k: v for k, v in spec.params.items()
         if k not in _INIT_ONLY[spec.algorithm]}
    if spec.algorithm == "bfs":
        strategy = p.pop("strategy", "merge_path")
        max_degree = int(jnp.max(graph.degrees()))
        work_budget = default_work_budget(
            graph, wavefront, p.pop("work_budget", None),
            max_degree=max_degree)
        _reject_unknown(p)
        f = _bfs.make_wavefront_fn(graph, strategy, work_budget, max_degree,
                                   backend=backend)
        return dict(f=f, on_empty=None, stop=None,
                    result=lambda s: s.dist, ideal=n)
    if spec.algorithm == "pagerank":
        damping = float(p.pop("damping", 0.85))
        eps = float(p.pop("eps", 1e-6))
        check_size = int(p.pop("check_size", 64))
        work_budget = p.pop("work_budget", None)
        _reject_unknown(p)
        f, on_empty, stop = _pagerank.make_wavefront_fns(
            graph, wavefront, n_check=num_workers * check_size,
            damping=damping, eps=eps, work_budget=work_budget,
            backend=backend,
        )
        return dict(f=f, on_empty=on_empty, stop=stop,
                    result=lambda s: s.rank, ideal=n)
    # coloring
    _reject_unknown(p)
    f = _coloring.make_wavefront_fn(graph)
    return dict(f=f, on_empty=None, stop=None,
                result=lambda s: s.colors, ideal=n)


def _make_init(spec: JobSpec, graph: CSRGraph, lane_capacity: int):
    """Per-job initial (state, seed tasks) — never cached."""
    if spec.algorithm == "bfs":
        source = int(spec.params.get("source", 0))
        return lambda: (_bfs.init_state(graph, source),
                        jnp.array([source], jnp.int32))
    if spec.algorithm == "pagerank":
        damping = float(spec.params.get("damping", 0.85))
        seed_count = min(graph.num_vertices, max(1, lane_capacity // 2))
        return lambda: _pagerank.init_state(graph, damping, seed_count)
    return lambda: _coloring.init_state(graph)


def _reject_unknown(params: Dict[str, Any]) -> None:
    if params:
        raise ValueError(f"unknown job params: {sorted(params)}")


class JobRegistry:
    """Named graphs + spec->Program compilation (with a kernel cache).

    Jobs that agree on (algorithm, graph, kernel params, server config)
    share one kernel bundle — and therefore, downstream, one XLA
    compilation of the scheduler step — even when init-only params like the
    BFS source differ.  This is the multi-tenant analogue of Atos reusing a
    loaded kernel across launches.
    """

    def __init__(self) -> None:
        self._graphs: Dict[str, CSRGraph] = {}
        self._kernels: Dict[tuple, Dict[str, Any]] = {}
        # compiled scheduler steps (filled by engine.TaskServer): scoped
        # here so every server over this registry shares executables, and
        # the cache's lifetime is the graphs' lifetime, not the process's
        self.step_cache: Dict[tuple, Any] = {}
        self.empty_step_cache: Dict[tuple, Any] = {}

    def register_graph(self, name: str, graph: CSRGraph) -> None:
        if name in self._graphs:
            raise ValueError(f"graph {name!r} already registered")
        self._graphs[name] = graph

    def graph(self, name: str) -> CSRGraph:
        if name not in self._graphs:
            raise KeyError(
                f"graph {name!r} not registered "
                f"(have: {sorted(self._graphs)})")
        return self._graphs[name]

    @property
    def graph_names(self):
        return sorted(self._graphs)

    def build(self, spec: JobSpec, job_id: int, wavefront: int,
              num_workers: int, lane_capacity: int,
              backend: str = "jnp") -> Program:
        graph = self.graph(spec.graph)
        check_job_fits(job_id, graph.num_vertices)
        kernel_params = tuple(sorted(
            (k, v) for k, v in spec.params.items()
            if k not in _INIT_ONLY[spec.algorithm]))
        key = (spec.algorithm, spec.graph, kernel_params,
               wavefront, num_workers, backend)
        if key not in self._kernels:
            self._kernels[key] = _kernel_bundle(
                spec, graph, wavefront, num_workers, backend)
        k = self._kernels[key]
        return Program(
            algorithm=spec.algorithm, graph_name=spec.graph, graph=graph,
            init=_make_init(spec, graph, lane_capacity),
            wavefront_fn=k["f"], on_empty=k["on_empty"], stop=k["stop"],
            result=k["result"],
            work=lambda s: s.counter.work,
            ideal_work=k["ideal"],
        )
