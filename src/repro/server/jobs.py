"""Job abstraction: what a tenant submits and how it runs on the server.

A **JobSpec** is the wire-level request ("run PageRank on graph 'web' with
damping 0.85, weight 2.0").  The **JobRegistry** owns the named graphs and
compiles a spec into a **Program** — the job-parameterized bundle of pure
callables the scheduler drives:

    init()                -> (state, seed natural tasks)
    wavefront_fn(i, v, s) -> (out, mask, s')     # the algorithm's expansion
    on_empty(s)           -> optional refill step (PageRank's re-scan)
    stop(s)               -> optional convergence predicate
    result(s)             -> the job's answer (dist / rank / colors)

Since the runtime layer (DESIGN.md section 11) the registry adds no
algorithmic knowledge of its own: it compiles the spec through the single
per-algorithm :class:`~repro.runtime.program.AtosProgram` definition
(``repro.runtime.build_program``) and materializes the bundle by building
the program's body for the server's fused execution context.  The old
per-algorithm ``_kernel_bundle`` parameter parsing is gone — adding an
algorithm to the registry is now one line in ``repro/runtime/programs.py``.

Kernel backends (DESIGN.md section 9): ``build(..., backend=...)`` threads
the server's kernel-backend axis into each bundle, so under
``SchedulerConfig(backend="pallas")`` every BFS/PageRank tenant's merge-path
expansion runs the Pallas LBS kernel (``kernels/frontier_expand``) and every
tenant's queue push runs the Pallas compaction kernel
(``kernels/queue_compact``) via the engine's step.  ``backend`` is part of
the kernel-cache key: bundles are shared only between jobs that agree on it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax

from ..core.scheduler import SchedulerConfig
from ..graph.csr import CSRGraph
from ..runtime.program import ProgramContext
from ..runtime.programs import build_program as _build_runtime_program
from .encoding import check_job_fits

ALGORITHMS = ("bfs", "pagerank", "coloring")


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """A tenant's request.  ``weight`` feeds the weighted fairness policy.

    ``shards > 1`` asks for a *sharded single-tenant* drain: instead of a
    lane in the fused multi-tenant wavefront, the job gets the whole
    ``shards``-device mesh to itself for the duration of its drain
    (repro/shard), and the server runs such jobs as device-wide phases
    before the fused rounds (DESIGN.md section 10).
    """

    algorithm: str                 # one of ALGORITHMS
    graph: str                     # name registered with the JobRegistry
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    weight: float = 1.0
    shards: int = 1                # >1 = sharded single-tenant job
    #: optional :class:`~repro.stream.driver.StreamSpec`: the job is a
    #: *streaming* job — a delta log is committed batch-by-batch against
    #: its graph with incremental recompute between drains.  Served as a
    #: dedicated phase (like sharded jobs), not as a fused lane; combine
    #: with ``shards > 1`` for a sharded streaming drain.
    stream: Optional[Any] = None

    def __post_init__(self):
        if self.algorithm not in ALGORITHMS:
            raise ValueError(f"unknown algorithm {self.algorithm!r}; "
                             f"expected one of {ALGORITHMS}")
        if self.weight <= 0:
            raise ValueError("job weight must be positive")
        if self.shards < 1:
            raise ValueError("job shards must be >= 1")
        if self.stream is not None and not hasattr(self.stream, "deltas"):
            raise ValueError(
                "JobSpec.stream must be a repro.stream.StreamSpec")


@dataclasses.dataclass(frozen=True)
class Program:
    """Compiled form of a JobSpec: pure callables the scheduler drives."""

    algorithm: str
    graph_name: str
    graph: Optional[CSRGraph]
    init: Callable[[], Tuple[Any, jax.Array]]
    wavefront_fn: Callable
    result: Callable[[Any], jax.Array]
    work: Callable[[Any], jax.Array]
    ideal_work: int
    on_empty: Optional[Callable] = None
    stop: Optional[Callable] = None
    #: mirrors AtosProgram.empty_means_done: when False (and stop is None)
    #: a drained lane does NOT finish the job — the engine keeps serving
    #: its on_empty refills until stop/max_rounds (DESIGN.md section 11).
    empty_means_done: bool = True
    #: mirrors AtosProgram.task_width (natural task -> chunk width): feeds
    #: the engine's vertex-denominated lane loads and pop quotas when the
    #: server runs at granularity > 1 (DESIGN.md section 12).
    task_width: Optional[Callable] = None


# init-only params: they shape a job's initial state but NOT its wavefront
# kernel, so jobs differing only in these share one compiled kernel bundle.
_INIT_ONLY = {"bfs": ("source",), "pagerank": (), "coloring": ()}


class JobRegistry:
    """Named graphs + spec->Program compilation (with a kernel cache).

    Jobs that agree on (algorithm, graph, kernel params, server config)
    share one kernel bundle — and therefore, downstream, one XLA
    compilation of the scheduler step — even when init-only params like the
    BFS source differ.  This is the multi-tenant analogue of Atos reusing a
    loaded kernel across launches.
    """

    def __init__(self) -> None:
        self._graphs: Dict[str, CSRGraph] = {}
        self._kernels: Dict[tuple, Dict[str, Any]] = {}
        # compiled scheduler steps (filled by engine.TaskServer): scoped
        # here so every server over this registry shares executables, and
        # the cache's lifetime is the graphs' lifetime, not the process's
        self.step_cache: Dict[tuple, Any] = {}
        self.empty_step_cache: Dict[tuple, Any] = {}

    def register_graph(self, name: str, graph: CSRGraph) -> None:
        if name in self._graphs:
            raise ValueError(f"graph {name!r} already registered")
        self._graphs[name] = graph

    def graph(self, name: str) -> CSRGraph:
        if name not in self._graphs:
            raise KeyError(
                f"graph {name!r} not registered "
                f"(have: {sorted(self._graphs)})")
        return self._graphs[name]

    @property
    def graph_names(self):
        return sorted(self._graphs)

    def build(self, spec: JobSpec, job_id: int, wavefront: int,
              num_workers: int, lane_capacity: int,
              backend: str = "jnp", granularity: int = 1,
              split_threshold: int = 0) -> Program:
        graph = self.graph(spec.graph)
        check_job_fits(job_id, graph.num_vertices, granularity=granularity)
        if num_workers <= 0 or wavefront % num_workers:
            # the reconstructed config must reproduce the engine's wavefront
            # exactly — a silent floor-division here would size the kernel
            # budgets for a narrower wavefront than the engine pops.
            raise ValueError(
                f"wavefront {wavefront} is not num_workers "
                f"({num_workers}) x fetch_size")
        cfg = SchedulerConfig(num_workers=num_workers,
                              fetch_size=wavefront // num_workers,
                              backend=backend, granularity=granularity,
                              split_threshold=split_threshold)
        kernel_params = tuple(sorted(
            (k, v) for k, v in spec.params.items()
            if k not in _INIT_ONLY[spec.algorithm]))
        key = (spec.algorithm, spec.graph, kernel_params,
               wavefront, num_workers, backend, granularity,
               split_threshold)
        if key not in self._kernels:
            # one AtosProgram per kernel key; its body, built for the fused
            # execution context, is the shared (init-independent) kernel.
            prog = _build_runtime_program(
                spec.algorithm, graph, cfg, params=dict(kernel_params),
                queue_capacity=lane_capacity)
            ctx = ProgramContext(wavefront=wavefront,
                                 num_workers=num_workers, backend=backend,
                                 granularity=granularity)
            self._kernels[key] = dict(
                f=prog.body(graph, ctx),
                on_empty=prog.on_empty(graph, ctx),
                stop=prog.stop, result=prog.result,
                ideal=prog.ideal_work,
                empty_means_done=prog.empty_means_done,
                task_width=prog.task_width)
        k = self._kernels[key]
        # a full-params program supplies the per-job init (never cached) —
        # and validates init-only params like the BFS source at build time.
        job_prog = _build_runtime_program(
            spec.algorithm, graph, cfg, params=dict(spec.params),
            queue_capacity=lane_capacity)
        return Program(
            algorithm=spec.algorithm, graph_name=spec.graph, graph=graph,
            init=job_prog.init,
            wavefront_fn=k["f"], on_empty=k["on_empty"], stop=k["stop"],
            result=k["result"],
            work=lambda s: s.counter.work,
            ideal_work=k["ideal"],
            empty_means_done=k["empty_means_done"],
            task_width=k["task_width"],
        )
