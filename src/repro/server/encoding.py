"""Packed ``(job_id, payload)`` task encoding for the multi-tenant server.

Atos tags tasks inside one int by sign (graph coloring's +v+1 / -(v+1)) or by
payload bits.  The task server generalizes the trick: every task carried by a
``MultiQueue`` lane is a single **positive** int32

    packed = (job_id << PAYLOAD_BITS) | zigzag(natural_task)

so a task is self-identifying even when wavefronts from different tenants
mix.  The *natural* task is whatever the algorithm's wavefront body consumes
(a vertex id for BFS/PageRank, a signed ±(v+1) for coloring); zigzag folds
the sign into the low bit so negatives survive the bitfield (DESIGN.md
section 8).

Layout (int32, sign bit always 0):
    bit 31    : 0                     (keeps packed tasks orderable/positive)
    bits 24-30: job_id                (MAX_JOBS = 128 concurrent tenants)
    bits 0-23 : zigzag(natural task)  (graphs up to ~8.3M vertices)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

PAYLOAD_BITS = 24
PAYLOAD_MASK = (1 << PAYLOAD_BITS) - 1
MAX_JOBS = 1 << (31 - PAYLOAD_BITS)          # 128
MAX_NATURAL = (1 << (PAYLOAD_BITS - 1)) - 1  # |natural| bound after zigzag


def zigzag(t: jax.Array) -> jax.Array:
    """Map signed int32 to unsigned-style: 0,-1,1,-2,2 -> 0,1,2,3,4."""
    t = jnp.asarray(t, jnp.int32)
    return (t << 1) ^ (t >> 31)  # arithmetic shift propagates the sign


def unzigzag(z: jax.Array) -> jax.Array:
    z = jnp.asarray(z, jnp.int32)
    return (z >> 1) ^ -(z & 1)


def pack(job_id, natural: jax.Array) -> jax.Array:
    """Pack natural tasks for ``job_id``.  Vectorized; ``job_id`` may be a
    scalar (the usual case: a whole wavefront belongs to one lane/tenant)."""
    job = jnp.asarray(job_id, jnp.int32)
    return (job << PAYLOAD_BITS) | (zigzag(natural) & PAYLOAD_MASK)


def unpack_job(packed: jax.Array) -> jax.Array:
    return (jnp.asarray(packed, jnp.int32) >> PAYLOAD_BITS) & (MAX_JOBS - 1)


def unpack_natural(packed: jax.Array) -> jax.Array:
    return unzigzag(jnp.asarray(packed, jnp.int32) & PAYLOAD_MASK)


def check_job_fits(job_id: int, num_vertices: int) -> None:
    """Host-side admission validation: the encoding must be lossless."""
    if not (0 <= job_id < MAX_JOBS):
        raise ValueError(f"job_id {job_id} out of range [0, {MAX_JOBS})")
    # coloring's natural tasks reach ±(n+1); BFS/PageRank stay in [0, n)
    if num_vertices + 1 > MAX_NATURAL:
        raise ValueError(
            f"graph too large for {PAYLOAD_BITS}-bit payload: "
            f"n={num_vertices} > {MAX_NATURAL - 1}"
        )
