"""Packed ``(job_id, payload)`` task encoding for the multi-tenant server.

Atos tags tasks inside one int by sign (graph coloring's +v+1 / -(v+1)) or by
payload bits.  The task server generalizes the trick: every task carried by a
``MultiQueue`` lane is a single **positive** int32

    packed = (job_id << PAYLOAD_BITS) | zigzag(natural_task)

so a task is self-identifying even when wavefronts from different tenants
mix.  The *natural* task is whatever the algorithm's wavefront body consumes
(a vertex id for BFS/PageRank, a signed ±(v+1) for coloring); zigzag folds
the sign into the low bit so negatives survive the bitfield (DESIGN.md
section 8).

Layout (int32, sign bit always 0):
    bit 31    : 0                     (keeps packed tasks orderable/positive)
    bits 24-30: job_id                (MAX_JOBS = 128 concurrent tenants)
    bits 0-23 : zigzag(natural task)  (graphs up to ~8.3M vertices)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

PAYLOAD_BITS = 24
PAYLOAD_MASK = (1 << PAYLOAD_BITS) - 1
MAX_JOBS = 1 << (31 - PAYLOAD_BITS)          # 128
MAX_NATURAL = (1 << (PAYLOAD_BITS - 1)) - 1  # |natural| bound after zigzag


def zigzag(t: jax.Array) -> jax.Array:
    """Map signed int32 to unsigned-style: 0,-1,1,-2,2 -> 0,1,2,3,4."""
    t = jnp.asarray(t, jnp.int32)
    return (t << 1) ^ (t >> 31)  # arithmetic shift propagates the sign


def unzigzag(z: jax.Array) -> jax.Array:
    z = jnp.asarray(z, jnp.int32)
    return (z >> 1) ^ -(z & 1)


def pack(job_id, natural: jax.Array) -> jax.Array:
    """Pack natural tasks for ``job_id``.  Vectorized; ``job_id`` may be a
    scalar (the usual case: a whole wavefront belongs to one lane/tenant)."""
    job = jnp.asarray(job_id, jnp.int32)
    return (job << PAYLOAD_BITS) | (zigzag(natural) & PAYLOAD_MASK)


def unpack_job(packed: jax.Array) -> jax.Array:
    return (jnp.asarray(packed, jnp.int32) >> PAYLOAD_BITS) & (MAX_JOBS - 1)


def unpack_natural(packed: jax.Array) -> jax.Array:
    return unzigzag(jnp.asarray(packed, jnp.int32) & PAYLOAD_MASK)


def packed_width(task_width):
    """Lift a *natural*-task chunk-width function (core/task.py) to this
    module's packed wire format — the one place the natural-vs-packed width
    contract lives (used by both the fused QueueOps pop quota and the
    engine's lane-load accounting)."""
    return lambda p: task_width(unpack_natural(p))


def check_job_fits(job_id: int, num_vertices: int,
                   granularity: int = 1) -> None:
    """Host-side admission validation: the encoding must be lossless.

    ``granularity > 1`` tasks are bit-packed ``(vertex, width)`` chunk
    codes (core/task.py), so the payload must absorb the vertex id shifted
    by the codec's width bits — each doubling of the chunk width halves the
    largest admissible graph.
    """
    from ..core.task import ChunkCodec  # lazy: server<->core layering

    if not (0 <= job_id < MAX_JOBS):
        raise ValueError(f"job_id {job_id} out of range [0, {MAX_JOBS})")
    # coloring's natural tasks reach ±(task+1), where task is the raw
    # vertex id at granularity 1 and a packed chunk code beyond
    max_code = ChunkCodec(granularity).max_code(num_vertices + 1)
    if max_code + 1 > MAX_NATURAL:
        raise ValueError(
            f"graph too large for {PAYLOAD_BITS}-bit payload at "
            f"granularity {granularity}: n={num_vertices} needs chunk codes "
            f"up to {max_code + 1} > {MAX_NATURAL - 1}"
        )
