"""Atos-as-a-service: multi-tenant graph task server (DESIGN.md section 8).

One resident scheduler, per-job MultiQueue lanes, packed (job_id, payload)
tasks, pluggable fairness policies, backpressure/admission control, and a
SchedulerConfig autotuner implementing the paper's selection guidelines.
"""
from .autotune import (AUTOTUNE_SCHEMA, Autotuner, BACKEND_GRID,
                       DEFAULT_CANDIDATES, GRANULARITY_GRID, GraphStats,
                       TOPOLOGY_GRID, graph_class, graph_stats,
                       predict_cost, structural_cost_runner)
from .encoding import (MAX_JOBS, PAYLOAD_BITS, pack, unpack_job,
                       unpack_natural, unzigzag, zigzag)
from .engine import (Job, ServerResult, ServerStats, TaskServer,
                     serve_sequential)
from .jobs import ALGORITHMS, JobRegistry, JobSpec, Program
from .policies import (FairnessPolicy, LongestQueueFirst, RoundRobin,
                       WeightedShare, make_policy)

__all__ = [
    "AUTOTUNE_SCHEMA", "Autotuner", "BACKEND_GRID", "DEFAULT_CANDIDATES",
    "GRANULARITY_GRID", "GraphStats", "TOPOLOGY_GRID", "graph_class",
    "graph_stats", "predict_cost", "structural_cost_runner",
    "MAX_JOBS", "PAYLOAD_BITS", "pack", "unpack_job", "unpack_natural",
    "unzigzag", "zigzag",
    "Job", "ServerResult", "ServerStats", "TaskServer", "serve_sequential",
    "ALGORITHMS", "JobRegistry", "JobSpec", "Program",
    "FairnessPolicy", "LongestQueueFirst", "RoundRobin", "WeightedShare",
    "make_policy",
]
