"""The multi-tenant task server: one resident scheduler, many graph jobs.

Atos's final analysis derives per-workload launch configurations; its
``num_queues`` lanes let one queue serve heterogeneous task streams.  This
module turns both into a serving system (DESIGN.md section 8):

  * every admitted job owns one **lane** of a shared :class:`MultiQueue`;
    its tasks are packed ``(job_id, payload)`` int32s (``server/encoding``);
  * each scheduling round a **fairness policy** splits the wavefront budget
    ``W = num_workers x fetch_size`` into per-lane quotas, and the server
    drives every granted lane through its job's wavefront body — a *fused
    wavefront*: one scheduler round advances many tenants, so the
    small-frontier rounds that underfill a single-tenant wavefront instead
    overlap across jobs and the batch finishes in fewer total rounds;
  * **backpressure**: a lane whose ``dropped`` counter grew last round is
    drain-boosted (served first) and new admissions are deferred until the
    overflow clears;
  * **admission control**: at most one job per lane; excess jobs wait in a
    FIFO and are admitted as lanes free up.

The loop is host-driven — the discrete-kernel regime — because tenants have
heterogeneous graph shapes and therefore distinct XLA executables; the
per-round host sync is exactly the discrete launch overhead the paper
measures, and the autotuner (``server/autotune``) still picks persistent
configs for the single-tenant calibration runs.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.counters import JobTelemetry
from ..core.queue import MultiQueue, make_multiqueue
from ..core.scheduler import SchedulerConfig, wavefront_step
from ..runtime.api import fused_lane_ops
from .encoding import MAX_JOBS, pack
from .encoding import packed_width as encoding_packed_width
from .jobs import JobRegistry, JobSpec, Program
from .policies import FairnessPolicy, make_policy

log = logging.getLogger("repro.server")


@dataclasses.dataclass
class Job:
    """Runtime record of one submitted job."""

    job_id: int
    program: Optional[Program]     # built at admission (config-specialized)
    weight: float
    spec: Optional[JobSpec] = None
    status: str = "pending"        # pending -> active -> done
    lane: int = -1
    state: Any = None
    counters: Any = None           # device int32[3]: (items, verts, mism)
    #: packed-wire chunk-width fn (encoding.packed_width), built once at
    #: admission; None when the program is width-1 or width-agnostic
    width_of: Any = None
    stopped: bool = False
    telemetry: Optional[JobTelemetry] = None
    result: Optional[np.ndarray] = None
    #: streaming jobs only: the full per-batch StreamResult (repro/stream)
    stream_result: Any = None


@dataclasses.dataclass
class ServerStats:
    rounds: int = 0
    wall_seconds: float = 0.0
    items_processed: int = 0
    backpressure_events: int = 0
    deferred_admissions: int = 0
    wavefront: int = 0
    sharded_jobs: int = 0          # jobs served as device-wide sharded phases
    sharded_rounds: int = 0        # device rounds spent in those phases
    streaming_jobs: int = 0        # jobs served as streaming phases
    stream_batches: int = 0        # delta batches drained in those phases

    @property
    def occupancy(self) -> float:
        denom = self.rounds * self.wavefront
        return self.items_processed / denom if denom else 0.0

    def as_dict(self) -> dict:
        """Serialize into the canonical ``server`` doc (obs/schema)."""
        from ..obs.schema import metric_doc  # lazy: obs is a leaf layer

        d = dataclasses.asdict(self)
        d["occupancy"] = self.occupancy
        return metric_doc("server", **d)


@dataclasses.dataclass
class ServerResult:
    results: Dict[int, np.ndarray]
    telemetry: Dict[int, JobTelemetry]
    stats: ServerStats


class TaskServer:
    """Multi-tenant graph-analytics server over one shared MultiQueue."""

    def __init__(
        self,
        registry: JobRegistry,
        num_lanes: int = 8,
        config: Optional[SchedulerConfig] = None,
        policy: str | FairnessPolicy = "weighted",
        lane_capacity: Optional[int] = None,
        autotuner=None,
        max_rounds: int = 1 << 17,
        strict_drops: bool = True,
        trace=None,
    ) -> None:
        self.registry = registry
        self.num_lanes = num_lanes
        self._config = config
        self.policy = (policy if isinstance(policy, FairnessPolicy)
                       else make_policy(policy))
        self._lane_capacity = lane_capacity
        self.autotuner = autotuner
        self.max_rounds = max_rounds
        #: optional :class:`~repro.obs.Trace`: one device ring rides every
        #: compiled lane step (one row per granted lane per round, written
        #: in-trace), drained once when ``run()`` returns, alongside the
        #: canonical server/job summary docs and per-job latency histograms.
        self.trace = trace
        # a dropped task is work lost forever: for the graph algorithms that
        # silently corrupts the answer (an unreached BFS vertex stays INF),
        # so by default any overflow fails the run loudly.  Opt out only for
        # workloads that tolerate loss (see tests' synthetic flood program).
        self.strict_drops = strict_drops
        self._jobs: List[Job] = []

    # ------------------------------------------------------------ submission
    def _next_job_id(self) -> int:
        # job ids are baked into the packed-task bitfield and never
        # recycled, so one server instance serves at most MAX_JOBS jobs
        # over its lifetime; fail at submit time, not mid-run.
        job_id = len(self._jobs)
        if job_id >= MAX_JOBS:
            raise ValueError(
                f"job id space exhausted: one TaskServer serves at most "
                f"{MAX_JOBS} jobs over its lifetime (encoding.PAYLOAD_BITS "
                f"bitfield); create a new server for the next batch")
        return job_id

    def submit(self, spec: JobSpec) -> int:
        """Queue a job for admission; returns its job_id."""
        job_id = self._next_job_id()
        self._jobs.append(Job(job_id=job_id, program=None,
                              weight=spec.weight, spec=spec))
        return job_id

    def submit_program(self, program: Program, weight: float = 1.0) -> int:
        """Escape hatch for synthetic/custom programs (tests, experiments).

        The program must already match the server's wavefront width.
        """
        job_id = self._next_job_id()
        self._jobs.append(Job(job_id=job_id, program=program, weight=weight))
        return job_id

    # ------------------------------------------------------------- plumbing
    def _resolve_config(self) -> SchedulerConfig:
        if self._config is not None:
            return self._config
        if self.autotuner is not None:
            pairs = [(j.spec.algorithm, self.registry.graph(j.spec.graph))
                     for j in self._jobs if j.spec is not None]
            if pairs:
                cfg = self.autotuner.recommend_for_mix(pairs)
                log.info("autotuned server config: %s", cfg)
                return cfg
        return SchedulerConfig()

    def _resolve_lane_capacity(self) -> int:
        if self._lane_capacity is not None:
            return self._lane_capacity
        biggest = 1024
        for j in self._jobs:
            if j.spec is not None:
                n = self.registry.graph(j.spec.graph).num_vertices
                biggest = max(biggest, 8 * n)
        return biggest

    def _step_for(self, f, stop, W: int, backend: str, task_width=None,
                  work_fn=None, traced: bool = False):
        """One compiled scheduler step per distinct wavefront body.

        The pop->body->push spine is the shared
        :func:`~repro.core.scheduler.wavefront_step` core (DESIGN.md
        section 11), driven through fused-lane QueueOps: pop unpacks
        ``(job_id, payload)`` tasks from one MultiQueue lane (metering
        routing mismatches on the way), push re-packs.  ``quota`` and
        ``job_id`` are traced scalars, so every tenant sharing a kernel
        bundle shares this executable.  Telemetry (items popped, routing
        mismatches) accumulates in a device-side ``counters`` array and the
        convergence predicate is evaluated in-step, so the host loop syncs
        one boolean per stop-ful job per round and nothing else.

        Steps are cached on the registry (whose kernel bundles own the
        closures), so a fused server and the sequential baseline over the
        same registry share executables, and the cache dies with the
        registry instead of pinning every served graph process-wide.
        """
        cache = self.registry.step_cache
        # function objects as keys: no id-reuse after GC; backend is part of
        # the key so jnp- and pallas-backed servers never share a step.
        # task_width switches the pop quota to vertex units (granularity >
        # 1, DESIGN.md section 12), so it distinguishes executables too.
        # traced variants live under distinct keys: an untraced server keeps
        # exactly the pre-observability executables (disabled = identity).
        key = (f, stop, W, backend, task_width, work_fn, traced)
        if key not in cache:
            def core(mq, lane_id, state, counters, quota, job_id):
                # lane extraction/writeback is traced: one dispatch per
                # scheduler step instead of a shower of eager slice ops.
                aux = {}
                ops = fused_lane_ops(W, backend, lane_id, job_id,
                                     quota=quota, aux=aux,
                                     task_width=task_width)
                # always_run_body: a granted lane advances even on a
                # zero-valid pop (PageRank's in-body rescan must tick).
                mq, state, _, n_valid = wavefront_step(
                    f, None, ops, (mq, state, jnp.int32(0), jnp.int32(0)),
                    always_run_body=True)
                counters = counters + jnp.stack(
                    [n_valid, aux["vertices"], aux["mismatch"]])
                stopped = (jnp.bool_(False) if stop is None
                           else stop(state))
                return mq, state, counters, stopped, n_valid

            if traced:
                @jax.jit
                def step(mq, lane_id, state, counters, quota, job_id,
                         ring, round_ix):
                    size_before = mq.lane(lane_id).size
                    work0 = work_fn(state) if work_fn is not None else 0
                    mq, state, counters, stopped, n_valid = core(
                        mq, lane_id, state, counters, quota, job_id)
                    work1 = work_fn(state) if work_fn is not None else 0
                    size_after = mq.lane(lane_id).size
                    ring = ring.record(
                        round=round_ix, lane=lane_id,
                        queue_size=size_before, pops=n_valid,
                        pushes=size_after - size_before + n_valid,
                        work=work1 - work0)
                    return mq, state, counters, stopped, ring
            else:
                @jax.jit
                def step(mq, lane_id, state, counters, quota, job_id):
                    return core(mq, lane_id, state, counters, quota,
                                job_id)[:4]

            cache[key] = step
        return cache[key]

    def _empty_step_for(self, on_empty, stop, backend: str,
                        traced: bool = False):
        cache = self.registry.empty_step_cache
        key = (on_empty, stop, backend, traced)
        if key not in cache:
            def core(mq, lane_id, state, job_id):
                out, mask, state = on_empty(state)
                mq = mq.push(lane_id, pack(job_id, out), mask,
                             backend=backend)
                stopped = (jnp.bool_(False) if stop is None
                           else stop(state))
                return mq, state, stopped

            if traced:
                @jax.jit
                def step(mq, lane_id, state, job_id, ring, round_ix):
                    size_before = mq.lane(lane_id).size
                    mq, state, stopped = core(mq, lane_id, state, job_id)
                    ring = ring.record(
                        round=round_ix, lane=lane_id,
                        queue_size=size_before, pops=0,
                        pushes=mq.lane(lane_id).size - size_before)
                    return mq, state, stopped, ring
            else:
                step = jax.jit(core)

            cache[key] = step
        return cache[key]

    def _admit(self, job: Job, mq: MultiQueue, lane: int, cfg: SchedulerConfig,
               lane_capacity: int, rounds: int) -> MultiQueue:
        if job.program is None:
            job.program = self.registry.build(
                job.spec, job.job_id, cfg.wavefront, cfg.num_workers,
                lane_capacity, backend=cfg.backend,
                granularity=cfg.granularity,
                split_threshold=cfg.split_threshold)
        prog = job.program
        job.state, seeds = prog.init()
        job.counters = jnp.zeros((3,), jnp.int32)
        job.width_of = (encoding_packed_width(prog.task_width)
                        if cfg.granularity > 1 and prog.task_width is not None
                        else None)
        job.stopped = False
        job.lane = lane
        job.status = "active"
        if job.telemetry is None:  # submit-time round was 0 for batch mode
            job.telemetry = JobTelemetry(
                job_id=job.job_id, algorithm=prog.algorithm,
                graph=prog.graph_name, wavefront=cfg.wavefront,
                ideal_work=prog.ideal_work, granularity=cfg.granularity)
        job.telemetry.admitted_round = rounds
        mq = mq.reset_lane(lane)
        seeds = jnp.asarray(seeds, jnp.int32)
        # seed push stays on the jnp path: it runs once per admission outside
        # the compiled round step, and push results are backend-identical.
        mq = mq.push(lane, pack(job.job_id, seeds),
                     jnp.ones(seeds.shape, bool))
        log.info("admit job %d (%s on %s) -> lane %d at round %d",
                 job.job_id, prog.algorithm, prog.graph_name, lane, rounds)
        return mq

    def _finalize(self, job: Job, mq: MultiQueue, rounds: int) -> MultiQueue:
        prog = job.program
        job.result = np.asarray(prog.result(job.state))
        items, vertices, mismatches = (int(x)
                                       for x in np.asarray(job.counters))
        job.telemetry.items_processed = items
        job.telemetry.vertices_processed = vertices
        job.telemetry.routing_mismatches = mismatches
        job.telemetry.work = int(prog.work(job.state))
        job.telemetry.completed_round = rounds
        job.telemetry.dropped += int(mq.lane(job.lane).dropped)
        if self.strict_drops and job.telemetry.dropped > 0:
            raise RuntimeError(
                f"job {job.job_id} ({prog.algorithm} on {prog.graph_name}) "
                f"dropped {job.telemetry.dropped} tasks to lane overflow — "
                f"its result would be silently wrong.  Raise lane_capacity "
                f"(or pass strict_drops=False for loss-tolerant workloads).")
        job.status = "done"
        mq = mq.reset_lane(job.lane)
        log.info("job %d done at round %d (work=%d, occupancy=%.3f)",
                 job.job_id, rounds, job.telemetry.work,
                 job.telemetry.occupancy)
        job.lane = -1
        return mq

    # -------------------------------------------------------- sharded jobs
    def _run_sharded(self, job: Job, cfg: SchedulerConfig,
                     stats: ServerStats) -> None:
        """Serve one ``shards > 1`` job as a device-wide sharded drain.

        A sharded drain owns the whole mesh (every device runs a queue
        replica plus the exchange/steal collectives), so these jobs run as
        serialized phases before the fused multi-tenant rounds rather than
        as lanes inside them — coexistence at the batch level, not the
        round level (DESIGN.md section 10).
        """
        from .. import shard as _shard
        from ..runtime import build_program

        spec = job.spec
        graph = self.registry.graph(spec.graph)
        scfg = dataclasses.replace(cfg, num_shards=spec.shards,
                                   topology="sharded")
        program = build_program(spec.algorithm, graph, scfg,
                                params=dict(spec.params),
                                queue_capacity=self._lane_capacity)
        log.info("sharded job %d (%s on %s) over %d shards",
                 job.job_id, spec.algorithm, spec.graph, spec.shards)
        state, sstats = _shard.run_sharded(
            program, graph, scfg, queue_capacity=self._lane_capacity,
            trace=self.trace,
            trace_engine=f"server.job{job.job_id}.sharded")
        job.result = np.asarray(program.result(state))
        tel = JobTelemetry(
            job_id=job.job_id, algorithm=spec.algorithm, graph=spec.graph,
            wavefront=scfg.wavefront * spec.shards,  # mesh-wide pop budget
            ideal_work=program.ideal_work)
        tel.admitted_round = tel.completed_round = 0
        tel.rounds_active = sstats.rounds
        tel.items_processed = sstats.items_processed
        tel.work = int(program.work(state))
        tel.dropped = sstats.dropped + sstats.route_dropped
        job.telemetry = tel
        if self.strict_drops and tel.dropped > 0:
            raise RuntimeError(
                f"sharded job {job.job_id} ({spec.algorithm} on "
                f"{spec.graph}) dropped {tel.dropped} tasks to replica "
                f"overflow — its result would be silently wrong.  Raise "
                f"lane_capacity (or pass strict_drops=False).")
        if sstats.mis_routed:
            raise RuntimeError(
                f"sharded job {job.job_id}: {sstats.mis_routed} tasks ran "
                f"off their owner shard (routing invariant violated)")
        job.status = "done"
        stats.sharded_jobs += 1
        stats.sharded_rounds += sstats.rounds
        log.info("sharded job %d done in %d device rounds "
                 "(exchanged=%d donated=%d balance=%.3f)",
                 job.job_id, sstats.rounds, sstats.exchanged,
                 sstats.donated, sstats.occupancy_balance)

    # ------------------------------------------------------ streaming jobs
    def _run_streaming(self, job: Job, cfg: SchedulerConfig,
                       stats: ServerStats) -> None:
        """Serve one streaming job (``spec.stream``) as a dedicated phase.

        A streaming job mutates its graph between drains, so it cannot
        share the fused wavefront (every other lane's kernel is compiled
        against the registry's immutable CSR); like sharded jobs it runs as
        a serialized phase — ``run_stream`` over the spec's delta log, with
        the spec's snapshot/resume posture (repro/stream).  ``shards > 1``
        makes each per-batch drain a device-wide sharded one.
        """
        from ..stream.driver import run_stream

        spec = job.spec
        stream = spec.stream
        graph = self.registry.graph(spec.graph)
        scfg = (dataclasses.replace(cfg, num_shards=spec.shards,
                                    topology="sharded")
                if spec.shards > 1 else
                dataclasses.replace(cfg, topology="single"))
        log.info("streaming job %d (%s on %s): %d delta batches",
                 job.job_id, spec.algorithm, spec.graph, len(stream.deltas))
        res = run_stream(
            spec.algorithm, graph, stream.deltas, scfg,
            params=dict(spec.params), queue_capacity=self._lane_capacity,
            incremental=stream.incremental,
            snapshot_every=stream.snapshot_every,
            checkpoint_dir=stream.checkpoint_dir, resume=stream.resume,
            compact_every=stream.compact_every,
            overlay_slack=stream.overlay_slack,
            trace=self.trace,
            trace_engine=f"server.job{job.job_id}.stream")
        job.result = np.asarray(res.result)
        job.stream_result = res
        tel = JobTelemetry(
            job_id=job.job_id, algorithm=spec.algorithm, graph=spec.graph,
            wavefront=scfg.wavefront * max(spec.shards, 1),
            ideal_work=0)
        tel.admitted_round = tel.completed_round = 0
        tel.rounds_active = res.info["rounds"]
        tel.items_processed = res.info["processed"]
        tel.work = res.info["work"]
        tel.dropped = res.info["dropped"]
        job.telemetry = tel
        if self.strict_drops and tel.dropped > 0:
            raise RuntimeError(
                f"streaming job {job.job_id} ({spec.algorithm} on "
                f"{spec.graph}) dropped {tel.dropped} tasks to queue "
                f"overflow — its result would be silently wrong.  Raise "
                f"lane_capacity (or pass strict_drops=False).")
        job.status = "done"
        stats.streaming_jobs += 1
        stats.stream_batches += len(res.batches)
        log.info("streaming job %d done: %d batches, %d rounds, work=%d",
                 job.job_id, len(res.batches), res.info["rounds"],
                 res.info["work"])

    # ------------------------------------------------------------------ run
    def run(self) -> ServerResult:
        """Drain every submitted job; returns per-job results + telemetry.

        Jobs with ``spec.shards > 1`` are served first as device-wide
        sharded phases; everything else shares the fused multi-tenant
        wavefront that follows.
        """
        cfg = self._resolve_config()
        if getattr(cfg, "kernel", "auto") == "megakernel":
            # the multi-tenant loop is host-driven by design (tenants are
            # admitted/finalized between rounds), so it cannot fuse a
            # tenant's whole drain into one launch — never degrade to the
            # persistent strategy silently.  Streaming jobs are unaffected:
            # their per-batch drains go through stream/driver, which does
            # honor the megakernel.
            log.warning(
                "kernel='megakernel' requested, but the multi-tenant "
                "server loop is host-driven (one dispatch per scheduling "
                "round) and cannot fuse a tenant's drain into one launch; "
                "batch jobs run the per-round wavefront instead (streaming "
                "jobs still drain via the megakernel).  Use "
                "runtime.execute() for a fused single-tenant drain.")
        W = cfg.wavefront
        lane_capacity = self._resolve_lane_capacity()
        stats = ServerStats(wavefront=W)
        trace = self.trace
        ring = trace.ring() if trace is not None else None
        t0 = time.perf_counter()
        for job in self._jobs:
            if job.status != "pending" or job.spec is None:
                continue
            if job.spec.stream is not None:
                self._run_streaming(job, cfg, stats)
            elif job.spec.shards > 1:
                self._run_sharded(job, cfg, stats)
        mq = make_multiqueue(lane_capacity, self.num_lanes)
        pending = deque(j for j in self._jobs if j.status == "pending")
        lane_owner: Dict[int, Job] = {}
        free_lanes = deque(range(self.num_lanes))
        prev_dropped = np.zeros(self.num_lanes, dtype=np.int64)
        backpressured = False
        rounds = 0

        while (pending or lane_owner) and rounds < self.max_rounds:
            # -- one snapshot per round drives completion, backpressure
            # detection, and quota allocation (two scalars-vectors synced;
            # everything else stays on device until a job finalizes).
            sizes = np.asarray(mq.lane_sizes(), dtype=np.int64)
            dropped_now = np.asarray(mq.lane_dropped(), dtype=np.int64)

            # -- completion: convergence predicate wins (its flag was
            # computed inside last round's step); otherwise a drained lane
            # finishes the job iff the program declares empty-means-done
            # (an empty_means_done=False program without a stop keeps
            # running its on_empty refills until max_rounds — the same
            # contract as the other engines, DESIGN.md section 11).
            for lane, job in list(lane_owner.items()):
                done = (job.stopped if job.program.stop is not None
                        else (sizes[lane] == 0
                              and job.program.empty_means_done))
                if done:
                    mq = self._finalize(job, mq, rounds)
                    del lane_owner[lane]
                    free_lanes.append(lane)
                    prev_dropped[lane] = dropped_now[lane] = 0
                    sizes[lane] = 0

            # -- admission control: drops observed last round defer new
            # tenants (the queue is telling us it is over-committed), unless
            # the server is idle and would otherwise deadlock the FIFO.
            if pending and (not backpressured or not lane_owner):
                while pending and free_lanes:
                    lane = free_lanes.popleft()
                    job = pending.popleft()
                    mq = self._admit(job, mq, lane, cfg, lane_capacity,
                                     rounds)
                    lane_owner[lane] = job
                    sizes[lane] = int(mq.lane(lane).size)  # seeded just now
            elif pending and backpressured:
                stats.deferred_admissions += 1
            if not lane_owner:
                break  # everything drained and nothing left to admit

            boosted = np.zeros(self.num_lanes, dtype=bool)
            weights = np.zeros(self.num_lanes)
            for lane, job in lane_owner.items():
                weights[lane] = job.weight
                if dropped_now[lane] > prev_dropped[lane]:
                    boosted[lane] = True
                    job.telemetry.backpressure_events += 1
                    stats.backpressure_events += 1
            backpressured = bool(boosted.any())
            prev_dropped = dropped_now

            # -- quota allocation: slot-denominated at granularity 1
            # (bit-for-bit the pre-granularity behavior); vertex-denominated
            # beyond (DESIGN.md section 12) — lane occupancy is chunk-width
            # weighted and the round budget is the wavefront's vertex
            # capacity W x G, so a coarse-chunk tenant is charged for the
            # vertices it actually advances, not the slots it occupies.
            granular = cfg.granularity > 1
            if granular:
                # one eager ring scan per occupied coarse lane per round
                # (widths live in the task bits; empty lanes are free).
                # Fine enough for the serving loop's O(lanes) host work —
                # an incremental load tracker would save the scan but put
                # a second copy of the occupancy invariant at risk.
                loads = sizes.copy()
                for lane, job in lane_owner.items():
                    if job.width_of is not None and sizes[lane] > 0:
                        loads[lane] = int(
                            mq.lane(lane).vertex_size(job.width_of))
                quotas = self.policy.allocate(loads, weights, boosted,
                                              W * cfg.granularity)
            else:
                quotas = self.policy.allocate(sizes, weights, boosted, W)

            # -- fused wavefront: every granted lane advances this round
            for lane, job in lane_owner.items():
                prog = job.program
                quota = int(quotas[lane])
                if quota > 0:
                    step = self._step_for(
                        prog.wavefront_fn, prog.stop, W, cfg.backend,
                        task_width=prog.task_width if granular else None,
                        work_fn=prog.work if trace is not None else None,
                        traced=trace is not None)
                    if trace is not None:
                        mq, job.state, job.counters, stopped, ring = step(
                            mq, lane, job.state, job.counters, quota,
                            job.job_id, ring, rounds)
                    else:
                        mq, job.state, job.counters, stopped = step(
                            mq, lane, job.state, job.counters, quota,
                            job.job_id)
                    job.telemetry.rounds_active += 1
                elif sizes[lane] == 0 and prog.on_empty is not None \
                        and not job.stopped:
                    estep = self._empty_step_for(prog.on_empty, prog.stop,
                                                 cfg.backend,
                                                 traced=trace is not None)
                    if trace is not None:
                        mq, job.state, stopped, ring = estep(
                            mq, lane, job.state, job.job_id, ring, rounds)
                    else:
                        mq, job.state, stopped = estep(
                            mq, lane, job.state, job.job_id)
                    job.telemetry.rounds_active += 1
                else:
                    continue
                if prog.stop is not None:
                    job.stopped = bool(stopped)

            rounds += 1

        if pending or lane_owner:
            unfinished = [j.job_id for j in self._jobs if j.status != "done"]
            raise RuntimeError(
                f"server hit max_rounds={self.max_rounds} with unfinished "
                f"jobs {unfinished}")

        stats.rounds = rounds
        stats.wall_seconds = time.perf_counter() - t0
        stats.items_processed = sum(
            j.telemetry.items_processed for j in self._jobs)
        if trace is not None:
            trace.drain(ring, engine="server")
            trace.add_metric(stats.as_dict())
            latency = trace.histogram("job_latency_rounds")
            delay = trace.histogram("job_queue_delay_rounds")
            for j in self._jobs:
                tel = j.telemetry
                if tel is None:
                    continue
                trace.add_metric(tel.as_dict())
                if tel.latency_rounds >= 0:
                    latency.add(tel.latency_rounds)
                if tel.queue_delay_rounds >= 0:
                    delay.add(tel.queue_delay_rounds)
                # per-job distribution: one sample per drain the job ran —
                # each delta batch for a streaming job, the whole drain for
                # a batch job — so p50/p99 are meaningful per tenant.
                per_job = trace.histogram(f"job{j.job_id}_latency_rounds")
                if j.stream_result is not None:
                    per_job.extend(b.rounds
                                   for b in j.stream_result.batches)
                elif tel.latency_rounds >= 0:
                    per_job.add(tel.latency_rounds)
        return ServerResult(
            results={j.job_id: j.result for j in self._jobs},
            telemetry={j.job_id: j.telemetry for j in self._jobs},
            stats=stats,
        )


def serve_sequential(
    registry: JobRegistry,
    specs: List[JobSpec],
    config: Optional[SchedulerConfig] = None,
    lane_capacity: Optional[int] = None,
    max_rounds: int = 1 << 17,
) -> ServerResult:
    """Baseline: each job runs alone (single lane, full wavefront).

    Total rounds are the sum over jobs — what a tenant-at-a-time deployment
    pays.  Job ids match submission order so results are comparable 1:1 with
    a fused :class:`TaskServer` run over the same specs.
    """
    results: Dict[int, np.ndarray] = {}
    telemetry: Dict[int, JobTelemetry] = {}
    stats = ServerStats()
    t0 = time.perf_counter()
    for i, spec in enumerate(specs):
        server = TaskServer(registry, num_lanes=1, config=config,
                            policy="weighted", lane_capacity=lane_capacity,
                            max_rounds=max_rounds)
        server.submit(spec)
        out = server.run()
        results[i] = out.results[0]
        tel = out.telemetry[0]
        tel.job_id = i
        telemetry[i] = tel
        stats.rounds += out.stats.rounds
        stats.items_processed += out.stats.items_processed
        stats.backpressure_events += out.stats.backpressure_events
        stats.wavefront = out.stats.wavefront
    stats.wall_seconds = time.perf_counter() - t0
    return ServerResult(results=results, telemetry=telemetry, stats=stats)
