"""The execution-policy axis: (topology) x (kernel strategy).

Atos exposes orthogonal scheduling controls — kernel strategy
(persistent/discrete), worker granularity, load-balancing mode — and the
runtime layer adds the deployment topology on top:

    topology:  single  | fused  | sharded
    kernel:    persistent | discrete

``single``  — one TaskQueue, one device: the classic Atos drain.
``fused``   — the drain runs through a packed (job_id, payload) MultiQueue
              lane, i.e. the task server's engine; a single-tenant fused run
              is the degenerate one-lane case, and the multi-tenant server
              interleaves many programs through the same step.
``sharded`` — per-device queue replicas over a 1-D ``("shard",)`` mesh with
              routed exchange and optional stealing (repro/shard).

``persistent`` wraps the drain in one ``lax.while_loop`` (zero host
round-trips); ``discrete`` dispatches one jitted round per host-loop
iteration.  Every :class:`~repro.runtime.program.AtosProgram` runs under all
six combinations unchanged — that 3x2 matrix is what the parity tests
(tests/test_runtime.py) pin down.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

TOPOLOGIES: Tuple[str, ...] = ("single", "fused", "sharded")
KERNELS: Tuple[str, ...] = ("persistent", "discrete")


@dataclasses.dataclass(frozen=True)
class ExecutionPolicy:
    """One cell of the (topology x kernel) matrix."""

    topology: str = "single"
    kernel: str = "persistent"

    def __post_init__(self):
        if self.topology not in TOPOLOGIES:
            raise ValueError(f"unknown topology {self.topology!r}; "
                             f"expected one of {TOPOLOGIES}")
        if self.kernel not in KERNELS:
            raise ValueError(f"unknown kernel strategy {self.kernel!r}; "
                             f"expected one of {KERNELS}")

    @property
    def persistent(self) -> bool:
        return self.kernel == "persistent"

    def __str__(self) -> str:
        return f"{self.topology}.{self.kernel}"


#: every policy combination, row-major over (topology, kernel)
POLICY_GRID: Tuple[ExecutionPolicy, ...] = tuple(
    ExecutionPolicy(t, k) for t in TOPOLOGIES for k in KERNELS
)


def parse_policy(text: str) -> ExecutionPolicy:
    """Parse ``"fused.discrete"``-style policy names (CLI / cache keys)."""
    parts = text.split(".")
    if len(parts) != 2:
        raise ValueError(
            f"bad policy {text!r}; expected '<topology>.<kernel>' like "
            f"'single.persistent'")
    return ExecutionPolicy(parts[0], parts[1])


def policy_of(cfg) -> ExecutionPolicy:
    """Resolve a :class:`~repro.core.scheduler.SchedulerConfig`'s policy.

    ``topology="auto"`` resolves to ``sharded`` iff ``num_shards > 1``; an
    explicit non-sharded topology with ``num_shards > 1`` is a
    contradiction and raises rather than silently dropping the mesh.
    """
    topology = cfg.topology
    if topology == "auto":
        topology = "sharded" if cfg.num_shards > 1 else "single"
    elif topology != "sharded" and cfg.num_shards > 1:
        raise ValueError(
            f"topology={topology!r} is incompatible with "
            f"num_shards={cfg.num_shards}; use topology='sharded' (or 'auto')")
    return ExecutionPolicy(topology,
                           "persistent" if cfg.persistent else "discrete")


def config_for(cfg, policy: ExecutionPolicy):
    """A config whose resolved policy is ``policy`` (other axes unchanged)."""
    return dataclasses.replace(cfg, topology=policy.topology,
                               persistent=policy.persistent)
