"""The execution-policy axis: (topology) x (kernel strategy) x (granularity).

Atos exposes orthogonal scheduling controls — kernel strategy
(persistent/discrete), worker granularity, load-balancing mode — and the
runtime layer adds the deployment topology on top:

    topology:     single  | fused  | sharded
    kernel:       persistent | discrete | megakernel
    granularity:  g1 | g2 | g4 | ... (max chunk width, core/task.py)

``single``  — one TaskQueue, one device: the classic Atos drain.
``fused``   — the drain runs through a packed (job_id, payload) MultiQueue
              lane, i.e. the task server's engine; a single-tenant fused run
              is the degenerate one-lane case, and the multi-tenant server
              interleaves many programs through the same step.
``sharded`` — per-device queue replicas over a 1-D ``("shard",)`` mesh with
              routed exchange and optional stealing (repro/shard).

``persistent`` wraps the drain in one ``lax.while_loop`` (zero host
round-trips); ``discrete`` dispatches one jitted round per host-loop
iteration; ``megakernel`` fuses the whole drain loop into a single Pallas
kernel launch with in-kernel DMA-streamed CSR expansion
(``kernels/drain_loop``, DESIGN.md §14) — bit-identical results, ONE
kernel entry per drain.  ``sharded.megakernel`` is the one invalid cell:
the sharded round is a cross-device collective (routed all_to_all
exchange) that cannot run inside a single device-resident kernel.

``granularity`` is the paper's task-parallel granularity control
(DESIGN.md section 12): how many consecutive CSR rows one queue slot may
carry.  ``1`` reproduces the single-vertex task stream bit-for-bit; wider
chunks trade scheduling overhead against load-balancing freedom.  In
policy names it is spelled as a ``.g<width>`` suffix — omitted for the
default width 1, so every pre-granularity policy string still parses to
the same cell.

Every :class:`~repro.runtime.program.AtosProgram` runs under every valid
cell of the 3 x 3 x G matrix unchanged — the parity tests
(tests/test_runtime.py, tests/test_megakernel.py) pin the full 8-cell grid
(3 x 3 minus ``sharded.megakernel``) at g = 1 and g = 4.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from ..core.task import MAX_GRANULARITY

TOPOLOGIES: Tuple[str, ...] = ("single", "fused", "sharded")
KERNELS: Tuple[str, ...] = ("persistent", "discrete", "megakernel")


def _valid_cell(topology: str, kernel: str) -> bool:
    """``sharded.megakernel`` is the single invalid (topology, kernel) pair:
    the sharded round's routed exchange is a cross-device collective, and a
    megakernel is by definition one device-resident launch."""
    return not (topology == "sharded" and kernel == "megakernel")


def _matrix_help() -> str:
    """One shared enumeration of the policy matrix for error messages."""
    cells = ", ".join(f"{t}.{k}" for t in TOPOLOGIES for k in KERNELS
                      if _valid_cell(t, k))
    return (f"valid cells are '<topology>.<kernel>[.g<width>]' with "
            f"topology x kernel in {{{cells}}} and an optional granularity "
            f"suffix g1..g{MAX_GRANULARITY} (omitted = g1)")


@dataclasses.dataclass(frozen=True)
class ExecutionPolicy:
    """One cell of the (topology x kernel x granularity) matrix."""

    topology: str = "single"
    kernel: str = "persistent"
    granularity: int = 1

    def __post_init__(self):
        if self.topology not in TOPOLOGIES:
            raise ValueError(f"unknown topology {self.topology!r}; "
                             f"expected one of {TOPOLOGIES} — "
                             f"{_matrix_help()}")
        if self.kernel not in KERNELS:
            raise ValueError(f"unknown kernel strategy {self.kernel!r}; "
                             f"expected one of {KERNELS} — "
                             f"{_matrix_help()}")
        if not _valid_cell(self.topology, self.kernel):
            raise ValueError(
                "sharded.megakernel is not a valid cell: the megakernel "
                "fuses one device's whole drain into a single kernel "
                "launch, but the sharded topology routes tasks between "
                "devices every round (a collective that cannot run inside "
                f"a resident kernel) — {_matrix_help()}")
        if not 1 <= self.granularity <= MAX_GRANULARITY:
            raise ValueError(
                f"bad granularity {self.granularity!r}; expected an int in "
                f"[1, {MAX_GRANULARITY}] — {_matrix_help()}")

    @property
    def persistent(self) -> bool:
        """True for the device-resident strategies (``persistent`` and
        ``megakernel``): code that only knows the legacy bool treats a
        megakernel drain as persistent-style, which is the safe
        *result*-preserving degradation (one launch, zero host
        round-trips).  It is not license to degrade silently — dispatch
        paths that cannot honor the megakernel either route it explicitly
        (``core.scheduler.run``) or warn (``server.engine.TaskServer``)
        rather than consult only this bool."""
        return self.kernel != "discrete"

    def __str__(self) -> str:
        base = f"{self.topology}.{self.kernel}"
        return base if self.granularity == 1 else \
            f"{base}.g{self.granularity}"


#: every valid (topology, kernel) combination at the default granularity,
#: row-major — the finite slice of the matrix tests and CLIs enumerate
#: (granularity is unbounded; name a cell with a ``.g<width>`` suffix).
#: 8 cells: 3 x 3 minus the invalid ``sharded.megakernel``.
POLICY_GRID: Tuple[ExecutionPolicy, ...] = tuple(
    ExecutionPolicy(t, k) for t in TOPOLOGIES for k in KERNELS
    if _valid_cell(t, k)
)


def parse_policy(text: str) -> ExecutionPolicy:
    """Parse ``"fused.discrete"`` / ``"sharded.persistent.g4"``-style policy
    names (CLI / cache keys).  The granularity segment is optional and
    defaults to 1, so pre-granularity policy strings parse unchanged."""
    parts = text.split(".")
    if len(parts) not in (2, 3):
        raise ValueError(
            f"bad policy {text!r}; expected '<topology>.<kernel>' like "
            f"'single.persistent' or '<topology>.<kernel>.g<width>' like "
            f"'sharded.persistent.g4' — {_matrix_help()}")
    granularity = 1
    if len(parts) == 3:
        seg = parts[2]
        if not (seg.startswith("g") and seg[1:].isdigit()):
            raise ValueError(
                f"bad granularity segment {seg!r} in policy {text!r}; "
                f"expected 'g<width>' like 'g4' — {_matrix_help()}")
        granularity = int(seg[1:])
    return ExecutionPolicy(parts[0], parts[1], granularity)


def policy_of(cfg) -> ExecutionPolicy:
    """Resolve a :class:`~repro.core.scheduler.SchedulerConfig`'s policy.

    ``topology="auto"`` resolves to ``sharded`` iff ``num_shards > 1``; an
    explicit non-sharded topology with ``num_shards > 1`` is a
    contradiction and raises rather than silently dropping the mesh.
    ``kernel="auto"`` (the config default) defers to the legacy
    ``persistent`` bool, so every pre-megakernel config resolves exactly
    as before; an explicit kernel name wins over the bool.
    ``granularity`` is carried through verbatim (validated against the
    matrix bounds by :class:`ExecutionPolicy`).
    """
    topology = cfg.topology
    if topology == "auto":
        topology = "sharded" if cfg.num_shards > 1 else "single"
    elif topology != "sharded" and cfg.num_shards > 1:
        raise ValueError(
            f"topology={topology!r} is incompatible with "
            f"num_shards={cfg.num_shards}; use topology='sharded' (or "
            f"'auto') — {_matrix_help()}")
    kernel = getattr(cfg, "kernel", "auto")
    if kernel == "auto":
        kernel = "persistent" if cfg.persistent else "discrete"
    return ExecutionPolicy(topology, kernel, getattr(cfg, "granularity", 1))


def config_for(cfg, policy: ExecutionPolicy):
    """A config whose resolved policy is ``policy`` (other axes unchanged).

    Both kernel fields are written: the explicit ``kernel`` name (which
    :func:`policy_of` reads back) and the legacy ``persistent`` bool
    (True for both device-resident strategies) for code that predates the
    three-valued axis.
    """
    return dataclasses.replace(cfg, topology=policy.topology,
                               kernel=policy.kernel,
                               persistent=policy.persistent,
                               granularity=policy.granularity)
