"""``execute`` — one front door for every (program, policy) combination.

The three drain engines (single-device scheduler, fused MultiQueue lane,
sharded device mesh) share the :func:`~repro.core.scheduler.wavefront_step`
core and differ only in their :class:`~repro.core.scheduler.QueueOps` and
host-vs-device loop; this module is the dispatch that picks the driver from
the config's resolved :class:`~repro.runtime.policy.ExecutionPolicy` and
normalizes the outcome to ``(state, RunStats, info)`` so callers (algorithm
drivers, the autotuner, benchmarks, tests) never branch on topology.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, NamedTuple, Optional

import jax.numpy as jnp

from ..core.backend import STREAM
from ..core.queue import make_multiqueue, make_queue
from ..core.scheduler import (QueueOps, RunStats, SchedulerConfig,
                              continuation, discrete_drive, megakernel_drive,
                              persistent_drive, taskqueue_ops, wavefront_step)
from ..obs import Trace
from .policy import ExecutionPolicy, policy_of
from .program import AtosProgram, ProgramContext


class ExecutionResult(NamedTuple):
    state: Any
    stats: RunStats
    info: dict


def _context(cfg: SchedulerConfig) -> ProgramContext:
    return ProgramContext(wavefront=cfg.wavefront,
                          num_workers=cfg.num_workers,
                          backend=cfg.backend,
                          granularity=cfg.granularity)


def fused_lane_ops(wavefront: int, backend: str, lane_id, job_id,
                   quota=None, aux: Optional[dict] = None,
                   task_width=None) -> QueueOps:
    """QueueOps over one packed MultiQueue lane — the task server's engine.

    Tasks on the wire are ``(job_id, zigzag(payload))`` int32s; the pop
    unpacks naturals for the body, the push re-packs.  ``lane_id``,
    ``job_id`` and ``quota`` may be traced scalars, so one compiled step
    serves every tenant sharing a kernel bundle (DESIGN.md section 8).
    ``aux``, if given, receives the per-pop routing-mismatch count
    (``aux["mismatch"]``) — the multi-tenant engine's wire-integrity meter.
    ``task_width`` (a *natural*-task -> chunk-width function, core/task.py)
    switches the quota to vertex units: the pop takes the longest slot
    prefix whose summed chunk widths fit the grant, so coarse-chunk lanes
    are charged for the vertices they actually advance.
    """
    from ..server.encoding import (pack, packed_width, unpack_job,
                                   unpack_natural)  # lazy: server->core

    width_of = None if task_width is None else packed_width(task_width)

    def pop(mq):
        packed, valid, mq2 = mq.pop_lane(lane_id, wavefront, quota,
                                         width_of=width_of)
        natural = jnp.where(valid, unpack_natural(packed), 0)
        if aux is not None:
            aux["mismatch"] = jnp.sum(
                (valid & (unpack_job(packed) != job_id)).astype(jnp.int32))
            # vertices the pop actually advanced: chunk-width weighted under
            # granularity (the occupancy numerator, DESIGN.md section 12);
            # one vertex per valid slot at G = 1.
            if width_of is None:
                aux["vertices"] = jnp.sum(valid.astype(jnp.int32))
            else:
                aux["vertices"] = jnp.sum(
                    jnp.where(valid, width_of(packed), 0).astype(jnp.int32))
        return natural, valid, mq2

    def push(mq, items, mask):
        return mq.push(lane_id, pack(job_id, items), mask, backend=backend)

    return QueueOps(pop=pop, push=push, size=lambda mq: mq.size)


def shared_queue_capacity(program: AtosProgram,
                          queue_capacity: Optional[int]) -> int:
    """The single/fused capacity rule — deterministic, so a snapshot restore
    (repro/stream) rebuilds an identically-shaped queue template."""
    return queue_capacity or program.default_queue_capacity


def _shared_setup(program: AtosProgram, graph, cfg: SchedulerConfig,
                  policy: ExecutionPolicy, queue_capacity: Optional[int],
                  *, init=None, queue=None):
    """Build the drain bundle for the single / fused topologies.

    Returns ``(queue, state, ops, step, cond, dropped_of)`` — everything a
    driver needs to run :func:`~repro.core.scheduler.wavefront_step` to a
    fixed point.  ``init=(state, seeds)`` overrides ``program.init()`` (the
    streaming driver passes the dirty-seed reseed here); ``queue`` bypasses
    seed placement entirely (snapshot restore hands back a mid-drain queue).
    """
    state, seeds = program.init() if init is None else init
    seeds = jnp.asarray(seeds, jnp.int32)
    capacity = shared_queue_capacity(program, queue_capacity)
    ctx = _context(cfg)
    mega = policy.kernel == "megakernel"
    if mega:
        # the megakernel body expands through the in-kernel DMA stream
        # (backend.STREAM, kernels/drain_loop/csr_stream); its queue ops run
        # on the jnp reference — a nested compaction kernel inside the fused
        # drain would add launch structure without changing a bit.
        ctx = ctx._replace(backend=STREAM)
        cfg = dataclasses.replace(cfg, backend="jnp")
    f = program.body(graph, ctx)
    on_empty = program.on_empty(graph, ctx)

    if policy.topology == "single":
        if queue is None:
            queue = make_queue(capacity, seeds)
        ops = taskqueue_ops(cfg)
        dropped_of = lambda q: q.dropped
    else:  # fused: the degenerate one-lane, one-tenant server drain
        from ..server.encoding import check_job_fits, pack
        if graph is not None:
            check_job_fits(0, graph.num_vertices)
        if queue is None:
            queue = make_multiqueue(capacity, 1).push(
                0, pack(0, seeds), jnp.ones(seeds.shape, bool))
        ops = fused_lane_ops(cfg.wavefront, cfg.backend, lane_id=0, job_id=0)
        dropped_of = lambda mq: jnp.sum(mq.lanes.dropped)

    cond = continuation(ops, cfg, program.stop, program.empty_means_done)
    step = lambda carry: wavefront_step(f, on_empty, ops, carry)
    return queue, state, ops, step, cond, dropped_of


def instrument_step(step, cond, ops: QueueOps, program: Optional[AtosProgram],
                    *, lane: int = 0):
    """Wrap a 4-tuple drain ``(step, cond)`` to thread a TraceRing.

    The traced carry is ``(*inner, ring)`` — the ring rides **last**, so the
    ``carry[2]``/``carry[3]`` index conventions every driver relies on are
    untouched.  Each round appends one structured record (pre-pop queue
    size, pops, pushes, per-round work/split deltas) with pure in-trace
    ``.at[]`` writes — zero host syncs; the wrapped ``cond`` simply strips
    the ring.  Work/splits deltas come from ``program.work``/``.splits``
    when declared (traced scalars), else 0.
    """
    work_of = program.work if program is not None else None
    splits_of = program.splits if program is not None else None

    def traced_step(carry):
        *inner, ring = carry
        q0, s0, r0, p0 = inner
        size_before = ops.size(q0)
        q1, s1, r1, p1 = step((q0, s0, r0, p0))
        pops = p1 - p0
        ring = ring.record(
            round=r0, lane=lane, queue_size=size_before, pops=pops,
            pushes=ops.size(q1) - size_before + pops,
            work=(work_of(s1) - work_of(s0)) if work_of is not None else 0,
            splits=(splits_of(s1) - splits_of(s0))
                   if splits_of is not None else 0,
            donated=0, exchanged=0)
        return q1, s1, r1, p1, ring

    def traced_cond(carry):
        return cond(tuple(carry[:4]))

    return traced_step, traced_cond


def _run_shared_core(program: AtosProgram, graph, cfg: SchedulerConfig,
                     policy: ExecutionPolicy, queue_capacity: Optional[int],
                     trace):
    """single / fused topologies: same step core, different QueueOps."""
    obs = trace if isinstance(trace, Trace) else None
    legacy = trace if isinstance(trace, list) else None
    queue, state, ops, step, cond, dropped_of = _shared_setup(
        program, graph, cfg, policy, queue_capacity)
    carry0 = (queue, state, jnp.int32(0), jnp.int32(0))
    ring = None
    if obs is not None:
        # tracing on: identical drain with the ring as one extra carry leaf
        step, cond = instrument_step(step, cond, ops, program)
        carry0 = carry0 + (obs.ring(),)
    span = (obs.span(f"execute {policy}") if obs is not None
            else contextlib.nullcontext())
    with span:
        if policy.kernel == "megakernel":
            carry = megakernel_drive(step, cond, carry0)
        elif policy.persistent:
            carry = persistent_drive(step, cond, carry0)
        else:
            carry = discrete_drive(step, cond, ops, carry0, trace=legacy)
    queue, state, rounds, processed = carry[:4]
    if obs is not None:
        ring = carry[4]
    stats = RunStats(rounds, processed, dropped_of(queue))
    info = {
        "rounds": int(stats.rounds),
        "work": program.work_of(state),
        "dropped": int(stats.dropped),
        "splits": program.splits_of(state),
        # kernel-entry events per drain: persistent/discrete re-enter the
        # expand/push kernels every round (one host dispatch per round for
        # discrete; one while-loop iteration per round for persistent);
        # the megakernel is ONE launch for the whole drain (DESIGN.md §14)
        "launches": 1 if policy.kernel == "megakernel" else int(rounds),
    }
    if obs is not None:
        obs.drain(ring, engine=str(policy))
        obs.add_metric(run_doc(policy, stats, info))
    return ExecutionResult(state, stats, info)


def run_doc(policy, stats: RunStats, info: dict) -> dict:
    """Serialize a single/fused run summary into the canonical ``run`` doc."""
    from ..obs.schema import metric_doc

    return metric_doc(
        "run", policy=str(policy), rounds=int(stats.rounds),
        items_processed=int(stats.items_processed),
        dropped=int(stats.dropped), work=int(info.get("work", 0)),
        splits=int(info.get("splits", 0)),
        launches=int(info.get("launches", 0)))


def _run_sharded(program: AtosProgram, graph, cfg: SchedulerConfig,
                 queue_capacity, trace, route_width, mesh):
    from .. import shard as _shard  # lazy: shard imports this package

    state, sstats = _shard.run_sharded(
        program, graph, cfg, queue_capacity=queue_capacity,
        route_width=route_width, mesh=mesh, trace=trace)
    stats = RunStats(jnp.int32(sstats.rounds),
                     jnp.int32(sstats.items_processed),
                     jnp.int32(sstats.dropped + sstats.route_dropped))
    info = {
        "rounds": sstats.rounds,
        "work": program.work_of(state),
        "dropped": sstats.dropped + sstats.route_dropped,
        "splits": program.splits_of(state),
        "shards": len(sstats.per_device_items),
        "exchanged": sstats.exchanged,
        "donated": sstats.donated,
        "steal_rounds": sstats.steal_rounds,
        "mis_routed": sstats.mis_routed,
        "occupancy_balance": sstats.occupancy_balance,
        # wire accounting (DESIGN.md §16): per-axis cross-device volume,
        # payload vs padding, metered wire ints, and the overlap pipeline
        "exchanged_row": sstats.exchanged_row,
        "exchanged_col": sstats.exchanged_col,
        "payload_ints": sstats.payload_ints,
        "padding_ints": sstats.padding_ints,
        "wire_ints": sstats.wire_ints,
        "deferred": sstats.deferred_delivered,
        "overlap_rounds": sstats.overlap_rounds,
        "overlap_occupancy": sstats.overlap_occupancy,
    }
    return ExecutionResult(state, stats, info)


def execute(
    program: AtosProgram,
    graph,
    cfg: SchedulerConfig,
    *,
    queue_capacity: Optional[int] = None,
    trace: Optional[Any] = None,
    route_width: Optional[int] = None,
    mesh=None,
) -> ExecutionResult:
    """Drain ``program`` under the config's resolved execution policy.

    Returns ``(final_state, RunStats, info)``; ``info`` carries the
    per-topology telemetry (exchange/steal meters for sharded runs; for
    single/fused runs ``info["launches"]`` counts kernel-entry events per
    drain — O(rounds) for persistent/discrete, 1 for the megakernel).
    ``trace`` accepts either an :class:`~repro.obs.Trace` — the unified
    observability collector (DESIGN.md §15): a device-side ring buffer rides
    the drain carry under *every* policy, recording one structured row per
    round with zero host syncs, drained into the collector at run end
    alongside a canonical run-summary doc — or, for backward compatibility,
    a plain ``list``, honored by the discrete kernel strategy only
    (per-round ``(size, items)`` tuples).  ``trace=None`` (default) builds
    exactly the untraced computation — no ring, no wrapped step.
    """
    policy = policy_of(cfg)
    if policy.topology == "sharded":
        return _run_sharded(program, graph, cfg, queue_capacity, trace,
                            route_width, mesh)
    return _run_shared_core(program, graph, cfg, policy, queue_capacity,
                            trace)


def stream_execute(
    algorithm,
    graph,
    deltas,
    cfg: SchedulerConfig,
    *,
    params: Optional[dict] = None,
    queue_capacity: Optional[int] = None,
    incremental: bool = True,
    snapshot_every: int = 0,
    checkpoint_dir: Optional[str] = None,
    keep: int = 3,
    resume: bool = False,
    route_width: Optional[int] = None,
    mesh=None,
    snapshot_hook=None,
    trace: Optional[Trace] = None,
    compact_every: int = 0,
    overlay_slack: float = 0.25,
):
    """Run ``algorithm`` as a long-lived streaming job over a mutating graph.

    Batch 0 drains the base ``graph``; each subsequent batch commits one
    :class:`~repro.stream.deltas.EdgeDelta` from ``deltas`` — an O(touched
    rows) in-place slotted-CSR commit (``graph/slotted.py``), never a full
    rebuild — re-seeds only the dirtied frontier (the program's
    ``dirty_seeds`` rule, unless ``incremental=False`` forces the
    full-recompute baseline), and drains again — under any of the six
    execution policies ``cfg`` resolves to.  ``compact_every`` /
    ``overlay_slack`` steer the slab compaction schedule: compact every N
    batches, and whenever the edge-log overlay exceeds ``overlay_slack *
    m`` (a slab-slack violation always forces one).
    ``snapshot_every > 0`` (with ``checkpoint_dir``) writes crash-consistent
    mid-drain snapshots every that-many rounds; ``resume=True`` continues
    from the newest one.  ``algorithm`` is a registered program name (the
    program must be *rebuilt* per batch — its body closes over the graph —
    so an :class:`AtosProgram` instance is accepted only as a name carrier).
    Returns a :class:`~repro.stream.driver.StreamResult`.
    """
    from ..stream.driver import run_stream  # lazy: stream imports runtime

    if isinstance(algorithm, AtosProgram):
        algorithm = algorithm.name
    return run_stream(
        algorithm, graph, deltas, cfg, params=params,
        queue_capacity=queue_capacity, incremental=incremental,
        snapshot_every=snapshot_every, checkpoint_dir=checkpoint_dir,
        keep=keep, resume=resume, route_width=route_width, mesh=mesh,
        snapshot_hook=snapshot_hook, trace=trace,
        compact_every=compact_every, overlay_slack=overlay_slack)
