"""The Atos runtime layer: programs x execution policies (DESIGN.md §11).

Applications declare *what* a task does once — an :class:`AtosProgram`
(wavefront body, stop condition, rescan hook, replica-merge spec) — and an
:class:`ExecutionPolicy` decides *how* it is scheduled: topology
(``single | fused | sharded``) crossed with kernel strategy
(``persistent | discrete | megakernel``).  :func:`execute` is the front
door.

``execute`` / ``build_program`` are imported lazily: the algorithm modules
import :mod:`repro.runtime.program` for the protocol types, and an eager
import here would cycle back through them.
"""
from .policy import (ExecutionPolicy, KERNELS, POLICY_GRID, TOPOLOGIES,
                     config_for, parse_policy, policy_of)
from .program import (AtosProgram, MERGE_RULES, ProgramContext, build_merge,
                      delta_psum, identity_task_vertex)

__all__ = [
    "ExecutionPolicy", "KERNELS", "POLICY_GRID", "TOPOLOGIES",
    "config_for", "parse_policy", "policy_of",
    "AtosProgram", "MERGE_RULES", "ProgramContext", "build_merge",
    "delta_psum", "identity_task_vertex",
    "ExecutionResult", "execute", "fused_lane_ops", "instrument_step",
    "stream_execute", "algorithms", "build_program",
]

_LAZY = {
    "ExecutionResult": "api",
    "execute": "api",
    "fused_lane_ops": "api",
    "instrument_step": "api",
    "stream_execute": "api",
    "algorithms": "programs",
    "build_program": "programs",
}


def __getattr__(name):
    if name in _LAZY:
        import importlib

        module = importlib.import_module(f".{_LAZY[name]}", __name__)
        return getattr(module, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
