"""The :class:`AtosProgram` protocol — declare a drain once, run it anywhere.

Atos's core claim is that one scheduling framework serves many irregular
applications by keeping the application logic orthogonal to the launch
strategy.  Before this layer the repo had three divergent drain engines
(``core/scheduler``, ``server/engine``, ``shard/driver``) and each algorithm
re-implemented its wavefront body, stop condition, rescan hook, and
replica-merge adapter per engine.  An ``AtosProgram`` packages all of that
*once*:

    init()                    -> (state, seed tasks)
    make_body(graph, ctx)     -> WavefrontFn        (the expansion kernel)
    make_on_empty(graph, ctx) -> optional refill    (PageRank's re-scan)
    stop(state)               -> optional convergence predicate
    empty_means_done          -> does a drained queue end the run?
    merge                     -> per-field replica-merge spec (sharded runs)
    task_vertex(task)         -> head vertex id (ownership/routing/stealing)
    task_width(task)          -> chunk width (vertex-denominated occupancy)
    dirty_seeds(delta, state) -> optional incremental re-seed (repro/stream)
    result(state), work(state), ideal_work

The body builders receive a :class:`ProgramContext` describing *where* the
body will run: wavefront width, backend, and — under the sharded topology —
the device's (traced) shard index and the mesh axis name, so a program can
restrict its rescan to the owned vertex block or switch to an
epoch-consistent variant without knowing anything about the driver.

The **merge spec** replaces ``shard/programs.py``'s hand-written per-
algorithm merges.  Each state field declares its reconciliation lattice:

  * ``"pmin"`` / ``"pmax"``   — monotone lattices (BFS dist: the union of
    all relaxations is the elementwise min of the replicas);
  * ``"sum_delta"``           — exact for single-writer-per-round or
    additive fields: ``prev + psum(new - prev)`` reassembles the global
    round (PageRank residue scatter-adds, coloring's unique-target colors,
    every WorkCounter);
  * ``"or_delta"``            — boolean single-writer fields (presence bits);
  * ``"replicated"``          — already identical on every device (cursors
    advanced by the same constant each round): no collective.

A spec may be a dict over dataclass field names, one rule string applied to
the whole state pytree, or a bare callable ``(prev, new, axis_name) ->
merged`` for exotic states.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp


class ProgramContext(NamedTuple):
    """Where a wavefront body is about to run.

    ``shard``/``axis_name`` are ``None`` outside the sharded topology; under
    it, ``shard`` is the device's (traced) mesh index and ``axis_name`` the
    1-D mesh axis, and ``graph`` passed to the builders is the device-local
    CSR slice — static bounds (budgets, max degree) must come from the
    program's construction-time view of the global graph so every device
    traces the identical computation.
    """

    wavefront: int
    num_workers: int
    backend: str = "jnp"
    shard: Any = None            # traced device index | None
    num_shards: int = 1
    axis_name: Optional[str] = None
    granularity: int = 1         # max chunk width G (core/task.py)

    @property
    def sharded(self) -> bool:
        return self.axis_name is not None


def identity_task_vertex(items: jax.Array) -> jax.Array:
    return items


def unit_task_width(items: jax.Array) -> jax.Array:
    """Default ``task_width``: every task is one vertex wide (G = 1)."""
    return jnp.ones(jnp.asarray(items).shape, jnp.int32)


# ------------------------------------------------------------- merge rules
def delta_psum(prev: jax.Array, new: jax.Array, axis_name: str) -> jax.Array:
    """Exact cross-device merge for single-writer / additive round updates."""
    return prev + jax.lax.psum(new - prev, axis_name)


def _or_delta(prev: jax.Array, new: jax.Array, axis_name: str) -> jax.Array:
    d = delta_psum(prev.astype(jnp.int32), new.astype(jnp.int32), axis_name)
    return d > 0


def _merge_work_counter(prev, new, axis_name: str):
    """Field-level merge for a whole :class:`~repro.core.counters.WorkCounter`.

    ``work``/``splits`` are single-writer additive per round (delta-psum,
    exactly ``sum_delta``), but ``rounds`` ticks in lockstep on every replica
    (``wavefront_step`` bumps it unconditionally), so it must be taken as-is
    — delta-summing a replicated tick would multiply the round count by the
    shard count every round.
    """
    from ..core.counters import WorkCounter  # local: keep layering one-way

    assert isinstance(new, WorkCounter), new
    return dataclasses.replace(
        new,
        work=delta_psum(prev.work, new.work, axis_name),
        splits=delta_psum(prev.splits, new.splits, axis_name))


def _is_work_counter(x) -> bool:
    from ..core.counters import WorkCounter

    return isinstance(x, WorkCounter)


#: rules that consume a whole sub-pytree instead of individual array leaves
_merge_work_counter.whole = _is_work_counter  # type: ignore[attr-defined]


MERGE_RULES: Dict[str, Callable] = {
    "pmin": lambda prev, new, axis: jax.lax.pmin(new, axis),
    "pmax": lambda prev, new, axis: jax.lax.pmax(new, axis),
    "sum_delta": delta_psum,
    "or_delta": _or_delta,
    "replicated": lambda prev, new, axis: new,
    "work_counter": _merge_work_counter,
}

MergeSpec = Union[str, Callable, Dict[str, Union[str, Callable]]]


def _leafwise(rule: Callable, prev, new, axis_name: str):
    return jax.tree.map(lambda p, n: rule(p, n, axis_name), prev, new,
                        is_leaf=getattr(rule, "whole", None))


def build_merge(spec: MergeSpec) -> Callable[[Any, Any, str], Any]:
    """Compile a merge spec into ``merge(prev, new, axis_name) -> state``."""
    if callable(spec):
        return spec
    if isinstance(spec, str):
        rule = MERGE_RULES[spec]
        return lambda prev, new, axis: _leafwise(rule, prev, new, axis)
    if isinstance(spec, dict):
        rules = {name: (MERGE_RULES[r] if isinstance(r, str) else r)
                 for name, r in spec.items()}

        def merge(prev, new, axis_name):
            fields = {f.name for f in dataclasses.fields(prev)}
            unknown = set(rules) - fields
            if unknown:
                raise ValueError(
                    f"merge spec names unknown state fields {sorted(unknown)}")
            # a field-spec must be total: silently keeping `prev` for an
            # omitted field would drop that field's per-device updates after
            # every sharded round — wrong state with no error.  Fields that
            # really are identical on every device declare "replicated".
            missing = fields - set(rules)
            if missing:
                raise ValueError(
                    f"merge spec missing rules for state fields "
                    f"{sorted(missing)} (declare 'replicated' for fields "
                    f"that are identical on every device)")
            updates = {
                name: _leafwise(rule, getattr(prev, name), getattr(new, name),
                                axis_name)
                for name, rule in rules.items()
            }
            return dataclasses.replace(prev, **updates)

        return merge
    raise TypeError(f"bad merge spec: {spec!r}")


# ----------------------------------------------------------------- program
@dataclasses.dataclass(frozen=True)
class AtosProgram:
    """One drain, declared once, runnable under every execution policy.

    Construct via the per-algorithm factories (``bfs.make_program`` etc.) or
    directly for synthetic workloads; run via :func:`repro.runtime.execute`
    (any topology), the task server (fused multi-tenant), or
    ``repro.shard.run_sharded`` (device mesh).
    """

    name: str
    init: Callable[[], Tuple[Any, jax.Array]]
    make_body: Callable[..., Callable]       # (graph, ProgramContext) -> f
    result: Callable[[Any], Any]
    make_on_empty: Optional[Callable] = None  # (graph, ctx) -> on_empty fn
    stop: Optional[Callable[[Any], jax.Array]] = None
    #: does a globally empty queue end the drain?  Programs whose body (or
    #: ``on_empty``) legally refills a drained queue — PageRank's rotating
    #: rescan — declare False and must provide ``stop`` (or rely on
    #: ``max_rounds``).  This replaces the old implicit "``on_empty`` is set,
    #: so ignore queue size" inference (DESIGN.md section 11).
    empty_means_done: bool = True
    merge: MergeSpec = "sum_delta"
    #: task -> *head* vertex id; with chunked tasks (core/task.py) routing,
    #: ownership, and steal accounting all key off the chunk head (chunk
    #: formation guarantees every member shares the head's owner).
    task_vertex: Callable[[jax.Array], jax.Array] = identity_task_vertex
    #: task -> chunk width in vertices; drives vertex-denominated queue
    #: occupancy, fairness quotas, and steal plans (DESIGN.md section 12).
    task_width: Callable[[jax.Array], jax.Array] = unit_task_width
    work: Optional[Callable[[Any], jax.Array]] = None
    #: optional state -> chunks split by the formation threshold (the
    #: granularity dial's schedule-deterministic meter; see WorkCounter)
    splits: Optional[Callable[[Any], jax.Array]] = None
    ideal_work: int = 0
    #: capacity hint when the caller does not size the queue explicitly
    default_queue_capacity: int = 1024
    #: optional streaming hook (repro/stream): ``dirty_seeds(applied, state)
    #: -> (state', seeds)`` re-seeds only the frontier invalidated by a
    #: committed edge-delta batch.  ``applied`` is a
    #: :class:`~repro.stream.ingest.AppliedDelta` whose ``new_graph`` is the
    #: graph this program was built on; ``state`` is the previous drain's
    #: final state (shapes match: deltas change edges, never the vertex
    #: count).  ``None`` means "no incremental rule": the stream driver
    #: falls back to a conservative full reseed via ``init()``.
    dirty_seeds: Optional[Callable[[Any, Any], Tuple[Any, jax.Array]]] = None

    # ------------------------------------------------------------- helpers
    def body(self, graph, ctx: ProgramContext):
        return self.make_body(graph, ctx)

    def on_empty(self, graph, ctx: ProgramContext):
        if self.make_on_empty is None:
            return None
        return self.make_on_empty(graph, ctx)

    def merge_fn(self) -> Callable[[Any, Any, str], Any]:
        return build_merge(self.merge)

    def work_of(self, state) -> int:
        if self.work is None:
            return 0
        return int(self.work(state))

    def splits_of(self, state) -> int:
        if self.splits is None:
            return 0
        return int(self.splits(state))

    # ----------------------------------------------------- legacy adapters
    @property
    def algorithm(self) -> str:
        """Deprecated alias (pre-runtime ``ShardProgram.algorithm``)."""
        return self.name

    @property
    def rescans(self) -> bool:
        """Deprecated alias (pre-runtime ``ShardProgram.rescans``)."""
        return not self.empty_means_done
