"""Program registry: compile (algorithm name, graph, config) -> AtosProgram.

The single source of the per-algorithm parameter parsing that used to be
copied between ``shard/programs.build_program`` and
``server/jobs._kernel_bundle``.  Each algorithm module owns exactly one
program definition (``make_program``); adding a workload is now a
single-file drop plus one registry line.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from ..core.scheduler import SchedulerConfig
from ..graph.csr import CSRGraph
from .program import AtosProgram


def _factories():
    # lazy: the algorithm modules import repro.runtime.program at top level
    from ..algorithms import bfs, coloring, pagerank

    return {
        "bfs": bfs.make_program,
        "pagerank": pagerank.make_program,
        "coloring": coloring.make_program,
    }


def algorithms() -> tuple:
    """Registered algorithm names (stable order)."""
    return tuple(sorted(_factories()))


def build_program(algorithm: str, graph: CSRGraph, cfg: SchedulerConfig,
                  params: Optional[Dict[str, Any]] = None,
                  queue_capacity: Optional[int] = None) -> AtosProgram:
    """Compile one drain.  ``params`` mirrors the single-tenant drivers'
    keyword arguments (BFS ``source``/``strategy``, PageRank ``damping``/
    ``eps``/``check_size``, ...); unknown keys raise ``ValueError`` at build
    time, not mid-drain.  All static budgets derive from the *global* graph
    so a sharded run traces the identical body on every device.
    """
    factories = _factories()
    if algorithm not in factories:
        raise ValueError(f"unknown algorithm {algorithm!r}; "
                         f"expected one of {algorithms()}")
    return factories[algorithm](graph, cfg, queue_capacity=queue_capacity,
                                **dict(params or {}))


def reject_unknown_params(algorithm: str, params: Dict[str, Any]) -> None:
    """Shared tail-check for the factories' explicit ``pop`` parsing."""
    if params:
        raise ValueError(
            f"unknown {algorithm} params: {sorted(params)}")
