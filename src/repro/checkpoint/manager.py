"""Checkpointing: atomic, async-capable, elastic-reshard on restore.

Fault-tolerance posture (DESIGN.md section 6):
  * **atomic commit** — writes go to ``step_N.tmp/`` and are renamed to
    ``step_N/`` only after fsync; a crash mid-save never corrupts the latest
    checkpoint;
  * **async save** — the host thread snapshots device arrays (device->host
    copy) and a background thread serializes, so the train loop resumes
    immediately (overlap of checkpoint I/O with compute);
  * **elastic restore** — arrays are stored with their *pytree path*, not
    their device layout; on restore they are ``device_put`` against the
    *current* mesh's shardings, so a job may resume on a different number of
    pods/hosts (elastic scaling);
  * **retention** — keep the newest K checkpoints, delete older ones after a
    successful commit (never before).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


class CheckpointManager:
    """``prefix`` namespaces the step directories (``<prefix>_<step>/``).

    Retention (``keep``) applies per prefix: a drain-snapshot manager
    (``prefix="snap"``, repro/stream) and a train-checkpoint manager
    (default ``"step"``) can share one directory without either's GC
    clobbering the other's retention window.
    """

    def __init__(self, directory: str, keep: int = 3, prefix: str = "step"):
        if not re.fullmatch(r"[A-Za-z][A-Za-z0-9._-]*", prefix):
            raise ValueError(f"bad checkpoint prefix {prefix!r}")
        self.dir = directory
        self.keep = keep
        self.prefix = prefix
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, blocking: bool = True):
        """Snapshot to host, then serialize (optionally in background)."""
        host = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()  # one in-flight async save at a time
        if blocking:
            self._write(step, host, tree)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, tree), daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any, orig_tree: Any):
        tmp = os.path.join(self.dir, f"{self.prefix}_{step}.tmp")
        final = os.path.join(self.dir, f"{self.prefix}_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten_with_paths(host_tree)
        manifest = {}
        for i, (path, arr) in enumerate(sorted(flat.items())):
            fname = f"arr_{i}.npy"
            arr = np.asarray(arr)
            dtype = str(arr.dtype)
            if dtype == "bfloat16":  # npy has no bf16: widen losslessly
                arr = arr.astype(np.float32)
            np.save(os.path.join(tmp, fname), arr)
            manifest[path] = {"file": fname, "shape": list(np.shape(arr)),
                              "dtype": dtype}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "arrays": manifest}, f)
        os.replace(tmp, final)  # atomic commit
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"{self.prefix}_{s}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self):
        steps = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(rf"{re.escape(self.prefix)}_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "manifest.json")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Restore into the structure of ``like``; reshard onto ``shardings``
        (same pytree structure) if given — this is the elastic path."""
        d = os.path.join(self.dir, f"{self.prefix}_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)["arrays"]
        paths_like = _flatten_with_paths(like)
        flat_like, treedef = jax.tree.flatten(like)
        sh_flat = (jax.tree.flatten(shardings)[0]
                   if shardings is not None else [None] * len(flat_like))
        out = []
        keys = list(_flatten_with_paths(like).keys())
        for key, ref, sh in zip(keys, flat_like, sh_flat):
            meta = manifest[key]
            arr = np.load(os.path.join(d, meta["file"]))
            dtype = getattr(ref, "dtype", None) or meta["dtype"]
            if sh is not None:
                out.append(jax.device_put(
                    jax.numpy.asarray(arr, dtype=dtype), sh))
            else:
                out.append(jax.numpy.asarray(arr, dtype=dtype))
        return jax.tree.unflatten(treedef, out)
