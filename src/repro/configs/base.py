"""Model/config system: every assigned architecture is a ``ModelConfig``.

Shapes (assigned per-arch input-shape set):
  train_4k    : seq 4096,   global_batch 256  -> train_step
  prefill_32k : seq 32768,  global_batch 32   -> prefill (forward, KV out)
  decode_32k  : KV 32768,   global_batch 128  -> serve_step (1 new token)
  long_500k   : KV 524288,  global_batch 1    -> serve_step (sub-quadratic only)
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0          # 0 -> d_model // num_heads
    qkv_bias: bool = False
    sliding_window: int = 0    # 0 = full attention
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"      # rmsnorm | layernorm
    act: str = "swiglu"        # swiglu | gelu
    tie_embeddings: bool = False
    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba1: ssm_version=1; mamba2/SSD: ssm_version=2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_version: int = 1
    # hybrid (zamba2): one shared attention block applied every `attn_every`
    attn_every: int = 0
    # encoder-decoder
    encoder_layers: int = 0
    # modality frontend stub: precomputed embeddings appended to the token seq
    frontend: str = "none"     # none | patches | frames
    frontend_len: int = 0      # patches/frames per example
    # numerics / training
    dtype: str = "bfloat16"
    remat: str = "dots"        # none | dots | full
    use_adafactor: bool = False  # 1T-param configs: factored 2nd moment
    # perf variants (section Perf hillclimbs)
    pad_heads_to: int = 0      # TP head alignment (0 = off)
    attn_block: int = 0        # blocked-attention tile (0 = default)
    moe_ep_axis: str = ""      # constrain expert buffers to this mesh axis
    moe_cap_factor_override: float = 0.0  # >0: capacity-factor hillclimb

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def d_inner(self) -> int:  # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(self.d_model // 16, 16)

    def param_count(self) -> int:
        """Exact parameter count from the model's spec tree."""
        from ..models import transformer as _T
        from ..models.params import count_params as _cp
        return _cp(_T.model_spec(self))

    def _analytic_param_count(self) -> int:
        """Analytic estimate (weight matrices only; norms/router/bias
        excluded) — used as a cross-check in tests."""
        d, hd = self.d_model, self.hd
        attn = d * self.num_heads * hd * 2 + d * self.num_kv_heads * hd * 2
        if self.qkv_bias:
            attn += (self.num_heads + 2 * self.num_kv_heads) * hd
        if self.act == "swiglu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        if self.family == "moe":
            moe = self.num_experts * 3 * d * self.d_ff + d * self.num_experts
            layer = attn + moe
        elif self.family == "ssm":
            di, n, dtr = self.d_inner, self.ssm_state, self.dt_rank
            layer = (d * 2 * di + di * self.ssm_conv + di * (dtr + 2 * n)
                     + dtr * di + di * n + di + di * d)
        elif self.family == "hybrid":
            di, n = self.d_inner, self.ssm_state
            mamba = (d * 2 * di + di * self.ssm_conv + di * (self.dt_rank + 2 * n)
                     + self.dt_rank * di + di * n + di + di * d)
            shared = attn + mlp  # one shared block, counted once below
            layer = mamba
            extra = shared
            n_emb = 2 * self.vocab_size * d if not self.tie_embeddings else self.vocab_size * d
            return self.num_layers * layer + extra + n_emb
        else:
            layer = attn + mlp
        n_layers = self.num_layers + self.encoder_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        cross = attn if self.encoder_layers else 0
        return n_layers * layer + self.num_layers * cross + emb

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        total = self.param_count()
        if self.family != "moe":
            return total
        d = self.d_model
        moe_all = self.num_layers * self.num_experts * 3 * d * self.d_ff
        moe_active = self.num_layers * self.num_experts_per_tok * 3 * d * self.d_ff
        return total - moe_all + moe_active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k only for sub-quadratic attention (DESIGN.md section 5)."""
    if shape.name != "long_500k":
        return True
    return (cfg.family in ("ssm", "hybrid")) or cfg.sliding_window > 0
