"""seamless-m4t-medium [audio] — enc-dec, frame-embedding stub [arXiv:2308.11596; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec", num_layers=12,
    encoder_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256206, act="gelu", norm="layernorm",
    frontend="frames", frontend_len=1536)
