"""llava-next-34b [vlm] — anyres tiling patch stub [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm", num_layers=60, d_model=7168,
    num_heads=56, num_kv_heads=8, d_ff=20480, vocab_size=64000,
    head_dim=128, frontend="patches", frontend_len=2880)
