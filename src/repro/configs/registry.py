"""Assigned architecture registry: ``get_config(arch_id)`` + reduced smokes.

One module per architecture (``src/repro/configs/<arch>.py``), each exposing
``CONFIG`` with the exact assigned hyperparameters; ``smoke_config`` shrinks
the same family for 1-CPU tests.  The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses

from .base import ModelConfig
from . import (qwen1_5_110b, minitron_4b, stablelm_1_6b, h2o_danube3_4b,
               llava_next_34b, seamless_m4t_medium, zamba2_1_2b, olmoe_1b_7b,
               kimi_k2_1t, falcon_mamba_7b)

_MODULES = [qwen1_5_110b, minitron_4b, stablelm_1_6b, h2o_danube3_4b,
            llava_next_34b, seamless_m4t_medium, zamba2_1_2b, olmoe_1b_7b,
            kimi_k2_1t, falcon_mamba_7b]

_REGISTRY: dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}
ARCH_IDS = list(_REGISTRY)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")
    return _REGISTRY[arch_id]


def smoke_config(arch_id: str) -> ModelConfig:
    """Reduced same-family config for 1-CPU smoke tests."""
    full = get_config(arch_id)
    kw = dict(
        name=full.name + "-smoke",
        num_layers=2 if full.family != "hybrid" else 4,
        d_model=64, d_ff=128 if full.d_ff else 0, vocab_size=512,
        num_heads=4 if full.num_heads > 1 else 1,
        num_kv_heads=(2 if 1 < full.num_kv_heads < full.num_heads else
                      (4 if full.num_kv_heads == full.num_heads
                       and full.num_heads > 1 else 1)),
        head_dim=16 if full.hd else 0,
        encoder_layers=2 if full.encoder_layers else 0,
        sliding_window=32 if full.sliding_window else 0,
        num_experts=8 if full.num_experts else 0,
        num_experts_per_tok=2 if full.num_experts_per_tok else 0,
        ssm_state=8 if full.ssm_state else 0,
        attn_every=2 if full.attn_every else 0,
        frontend_len=8 if full.frontend_len else 0,
        dtype="float32", remat="none",
    )
    return dataclasses.replace(full, **kw)
