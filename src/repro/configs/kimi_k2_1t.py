"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384e top-8 [arXiv:2501.kimi2; unverified].

Adafactor (factored second moment) keeps optimizer state feasible at 1T
params — see EXPERIMENTS.md memory note.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe", num_layers=61, d_model=7168,
    num_heads=64, num_kv_heads=8, d_ff=2048, vocab_size=163840,
    head_dim=128, num_experts=384, num_experts_per_tok=8,
    use_adafactor=True)
