"""zamba2-1.2b [hybrid] — Mamba2 trunk + shared attn blocks [arXiv:2411.15242; hf].

The two shared attention invocations use a bounded (sliding-window) KV at
long_500k; trunk layers are Mamba2-style (diagonal selective SSM, state 64).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid", num_layers=38, d_model=2048,
    num_heads=32, num_kv_heads=32, d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_version=2, attn_every=19, sliding_window=4096)
