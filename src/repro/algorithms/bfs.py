"""BFS case study — BSP Dijkstra (Alg 1) vs. speculative relaxed-barrier BFS (Alg 2).

BSP BFS is level-synchronous: the frontier at depth d is fully expanded
behind a barrier before depth d+1 starts, so every vertex is first reached on
a shortest path (zero overwork).  Speculative BFS pops a *wavefront* of
vertices from the Atos queue; because the queue mixes depths, a vertex may be
reached first via a non-shortest path and later re-relaxed — the paper's
concurrency-vs-overwork trade.  Both produce exact shortest hop distances.

GPU->TPU adaptation: ``atomicMin(&neighbor.dist, ...)`` becomes a vectorized
``dist.at[nbr].min(cand)`` scatter-min over the wavefront's expanded edges
(order-independent, deterministic).  "Was my relaxation the winner?" is
answered by comparing against the pre-scatter value — the same information
CUDA's atomicMin returns.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from ..core import (ChunkCodec, SchedulerConfig, WorkCounter, chunk_degrees,
                    adjacency_of, chunk_seeds, coalesce_chunks,
                    expand_merge_path, expand_per_item, flatten_chunks)
from ..graph.csr import CSRGraph
from ..runtime.program import AtosProgram, ProgramContext
from ..runtime.programs import reject_unknown_params
from .common import chunking_for, default_work_budget, max_degree_of

INF = jnp.int32(0x7FFFFFFF)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BFSState:
    dist: jax.Array
    counter: WorkCounter


# --------------------------------------------------------------------- BSP
@partial(jax.jit, static_argnums=(2,))
def _bsp_level(graph: CSRGraph, carry, max_degree: int):
    """One level-synchronous step over a dense frontier mask."""
    dist, frontier, level, work = carry
    deg = graph.row_ptr[1:] - graph.row_ptr[:-1]
    # expand every frontier vertex, padded to max_degree (data-parallel flat)
    vids = jnp.arange(graph.num_vertices, dtype=jnp.int32)
    j = jnp.arange(max_degree, dtype=jnp.int32)
    edge = graph.row_ptr[:-1][:, None] + j[None, :]
    in_row = j[None, :] < deg[:, None]
    active = in_row & frontier[:, None]
    nbr = graph.col_idx[jnp.clip(edge, 0, graph.num_edges - 1)]
    cand = jnp.where(active, level + 1, INF)
    new_dist = dist.at[jnp.where(active, nbr, 0)].min(
        jnp.where(active, cand, INF), mode="drop"
    )
    new_frontier = new_dist < dist  # improved this level
    work = work + jnp.sum(active.astype(jnp.int32))
    return new_dist, new_frontier, level + 1, work


def bfs_bsp(graph: CSRGraph, source: int, max_levels: int | None = None):
    """Level-synchronous BFS; host loop per level = discrete BSP kernels."""
    n = graph.num_vertices
    max_degree = int(jnp.max(graph.degrees()))
    dist = jnp.full((n,), INF, jnp.int32).at[source].set(0)
    frontier = jnp.zeros((n,), bool).at[source].set(True)
    level = jnp.int32(0)
    work = jnp.int32(0)
    max_levels = max_levels or n
    levels = 0
    frontier_sizes = []
    while bool(jnp.any(frontier)) and levels < max_levels:
        frontier_sizes.append(int(jnp.sum(frontier)))
        dist, frontier, level, work = _bsp_level(
            graph, (dist, frontier, level, work), max_degree
        )
        levels += 1
    return dist, {"levels": levels, "work": int(work),
                  "frontier_sizes": frontier_sizes}


# ------------------------------------------------------------- speculative
def init_state(graph: CSRGraph, source: int) -> BFSState:
    """Job-parameterized initial state: dist=INF except the source."""
    n = graph.num_vertices
    return BFSState(
        dist=jnp.full((n,), INF, jnp.int32).at[source].set(0),
        counter=WorkCounter.zero(),
    )


def make_wavefront_fn(graph: CSRGraph, strategy: str, work_budget: int,
                      max_degree: int, backend: str = "jnp",
                      codec: ChunkCodec | None = None,
                      split_threshold: int | None = None,
                      owner_block: int | None = None,
                      formation_row_ptr=None):
    """Reusable speculative-BFS wavefront body.

    Closed over the graph only — the returned ``f(items, valid, state)`` is a
    pure :data:`~repro.core.scheduler.WavefrontFn`, so it can drive a
    single-tenant run (``bfs_speculative``) or serve as one tenant's
    expansion logic inside the multi-job task server (``repro.server``).

    ``backend`` selects the merge-path LBS implementation (jnp reference vs
    the Pallas kernel) — outputs are bit-identical either way (DESIGN.md
    section 9).

    ``codec`` makes the body chunk-aware (DESIGN.md section 12): popped
    tasks decode to ``(head, width)`` row runs, the merge-path LBS balances
    chunk degree-*sums*, and improved neighbors are re-coalesced into
    chunks at push time (bounded by ``split_threshold`` and the shard
    ``owner_block``; ``formation_row_ptr`` is the *global* row_ptr — pushed
    vertices may be remote, so formation degree sums cannot come from a
    device-local CSR slice).  The identity codec (G = 1) reproduces the
    single-vertex body bit-for-bit.
    """
    codec = codec or ChunkCodec(1)
    g = codec.granularity
    form_rp = graph.row_ptr if formation_row_ptr is None else formation_row_ptr

    rp, cols, overlay = adjacency_of(graph)

    def f(items, valid, state: BFSState):
        safe = jnp.where(valid, items, 0)
        heads, widths = codec.decode(safe)
        if strategy == "merge_path":      # CTA worker: task+data-parallel LB
            ex = expand_merge_path(heads, valid, rp, cols,
                                   work_budget, backend=backend,
                                   widths=widths, max_width=g,
                                   overlay=overlay)
            # chunks whose rows spill past the work budget are re-queued
            # whole (progress is guaranteed: budget >= max_degree >= any
            # formed chunk's degree-sum, so the first popped task always
            # expands fully).
            deg = chunk_degrees(heads, widths, valid, graph.row_ptr)
            excl = jnp.cumsum(deg) - deg
            truncated = valid & (excl + deg > work_budget)
        else:                             # warp worker: task-parallel only
            flat_v, flat_valid, _ = flatten_chunks(heads, widths, valid, g)
            ex = expand_per_item(flat_v, flat_valid, rp, cols, max_degree,
                                 overlay=overlay)
            truncated = jnp.zeros_like(valid)
        # edges owned by truncated chunks are excluded entirely: the chunk
        # is re-queued whole and will relax+push on re-expansion (if we
        # relaxed the prefix now but suppressed its pushes, the re-expansion
        # would see "no improvement" and the neighbor would never be
        # enqueued).  (per_item never truncates; its ex.owner indexes the
        # flattened per-vertex lanes, so the mask below is the chunk one
        # only on the merge_path branch.)
        live = (ex.valid & ~truncated[ex.owner] if strategy == "merge_path"
                else ex.valid)
        cand = jnp.where(live, state.dist[ex.src] + 1, INF)
        before = state.dist[ex.nbr]
        tgt = jnp.where(live, ex.nbr, 0)
        new_dist = state.dist.at[tgt].min(jnp.where(live, cand, INF),
                                          mode="drop")
        improved = live & (cand < before)
        # within-wavefront dedup (beyond-paper): several lanes may improve
        # the same neighbor; only the winning relaxation needs to requeue it.
        # On the GPU this would need extra atomics; in the deterministic
        # wavefront a scatter-min over lane ids is free and cuts overwork.
        n = state.dist.shape[0]
        lanes = jnp.arange(ex.nbr.shape[0], dtype=jnp.int32)
        first_lane = jnp.full((n,), ex.nbr.shape[0], jnp.int32).at[
            jnp.where(improved, ex.nbr, n)
        ].min(jnp.where(improved, lanes, ex.nbr.shape[0]), mode="drop")
        improved &= first_lane[ex.nbr] == lanes
        counter = state.counter.add(jnp.sum(jnp.where(
            valid & ~truncated, widths, 0)))
        # push: improved (deduplicated) neighbors re-coalesce into chunks;
        # truncated chunks are re-queued whole, unchanged.
        out_new, new_mask, n_splits = coalesce_chunks(
            ex.nbr, improved, codec, form_rp,
            split_threshold=split_threshold, owner_block=owner_block)
        counter = counter.add_splits(n_splits)
        out_items = jnp.concatenate([out_new, jnp.where(truncated, items, 0)])
        out_mask = jnp.concatenate([new_mask, truncated])
        return out_items, out_mask, BFSState(dist=new_dist, counter=counter)

    return f


def make_program(graph: CSRGraph, cfg: SchedulerConfig, *,
                 queue_capacity: int | None = None,
                 **params) -> AtosProgram:
    """Speculative BFS as **one** :class:`AtosProgram` — the single
    definition every execution policy (single/fused/sharded x
    persistent/discrete) drains unchanged (DESIGN.md section 11).

    ``params``: ``source`` (init-only), ``strategy`` (merge_path |
    per_item), ``work_budget``.  Static bounds (budget, max degree) come
    from the global graph so a sharded run traces the identical body on
    every device; ``dist`` merges by ``pmin`` — the exact union of all
    relaxations — and the work counter by delta-psum.  ``cfg.granularity``
    sets the chunk width G (DESIGN.md section 12): tasks are packed
    ``(head, width)`` row runs, routed and stolen by their head vertex, and
    the seed is a width-1 chunk.
    """
    source = int(params.pop("source", 0))
    strategy = params.pop("strategy", "merge_path")
    work_budget = params.pop("work_budget", None)
    reject_unknown_params("bfs", params)
    n = graph.num_vertices
    max_degree = max_degree_of(graph)
    budget = default_work_budget(graph, cfg.wavefront, work_budget,
                                 max_degree=max_degree)
    codec, threshold, owner_block = chunking_for(
        graph, cfg, budget if strategy == "merge_path" else None)

    def make_body(local_graph: CSRGraph, ctx: ProgramContext):
        return make_wavefront_fn(local_graph, strategy, budget, max_degree,
                                 backend=ctx.backend, codec=codec,
                                 split_threshold=threshold,
                                 owner_block=owner_block,
                                 formation_row_ptr=graph.row_ptr)

    def dirty_seeds(applied, state):
        from ..stream.incremental import bfs_dirty_seeds  # lazy: stream layer

        return bfs_dirty_seeds(applied, state, codec=codec,
                               split_threshold=threshold,
                               owner_block=owner_block)

    return AtosProgram(
        name="bfs",
        init=lambda: (init_state(graph, source),
                      jnp.asarray(chunk_seeds([source], codec,
                                              graph.row_ptr))),
        make_body=make_body,
        result=lambda s: s.dist,
        merge={"dist": "pmin", "counter": "work_counter"},
        task_vertex=codec.head,
        task_width=codec.width,
        work=lambda s: s.counter.work,
        splits=lambda s: s.counter.splits,
        ideal_work=n,
        default_queue_capacity=queue_capacity or max(4 * n, 1024),
        dirty_seeds=dirty_seeds,
    )


def bfs_speculative(
    graph: CSRGraph,
    source: int,
    cfg: SchedulerConfig,
    strategy: str = "merge_path",
    work_budget: int | None = None,
    queue_capacity: int | None = None,
    trace: list | None = None,
) -> Tuple[jax.Array, dict]:
    """Relaxed-barrier BFS on the Atos scheduler.

    Thin driver over :func:`repro.runtime.execute`: builds the BFS
    :class:`AtosProgram` and drains it under ``cfg``'s resolved execution
    policy.  ``strategy``: "merge_path" (CTA-style) or "per_item"
    (warp-style).  Under the sharded topology (``cfg.num_shards > 1`` or
    ``topology="sharded"``) distances are bit-identical to the
    single-device run, and ``trace`` entries are per-round dicts
    (sizes/exchanged/donated) instead of tuples.
    """
    from ..runtime import execute  # lazy: runtime.api imports this module

    program = make_program(graph, cfg, queue_capacity=queue_capacity,
                           source=source, strategy=strategy,
                           work_budget=work_budget)
    state, _, info = execute(program, graph, cfg,
                             queue_capacity=queue_capacity, trace=trace)
    return state.dist, info
