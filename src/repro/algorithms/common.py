"""Budget helpers shared by the queue-driven algorithm drivers."""
from __future__ import annotations

import jax.numpy as jnp

from ..graph.csr import CSRGraph


def default_work_budget(graph: CSRGraph, wavefront: int,
                        work_budget: int | None = None,
                        max_degree: int | None = None) -> int:
    """LBS (merge-path) work budget per wavefront.

    Truncated rows are re-queued, so this is a throughput knob, not a
    correctness one — except that the first popped item must always expand
    fully (progress guarantee), hence the ``max_degree`` floor.  Pass
    ``max_degree`` if the caller already computed it (saves a device
    reduction).
    """
    if max_degree is None:
        max_degree = int(jnp.max(graph.degrees()))
    if work_budget is None:
        work_budget = wavefront * max(
            8, int(float(jnp.mean(graph.degrees())) * 4)
        )
    return max(work_budget, max_degree)
