"""Budget helpers shared by the queue-driven algorithm drivers."""
from __future__ import annotations

from collections import OrderedDict
from typing import Optional, Tuple

import jax.numpy as jnp

from ..core.task import ChunkCodec
from ..graph.csr import CSRGraph

_MAX_DEGREE_CACHE: OrderedDict = OrderedDict()
_MAX_DEGREE_CACHE_SIZE = 64


def max_degree_of(graph: CSRGraph) -> int:
    """Max degree, cached per graph identity (bounded LRU).

    The program factories need it for every build, and the JobRegistry
    builds a program per admission — without the cache each admit pays a
    device reduction + host sync.  The row_ptr reference is pinned in the
    value so a GC'd id can never alias a different graph; the LRU bound
    keeps a long-lived process over many transient graphs from pinning
    device arrays without limit (eviction only costs a re-reduction).
    """
    key = id(graph.row_ptr)
    cache = _MAX_DEGREE_CACHE
    if key in cache:
        cache.move_to_end(key)
    else:
        cache[key] = (graph.row_ptr, int(jnp.max(graph.degrees())))
        while len(cache) > _MAX_DEGREE_CACHE_SIZE:
            cache.popitem(last=False)
    return cache[key][1]


def default_work_budget(graph: CSRGraph, wavefront: int,
                        work_budget: int | None = None,
                        max_degree: int | None = None) -> int:
    """LBS (merge-path) work budget per wavefront.

    Truncated rows are re-queued, so this is a throughput knob, not a
    correctness one — except that the first popped item must always expand
    fully (progress guarantee), hence the ``max_degree`` floor.  Pass
    ``max_degree`` if the caller already computed it (saves a device
    reduction).
    """
    if max_degree is None:
        max_degree = int(jnp.max(graph.degrees()))
    if work_budget is None:
        work_budget = wavefront * max(
            8, int(float(jnp.mean(graph.degrees())) * 4)
        )
    return max(work_budget, max_degree)


def chunking_for(graph: CSRGraph, cfg,
                 work_budget: int | None = None
                 ) -> Tuple[ChunkCodec, Optional[int], Optional[int]]:
    """The granularity bundle every chunk-aware body needs.

    Returns ``(codec, split_threshold, owner_block)``:

      * ``codec`` — the :class:`~repro.core.task.ChunkCodec` for
        ``cfg.granularity`` (the identity codec at G = 1);
      * ``split_threshold`` — the effective chunk degree-sum cap at
        formation time: the tighter of ``cfg.split_threshold`` (0 = unset)
        and the merge-path ``work_budget``.  Capping at the budget is a
        *liveness* bound, not a tuning choice: a chunk whose degree-sum
        exceeded the budget would be truncated and re-queued whole forever;
      * ``owner_block`` — the shard-ownership block size when the config
        names a mesh (chunks must never cross it: routing keys off the
        chunk head, and a device's CSR slice only covers its own block).
    """
    from ..shard.partition import block_size  # lazy: shard imports runtime

    codec = ChunkCodec(cfg.granularity)
    bounds = [b for b in (cfg.split_threshold, work_budget) if b]
    threshold = min(bounds) if bounds else None
    owner_block = (block_size(graph.num_vertices, cfg.num_shards)
                   if cfg.num_shards > 1 else None)
    return codec, threshold, owner_block
