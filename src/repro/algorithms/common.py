"""Budget helpers shared by the queue-driven algorithm drivers."""
from __future__ import annotations

import jax.numpy as jnp

from ..graph.csr import CSRGraph


def default_work_budget(graph: CSRGraph, wavefront: int,
                        work_budget: int | None = None,
                        max_degree: int | None = None) -> int:
    """LBS (merge-path) work budget per wavefront.

    Truncated rows are re-queued, so this is a throughput knob, not a
    correctness one — except that the first popped item must always expand
    fully (progress guarantee), hence the ``max_degree`` floor.  Pass
    ``max_degree`` if the caller already computed it (saves a device
    reduction).
    """
    if max_degree is None:
        max_degree = int(jnp.max(graph.degrees()))
    if work_budget is None:
        work_budget = wavefront * max(
            8, int(float(jnp.mean(graph.degrees())) * 4)
        )
    return max(work_budget, max_degree)


def shard_info(stats, state) -> dict:
    """Uniform ``info`` dict for sharded runs (mirrors the single-device
    drivers' keys, plus the exchange/steal telemetry)."""
    return {
        "rounds": stats.rounds,
        "work": int(state.counter.work),
        "dropped": stats.dropped + stats.route_dropped,
        "shards": len(stats.per_device_items),
        "exchanged": stats.exchanged,
        "donated": stats.donated,
        "steal_rounds": stats.steal_rounds,
        "mis_routed": stats.mis_routed,
        "occupancy_balance": stats.occupancy_balance,
    }
