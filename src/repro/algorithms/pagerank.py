"""PageRank case study — BSP push (Alg 3) vs. asynchronous push (Alg 4).

Residual ("push") PageRank: every vertex holds (rank, residue).  Processing a
vertex harvests its residue into its rank and pushes ``lambda * res / deg`` to
each out-neighbor's residue.  Converged when all residues <= eps; the result
solves  pr = (1-lambda)*1 + lambda * A^T D^{-1} pr  to within eps*deg slack.

PageRank is *naturally unordered* (Dijkstra's don't-care non-determinism):
relaxing the barrier never produces wrong answers, only a different
propagation schedule.  The paper shows the async schedule does *less* total
work because high-residue hubs get re-processed promptly instead of once per
global sweep — our work counters reproduce that (benchmarks/bench_table4).

GPU->TPU adaptation: ``atomicExch(residue+v, 0)`` = gather residues then
scatter zeros (the wavefront pops each vertex at most once — duplicates in
the wavefront are de-duplicated by keeping the first occurrence, which is
what the atomic exchange guarantees on the GPU); ``atomicAdd`` = scatter-add.
Algorithm 4's "exclusively reserve Check_Size vertices" rotating re-scan is a
per-wavefront rotating window driven by a cursor in the state.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (ChunkCodec, SchedulerConfig, WorkCounter, adjacency_of,
                    chunk_degrees, chunk_seeds, coalesce_chunks,
                    expand_merge_path, flatten_chunks)
from ..graph.csr import CSRGraph
from ..runtime.program import AtosProgram, ProgramContext
from ..runtime.programs import reject_unknown_params
from .common import chunking_for, default_work_budget, max_degree_of


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PRState:
    rank: jax.Array       # f32 [n]
    residue: jax.Array    # f32 [n]
    in_queue: jax.Array   # bool [n] — presence bit (see adaptation note)
    check_cursor: jax.Array  # int32 — Alg 4 rotating re-scan cursor
    counter: WorkCounter


# Adaptation note (recorded in DESIGN.md): Alg 4 tolerates duplicate queue
# entries because a duplicate pop's atomicExch harvests zero residue (a
# no-op).  In the deterministic wavefront queue, duplicates instead flood the
# ring buffer (the checker re-finds hot vertices every rotation), so we
# de-duplicate at *push* time with a presence bit — the observable schedule
# (each vertex re-processed while residue > eps) is identical, queue pressure
# is bounded by n.


def _push_wavefront(graph: CSRGraph, damping: float, work_budget: int,
                    backend: str = "jnp", codec: ChunkCodec | None = None):
    """Shared core: harvest residues of popped chunks, push to neighbors.

    Chunk-aware (DESIGN.md section 12): a popped task is a ``(head, width)``
    run of rows (core/task.py); the whole chunk is harvested or re-queued
    as a unit, the LBS balances chunk degree-sums, and every expanded edge's
    contribution reads its true member row's residue/degree.  The identity
    codec (G = 1) is the original per-vertex core.
    """
    codec = codec or ChunkCodec(1)
    g = codec.granularity

    def push(items, valid, state: PRState):
        n = state.rank.shape[0]
        k = items.shape[0]
        safe = jnp.where(valid, items, 0)
        heads, widths = codec.decode(safe)
        # de-duplicate within the wavefront (atomicExch semantics): keep the
        # first occurrence of each chunk head.  Chunks never overlap (the
        # presence bit gates every enqueue per vertex), so head identity is
        # chunk identity.
        order = jnp.arange(k, dtype=jnp.int32)
        first_idx = jnp.full((n,), k, jnp.int32)
        first_idx = first_idx.at[heads].min(jnp.where(valid, order, k),
                                            mode="drop")
        is_first = valid & (first_idx[heads] == order)

        # chunks spilling past the work budget are not harvested; they are
        # re-queued whole (same discipline as speculative BFS; formation
        # caps every chunk's degree-sum at the budget, so the first popped
        # task always expands fully).
        deg = chunk_degrees(heads, widths, is_first, graph.row_ptr)
        excl = jnp.cumsum(deg) - deg
        truncated = is_first & (excl + deg > work_budget)
        process = is_first & ~truncated

        # harvest: dense mask avoids duplicate-index scatter hazards
        flat_v, flat_valid, flat_owner = flatten_chunks(heads, widths,
                                                        valid, g)
        proc_flat = flat_valid & process[flat_owner]
        popped = jnp.zeros((n,), bool).at[
            jnp.where(proc_flat, flat_v, n)
        ].set(True, mode="drop")
        rank = state.rank + jnp.where(popped, state.residue, 0.0)
        residue = jnp.where(popped, 0.0, state.residue)
        # popped vertices leave the queue; truncated ones stay (re-queued)
        trunc_flat = flat_valid & truncated[flat_owner]
        trunc_mask = jnp.zeros((n,), bool).at[
            jnp.where(trunc_flat, flat_v, n)
        ].set(True, mode="drop")
        in_queue = jnp.where(popped & ~trunc_mask, False, state.in_queue)

        rp, cols, overlay = adjacency_of(graph)
        ex = expand_merge_path(heads, process, rp, cols,
                               work_budget, backend=backend,
                               widths=widths, max_width=g, overlay=overlay)
        # per-edge contribution from the edge's true source row: ex.src is
        # the chunk member owning the edge, its residue read pre-harvest.
        row_deg = jnp.maximum(
            graph.row_ptr[ex.src + 1] - graph.row_ptr[ex.src], 1
        ).astype(jnp.float32)
        res_src = jnp.where(popped[ex.src], state.residue[ex.src], 0.0)
        contrib = jnp.where(ex.valid, damping * res_src / row_deg, 0.0)
        residue = residue.at[jnp.where(ex.valid, ex.nbr, 0)].add(contrib,
                                                                 mode="drop")
        counter = state.counter.add(jnp.sum(jnp.where(process, widths, 0)))
        return residue, rank, in_queue, counter, truncated

    return push


def pagerank_bsp(
    graph: CSRGraph,
    damping: float = 0.85,
    eps: float = 1e-6,
    max_iters: int = 1000,
    trace: list | None = None,
) -> Tuple[jax.Array, dict]:
    """Alg 3: process the whole frontier (all residues > eps) per sweep."""
    n = graph.num_vertices
    deg = jnp.maximum(graph.degrees(), 1).astype(jnp.float32)
    edge_src = _edge_sources(graph)  # host-side, hoisted out of the jit

    @jax.jit
    def sweep(rank, residue):
        active = residue > eps
        res = jnp.where(active, residue, 0.0)
        rank = rank + res
        residue = jnp.where(active, 0.0, residue)
        # dense edge-parallel push: for every edge (u -> v) add contribution
        contrib_per_v = damping * res / deg
        adds = contrib_per_v[edge_src]
        residue = residue.at[graph.col_idx].add(adds)
        return rank, residue, jnp.sum(active.astype(jnp.int32))

    rank = jnp.zeros((n,), jnp.float32)
    residue = jnp.full((n,), 1.0 - damping, jnp.float32)
    iters, work = 0, 0
    while iters < max_iters:
        if not bool(jnp.any(residue > eps)):
            break
        rank, residue, nactive = sweep(rank, residue)
        work += int(nactive)
        iters += 1
        if trace is not None:
            trace.append(int(nactive))
    return rank, {"iters": iters, "work": work}


_EDGE_SRC_CACHE: dict = {}


def _edge_sources(graph: CSRGraph) -> jax.Array:
    """[m] source vertex of every CSR edge (cached per graph identity)."""
    key = id(graph.row_ptr)
    if key not in _EDGE_SRC_CACHE:
        import numpy as np

        rp = np.asarray(graph.row_ptr)
        src = np.repeat(np.arange(graph.num_vertices, dtype=np.int32),
                        np.diff(rp))
        _EDGE_SRC_CACHE[key] = jnp.asarray(src)
    return _EDGE_SRC_CACHE[key]


def init_state(graph: CSRGraph, damping: float = 0.85,
               seed_count: int | None = None) -> Tuple[PRState, jax.Array]:
    """Job-parameterized initial state + the seed tasks that prime the queue.

    Every vertex starts with residue ``1 - damping``; the first ``seed_count``
    vertices (default: all) are pre-enqueued, the rest are found by the
    rotating re-scan.
    """
    n = graph.num_vertices
    n_seed = n if seed_count is None else min(n, seed_count)
    state = PRState(
        rank=jnp.zeros((n,), jnp.float32),
        residue=jnp.full((n,), 1.0 - damping, jnp.float32),
        in_queue=jnp.arange(n, dtype=jnp.int32) < n_seed,
        check_cursor=jnp.int32(0),
        counter=WorkCounter.zero(),
    )
    return state, jnp.arange(n_seed, dtype=jnp.int32)


def make_wavefront_fns(
    graph: CSRGraph,
    wavefront: int,
    n_check: int,
    damping: float = 0.85,
    eps: float = 1e-6,
    work_budget: int | None = None,
    backend: str = "jnp",
    check_block=None,
    max_degree: int | None = None,
    codec: ChunkCodec | None = None,
    split_threshold: int | None = None,
    owner_block: int | None = None,
    formation_row_ptr=None,
):
    """Reusable async-PageRank wavefront bodies: ``(f, on_empty, stop)``.

    ``wavefront`` sizes ``on_empty``'s padding (it must emit a full-width
    wavefront), ``n_check`` is the rotating re-scan window.  All three
    returned callables are pure and job-parameterized, shared by the
    single-tenant driver (``pagerank_async``) and the task server.
    ``backend`` selects the merge-path LBS implementation (DESIGN.md §9).

    ``check_block=(start, length)`` restricts the rotating re-scan to one
    contiguous vertex block — the sharded driver passes each device its
    owned block so re-scan tasks are born on their owner and the presence
    bit stays single-writer (DESIGN.md section 10).  Both values may be
    traced scalars (they derive from ``lax.axis_index`` under shard_map).
    ``max_degree`` must then be passed explicitly (precomputed from the
    global graph): the budget's progress-guarantee floor cannot concretize
    the device-local CSR slice inside the trace.

    ``codec`` (+ ``split_threshold``/``owner_block``/``formation_row_ptr``,
    see :func:`~repro.algorithms.common.chunking_for`) makes the bodies
    chunk-aware: the rotating re-scan's over-eps vertices — a naturally
    run-heavy stream — coalesce into ``(head, width)`` chunk tasks at push
    time (DESIGN.md section 12).
    """
    n = graph.num_vertices
    work_budget = default_work_budget(graph, wavefront, work_budget,
                                      max_degree=max_degree)
    codec = codec or ChunkCodec(1)
    form_rp = (graph.row_ptr if formation_row_ptr is None
               else formation_row_ptr)
    push = _push_wavefront(graph, damping, work_budget, backend=backend,
                           codec=codec)
    n_check = min(n_check, n)
    if check_block is None:
        block_start, block_len = jnp.int32(0), jnp.int32(n)
    else:
        block_start = jnp.asarray(check_block[0], jnp.int32)
        block_len = jnp.asarray(check_block[1], jnp.int32)

    def scan_window(cursor):
        """Next ``n_check`` ids of the rotating block scan + validity.

        Lanes past the block length are masked off (a short or empty block
        — the last shards of an uneven partition — must not rescan other
        owners' vertices, and must never enqueue one vertex twice in one
        window)."""
        j = jnp.arange(n_check, dtype=jnp.int32)
        ids = block_start + (cursor + j) % jnp.maximum(block_len, 1)
        return jnp.where(j < block_len, ids, 0), j < block_len

    def chunk_window(check_ids, over):
        """Coalesce the window's over-eps vertices into chunk tasks."""
        return coalesce_chunks(check_ids, over, codec, form_rp,
                               split_threshold=split_threshold,
                               owner_block=owner_block)

    def f(items, valid, state: PRState):
        residue, rank, in_queue, counter, truncated = push(items, valid, state)
        # rotating residual re-scan (Alg 4 lines 11-14): each wavefront checks
        # the next n_check vertices and enqueues those above eps that are not
        # already queued (presence bit — see adaptation note above).
        check_ids, in_window = scan_window(state.check_cursor)
        over = in_window & (residue[check_ids] > eps) & ~in_queue[check_ids]
        in_queue = in_queue.at[jnp.where(over, check_ids, n)].set(
            True, mode="drop")
        out_scan, scan_mask, n_splits = chunk_window(check_ids, over)
        counter = counter.add_splits(n_splits)
        new_state = PRState(rank=rank, residue=residue, in_queue=in_queue,
                            check_cursor=state.check_cursor + n_check,
                            counter=counter)
        out = jnp.concatenate([out_scan, jnp.where(truncated, items, 0)])
        mask = jnp.concatenate([scan_mask, truncated])
        return out, mask, new_state

    def on_empty(state: PRState):
        check_ids, in_window = scan_window(state.check_cursor)
        over = (in_window & (state.residue[check_ids] > eps)
                & ~state.in_queue[check_ids])
        in_queue = state.in_queue.at[jnp.where(over, check_ids, n)].set(
            True, mode="drop")
        out_scan, scan_mask, n_splits = chunk_window(check_ids, over)
        new_state = dataclasses.replace(
            state, in_queue=in_queue,
            check_cursor=state.check_cursor + n_check,
            counter=state.counter.add_splits(n_splits),
        )
        pad = jnp.zeros((wavefront,), jnp.int32)
        return (jnp.concatenate([out_scan, pad]),
                jnp.concatenate([scan_mask, jnp.zeros((wavefront,), bool)]),
                new_state)

    def stop(state: PRState):
        # converged when nothing is above eps anywhere (O(n) reduce per
        # wavefront — measured as part of the scheduler's fixed cost).
        return jnp.max(state.residue) <= eps

    return f, on_empty, stop


def make_program(graph: CSRGraph, cfg: SchedulerConfig, *,
                 queue_capacity: int | None = None,
                 **params) -> AtosProgram:
    """Async push PageRank as **one** :class:`AtosProgram` (DESIGN.md §11).

    ``params``: ``damping``, ``eps``, ``check_size``, ``work_budget``,
    ``seed_count``.  The program declares ``empty_means_done=False`` — the
    rotating rescan legally refills a drained queue, so only ``stop``
    (max residue <= eps) ends the drain; this replaces the old implicit
    "``on_empty`` is set, ignore queue size" inference.  Under the sharded
    topology the body's rescan window is restricted to the device's owned
    vertex block (``ctx.shard``), residue/rank merge by delta-psum, the
    presence bit by or-delta, and the cursor — advanced by the same
    constant on every device — stays collective-free.
    """
    from ..shard.partition import block_size  # lazy: shard imports runtime

    damping = float(params.pop("damping", 0.85))
    eps = float(params.pop("eps", 1e-6))
    check_size = int(params.pop("check_size", 64))
    work_budget = params.pop("work_budget", None)
    seed_count = params.pop("seed_count", None)
    reject_unknown_params("pagerank", params)
    n = graph.num_vertices
    max_degree = max_degree_of(graph)
    budget = default_work_budget(graph, cfg.wavefront, work_budget,
                                 max_degree=max_degree)
    codec, threshold, owner_block = chunking_for(graph, cfg, budget)
    n_check = min(cfg.num_workers * check_size, n)
    # the rescan blocks must match the partitioner's ownership map exactly,
    # or rescan tasks are born off-owner and break the single-writer merges
    blk = block_size(n, cfg.num_shards)
    fns_cache: dict = {}

    def _fns(local_graph: CSRGraph, ctx: ProgramContext):
        chunk_kw = dict(codec=codec, split_threshold=threshold,
                        owner_block=owner_block,
                        formation_row_ptr=graph.row_ptr)
        if ctx.sharded:
            # traced shard index — rebuild inside the shard_map, no caching
            start = jnp.asarray(ctx.shard, jnp.int32) * blk
            check_block = (start, jnp.clip(jnp.int32(n) - start, 0, blk))
            return make_wavefront_fns(
                local_graph, ctx.wavefront, n_check=n_check, damping=damping,
                eps=eps, work_budget=budget, backend=ctx.backend,
                check_block=check_block, max_degree=max_degree, **chunk_kw)
        # body / on_empty / stop share one closure build per host context
        key = (id(local_graph.row_ptr), ctx.wavefront, ctx.backend)
        if key not in fns_cache:
            fns_cache[key] = (local_graph, make_wavefront_fns(
                local_graph, ctx.wavefront, n_check=n_check, damping=damping,
                eps=eps, work_budget=budget, backend=ctx.backend,
                max_degree=max_degree, **chunk_kw))
        return fns_cache[key][1]

    # stop reads only the (merged, replicated) state — build it once on the
    # host from the global graph; bodies are rebuilt per execution context.
    _, _, stop = _fns(graph, ProgramContext(cfg.wavefront, cfg.num_workers,
                                            cfg.backend))

    if seed_count is None:
        cap = queue_capacity or max(8 * n, 1024)
        seed_count = min(n, max(1, cap // 2))

    def dirty_seeds(applied, state):
        from ..stream.incremental import pagerank_dirty_seeds  # lazy

        return pagerank_dirty_seeds(applied, state, damping=damping,
                                    eps=eps, codec=codec,
                                    split_threshold=threshold,
                                    owner_block=owner_block)

    def init():
        state, seeds = init_state(graph, damping, seed_count=seed_count)
        # the dense seed frontier is the coarsening jackpot: consecutive
        # vertex ids pack into maximal chunks (bounded by the split
        # threshold and shard blocks), so the warm-up rounds shrink ~G-fold
        return state, jnp.asarray(chunk_seeds(
            np.asarray(seeds), codec, graph.row_ptr,
            split_threshold=threshold, owner_block=owner_block))

    return AtosProgram(
        name="pagerank",
        init=init,
        make_body=lambda g, ctx: _fns(g, ctx)[0],
        make_on_empty=lambda g, ctx: _fns(g, ctx)[1],
        result=lambda s: s.rank,
        stop=stop,
        empty_means_done=False,
        merge={"rank": "sum_delta", "residue": "sum_delta",
               "in_queue": "or_delta", "check_cursor": "replicated",
               "counter": "work_counter"},
        task_vertex=codec.head,
        task_width=codec.width,
        work=lambda s: s.counter.work,
        splits=lambda s: s.counter.splits,
        ideal_work=n,
        default_queue_capacity=queue_capacity or max(8 * n, 1024),
        dirty_seeds=dirty_seeds,
    )


def pagerank_async(
    graph: CSRGraph,
    cfg: SchedulerConfig,
    damping: float = 0.85,
    eps: float = 1e-6,
    check_size: int = 64,
    work_budget: int | None = None,
    queue_capacity: int | None = None,
    trace: list | None = None,
) -> Tuple[jax.Array, dict]:
    """Alg 4: queue-driven asynchronous PageRank on the Atos scheduler.

    Thin driver over :func:`repro.runtime.execute`.  Under the sharded
    topology each shard's rotating re-scan covers its owned vertex block,
    residue deltas merge by psum every round, and ranks match the
    single-device schedule within the usual ``eps * deg`` slack.
    """
    from ..runtime import execute  # lazy: runtime.api imports this module

    program = make_program(graph, cfg, queue_capacity=queue_capacity,
                           damping=damping, eps=eps, check_size=check_size,
                           work_budget=work_budget)
    state, _, info = execute(program, graph, cfg,
                             queue_capacity=queue_capacity, trace=trace)
    info["max_residue"] = float(jnp.max(state.residue))
    return state.rank, info


def pagerank_reference(graph: CSRGraph, damping: float = 0.85,
                       iters: int = 200) -> jax.Array:
    """Dense power iteration oracle: pr = (1-d)*1 + d*A^T D^{-1} pr."""
    n = graph.num_vertices
    deg = jnp.maximum(graph.degrees(), 1).astype(jnp.float32)
    edge_src = _edge_sources(graph)
    pr = jnp.full((n,), 1.0 - damping, jnp.float32)
    for _ in range(iters):
        contrib = damping * pr / deg
        pr = jnp.full((n,), 1.0 - damping, jnp.float32).at[graph.col_idx].add(
            contrib[edge_src]
        )
    return pr
