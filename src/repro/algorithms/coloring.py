"""Graph-coloring case study — BSP speculative greedy (Alg 5) vs. relaxed (Alg 6).

Both variants use *speculative greedy* coloring [Gebremedhin-Manne]: assign
each vertex the minimum color not used by its neighbors (reading possibly
stale neighbor colors), then detect conflicts and re-color.  The BSP variant
barriers between the assign and detect phases; the relaxed variant fuses them
in one uberkernel — task sign distinguishes assign (+) from detect (-),
exactly Alg 6's encoding (we use +v+1 / -(v+1) so vertex 0 is signable).

Speculation cost: adjacent vertices colored in the same wavefront read each
other's *stale* colors and may pick the same color -> conflict -> recolor.
The paper shows this is driven by "consecutive queue entries are neighbors"
(meaningful vertex IDs); we reproduce their 6.4 permutation experiment.

GPU->TPU adaptations (DESIGN.md):
  * conflict tie-break — Alg 5/6 re-add any vertex that sees its color on a
    neighbor; on the GPU, timing asymmetry breaks color-pick symmetry, but a
    deterministic lockstep wavefront would livelock (both endpoints forever
    re-pick the same color).  We use the standard ID tie-break: the
    higher-ID endpoint re-colors.  Same fixed point, guaranteed progress.
  * forbidden-color bitset — CUDA builds a shared-memory forbidden array per
    vertex; we build a [wavefront, max_colors] one-hot table and take argmin
    (vectorizes over the 8x128 VPU).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from ..core import (ChunkCodec, SchedulerConfig, WorkCounter, adjacency_of,
                    chunk_seeds, coalesce_chunks, flatten_chunks,
                    gather_neighbors)
from ..graph.csr import CSRGraph
from ..runtime.program import AtosProgram, ProgramContext
from ..runtime.programs import reject_unknown_params
from .common import chunking_for, max_degree_of


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ColorState:
    colors: jax.Array   # int32 [n], -1 = uncolored
    counter: WorkCounter  # assign tasks processed (Table 4 unit: ratio vs n)


def _gather_neighbor_colors(graph, vids, valid, max_degree):
    """[w, max_degree] neighbor colors, -1 padded."""
    rp, cols, overlay = adjacency_of(graph)
    safe = jnp.where(valid, vids, 0)
    deg = jnp.where(valid, rp[safe + 1] - rp[safe], 0)
    j = jnp.arange(max_degree, dtype=jnp.int32)
    edge = rp[safe][:, None] + j[None, :]
    in_row = j[None, :] < deg[:, None]
    nbr = gather_neighbors(rp, cols,
                           jnp.broadcast_to(safe[:, None], edge.shape),
                           edge, overlay=overlay)
    return nbr, in_row


def _min_free_color(colors, nbr, in_row, max_colors):
    """Per row: smallest color in [0, max_colors) unused by valid neighbors."""
    nbr_colors = jnp.where(in_row, colors[nbr], -1)          # [w, d]
    onehot = jax.nn.one_hot(nbr_colors, max_colors, dtype=jnp.bool_)
    forbidden = jnp.any(onehot, axis=1)                      # [w, c]
    return jnp.argmin(forbidden, axis=1).astype(jnp.int32)   # first False


def _priority(v):
    """Deterministic pseudo-random priority (Gebremedhin-Manne symmetry
    breaking).  A pure ID tie-break serializes lattice graphs into diagonal
    waves under the deterministic wavefront; hashing restores the O(log n)
    expected rounds the paper's GPU timing noise provides for free."""
    h = (v.astype(jnp.uint32) * jnp.uint32(2654435761)) ^ jnp.uint32(0x9E3779B9)
    h = (h ^ (h >> 13)) * jnp.uint32(0x85EBCA6B)
    return h ^ (h >> 16)


def _conflicts(colors, vids, valid, nbr, in_row):
    """Does v share a color with a higher-priority neighbor? (v recolors)."""
    safe = jnp.where(valid, vids, 0)
    my = colors[safe]
    pv, pn = _priority(safe)[:, None], _priority(nbr)
    # total order: (hash, id) — id breaks the (rare) hash collisions
    loses = (pn < pv) | ((pn == pv) & (nbr < safe[:, None]))
    clash = in_row & (colors[nbr] == my[:, None]) & loses & \
        (my[:, None] >= 0)
    return jnp.any(clash, axis=1) & valid


def coloring_bsp(
    graph: CSRGraph,
    max_iters: int = 10000,
    trace: list | None = None,
) -> Tuple[jax.Array, dict]:
    """Alg 5: assign-all / barrier / detect-all, double buffered."""
    n = graph.num_vertices
    max_degree = int(jnp.max(graph.degrees()))
    max_colors = max_degree + 1

    @jax.jit
    def assign(colors, frontier):
        vids = jnp.arange(n, dtype=jnp.int32)
        nbr, in_row = _gather_neighbor_colors(graph, vids, frontier, max_degree)
        pick = _min_free_color(colors, nbr, in_row, max_colors)
        return jnp.where(frontier, pick, colors)

    @jax.jit
    def detect(colors, frontier):
        vids = jnp.arange(n, dtype=jnp.int32)
        nbr, in_row = _gather_neighbor_colors(graph, vids, frontier, max_degree)
        return _conflicts(colors, vids, frontier, nbr, in_row)

    colors = jnp.full((n,), -1, jnp.int32)
    frontier = jnp.ones((n,), bool)
    iters, work = 0, 0
    while iters < max_iters and bool(jnp.any(frontier)):
        fsize = int(jnp.sum(frontier))
        colors = assign(colors, frontier)
        frontier = detect(colors, frontier)
        work += fsize
        iters += 1
        if trace is not None:
            trace.append(fsize)
    return colors, {"iters": iters, "work": work}


def init_state(graph: CSRGraph,
               codec: ChunkCodec | None = None,
               owner_block: int | None = None,
               split_threshold: int | None = None
               ) -> Tuple["ColorState", jax.Array]:
    """Job-parameterized initial state + seed tasks (an assign per vertex).

    With a coarse ``codec`` the every-vertex frontier packs into maximal
    ``(head, width)`` chunks — one assign-chunk task per run — encoded with
    the usual +(task + 1) sign convention (DESIGN.md section 12).
    """
    import numpy as np

    n = graph.num_vertices
    state = ColorState(colors=jnp.full((n,), -1, jnp.int32),
                       counter=WorkCounter.zero())
    if codec is None or codec.granularity == 1:
        return state, jnp.arange(1, n + 1, dtype=jnp.int32)
    chunks = chunk_seeds(np.arange(n), codec, graph.row_ptr,
                         split_threshold=split_threshold,
                         owner_block=owner_block)
    return state, jnp.asarray(chunks) + 1


def make_wavefront_fn(graph: CSRGraph, fused: bool = True,
                      max_degree: int | None = None,
                      codec: ChunkCodec | None = None,
                      split_threshold: int | None = None,
                      owner_block: int | None = None,
                      formation_row_ptr=None):
    """Reusable fused assign/detect uberkernel body (Alg 6).

    Task encoding: +(task+1) = assign, -(task+1) = detect, where ``task``
    is a packed ``(head, width)`` chunk code (core/task.py; the raw vertex
    id at granularity 1, reproducing the classic ±(v+1) scheme
    bit-for-bit).  An assign chunk colors ``width`` consecutive vertices
    and queues one detect chunk for the same run; conflicted vertices
    re-coalesce into new assign chunks.  A wavefront mixes both kinds (and
    multiple speculation depths).  The returned ``f`` is a pure WavefrontFn
    shared by the single-tenant driver (``coloring_async``) and the task
    server.

    ``fused=False`` makes phase B read the *pre-wavefront* colors instead of
    phase A's same-wavefront commits.  The sharded driver (repro/shard)
    needs this: remote assigns from the same epoch are invisible anyway, so
    uniform epoch-start reads keep detection independent of which shard a
    task ran on — detection is merely deferred one epoch, never lost
    (DESIGN.md section 10).  ``max_degree`` may be passed explicitly when
    the body is built inside a traced context (a shard_map) where the
    device-local CSR slice cannot be concretized.

    Backend note (DESIGN.md section 9): coloring's expansion is the padded
    per-item gather, not merge-path LBS, so the body itself has no kernel
    dispatch.  Under ``SchedulerConfig(backend="pallas")`` the algorithm
    still exercises the Pallas hot path through the scheduler's queue push
    (``kernels/queue_compact``), with bit-identical colors (tested).
    """
    n = graph.num_vertices
    if max_degree is None:
        max_degree = int(jnp.max(graph.degrees()))
    max_colors = max_degree + 1
    codec = codec or ChunkCodec(1)
    g = codec.granularity
    form_rp = (graph.row_ptr if formation_row_ptr is None
               else formation_row_ptr)

    def f(items, valid, state: ColorState):
        is_assign = valid & (items > 0)
        is_detect = valid & (items < 0)
        codes = jnp.where(is_assign, items - 1, -items - 1)
        codes = jnp.where(valid, codes, 0)
        heads, widths = codec.decode(codes)
        # explode chunk tasks into their member vertices: lane kind (assign
        # vs detect) is a chunk property, vertices are per member
        vids, flat_valid, owner = flatten_chunks(heads, widths, valid, g)
        flat_assign = flat_valid & is_assign[owner]
        flat_detect = flat_valid & is_detect[owner]

        # ---- phase A: assigns (all reads see pre-wavefront colors = stale
        # speculation, exactly the GPU race the paper analyzes)
        nbr, in_row = _gather_neighbor_colors(graph, vids, flat_assign,
                                              max_degree)
        pick = _min_free_color(state.colors, nbr, in_row, max_colors)
        # duplicate assign tasks for one vertex cannot exist (1 assign ->
        # 1 detect -> at most 1 re-assign, and chunk members are distinct),
        # so this scatter has unique targets
        colors = state.colors.at[jnp.where(flat_assign, vids, n)].set(
            jnp.where(flat_assign, pick, 0), mode="drop")

        # ---- phase B: detects run on post-assign colors of THIS wavefront
        # (uberkernel fusion: later tasks see earlier tasks' commits).  The
        # unfused variant reads epoch-start colors so detection is identical
        # no matter which device processed the wavefront (shard parity).
        nbr_d, in_row_d = _gather_neighbor_colors(graph, vids, flat_detect,
                                                  max_degree)
        detect_colors = colors if fused else state.colors
        bad = _conflicts(detect_colors, vids, flat_detect, nbr_d, in_row_d)

        # conflicted vertices re-coalesce into assign chunks (identity at
        # G = 1: each bad vertex re-assigns alone, exactly the old stream)
        re_assign, re_mask, n_splits = coalesce_chunks(
            vids, bad, codec, form_rp, split_threshold=split_threshold,
            owner_block=owner_block)
        out = jnp.concatenate([
            jnp.where(is_assign, -(codes + 1), 0),  # assign -> queue a detect
            jnp.where(re_mask, re_assign + 1, 0),   # conflict -> re-assign
        ])
        mask = jnp.concatenate([is_assign, re_mask])
        counter = state.counter.add(jnp.sum(flat_assign.astype(jnp.int32)))
        counter = counter.add_splits(n_splits)
        return out, mask, ColorState(colors=colors, counter=counter)

    return f


def make_program(graph: CSRGraph, cfg: SchedulerConfig, *,
                 queue_capacity: int | None = None,
                 **params) -> AtosProgram:
    """Speculative greedy coloring as **one** :class:`AtosProgram`
    (DESIGN.md section 11).

    The context picks the body variant: the single/fused topologies run the
    fused assign/detect uberkernel (Alg 6), the sharded topology the
    unfused one (detects read epoch-start colors), so results never depend
    on which device a same-epoch neighbor assign ran on.  Tasks are
    sign-encoded ±(task+1) chunk codes; ownership and occupancy follow the
    decoded chunk head/width (``task_vertex``/``task_width``).  Colors are
    single-writer per round, so both state fields merge by delta-psum.

    ``params``: ``dirty`` picks the streaming (repro/stream) incremental
    rule — ``"conflicts"`` (default) keeps carried colors and recolors only
    the losing endpoints of inserted same-colored edges (valid coloring,
    minimal work, but a *different* valid coloring than a from-scratch
    drain); ``"recolor"`` disables the rule, so delta batches trigger the
    conservative full reseed (bit-identical to from-scratch).
    """
    dirty = params.pop("dirty", "conflicts")
    reject_unknown_params("coloring", params)
    if dirty not in ("conflicts", "recolor"):
        raise ValueError(
            f"coloring dirty mode must be 'conflicts' or 'recolor', "
            f"got {dirty!r}")
    n = graph.num_vertices
    max_degree = max_degree_of(graph)
    codec, threshold, owner_block = chunking_for(graph, cfg)

    def make_body(local_graph: CSRGraph, ctx: ProgramContext):
        return make_wavefront_fn(local_graph, fused=not ctx.sharded,
                                 max_degree=max_degree, codec=codec,
                                 split_threshold=threshold,
                                 owner_block=owner_block,
                                 formation_row_ptr=graph.row_ptr)

    def natural_code(t):
        return jnp.abs(jnp.asarray(t, jnp.int32)) - 1

    def conflict_seeds(applied, state):
        from ..stream.incremental import coloring_dirty_seeds  # lazy

        return coloring_dirty_seeds(applied, state, codec=codec,
                                    split_threshold=threshold,
                                    owner_block=owner_block)

    return AtosProgram(
        name="coloring",
        init=lambda: init_state(graph, codec, owner_block, threshold),
        make_body=make_body,
        result=lambda s: s.colors,
        merge={"colors": "sum_delta", "counter": "work_counter"},
        task_vertex=lambda t: codec.head(natural_code(t)),
        task_width=lambda t: codec.width(natural_code(t)),
        work=lambda s: s.counter.work,
        splits=lambda s: s.counter.splits,
        ideal_work=n,
        default_queue_capacity=queue_capacity or max(4 * n, 1024),
        dirty_seeds=conflict_seeds if dirty == "conflicts" else None,
    )


def coloring_async(
    graph: CSRGraph,
    cfg: SchedulerConfig,
    queue_capacity: int | None = None,
    trace: list | None = None,
) -> Tuple[jax.Array, dict]:
    """Alg 6: fused assign/detect uberkernel on the Atos queue.

    Thin driver over :func:`repro.runtime.execute`.  The sharded topology
    uses the *unfused* body (detects read epoch-start colors), so a
    full-width sharded run produces bit-identical colors for every shard
    count, including 1 (tested in tests/test_shard.py).
    """
    from ..runtime import execute  # lazy: runtime.api imports this module

    program = make_program(graph, cfg, queue_capacity=queue_capacity)
    state, _, info = execute(program, graph, cfg,
                             queue_capacity=queue_capacity, trace=trace)
    return state.colors, info


def validate_coloring(graph: CSRGraph, colors) -> bool:
    """Proper coloring: no edge joins two same-colored vertices; all colored."""
    import numpy as np

    c = np.asarray(colors)
    if (c < 0).any():
        return False
    rp = np.asarray(graph.row_ptr)
    ci = np.asarray(graph.col_idx)
    src = np.repeat(np.arange(graph.num_vertices), np.diff(rp))
    return bool((c[src] != c[ci]).all())
