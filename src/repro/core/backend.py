"""Kernel-backend selection: jnp reference vs Pallas TPU kernels.

Atos treats the expansion schedule as a swappable component (cf. Osama et
al., "A Programming Model for GPU Load Balancing": composable LB schedules
behind one API).  This module is the TPU port of that idea — one ``backend``
axis threaded through every layer that owns a hot loop:

    SchedulerConfig.backend
      -> core/frontier.expand_merge_path   (kernels/frontier_expand LBS)
      -> core/queue.TaskQueue.push         (kernels/queue_compact reservation)
      -> algorithms/{bfs,pagerank,coloring} wavefront bodies
      -> server/jobs kernel bundles + server/autotune candidate grid

Values:

  * ``"jnp"``    — the pure-jnp reference implementations.  Portable,
    bit-exact oracle; the fastest choice on CPU.
  * ``"pallas"`` — the Pallas TPU kernels (``repro/kernels``).  On a real
    TPU they compile to Mosaic; anywhere else they run in ``interpret=True``
    mode so correctness tests double as backend-parity oracles on CPU.
  * ``"auto"``   — ``"pallas"`` when a TPU is attached, else ``"jnp"``.

Backend choice is a *performance* axis only: every dispatch site is required
(and tested) to produce bit-identical results across backends, so the
autotuner may measure both and pick freely (server/autotune.py).

One additional value exists *internally*: ``STREAM`` (``"stream"``), the
expansion backend the runtime substitutes for megakernel bodies
(``kernel="megakernel"``, DESIGN.md §14).  It is not user-facing — inside
the fused drain kernel the CSR lives in HBM and neighbor slices are
DMA-streamed through a double-buffered VMEM scratch
(``kernels/drain_loop/csr_stream``) instead of flat-gathered, still
bit-identical to the jnp reference.  ``resolve_backend`` rejects it like
any other unknown value; ``core.frontier.expand_merge_path`` dispatches it
before resolution, and the same interpret-mode fallback applies off-TPU.
"""
from __future__ import annotations

import functools

import jax

#: the public axis values, in the order they appear in CLIs and docs.
BACKENDS = ("jnp", "pallas", "auto")

#: internal expansion-backend value for megakernel bodies (see module doc);
#: never a valid ``SchedulerConfig.backend`` — the runtime injects it into
#: the :class:`~repro.runtime.program.ProgramContext` it builds for
#: ``kernel="megakernel"`` drains.
STREAM = "stream"


@functools.lru_cache(maxsize=1)
def has_tpu() -> bool:
    """True when the default JAX backend exposes at least one TPU device."""
    try:
        return any(d.platform == "tpu" for d in jax.devices())
    except Exception:  # no devices / uninitialized backend: act portable
        return False


def resolve_backend(backend: str) -> str:
    """Collapse the user-facing axis to an executable one: jnp | pallas.

    ``"auto"`` picks the Pallas kernels only when real TPU hardware is
    attached — off-TPU the jnp reference is both faster and what interpret
    mode would emulate anyway.
    """
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}; expected one of {BACKENDS}")
    if backend == "auto":
        return "pallas" if has_tpu() else "jnp"
    return backend


def default_interpret() -> bool:
    """Should ``pallas_call`` run in interpret mode?  Only off-TPU.

    This is the fallback that keeps tier-1 green on CPU: the kernels execute
    (slowly, via the Pallas interpreter) with exactly the compiled schedule,
    so parity tests exercise the real kernel code everywhere.
    """
    return not has_tpu()


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve an explicit/inherited interpret flag; ``None`` = auto-detect.

    Kernel wrappers (``kernels/*/ops.py``) default ``interpret=None`` so a
    real-TPU run never silently interprets, while CPU callers need no flag.
    """
    return default_interpret() if interpret is None else bool(interpret)
