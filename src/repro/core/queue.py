"""Functional task queue — the TPU-native analogue of Atos's shared queue.

Atos (GPU) uses a single HBM-resident MPMC queue with atomic ``concurrent_pop``
/ ``concurrent_push``.  TPU cores cannot contend on an atomic counter, so this
module implements the *wavefront queue*: a fixed-capacity ring buffer (a JAX
pytree, so it lives in HBM and threads through ``lax.while_loop``) where

  * ``pop(n)`` removes up to ``n`` items at once — one *wavefront* of
    ``num_workers x fetch_size`` tasks, mirroring all Atos workers popping in
    the same scheduling round; and
  * ``push(items, mask)`` reserves slots with an **exclusive prefix sum** over
    the validity mask instead of an atomic ticket counter.  This is
    deterministic and collision-free by construction — the TPU-idiomatic
    replacement for ``atomicAdd`` reservation (see DESIGN.md section 2).
    ``push(..., backend="pallas")`` runs the reservation through the
    two-phase Pallas stream-compaction kernel (``kernels/queue_compact``)
    instead of the jnp prefix sum — bit-identical results, hardware hot
    path (DESIGN.md section 9).

The queue stores int32 task ids.  Atos tags tasks by sign (graph coloring) or
by payload; both patterns work unchanged here.  A ``num_lanes``-wide variant
(``MultiQueue``) gives per-priority/per-iteration lanes like Atos's
``init(..., num_queues, ...)``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from .backend import resolve_backend

EMPTY = jnp.int32(-(2 ** 31))  # sentinel for "no item"


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TaskQueue:
    """Fixed-capacity ring buffer of int32 task ids.

    Invariants (checked by tests/property tests):
      0 <= tail - head <= capacity      (int32 wraparound-safe for < 2^31 ops)
      buf[(head + i) % capacity] for i in [0, size) are the live items.
    """

    buf: jax.Array        # [capacity] int32
    head: jax.Array       # scalar int32 — pop cursor
    tail: jax.Array       # scalar int32 — push cursor
    dropped: jax.Array    # scalar int32 — items lost to overflow (diagnostic)

    # ------------------------------------------------------------------ api
    @property
    def capacity(self) -> int:
        return self.buf.shape[0]

    @property
    def size(self) -> jax.Array:
        return self.tail - self.head

    def empty(self) -> jax.Array:
        return self.size == 0

    def pop(self, n: int) -> Tuple[jax.Array, jax.Array, "TaskQueue"]:
        """Pop up to ``n`` items.

        Returns ``(items[n], valid[n], queue')``.  Missing items are EMPTY
        with ``valid=False``.  ``n`` is a static wavefront width.
        """
        return self.pop_upto(n, n)

    def pop_upto(self, n: int, quota,
                 width_of=None) -> Tuple[jax.Array, jax.Array, "TaskQueue"]:
        """Pop up to ``quota``'s worth of items into an ``n``-wide wavefront.

        ``n`` is the static buffer width (compiled shape); ``quota`` may be a
        traced scalar — the dynamic share a fairness policy granted this
        queue for the round (see server/policies.py).  Lanes beyond the quota
        are EMPTY/invalid, so the same compiled step serves every quota.

        Without ``width_of`` the quota counts *slots* (one item each) — the
        pre-granularity behavior, unchanged bit-for-bit.  With ``width_of``
        (an item -> chunk-width function, see core/task.py) the quota counts
        **vertices**: the pop takes the longest slot prefix whose cumulative
        width stays within the quota, so a fairness share or a steal plan
        expressed in units of work grants fewer slots to coarse-chunk lanes.
        A chunk is never split by a pop — the first slot always pops when
        the quota is positive-enough only if its whole width fits; quota 0
        pops nothing either way.
        """
        quota = jnp.asarray(quota, jnp.int32)
        idx = (self.head + jnp.arange(n, dtype=jnp.int32)) % self.capacity
        items = self.buf[idx]
        in_queue = jnp.arange(n, dtype=jnp.int32) < jnp.minimum(
            jnp.int32(n), self.size)
        if width_of is None:
            valid = in_queue & (jnp.arange(n, dtype=jnp.int32) < quota)
        else:
            w = jnp.where(in_queue, jnp.asarray(width_of(items), jnp.int32), 0)
            # widths >= 1 inside the queue keep the cumsum strictly
            # increasing over live slots, so the quota cut is a prefix.
            valid = in_queue & (jnp.cumsum(w) <= quota)
        k = jnp.sum(valid.astype(jnp.int32))
        items = jnp.where(valid, items, EMPTY)
        q = dataclasses.replace(self, head=self.head + k)
        return items, valid, q

    def vertex_size(self, width_of=None) -> jax.Array:
        """Occupancy in *vertices*: the sum of live slots' chunk widths.

        ``width_of=None`` (or a width-1 codec) degenerates to :attr:`size`.
        Computed by scanning the ring's live window — chunk widths are
        carried by the task bits themselves (core/task.py), so the queue
        needs no auxiliary state and the pre-granularity pytree layout is
        untouched.
        """
        if width_of is None:
            return self.size
        cap = self.capacity
        i = jnp.arange(cap, dtype=jnp.int32)
        live = ((i - self.head) % cap) < self.size
        return jnp.sum(jnp.where(live,
                                 jnp.asarray(width_of(self.buf), jnp.int32),
                                 0))

    def push(self, items: jax.Array, mask: jax.Array,
             backend: str = "jnp") -> "TaskQueue":
        """Push ``items[mask]`` — prefix-sum slot reservation.

        Each valid item i gets slot ``tail + excl_cumsum(mask)[i]``; one
        vectorized scatter commits the wavefront.  Items beyond capacity are
        dropped and counted (Atos's queue is sized to never overflow; we keep
        the counter so tests & benchmarks can assert no drops happened).

        ``backend="pallas"`` routes the reservation through the Pallas
        stream-compaction kernel (``kernels/queue_compact``); the resulting
        queue pytree — buffer contents, cursors, dropped counter — is
        bit-identical to the jnp path (tested in tests/test_backend.py).
        """
        if resolve_backend(backend) == "pallas":
            return self._push_pallas(items, mask)
        mask = mask.astype(jnp.int32)
        offs = jnp.cumsum(mask) - mask  # exclusive prefix sum
        free = self.capacity - self.size
        will_fit = (offs < free) & (mask > 0)
        slots = (self.tail + offs) % self.capacity
        # scatter only surviving items; drop others
        buf = self.buf.at[jnp.where(will_fit, slots, self.capacity)].set(
            items, mode="drop"
        )
        n_push = jnp.sum(will_fit.astype(jnp.int32))
        n_drop = jnp.sum(mask) - n_push
        return dataclasses.replace(
            self, buf=buf, tail=self.tail + n_push, dropped=self.dropped + n_drop
        )

    def _push_pallas(self, items: jax.Array, mask: jax.Array) -> "TaskQueue":
        """Kernel-backed push: compact valid items, then one contiguous write.

        The compaction kernel assigns valid item i the same rank the jnp
        path's exclusive prefix sum does, so the first ``free`` valid items
        land in the same slots with the same values and the overflow
        accounting matches exactly.
        """
        from ..kernels.queue_compact.ops import compact  # lazy: kernels->core

        compacted, count = compact(items, mask.astype(bool))
        free = self.capacity - self.size
        n_push = jnp.minimum(count, free)
        j = jnp.arange(items.shape[0], dtype=jnp.int32)
        live = j < n_push
        slots = (self.tail + j) % self.capacity
        buf = self.buf.at[jnp.where(live, slots, self.capacity)].set(
            compacted, mode="drop"
        )
        return dataclasses.replace(
            self, buf=buf, tail=self.tail + n_push,
            dropped=self.dropped + (count - n_push)
        )

    def push_dense(self, items: jax.Array, backend: str = "jnp") -> "TaskQueue":
        """Push every element of ``items`` (all valid)."""
        return self.push(items, jnp.ones(items.shape, dtype=bool),
                         backend=backend)


def make_queue(capacity: int, init_items: jax.Array | None = None) -> TaskQueue:
    """Build an empty queue, optionally seeded with ``init_items`` (1-D)."""
    q = TaskQueue(
        buf=jnp.full((capacity,), EMPTY, dtype=jnp.int32),
        head=jnp.int32(0),
        tail=jnp.int32(0),
        dropped=jnp.int32(0),
    )
    if init_items is not None:
        q = q.push_dense(jnp.asarray(init_items, dtype=jnp.int32))
    return q


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MultiQueue:
    """``num_lanes`` independent ring buffers with a round-robin pop pointer.

    The Atos API exposes ``init(counter, num_queues, iteration)`` so that an
    application can segregate tasks (e.g. per outer iteration, or by task
    kind).  Pops rotate across non-empty lanes; pushes name a lane.
    """

    lanes: TaskQueue          # stacked: buf [L, capacity], cursors [L]
    rr: jax.Array             # scalar int32 round-robin pointer

    @property
    def num_lanes(self) -> int:
        return self.lanes.buf.shape[0]

    @property
    def size(self) -> jax.Array:
        return jnp.sum(self.lanes.tail - self.lanes.head)

    def empty(self) -> jax.Array:
        return self.size == 0

    # -------------------------------------------------------- lane plumbing
    def lane(self, lane_id) -> TaskQueue:
        """View of a single lane as a standalone ``TaskQueue``."""
        return jax.tree.map(lambda x: x[lane_id], self.lanes)

    def with_lane(self, lane_id, lane: TaskQueue) -> "MultiQueue":
        """Write a (possibly updated) lane back into the stack."""
        lanes = jax.tree.map(
            lambda full, new: full.at[lane_id].set(new), self.lanes, lane
        )
        return dataclasses.replace(self, lanes=lanes)

    def reset_lane(self, lane_id) -> "MultiQueue":
        """Recycle a lane for a new tenant: empty buffer, zeroed cursors."""
        cap = self.lanes.buf.shape[1]
        fresh = TaskQueue(
            buf=jnp.full((cap,), EMPTY, dtype=jnp.int32),
            head=jnp.int32(0), tail=jnp.int32(0), dropped=jnp.int32(0),
        )
        return self.with_lane(lane_id, fresh)

    def lane_sizes(self) -> jax.Array:
        return self.lanes.tail - self.lanes.head

    def lane_loads(self, width_of=None) -> jax.Array:
        """Per-lane occupancy in vertices (chunk-width weighted).

        The granularity-aware analogue of :meth:`lane_sizes`: fairness
        quotas and steal plans budget *work*, and with chunked tasks
        (core/task.py) a slot may carry several vertices.  ``width_of=None``
        is exactly :meth:`lane_sizes`.
        """
        if width_of is None:
            return self.lane_sizes()
        cap = self.lanes.buf.shape[1]
        i = jnp.arange(cap, dtype=jnp.int32)[None, :]
        live = ((i - self.lanes.head[:, None]) % cap) < self.lane_sizes()[:, None]
        w = jnp.asarray(width_of(self.lanes.buf), jnp.int32)
        return jnp.sum(jnp.where(live, w, 0), axis=1)

    def lane_dropped(self) -> jax.Array:
        return self.lanes.dropped

    # ----------------------------------------------------------------- api
    def pop(self, n: int) -> Tuple[jax.Array, jax.Array, "MultiQueue"]:
        """Pop up to ``n`` items from the next non-empty lane (round robin).

        The cursor is stored modulo ``num_lanes`` so it cannot overflow
        int32 over long runs (it previously grew without bound).
        """
        sizes = self.lane_sizes()
        order = (self.rr + jnp.arange(self.num_lanes, dtype=jnp.int32)) % self.num_lanes
        nonempty = sizes[order] > 0
        pick = order[jnp.argmax(nonempty)]  # first non-empty in rr order

        items, valid, lane2 = self.lane(pick).pop(n)
        return items, valid, dataclasses.replace(
            self.with_lane(pick, lane2), rr=(pick + 1) % self.num_lanes
        )

    def pop_lane(self, lane_id, n: int, quota=None, width_of=None):
        """Pop up to ``quota``'s worth of items from one named lane.

        ``quota`` counts slots by default, or vertices when ``width_of``
        gives each slot's chunk width (see :meth:`TaskQueue.pop_upto`).
        """
        items, valid, lane2 = self.lane(lane_id).pop_upto(
            n, n if quota is None else quota, width_of=width_of
        )
        return items, valid, self.with_lane(lane_id, lane2)

    def push(self, lane_id, items: jax.Array, mask: jax.Array,
             backend: str = "jnp") -> "MultiQueue":
        return self.with_lane(
            lane_id, self.lane(lane_id).push(items, mask, backend=backend))


def make_multiqueue(capacity: int, num_lanes: int) -> MultiQueue:
    lanes = TaskQueue(
        buf=jnp.full((num_lanes, capacity), EMPTY, dtype=jnp.int32),
        head=jnp.zeros((num_lanes,), jnp.int32),
        tail=jnp.zeros((num_lanes,), jnp.int32),
        dropped=jnp.zeros((num_lanes,), jnp.int32),
    )
    return MultiQueue(lanes=lanes, rr=jnp.int32(0))
