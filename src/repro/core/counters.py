"""Instrumentation for the paper's analysis artifacts.

The paper's quantitative story rests on three measurements:
  * runtime/speedup          (Table 1)  -> benchmarks/bench_table1.py
  * workload ratio / overwork (Table 4) -> ``WorkCounter``
  * throughput vs. time      (Figs 1-3) -> per-round traces (discrete driver)

``WorkCounter`` threads through algorithm state; every processed item bumps
``work``; the ideal workload (|V| for coloring, |E| for BFS, etc.) is fixed
per algorithm, giving ``overwork = work / ideal`` — the Table 4 metric.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WorkCounter:
    work: jax.Array  # vertices processed (int32)
    #: chunks the push-side coalescer declined to form because their CSR
    #: degree-sum exceeded the split threshold or they crossed a shard
    #: boundary (core/task.coalesce_chunks) — the task-granularity dial's
    #: engagement meter (DESIGN.md section 12).  Always 0 at granularity 1.
    splits: jax.Array
    #: scheduling rounds this counter's state has been driven through —
    #: bumped exactly once per :func:`~repro.core.scheduler.wavefront_step`
    #: (empty rounds included), so overwork and round counts come from ONE
    #: source of truth instead of each driver recomputing its own.  Under
    #: the sharded topology every replica bumps in lockstep, so the merge
    #: rule is replicated-take-new, not delta-sum (runtime/program
    #: ``"work_counter"``).
    rounds: jax.Array = dataclasses.field(
        default_factory=lambda: jnp.int32(0))

    @staticmethod
    def zero() -> "WorkCounter":
        return WorkCounter(work=jnp.int32(0), splits=jnp.int32(0),
                           rounds=jnp.int32(0))

    def add(self, n) -> "WorkCounter":
        return dataclasses.replace(
            self, work=self.work + jnp.asarray(n, jnp.int32))

    def add_splits(self, n) -> "WorkCounter":
        return dataclasses.replace(
            self, splits=self.splits + jnp.asarray(n, jnp.int32))

    def bump_round(self) -> "WorkCounter":
        return dataclasses.replace(self, rounds=self.rounds + jnp.int32(1))


def overwork_ratio(counter: WorkCounter, ideal: int) -> float:
    return float(counter.work) / float(max(ideal, 1))


@dataclasses.dataclass
class JobTelemetry:
    """Per-tenant metering for the multi-job task server (host-side).

    Layered on ``WorkCounter``: ``work`` is the job's counter value at
    completion, ``ideal_work`` the algorithm's minimum (|V| for our three
    workloads), so ``overwork`` is the Table 4 metric per tenant.  Rounds are
    *server* scheduling rounds, so ``latency_rounds`` is queueing delay plus
    service time — the serving-system view of the paper's round counts.
    """

    job_id: int
    algorithm: str
    graph: str
    wavefront: int                 # server W — denominator for occupancy
    ideal_work: int
    submitted_round: int = 0
    admitted_round: int = -1       # -1 while waiting for a lane
    completed_round: int = -1
    rounds_active: int = 0         # rounds with quota > 0 or an on_empty step
    items_processed: int = 0       # valid tasks popped for this job
    #: vertices those pops actually advanced (sum of chunk widths).  At
    #: granularity 1 this equals ``items_processed``; beyond it, quotas
    #: are vertex-denominated (DESIGN.md section 12), so occupancy must
    #: count vertices too — a width-4 chunk fills 4 vertex slots of the
    #: round budget, not 1.  0 means "not metered" (legacy paths) and
    #: occupancy falls back to the item count.
    vertices_processed: int = 0
    #: the server's chunk-width cap G — the occupancy denominator is the
    #: round budget ``rounds_active x wavefront x G`` (vertex units)
    granularity: int = 1
    work: int = 0                  # WorkCounter at completion
    dropped: int = 0               # lane overflow drops attributed to the job
    backpressure_events: int = 0   # rounds the lane was drain-boosted
    routing_mismatches: int = 0    # packed job_id != lane owner (must be 0)

    @property
    def latency_rounds(self) -> int:
        if self.completed_round < 0:
            return -1
        return self.completed_round - self.submitted_round

    @property
    def queue_delay_rounds(self) -> int:
        if self.admitted_round < 0:
            return -1
        return self.admitted_round - self.submitted_round

    @property
    def occupancy(self) -> float:
        """Mean fraction of the round budget this job filled while active.

        Vertex-denominated, matching the quota allocator: the numerator is
        the vertices the job's pops advanced (chunk-width weighted) and the
        denominator is ``rounds_active x wavefront x granularity`` — the
        vertex capacity of the rounds it was granted.  At granularity 1
        both reduce to the pre-granularity item/slot accounting bit-for-
        bit.  Paths that never metered vertices (``vertices_processed ==
        0`` with items popped) fall back to the item count.
        """
        denom = self.rounds_active * self.wavefront * max(self.granularity, 1)
        if not denom:
            return 0.0
        filled = self.vertices_processed or self.items_processed
        return filled / denom

    @property
    def overwork(self) -> float:
        return self.work / max(self.ideal_work, 1)

    def as_dict(self) -> dict:
        """Serialize into the canonical ``job`` metric doc (obs/schema)."""
        from ..obs.schema import metric_doc  # lazy: obs is a leaf layer

        d = dataclasses.asdict(self)
        d.update(latency_rounds=self.latency_rounds,
                 queue_delay_rounds=self.queue_delay_rounds,
                 occupancy=self.occupancy, overwork=self.overwork)
        return metric_doc("job", **d)
