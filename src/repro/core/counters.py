"""Instrumentation for the paper's analysis artifacts.

The paper's quantitative story rests on three measurements:
  * runtime/speedup          (Table 1)  -> benchmarks/bench_table1.py
  * workload ratio / overwork (Table 4) -> ``WorkCounter``
  * throughput vs. time      (Figs 1-3) -> per-round traces (discrete driver)

``WorkCounter`` threads through algorithm state; every processed item bumps
``work``; the ideal workload (|V| for coloring, |E| for BFS, etc.) is fixed
per algorithm, giving ``overwork = work / ideal`` — the Table 4 metric.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WorkCounter:
    work: jax.Array  # items processed (int32)

    @staticmethod
    def zero() -> "WorkCounter":
        return WorkCounter(work=jnp.int32(0))

    def add(self, n) -> "WorkCounter":
        return WorkCounter(work=self.work + jnp.asarray(n, jnp.int32))


def overwork_ratio(counter: WorkCounter, ideal: int) -> float:
    return float(counter.work) / float(max(ideal, 1))
