"""Worker-granularity expansion strategies — Atos's task/data-parallel blend.

Atos workers come in two flavours (paper section 3.2/3.3):

  * warp-sized worker, no intra-worker load balancing  -> ``expand_per_item``
  * CTA-sized worker + load-balancing search [Merrill/Baxter] inside the
    worker                                             -> ``expand_merge_path``

``expand_per_item`` assigns each popped task (a CSR row) to one lane-group
and pads the neighbor loop to ``max_degree`` — fast when degree variance is
low (mesh-like graphs), wasteful when it is high (scale-free graphs),
*exactly* the warp-worker behaviour measured in the paper.

``expand_merge_path`` flattens the wavefront's total neighbor work with a
vectorized *load-balancing search*: work item k binary-searches the exclusive
scan of the popped rows' degrees to find its source row.  Every lane receives
one unit of work regardless of degree skew — the paper's data-parallel LB,
retargeted at the 8x128 VPU.

The expansion schedule is a swappable component (DESIGN.md section 9): the
``backend`` argument dispatches ``expand_merge_path`` either to the jnp
implementation in this module (the bit-exact reference) or to the Pallas TPU
kernel with explicit VMEM BlockSpec tiling (``repro/kernels/frontier_expand``
— compiled on TPU, interpret mode elsewhere).  Both produce identical
outputs; the choice is pure performance and is searched by the server
autotuner (``server/autotune.py``).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .backend import STREAM, resolve_backend


def searchsorted_right(sorted_arr: jax.Array, values: jax.Array) -> jax.Array:
    """Vectorized upper_bound: index of first element > value.

    jnp.searchsorted is available but we keep an explicit branchless binary
    search so the Pallas kernel and the reference share the exact schedule.
    """
    n = sorted_arr.shape[0]
    lo = jnp.zeros(values.shape, jnp.int32)
    hi = jnp.full(values.shape, n, jnp.int32)
    bits = max(1, (n).bit_length())
    for _ in range(bits):
        mid = (lo + hi) // 2
        go_right = sorted_arr[jnp.clip(mid, 0, n - 1)] <= values
        lo = jnp.where(go_right & (mid < hi), mid + 1, lo)
        hi = jnp.where(go_right, hi, jnp.minimum(hi, mid))
    return lo


def adjacency_of(graph) -> Tuple[jax.Array, jax.Array, object]:
    """``(row_ptr, cols, overlay)`` of a canonical or slotted graph.

    The uniform unpacking for algorithm bodies: a canonical
    :class:`~repro.graph.csr.CSRGraph` yields its flat ``col_idx`` and
    ``overlay=None``; a :class:`~repro.graph.slotted.SlottedView` yields
    its slab slots plus the :class:`~repro.graph.slotted.Overlay` needed
    by :func:`gather_neighbors`.  ``row_ptr`` is canonical either way, so
    every degree-sum consumer (LBS, chunking, budgets) is representation
    agnostic.
    """
    overlay = getattr(graph, "overlay", None)
    if overlay is None:
        return graph.row_ptr, graph.col_idx, None
    return graph.row_ptr, graph.slab_col, overlay


def gather_neighbors(row_ptr: jax.Array, cols: jax.Array, src: jax.Array,
                     edge: jax.Array, overlay=None) -> jax.Array:
    """Neighbor id at flat canonical edge index ``edge`` of row ``src``.

    ``overlay=None`` is the canonical CSR flat gather.  With an
    :class:`~repro.graph.slotted.Overlay`, the within-row offset
    ``edge - row_ptr[src]`` reads the row's slab prefix while below
    ``slab_len[src]`` and its overlay tail beyond — both sorted with the
    prefix strictly below the tail, so the result is bit-identical to the
    canonical gather on the same edge set.  Broadcasts over any matching
    ``src``/``edge`` shape (flat LBS work lists and [n, max_degree] padded
    loops alike).
    """
    if overlay is None:
        return cols[jnp.clip(edge, 0, cols.shape[0] - 1)]
    off = edge - row_ptr[src]
    s_len = overlay.slab_len[src]
    s_idx = overlay.slab_ptr[src] + off
    s_val = cols[jnp.clip(s_idx, 0, cols.shape[0] - 1)]
    o_idx = overlay.ovl_ptr[src] + off - s_len
    o_val = overlay.ovl_col[jnp.clip(o_idx, 0,
                                     overlay.ovl_col.shape[0] - 1)]
    return jnp.where(off < s_len, s_val, o_val)


class Expansion(NamedTuple):
    """Flattened (source, neighbor) work units for one wavefront."""

    src: jax.Array        # [W] source row per work unit (chunk member)
    nbr: jax.Array        # [W] neighbor / column id
    owner: jax.Array      # [W] index into the popped wavefront of the source
    valid: jax.Array      # [W] bool
    total: jax.Array      # scalar int32 — true number of work units


def chunk_degrees(heads: jax.Array, widths, valid: jax.Array,
                  row_ptr: jax.Array) -> jax.Array:
    """Degree-sum of each ``[head, head + width)`` chunk (0 where invalid).

    ``widths=None`` is the single-row case (degree of ``head``), kept as
    the exact pre-granularity expression so G = 1 traces are unchanged.
    """
    safe = jnp.where(valid, heads, 0)
    if widths is None:
        return jnp.where(valid, row_ptr[safe + 1] - row_ptr[safe], 0)
    n = row_ptr.shape[0] - 1
    end = jnp.clip(safe + jnp.asarray(widths, jnp.int32), 0, n)
    return jnp.where(valid, row_ptr[end] - row_ptr[safe], 0)


def chunk_row_of(row_ptr: jax.Array, head: jax.Array, rank: jax.Array,
                 widths, max_width: int) -> jax.Array:
    """Source row of within-chunk edge offset ``rank`` in ``[head, head+w)``.

    The second, intra-chunk level of the load-balancing search: the LBS
    distributes work units across *chunks* by degree-sum; this locates each
    unit's member row by a ``max_width``-round compare-count against the
    chunk's local row offsets — O(G) broadcast compares, no gather-heavy
    binary search, the same VPU-friendly shape as the Pallas LBS kernel's
    owner count (``kernels/frontier_expand``).  ``max_width <= 1`` is the
    identity.  The ``j < width`` guard matters on device-local CSR slices
    (shard/partition.py): row_ptr entries past the chunk's block are not
    monotone there, so rows outside the chunk must never be counted.
    """
    if max_width <= 1:
        return head
    n = row_ptr.shape[0] - 1
    widths = jnp.asarray(widths, jnp.int32)
    base = row_ptr[head]
    local = jnp.zeros(head.shape, jnp.int32)
    for j in range(1, max_width):
        before = row_ptr[jnp.clip(head + j, 0, n)] - base
        local = local + ((j < widths) & (before <= rank)).astype(jnp.int32)
    return jnp.clip(head + local, 0, jnp.maximum(n - 1, 0))


def expand_merge_path(
    items: jax.Array,
    valid: jax.Array,
    row_ptr: jax.Array,
    col_idx: jax.Array,
    work_budget: int,
    backend: str = "jnp",
    widths: jax.Array | None = None,
    max_width: int = 1,
    overlay=None,
) -> Expansion:
    """CTA-style expansion: load-balancing search over the wavefront.

    items[i] is a vertex id (or EMPTY).  ``work_budget`` is the static upper
    bound on sum(degree(items)) processed per wavefront; excess work units are
    masked out (the caller sizes the budget; tests assert no truncation for
    the configured fetch sizes).

    ``backend`` selects the LBS implementation: ``"jnp"`` runs the reference
    below, ``"pallas"`` dispatches to the TPU kernel
    (``kernels/frontier_expand/ops.frontier_expand``), ``"auto"`` picks by
    hardware.  Outputs are bit-identical across backends (tested).

    With ``widths`` (and its static bound ``max_width``), item ``i`` is a
    *chunk* of ``widths[i]`` consecutive rows headed at ``items[i]``
    (core/task.py): the LBS balances over chunk degree-sums and each work
    unit's true source row is recovered by :func:`chunk_row_of`, so a
    coarse-grained wavefront still spreads its neighbor work evenly across
    every lane — the paper's granularity x load-balancing composition.
    """
    if backend == STREAM:
        # internal megakernel value (checked before resolve_backend, which
        # rejects it): the same LBS schedule, but neighbor slices are
        # DMA-streamed HBM->VMEM inside the fused drain kernel
        # (kernels/drain_loop/csr_stream; imported lazily — it imports
        # Expansion and the schedule helpers from this module)
        from ..kernels.drain_loop.csr_stream import expand_stream

        return expand_stream(items, valid, row_ptr, col_idx, work_budget,
                             widths=widths, max_width=max_width,
                             overlay=overlay)
    if resolve_backend(backend) == "pallas":
        # imported lazily: kernels/ imports Expansion from this module
        from ..kernels.frontier_expand.ops import frontier_expand

        return frontier_expand(items, valid, row_ptr, col_idx, work_budget,
                               widths=widths, max_width=max_width,
                               overlay=overlay)
    safe = jnp.where(valid, items, 0)
    deg = chunk_degrees(items, widths, valid, row_ptr)
    scan = jnp.cumsum(deg)                       # inclusive scan of degrees
    total = scan[-1] if scan.shape[0] > 0 else jnp.int32(0)

    k = jnp.arange(work_budget, dtype=jnp.int32)
    owner = searchsorted_right(scan, k)          # which popped item owns unit k
    owner = jnp.clip(owner, 0, items.shape[0] - 1)
    excl = scan - deg                            # exclusive scan
    rank = k - excl[owner]                       # edge offset within the chunk
    head = safe[owner]
    src = (head if widths is None else
           chunk_row_of(row_ptr, head, rank, widths[owner], max_width))
    in_range = k < total
    edge = row_ptr[head] + rank
    nbr = gather_neighbors(row_ptr, col_idx, src, edge, overlay=overlay)
    return Expansion(
        src=jnp.where(in_range, src, 0),
        nbr=jnp.where(in_range, nbr, 0),
        owner=jnp.where(in_range, owner, 0),
        valid=in_range,
        total=total,
    )


def expand_per_item(
    items: jax.Array,
    valid: jax.Array,
    row_ptr: jax.Array,
    col_idx: jax.Array,
    max_degree: int,
    overlay=None,
) -> Expansion:
    """Warp-style expansion: one padded neighbor loop per popped item.

    Produces a [n_items * max_degree] work list; lanes beyond a row's true
    degree are masked (idle lanes = the warp-worker load imbalance the paper
    measures on scale-free graphs).
    """
    safe = jnp.where(valid, items, 0)
    deg = jnp.where(valid, row_ptr[safe + 1] - row_ptr[safe], 0)
    j = jnp.arange(max_degree, dtype=jnp.int32)
    edge = row_ptr[safe][:, None] + j[None, :]          # [n, max_degree]
    in_range = j[None, :] < deg[:, None]
    nbr = gather_neighbors(row_ptr, col_idx,
                           jnp.broadcast_to(safe[:, None], edge.shape),
                           edge, overlay=overlay)
    src = jnp.broadcast_to(safe[:, None], nbr.shape)
    owner = jnp.broadcast_to(
        jnp.arange(items.shape[0], dtype=jnp.int32)[:, None], nbr.shape
    )
    return Expansion(
        src=jnp.where(in_range, src, 0).reshape(-1),
        nbr=jnp.where(in_range, nbr, 0).reshape(-1),
        owner=jnp.where(in_range, owner, 0).reshape(-1),
        valid=in_range.reshape(-1),
        total=jnp.sum(deg),
    )
