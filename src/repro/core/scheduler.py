"""Persistent and discrete schedulers — Atos's kernel-strategy axis on TPU.

Atos launches workers either as a *persistent* kernel (one launch; workers
loop, popping from the shared queue until it drains) or as *discrete* kernels
(one launch per scheduling round).  On TPU the launch boundary is the
host->device dispatch:

  * ``persistent_run``  — the whole drain loop is a single fused
    ``jax.lax.while_loop``; zero host round-trips, one XLA executable.  This
    is the persistent-kernel analogue and removes the "small frontier"
    fixed cost exactly as in the paper.
  * ``discrete_run``    — a host-side Python loop around one jitted wavefront
    step; every round pays a dispatch + a one-scalar device->host sync on the
    continuation flag (the analogue of per-kernel launch overhead + the BSP
    barrier).  The stop predicate is folded *into* the jitted step
    (DESIGN.md section 11), so the host never evaluates ``stop(state)``
    eagerly per round.
  * ``megakernel_drive`` — the literal persistent kernel (DESIGN.md
    section 14): the whole drain loop is fused into a single Pallas kernel
    launch (``kernels/drain_loop``) that owns the queue buffers and
    DMA-streams CSR row slices in-kernel; selected by
    ``SchedulerConfig(kernel="megakernel")`` through the runtime layer.

Both drivers run the same *wavefront step*: pop ``num_workers x fetch_size``
tasks, apply the application function f, push the produced tasks.  Since the
runtime layer (``repro/runtime``) the step core is parameterized over a
:class:`QueueOps` triple, so the exact same ``wavefront_step`` drives the
single-device ``TaskQueue``, the task server's packed ``MultiQueue`` lanes,
and the sharded per-device replicas with routed exchange — three policy
drivers, one core.

API mirror of Atos's ``launchWarp/launchCTA(ifPersist, numBlock, numThread,
f1, f2, ...)``: here ``ifPersist`` picks the driver, ``num_workers`` plays
numBlock, ``fetch_size`` plays FETCH_SIZE, ``f`` plays f1.  ``on_empty``
(Atos's f2) runs when a pop returns no valid items but the stop condition has
not fired — useful for PageRank's residual re-scan.  Whether an empty queue
*ends* the drain is an explicit declaration (``empty_means_done``), not an
inference from ``on_empty``'s presence (see :func:`resolve_empty_means_done`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .counters import WorkCounter
from .queue import TaskQueue

# f(items, valid, state) -> (new_items, new_mask, new_state)
WavefrontFn = Callable[[jax.Array, jax.Array, Any], Tuple[jax.Array, jax.Array, Any]]


class RunStats(NamedTuple):
    rounds: jax.Array          # wavefronts executed
    items_processed: jax.Array  # total valid items popped (overwork metric)
    dropped: jax.Array         # queue overflow drops (must be 0 in tests)


class QueueOps(NamedTuple):
    """The three queue operations the shared wavefront step is generic over.

    Each engine supplies its own triple: the single-device scheduler wraps a
    plain :class:`~repro.core.queue.TaskQueue`, the task server wraps one
    ``MultiQueue`` lane with (job_id, payload) packing, and the sharded
    driver wraps a per-device replica whose push is the routed all-to-all
    exchange.  ``queue`` below is whatever pytree the engine threads through.
    """

    pop: Callable[[Any], Tuple[jax.Array, jax.Array, Any]]  # q -> items, valid, q'
    push: Callable[[Any, jax.Array, jax.Array], Any]        # q, items, mask -> q'
    size: Callable[[Any], jax.Array]                        # q -> live item count


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Atos launch configuration (see Listing 3 of the paper).

    ``backend`` is the kernel-backend axis (DESIGN.md section 9): ``"jnp"``
    (reference, default — bit-exact and fastest on CPU), ``"pallas"`` (the
    TPU kernels: LBS expansion + stream-compaction push; interpret mode
    off-TPU), or ``"auto"`` (pallas iff a TPU is attached).  Results are
    bit-identical across backends, so the autotuner searches this axis
    alongside the paper's three (``server/autotune.py``).

    ``topology`` is the execution-policy axis (DESIGN.md section 11):
    ``"single"`` (one TaskQueue, the classic drain), ``"fused"`` (the drain
    runs through a packed MultiQueue lane — the task server's engine),
    ``"sharded"`` (per-device queue replicas over a 1-D mesh, repro/shard),
    or ``"auto"`` (sharded iff ``num_shards > 1``, else single).  Together
    with ``persistent`` and ``granularity`` it forms the 3 x 2 x G
    :class:`~repro.runtime.policy.ExecutionPolicy` matrix every
    :class:`~repro.runtime.program.AtosProgram` runs under unchanged.

    ``num_shards`` is the device-mesh axis (DESIGN.md section 10): with
    ``num_shards > 1`` the drain runs one queue replica per device of a 1-D
    ``("shard",)`` mesh, routing produced tasks to their owner shard every
    round (``repro/shard``).  ``num_workers x fetch_size`` is then the
    *per-device* wavefront.  ``steal_threshold`` enables work stealing: when
    ``(max - min)`` queue occupancy exceeds ``steal_threshold x mean``, rich
    shards donate up to ``steal_chunk`` owned tasks to their ring successor
    before the next round; ``0.0`` disables stealing.

    ``mesh_shape`` (DESIGN.md section 16) folds the shard axis into a 2-D
    ``("row", "col")`` mesh of ``rows x cols == num_shards`` devices: the
    routed exchange then decomposes into two smaller per-axis all_to_alls
    (dimension-ordered: column hop, then row hop) instead of one global
    one.  ``None`` (default) keeps the 1-D ``("shard",)`` ring exactly.

    ``defer_rounds`` (DESIGN.md section 16) relaxes exchange delivery by
    that many rounds (0 = strict, today's round-synchronous path bit for
    bit; 1 = double-buffered overlap: round ``k``'s routed tasks land in a
    staging buffer and enter the owner's queue at the start of round
    ``k+1``, so the collective overlaps round ``k+1``'s expansion on
    already-delivered work).  Legal under Atos semantics — tasks are
    idempotent re-checks, so delaying delivery changes the schedule, never
    the fixpoint; the global stop predicate counts staged tasks as live.

    ``compress`` (DESIGN.md section 16) delta-compresses exchange payloads
    before the wire (shard/codec.py: sorted-run delta + zigzag bit-packing
    with a raw fallback); results are unchanged and the wire meters record
    compressed words instead of raw buffer slots.

    ``kernel`` names the kernel strategy explicitly (DESIGN.md section 14):
    ``"persistent"`` / ``"discrete"`` are the two strategies ``persistent``
    has always toggled between; ``"megakernel"`` fuses the whole drain loop
    into a single Pallas kernel launch (``kernels/drain_loop``) with
    in-kernel DMA-streamed CSR expansion — bit-identical results, one
    kernel entry per drain instead of one per round.  The default
    ``"auto"`` defers to the legacy ``persistent`` bool so every existing
    config resolves exactly as before; configs naming ``"megakernel"``
    should keep ``persistent=True`` (the device-resident strategy it
    degrades to wherever only the bool is consulted).

    ``granularity`` is the task-granularity axis (DESIGN.md section 12):
    the maximum chunk width ``G`` — how many consecutive CSR rows one queue
    slot may carry (core/task.py).  ``1`` (default) is the pre-granularity
    single-vertex task, bit-for-bit; larger values let seed frontiers and
    coalescible pushes ride in coarse chunks, so one ``num_workers x
    fetch_size`` wavefront of slots advances up to ``G`` times as many
    vertices.  ``split_threshold`` caps a chunk's CSR degree-sum at
    formation time (0 = bounded only by the merge-path work budget): the
    paper's level-of-balancing dial — a low threshold keeps hub-bearing
    chunks fine on heavy-tailed graphs, a high one lets mesh-like graphs
    coarsen freely.
    """

    num_workers: int = 64        # numBlock — parallel workers per wavefront
    fetch_size: int = 1          # FETCH_SIZE — items each worker pops
    persistent: bool = True      # ifPersist — kernel strategy
    max_rounds: int = 1 << 16    # safety bound for while_loop
    backend: str = "jnp"         # kernel backend: jnp | pallas | auto
    topology: str = "auto"       # execution topology: single|fused|sharded|auto
    num_shards: int = 1          # device-mesh axis (repro/shard)
    steal_threshold: float = 0.0  # occupancy-skew trigger; 0 = stealing off
    steal_chunk: int = 64        # max tasks donated per shard per round
    granularity: int = 1         # max chunk width G (core/task.py); 1 = fine
    split_threshold: int = 0     # chunk degree-sum cap; 0 = work-budget only
    kernel: str = "auto"         # persistent | discrete | megakernel | auto
    mesh_shape: Optional[Tuple[int, int]] = None  # (rows, cols) 2-D mesh
    defer_rounds: int = 0        # exchange delivery relaxation (0 = strict)
    compress: bool = False       # delta-compress exchange payloads (codec)

    @property
    def wavefront(self) -> int:
        return self.num_workers * self.fetch_size


def taskqueue_ops(cfg: SchedulerConfig) -> QueueOps:
    """The single-device engine's ops: one plain TaskQueue."""
    w = cfg.wavefront
    return QueueOps(
        pop=lambda q: q.pop(w),
        push=lambda q, items, mask: q.push(items, mask, backend=cfg.backend),
        size=lambda q: q.size,
    )


def wavefront_step(f: WavefrontFn, on_empty, ops: QueueOps, carry,
                   *, always_run_body: bool = False):
    """One scheduling round, generic over the queue implementation.

    ``carry = (queue, state, rounds, processed)``.  When the pop yields no
    valid item, the body is skipped and ``on_empty`` (if any) runs instead —
    unless ``always_run_body`` is set, in which case ``f`` runs on the
    zero-valid wavefront (the sharded engine's mode: a rescan folded into
    ``f`` must advance even on a drained replica, and SPMD lockstep forbids
    data-dependent branching across devices anyway).
    """
    queue, state, rounds, processed = carry
    items, valid, queue = ops.pop(queue)
    n_valid = jnp.sum(valid.astype(jnp.int32))

    if always_run_body:
        out, mask, state = f(items, valid, state)
        queue = ops.push(queue, out, mask)
    else:
        def run_f(args):
            q, s = args
            out, mask, s2 = f(items, valid, s)
            return ops.push(q, out, mask), s2

        def run_empty(args):
            q, s = args
            if on_empty is None:
                return q, s
            out, mask, s2 = on_empty(s)
            return ops.push(q, out, mask), s2

        queue, state = jax.lax.cond(n_valid > 0, run_f, run_empty,
                                    (queue, state))
    # one source of truth for round counts: every WorkCounter in the state
    # ticks exactly once per step (empty rounds included), matching the
    # driver-level ``rounds`` carry element.
    state = jax.tree_util.tree_map(
        lambda x: x.bump_round() if isinstance(x, WorkCounter) else x,
        state, is_leaf=lambda x: isinstance(x, WorkCounter))
    return queue, state, rounds + 1, processed + n_valid


def resolve_empty_means_done(on_empty, empty_means_done: Optional[bool]) -> bool:
    """Explicit-declaration default: historically the mere *presence* of
    ``on_empty`` silently dropped the ``queue.size > 0`` term from the
    continuation — a drain with ``on_empty`` but no ``stop`` ran to
    ``max_rounds`` even after the queue emptied for good.  Programs now
    declare the interaction (``AtosProgram.empty_means_done``); ``None``
    preserves the legacy inference for the deprecated raw entry points.
    """
    return on_empty is None if empty_means_done is None else empty_means_done


def continuation(ops: QueueOps, cfg: SchedulerConfig, stop,
                 empty_means_done: bool):
    """The shared while-condition: bounded rounds, optional drain/stop terms."""

    def cond(carry):
        queue, state, rounds, _ = carry
        more = rounds < cfg.max_rounds
        if empty_means_done:
            more &= ops.size(queue) > 0
        if stop is not None:
            more &= ~stop(state)
        return more

    return cond


# ----------------------------------------------------------------- drivers
def persistent_drive(step, cond, carry0):
    """Whole drain in one ``lax.while_loop`` (zero host round-trips)."""
    return jax.lax.while_loop(cond, step, carry0)


def megakernel_drive(step, cond, carry0, *, limit=None, interpret=None):
    """Whole drain in ONE fused Pallas kernel launch (DESIGN.md §14).

    The third kernel strategy: where :func:`persistent_drive` still
    re-enters the expand/push kernels every round of its while-loop, the
    megakernel evaluates the identical loop jaxpr *inside* a single
    ``pallas_call`` — bit-identical by construction, one kernel entry per
    drain.  ``limit`` bounds the segment for the streaming snapshot layer.
    Imported lazily: kernels/ imports this module's types.
    """
    from ..kernels.drain_loop.ops import megakernel_drive as _drive

    return _drive(step, cond, carry0, limit=limit, interpret=interpret)


def megakernel_segment(step, cond, example_carry, *, interpret=None):
    """Build-once segmented megakernel driver for the snapshot layer.

    Returns ``seg(carry, limit)``: the round limit rides as a kernel
    operand, so one traced jaxpr / pallas_call serves every snapshot
    segment (:func:`repro.kernels.drain_loop.ops.make_megakernel_segment`)
    — the fused analogue of jitting one persistent segment function and
    reusing it with ``limit`` as a traced argument.  Imported lazily:
    kernels/ imports this module's types.
    """
    from ..kernels.drain_loop.ops import make_megakernel_segment

    return make_megakernel_segment(step, cond, example_carry,
                                   interpret=interpret)


def discrete_drive(step, cond, ops: QueueOps, carry0, trace=None):
    """Host loop, one jitted round per iteration (discrete kernels).

    The continuation predicate — including any ``stop(state)`` — is
    evaluated *inside* the jitted step, so each round costs exactly one
    scalar device->host sync (the flag) instead of a full ``stop``
    evaluation + retrace hazard on the host.  ``trace``, if given, collects
    per-round ``(queue_size_before_pop, items_processed)`` pairs — this
    powers the throughput-timeline benchmark (paper Figs 1-3) at the price
    of extra host syncs, which is why it is opt-in.
    """

    @jax.jit
    def round_step(carry):
        carry = step(carry)
        return carry, cond(carry)

    carry = carry0
    # cond on concrete arrays evaluates eagerly — the pre-loop check costs
    # one tiny dispatch, never a per-round one.
    more = bool(cond(carry0))
    prev_processed = 0
    while more:
        size_before = int(ops.size(carry[0])) if trace is not None else 0
        carry, more_dev = round_step(carry)
        if trace is not None:
            trace.append((size_before, int(carry[3]) - prev_processed))
            prev_processed = int(carry[3])
        more = bool(more_dev)  # the one per-round device->host sync
    return carry


# ---------------------------------------------------- TaskQueue entry points
def persistent_run(
    f: WavefrontFn,
    queue: TaskQueue,
    state: Any,
    cfg: SchedulerConfig,
    stop: Optional[Callable[[Any], jax.Array]] = None,
    on_empty=None,
    empty_means_done: Optional[bool] = None,
):
    """Run until the queue drains (or ``stop(state)``), fully on device."""
    ops = taskqueue_ops(cfg)
    cond = continuation(ops, cfg, stop,
                        resolve_empty_means_done(on_empty, empty_means_done))
    step = lambda carry: wavefront_step(f, on_empty, ops, carry)
    q, s, rounds, processed = persistent_drive(
        step, cond, (queue, state, jnp.int32(0), jnp.int32(0)))
    return q, s, RunStats(rounds, processed, q.dropped)


def discrete_run(
    f: WavefrontFn,
    queue: TaskQueue,
    state: Any,
    cfg: SchedulerConfig,
    stop: Optional[Callable[[Any], jax.Array]] = None,
    on_empty=None,
    empty_means_done: Optional[bool] = None,
    trace: Optional[list] = None,
):
    """Host-driven loop: one jitted wavefront per round (discrete kernels)."""
    ops = taskqueue_ops(cfg)
    cond = continuation(ops, cfg, stop,
                        resolve_empty_means_done(on_empty, empty_means_done))
    step = lambda carry: wavefront_step(f, on_empty, ops, carry)
    q, s, rounds, processed = discrete_drive(
        step, cond, ops, (queue, state, jnp.int32(0), jnp.int32(0)),
        trace=trace)
    return q, s, RunStats(rounds, processed, q.dropped)


def megakernel_run(
    f: WavefrontFn,
    queue: TaskQueue,
    state: Any,
    cfg: SchedulerConfig,
    stop: Optional[Callable[[Any], jax.Array]] = None,
    on_empty=None,
    empty_means_done: Optional[bool] = None,
):
    """Run the whole drain as ONE fused Pallas launch (DESIGN.md §14).

    The raw-``WavefrontFn`` analogue of the runtime layer's megakernel
    dispatch, so ``cfg.kernel="megakernel"`` is honored — not silently
    degraded to the persistent strategy — even through the legacy
    :func:`run` front door.
    """
    # queue ops inside the fused drain run the jnp reference — a nested
    # compaction kernel would add launch structure without changing a bit
    # (the runtime layer does the same, runtime/api._shared_setup).
    ops = taskqueue_ops(dataclasses.replace(cfg, backend="jnp"))
    cond = continuation(ops, cfg, stop,
                        resolve_empty_means_done(on_empty, empty_means_done))
    step = lambda carry: wavefront_step(f, on_empty, ops, carry)
    q, s, rounds, processed = megakernel_drive(
        step, cond, (queue, state, jnp.int32(0), jnp.int32(0)))
    return q, s, RunStats(rounds, processed, q.dropped)


def run(f, queue, state, cfg: SchedulerConfig, stop=None, on_empty=None,
        empty_means_done: Optional[bool] = None, trace=None):
    """Dispatch on the kernel strategy — the Atos ``ifPersist`` switch,
    three-valued since the megakernel: an explicit
    ``cfg.kernel="megakernel"`` routes to :func:`megakernel_run` (the
    legacy ``persistent`` bool alone never selects it).

    Deprecated front door: new code should express the drain as an
    :class:`~repro.runtime.program.AtosProgram` and call
    :func:`repro.runtime.execute`, which also serves the fused and sharded
    topologies.  This shim remains for raw-``WavefrontFn`` callers.
    """
    if getattr(cfg, "kernel", "auto") == "megakernel":
        return megakernel_run(f, queue, state, cfg, stop=stop,
                              on_empty=on_empty,
                              empty_means_done=empty_means_done)
    if cfg.persistent:
        return persistent_run(f, queue, state, cfg, stop=stop,
                              on_empty=on_empty,
                              empty_means_done=empty_means_done)
    return discrete_run(f, queue, state, cfg, stop=stop, on_empty=on_empty,
                        empty_means_done=empty_means_done, trace=trace)


# ------------------------------------------------------- deprecated aliases
def _wavefront_step(f: WavefrontFn, on_empty, cfg: SchedulerConfig, carry):
    """Deprecated: pre-runtime-layer signature (one PR grace period)."""
    return wavefront_step(f, on_empty, taskqueue_ops(cfg), carry)


def partial_step(f, on_empty, cfg):
    """Deprecated: pre-runtime-layer step builder (one PR grace period)."""
    ops = taskqueue_ops(cfg)

    def step(carry):
        return wavefront_step(f, on_empty, ops, carry)

    return step
