"""Persistent and discrete schedulers — Atos's kernel-strategy axis on TPU.

Atos launches workers either as a *persistent* kernel (one launch; workers
loop, popping from the shared queue until it drains) or as *discrete* kernels
(one launch per scheduling round).  On TPU the launch boundary is the
host->device dispatch:

  * ``persistent_run``  — the whole drain loop is a single fused
    ``jax.lax.while_loop``; zero host round-trips, one XLA executable.  This
    is the persistent-kernel analogue and removes the "small frontier"
    fixed cost exactly as in the paper.
  * ``discrete_run``    — a host-side Python loop around one jitted wavefront
    step; every round pays a dispatch + a device->host sync on the stop
    predicate (the analogue of per-kernel launch overhead + the BSP barrier).

Both drivers run the same *wavefront body*: pop ``num_workers x fetch_size``
tasks, apply the application function f, push the produced tasks.  The
application function is vectorized over the wavefront — Atos's "worker"
granularity (warp vs CTA, i.e. per-item vs merge-path expansion) lives inside
``f`` (see ``core/frontier.py``).

API mirror of Atos's ``launchWarp/launchCTA(ifPersist, numBlock, numThread,
f1, f2, ...)``: here ``ifPersist`` picks the driver, ``num_workers`` plays
numBlock, ``fetch_size`` plays FETCH_SIZE, ``f`` plays f1.  ``on_empty``
(Atos's f2) runs when a pop returns no valid items but the stop condition has
not fired — useful for PageRank's residual re-scan.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .queue import TaskQueue

# f(items, valid, state) -> (new_items, new_mask, new_state)
WavefrontFn = Callable[[jax.Array, jax.Array, Any], Tuple[jax.Array, jax.Array, Any]]


class RunStats(NamedTuple):
    rounds: jax.Array          # wavefronts executed
    items_processed: jax.Array  # total valid items popped (overwork metric)
    dropped: jax.Array         # queue overflow drops (must be 0 in tests)


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Atos launch configuration (see Listing 3 of the paper).

    ``backend`` is the kernel-backend axis (DESIGN.md section 9): ``"jnp"``
    (reference, default — bit-exact and fastest on CPU), ``"pallas"`` (the
    TPU kernels: LBS expansion + stream-compaction push; interpret mode
    off-TPU), or ``"auto"`` (pallas iff a TPU is attached).  Results are
    bit-identical across backends, so the autotuner searches this axis
    alongside the paper's three (``server/autotune.py``).

    ``num_shards`` is the device-mesh axis (DESIGN.md section 10): with
    ``num_shards > 1`` the drain runs one queue replica per device of a 1-D
    ``("shard",)`` mesh, routing produced tasks to their owner shard every
    round (``repro/shard``).  ``num_workers x fetch_size`` is then the
    *per-device* wavefront.  ``steal_threshold`` enables work stealing: when
    ``(max - min)`` queue occupancy exceeds ``steal_threshold x mean``, rich
    shards donate up to ``steal_chunk`` owned tasks to their ring successor
    before the next round; ``0.0`` disables stealing.
    """

    num_workers: int = 64        # numBlock — parallel workers per wavefront
    fetch_size: int = 1          # FETCH_SIZE — items each worker pops
    persistent: bool = True      # ifPersist — kernel strategy
    max_rounds: int = 1 << 16    # safety bound for while_loop
    backend: str = "jnp"         # kernel backend: jnp | pallas | auto
    num_shards: int = 1          # device-mesh axis (repro/shard)
    steal_threshold: float = 0.0  # occupancy-skew trigger; 0 = stealing off
    steal_chunk: int = 64        # max tasks donated per shard per round

    @property
    def wavefront(self) -> int:
        return self.num_workers * self.fetch_size


def _wavefront_step(f: WavefrontFn, on_empty, cfg: SchedulerConfig, carry):
    queue, state, rounds, processed = carry
    items, valid, queue = queue.pop(cfg.wavefront)
    n_valid = jnp.sum(valid.astype(jnp.int32))

    def run_f(args):
        q, s = args
        new_items, new_mask, s2 = f(items, valid, s)
        q2 = q.push(new_items, new_mask, backend=cfg.backend)
        return q2, s2

    def run_empty(args):
        q, s = args
        if on_empty is None:
            return q, s
        new_items, new_mask, s2 = on_empty(s)
        return q.push(new_items, new_mask, backend=cfg.backend), s2

    queue, state = jax.lax.cond(n_valid > 0, run_f, run_empty, (queue, state))
    return queue, state, rounds + 1, processed + n_valid


def persistent_run(
    f: WavefrontFn,
    queue: TaskQueue,
    state: Any,
    cfg: SchedulerConfig,
    stop: Optional[Callable[[Any], jax.Array]] = None,
    on_empty=None,
):
    """Run until the queue drains (or ``stop(state)``), fully on device."""

    def cond(carry):
        q, s, rounds, _ = carry
        more = (q.size > 0) & (rounds < cfg.max_rounds)
        if stop is not None:
            more &= ~stop(s)
        if on_empty is not None:
            # queue may be empty while the stop condition is still false
            # (e.g. PageRank residual rescan) — keep running on_empty.
            more = (rounds < cfg.max_rounds)
            if stop is not None:
                more &= ~stop(s)
        return more

    def body(carry):
        return _wavefront_step(f, on_empty, cfg, carry)

    q, s, rounds, processed = jax.lax.while_loop(
        cond, body, (queue, state, jnp.int32(0), jnp.int32(0))
    )
    return q, s, RunStats(rounds, processed, q.dropped)


def discrete_run(
    f: WavefrontFn,
    queue: TaskQueue,
    state: Any,
    cfg: SchedulerConfig,
    stop: Optional[Callable[[Any], jax.Array]] = None,
    on_empty=None,
    trace: Optional[list] = None,
):
    """Host-driven loop: one jitted wavefront per round (discrete kernels).

    ``trace``, if given, collects per-round (queue_size, items_processed)
    pairs on the host — this powers the throughput-timeline benchmark
    (paper Figs 1-3) without instrumenting the persistent variant.
    """
    step = jax.jit(partial_step(f, on_empty, cfg))
    rounds = 0
    processed = jnp.int32(0)
    carry = (queue, state, jnp.int32(0), jnp.int32(0))
    while rounds < cfg.max_rounds:
        q = carry[0]
        size = int(q.size)  # device->host sync: the discrete-kernel fixed cost
        s = carry[1]
        if stop is not None and bool(stop(s)):
            break
        if size == 0 and on_empty is None:
            break
        carry = step(carry)
        rounds += 1
        if trace is not None:
            trace.append((size, int(carry[3]) - int(processed)))
        processed = carry[3]
    q, s, _, processed = carry
    return q, s, RunStats(jnp.int32(rounds), processed, q.dropped)


def partial_step(f, on_empty, cfg):
    def step(carry):
        return _wavefront_step(f, on_empty, cfg, carry)

    return step


def run(f, queue, state, cfg: SchedulerConfig, stop=None, on_empty=None, trace=None):
    """Dispatch on ``cfg.persistent`` — the Atos ``ifPersist`` switch."""
    if cfg.persistent:
        return persistent_run(f, queue, state, cfg, stop=stop, on_empty=on_empty)
    return discrete_run(f, queue, state, cfg, stop=stop, on_empty=on_empty, trace=trace)
