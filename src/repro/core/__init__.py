"""Atos core: wavefront task queue, persistent/discrete schedulers, expansion."""
from .backend import (BACKENDS, default_interpret, has_tpu, resolve_backend,
                      resolve_interpret)
from .queue import EMPTY, MultiQueue, TaskQueue, make_multiqueue, make_queue
from .scheduler import RunStats, SchedulerConfig, discrete_run, persistent_run, run
from .frontier import (Expansion, adjacency_of, chunk_degrees, chunk_row_of,
                       expand_merge_path, expand_per_item, gather_neighbors)
from .task import (MAX_GRANULARITY, ChunkCodec, chunk_seeds, coalesce_chunks,
                   flatten_chunks)
from .counters import WorkCounter, overwork_ratio

__all__ = [
    "BACKENDS", "default_interpret", "has_tpu", "resolve_backend",
    "resolve_interpret",
    "EMPTY", "MultiQueue", "TaskQueue", "make_multiqueue", "make_queue",
    "RunStats", "SchedulerConfig", "discrete_run", "persistent_run", "run",
    "Expansion", "adjacency_of", "chunk_degrees", "chunk_row_of",
    "expand_merge_path", "expand_per_item", "gather_neighbors",
    "MAX_GRANULARITY", "ChunkCodec", "chunk_seeds", "coalesce_chunks",
    "flatten_chunks",
    "WorkCounter", "overwork_ratio",
]
