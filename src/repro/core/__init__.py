"""Atos core: wavefront task queue, persistent/discrete schedulers, expansion."""
from .queue import EMPTY, MultiQueue, TaskQueue, make_multiqueue, make_queue
from .scheduler import RunStats, SchedulerConfig, discrete_run, persistent_run, run
from .frontier import Expansion, expand_merge_path, expand_per_item
from .counters import WorkCounter, overwork_ratio

__all__ = [
    "EMPTY", "MultiQueue", "TaskQueue", "make_multiqueue", "make_queue",
    "RunStats", "SchedulerConfig", "discrete_run", "persistent_run", "run",
    "Expansion", "expand_merge_path", "expand_per_item",
    "WorkCounter", "overwork_ratio",
]
