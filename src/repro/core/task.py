"""First-class task granularity: packed ``(vertex, width)`` chunk tasks.

Atos's third headline control is *task-parallel granularity* (paper
section 3.2/5): how much work one popped task represents.  Before this
module every task in every queue was a single int32 vertex, hardwiring the
finest granularity; now a task is a **chunk** — ``width`` consecutive CSR
rows starting at a head vertex — bit-packed into the same int32 queue slot:

    task = (vertex << width_bits) | (width - 1),   width_bits = ceil(log2 G)

where ``G`` is the configured maximum chunk width
(:attr:`~repro.core.scheduler.SchedulerConfig.granularity`).  ``G = 1``
packs zero width bits, so every task *is* its vertex id and the whole
machinery degenerates bit-for-bit to the pre-granularity behavior — that
identity is what lets granularity ride the existing int32 queues, the
server's ``(job_id, zigzag(natural))`` packing (``server/encoding.py``
absorbs chunk codes exactly like plain vertex ids), and the shard layer's
EMPTY wire sentinel unchanged.  Encoded chunks are always non-negative, so
they can never collide with :data:`~repro.core.queue.EMPTY` (tested in
tests/test_task.py); sign-encoded task schemes (coloring's ±(task+1)) wrap
the chunk code in their sign exactly as they wrapped the vertex id.

Three tools live here:

  * :class:`ChunkCodec` — encode/decode/width/head, pure int32 bit ops,
    usable inside any trace (and on host numpy);
  * :func:`coalesce_chunks` — the **push-side chunk former**: packs marked
    vertex ids into aligned chunks *in place* (no sort, no host sync),
    splitting — i.e. refusing to form — any chunk whose CSR degree-sum
    exceeds ``split_threshold`` (the paper's granularity/level-of-balancing
    dial: coarse chunks amortize scheduling overhead on low-variance
    graphs, but on heavy-tailed graphs a hub-bearing chunk would swallow
    the whole load-balancing budget, so it is kept fine-grained) or that
    would cross a shard-ownership boundary (a chunk must be expandable
    from one device's CSR slice and routable by its head);
  * :func:`chunk_seeds` — host-side greedy chunker for initial frontiers.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

#: widest chunk any codec may express; 6 width bits is the most the server's
#: 24-bit payload can spare while still addressing interesting graphs
#: (n << width_bits must stay inside the zigzag payload — see
#: ``server/encoding.check_job_fits``).
MAX_GRANULARITY = 64


@dataclasses.dataclass(frozen=True)
class ChunkCodec:
    """Bit-packed ``(vertex, width)`` chunk codec for one granularity ``G``.

    ``G = 1`` is the exact identity codec: ``encode(v, 1) == v`` and every
    decode reads width 1, reproducing the pre-granularity task stream
    bit-for-bit.  Codecs are static (constructed per program from the
    config), so all bit widths are trace-time constants.
    """

    granularity: int = 1

    def __post_init__(self):
        if not 1 <= self.granularity <= MAX_GRANULARITY:
            raise ValueError(
                f"granularity must be in [1, {MAX_GRANULARITY}], got "
                f"{self.granularity}")

    @property
    def width_bits(self) -> int:
        return (self.granularity - 1).bit_length()

    @property
    def width_mask(self) -> int:
        return (1 << self.width_bits) - 1

    # -------------------------------------------------------------- traced
    def encode(self, vertex, width):
        """Pack a chunk; ``width`` lanes must be in [1, granularity]."""
        v = jnp.asarray(vertex, jnp.int32)
        w = jnp.asarray(width, jnp.int32)
        return (v << self.width_bits) | ((w - 1) & self.width_mask)

    def head(self, task):
        """Head vertex of a chunk task (identity when G = 1)."""
        return jnp.asarray(task, jnp.int32) >> self.width_bits

    def width(self, task):
        """Chunk width in [1, granularity] (all-ones when G = 1)."""
        return (jnp.asarray(task, jnp.int32) & self.width_mask) + 1

    def decode(self, task):
        return self.head(task), self.width(task)

    # ---------------------------------------------------------------- host
    def max_code(self, num_vertices: int) -> int:
        """Largest chunk code a graph of ``num_vertices`` can produce —
        the admission bound the packed encodings must clear."""
        if num_vertices <= 0:
            return 0
        return ((num_vertices - 1) << self.width_bits) | self.width_mask


def coalesce_chunks(vids, mask, codec: ChunkCodec, row_ptr, *,
                    split_threshold=None, owner_block=None):
    """Pack marked vertex ids into chunk tasks, in place.

    ``vids[mask]`` are the vertices a wavefront wants to push (already
    deduplicated by the caller).  Lanes are rewritten so that each maximal
    set of marked vertices falling in one G-aligned window ``[bG, bG + G)``
    that is (a) contiguous, (b) within ``split_threshold`` total degree,
    and (c) owned by one shard becomes a single chunk task on its head
    lane (the other member lanes are masked off); everything else stays a
    width-1 chunk on its own lane.  Returns ``(items, out_mask, n_splits)``
    where ``n_splits`` counts the windows that *would* have coalesced but
    were split by the threshold or an ownership boundary — the
    schedule-deterministic "granularity dial engaged" meter.

    Alignment does the heavy lifting: no sorting, no sequential scan —
    one scatter-min/max/add over a ``ceil(n/G)``-sized scratch, all
    vectorized, deterministic, and a no-op (identity) at G = 1.
    """
    vids = jnp.asarray(vids, jnp.int32)
    mask = jnp.asarray(mask, bool)
    if codec.granularity == 1:
        return jnp.where(mask, vids, 0), mask, jnp.int32(0)

    g = codec.granularity
    n = row_ptr.shape[0] - 1
    nb = n // g + 2                       # aligned windows + overflow slot
    k = vids.shape[0]
    blk = jnp.where(mask, vids // g, nb - 1)   # masked lanes -> spare slot

    m32 = mask.astype(jnp.int32)
    cnt = jnp.zeros((nb,), jnp.int32).at[blk].add(m32)
    vmin = jnp.full((nb,), jnp.int32(n)).at[blk].min(
        jnp.where(mask, vids, n))
    vmax = jnp.full((nb,), jnp.int32(-1)).at[blk].max(
        jnp.where(mask, vids, -1))

    contiguous = (cnt > 0) & (vmax - vmin + 1 == cnt)
    head = jnp.clip(vmin, 0, jnp.maximum(n - 1, 0))
    degsum = row_ptr[jnp.clip(vmin + cnt, 0, n)] - row_ptr[head]
    fits = jnp.bool_(True) if split_threshold is None else (
        degsum <= jnp.int32(split_threshold))
    same_owner = jnp.bool_(True) if owner_block is None else (
        (vmin // jnp.int32(owner_block)) == (vmax // jnp.int32(owner_block)))
    form = contiguous & fits & same_owner

    is_head = mask & form[blk] & (vids == vmin[blk])
    single = mask & ~form[blk]
    out_mask = is_head | single
    width = jnp.where(is_head, cnt[blk], 1)
    items = jnp.where(out_mask, codec.encode(jnp.where(out_mask, vids, 0),
                                             width), 0)
    n_splits = jnp.sum((contiguous & (cnt > 1) & ~(fits & same_owner))
                       .astype(jnp.int32))
    return items, out_mask, n_splits


def chunk_seeds(vids, codec: ChunkCodec, row_ptr, *,
                split_threshold=None, owner_block=None) -> np.ndarray:
    """Host-side greedy chunker for an initial frontier.

    Walks the seed vertex ids once (numpy; init runs on the host exactly
    once per drain) and emits maximal chunks of consecutive ids bounded by
    the codec width, the degree-sum ``split_threshold``, and the shard
    ``owner_block`` boundary.  Unlike :func:`coalesce_chunks` the runs need
    not be G-aligned — a seed frontier is dense, so greedy packing yields
    the coarsest legal chunks.  Returns the encoded chunk array (dense,
    every entry valid) — what ``AtosProgram.init`` hands the queue.
    """
    vids = np.asarray(vids, dtype=np.int64)
    rp = np.asarray(row_ptr, dtype=np.int64)
    g = codec.granularity
    if g == 1 or vids.size == 0:
        return vids.astype(np.int32)
    chunks = []
    head = int(vids[0])
    width = 1

    def flush():
        chunks.append((head << codec.width_bits)
                      | ((width - 1) & codec.width_mask))

    for v in vids[1:]:
        v = int(v)
        extends = (
            v == head + width
            and width < g
            and (split_threshold is None
                 or rp[v + 1] - rp[head] <= split_threshold)
            and (owner_block is None or v // owner_block == head // owner_block)
        )
        if extends:
            width += 1
        else:
            flush()
            head, width = v, 1
    flush()
    return np.asarray(chunks, dtype=np.int32)


def flatten_chunks(heads, widths, valid, max_width: int):
    """Explode a chunk wavefront into a per-vertex wavefront.

    ``[k]`` chunks become ``[k * max_width]`` vertex lanes: lane
    ``i * max_width + j`` carries vertex ``heads[i] + j``, valid iff chunk
    ``i`` is valid and ``j < widths[i]``.  Returns ``(vids, flat_valid,
    owner)`` with ``owner`` the source chunk lane — the bridge from the
    chunked queue to per-vertex bodies (warp-style expansion, coloring's
    neighbor gather, PageRank's harvest masks).  At ``max_width = 1`` this
    is the identity reshape.
    """
    heads = jnp.asarray(heads, jnp.int32)
    widths = jnp.asarray(widths, jnp.int32)
    k = heads.shape[0]
    j = jnp.arange(max_width, dtype=jnp.int32)
    vids = (heads[:, None] + j[None, :]).reshape(-1)
    flat_valid = (valid[:, None] & (j[None, :] < widths[:, None])).reshape(-1)
    owner = jnp.broadcast_to(
        jnp.arange(k, dtype=jnp.int32)[:, None], (k, max_width)).reshape(-1)
    return jnp.where(flat_valid, vids, 0), flat_valid, owner
