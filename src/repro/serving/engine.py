"""Atos continuous-batching serving engine.

This is the paper's scheduler carried into LLM serving (DESIGN.md section 3):

  * **requests are tasks**; **decode slots are workers**;
  * the BSP baseline (``mode='bsp'``) admits a batch and decodes until EVERY
    sequence in it finishes before admitting the next batch — the global
    barrier between "frontiers" of requests, with the straggler-convoy
    problem the paper's small-frontier analysis predicts;
  * the Atos engine (``mode='continuous'``) refills freed slots from the
    queue every wavefront — requests at different depths coexist (the cache
    carries a PER-SLOT length), exactly the relaxed-barrier execution.
    Serving is naturally unordered (like PageRank), so relaxation costs no
    overwork;
  * slot admission is a pop from the request ``TaskQueue``; freed slots are
    the "workers" that immediately grab new tasks.

The decode wavefront always runs all S slots; inactive slots are masked so
their caches don't advance (``blend_cache``).  Tests assert the engine's
outputs are bit-identical to one-request-at-a-time greedy decoding.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer as T


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list        # token ids
    max_new_tokens: int


@dataclasses.dataclass
class EngineStats:
    wavefronts: int = 0
    slot_occupancy_sum: float = 0.0
    completed: int = 0

    @property
    def mean_occupancy(self):
        return self.slot_occupancy_sum / max(self.wavefronts, 1)


def blend_cache(old: T.DecodeCache, new: T.DecodeCache, mask: jax.Array
                ) -> T.DecodeCache:
    """Keep ``new`` only for rows where mask is True.

    Batch-dim convention: kv/ssm leaves carry batch at dim 1 ([L, B, ...]);
    enc and length at dim 0.
    """
    def blend(o, n, bdim):
        shape = [1] * o.ndim
        shape[bdim] = o.shape[bdim]
        m = mask.reshape(shape)
        return jnp.where(m, n, o)

    kv = (jax.tree.map(lambda o, n: blend(o, n, 1), old.kv, new.kv)
          if old.kv is not None else None)
    ssm = (jax.tree.map(lambda o, n: blend(o, n, 1), old.ssm, new.ssm)
           if old.ssm is not None else None)
    enc = old.enc  # encoder cache is read-only during decode
    length = jnp.where(mask, new.length, old.length)
    return T.DecodeCache(kv=kv, ssm=ssm, enc=enc, length=length)


def reset_slot(cache: T.DecodeCache, s: int) -> T.DecodeCache:
    """Clear one slot's rows before admitting a new request into it."""
    kv = (jax.tree.map(lambda a: a.at[:, s].set(0), cache.kv)
          if cache.kv is not None else None)
    ssm = (jax.tree.map(lambda a: a.at[:, s].set(0), cache.ssm)
           if cache.ssm is not None else None)
    return T.DecodeCache(kv=kv, ssm=ssm, enc=cache.enc,
                         length=cache.length.at[s].set(0))


class ContinuousBatchingEngine:
    """mode='continuous' (Atos) or 'bsp' (barrier baseline)."""

    def __init__(self, cfg, params, num_slots: int, max_len: int,
                 mode: str = "continuous", dtype=jnp.float32):
        assert mode in ("continuous", "bsp")
        self.cfg, self.params = cfg, params
        self.num_slots, self.mode = num_slots, mode
        self.max_len = max_len
        self.dtype = dtype

        def step(params, cache, tokens, mask):
            logits, new_cache = T.decode_step(params, cfg, cache, tokens)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            return next_tok, blend_cache(cache, new_cache, mask)

        self._step = jax.jit(step)

    def fresh_cache(self):
        return T.init_cache(self.cfg, self.num_slots, self.max_len,
                            self.dtype)

    def run(self, requests: List[Request],
            trace: Optional[list] = None) -> dict:
        S = self.num_slots
        pending = list(requests)
        active: dict[int, Request] = {}
        outputs: dict[int, list] = {r.uid: [] for r in requests}
        cache = self.fresh_cache()
        slot_tok = np.zeros((S, 1), np.int32)
        slot_remaining = np.zeros(S, np.int64)
        stats = EngineStats()

        def admit():
            nonlocal cache
            for s in range(S):
                if s not in active and pending:
                    r = pending.pop(0)
                    active[s] = r
                    cache = reset_slot(cache, s)
                    # prefill the slot by replaying the prompt with only this
                    # slot unmasked (a production engine batches prefill; the
                    # scheduling policy is what we study here)
                    mask = np.zeros(S, bool)
                    mask[s] = True
                    jmask = jnp.asarray(mask)
                    for t in r.prompt[:-1]:
                        tok = slot_tok.copy()
                        tok[s, 0] = t
                        _, cache = self._step(self.params, cache,
                                              jnp.asarray(tok), jmask)
                    slot_tok[s, 0] = r.prompt[-1]
                    slot_remaining[s] = r.max_new_tokens

        while pending or active:
            if self.mode == "continuous" or not active:
                admit()
            mask = np.zeros(S, bool)
            for s in active:
                mask[s] = True
            next_tok, cache = self._step(self.params, cache,
                                         jnp.asarray(slot_tok),
                                         jnp.asarray(mask))
            next_np = np.asarray(next_tok)
            stats.wavefronts += 1
            stats.slot_occupancy_sum += len(active) / S
            if trace is not None:
                trace.append(len(active))
            for s in list(active):
                outputs[active[s].uid].append(int(next_np[s, 0]))
                slot_tok[s, 0] = int(next_np[s, 0])
                slot_remaining[s] -= 1
                if slot_remaining[s] <= 0:
                    del active[s]
                    stats.completed += 1
        return {"outputs": outputs, "stats": stats}


def decode_single(cfg, params, prompt: list, max_new_tokens: int,
                  max_len: int, dtype=jnp.float32) -> list:
    """Oracle: one-request greedy decode (the engine must match this)."""
    cache = T.init_cache(cfg, 1, max_len, dtype)
    step = jax.jit(lambda p, c, t: T.decode_step(p, cfg, c, t))
    tok = None
    for t in prompt:
        logits, cache = step(params, cache, jnp.asarray([[t]], jnp.int32))
    out = []
    tok = int(jnp.argmax(logits[0]))
    for _ in range(max_new_tokens):
        out.append(tok)
        logits, cache = step(params, cache, jnp.asarray([[tok]], jnp.int32))
        tok = int(jnp.argmax(logits[0]))
    return out
