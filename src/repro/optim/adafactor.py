"""Adafactor: factored second moment — optimizer state for 1T-param configs.

For a [r, c] matrix the second moment is stored as row/col vectors (r + c
floats instead of r*c), cutting optimizer HBM ~2x vs AdamW at kimi-k2 scale
(see EXPERIMENTS.md memory note).  First moment omitted (beta1=0 variant).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdafactorState(NamedTuple):
    step: jax.Array
    vr: Any   # row second-moment (or full moment for <2D leaves)
    vc: Any   # col second-moment (None-like placeholder for <2D leaves)


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-3
    decay: float = 0.8       # t^-decay running-average exponent
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0


def _factored(shape) -> bool:
    return len(shape) >= 2


def init(params) -> AdafactorState:
    def vr(p):
        if _factored(p.shape):
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    def vc(p):
        if _factored(p.shape):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((1,), jnp.float32)

    return AdafactorState(step=jnp.int32(0),
                          vr=jax.tree.map(vr, params),
                          vc=jax.tree.map(vc, params))


def update(cfg: AdafactorConfig, params, grads, state: AdafactorState):
    step = state.step + 1
    beta2 = 1.0 - step.astype(jnp.float32) ** (-cfg.decay)

    def upd(p, g, vr, vc):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + cfg.eps
        if _factored(p.shape):
            vr2 = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc2 = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr2, axis=-1, keepdims=True),
                                cfg.eps)
            u = g * jax.lax.rsqrt(vr2[..., None] / denom[..., None]) \
                * jax.lax.rsqrt(vc2[..., None, :])
        else:
            vr2 = beta2 * vr + (1 - beta2) * g2
            vc2 = vc
            u = g * jax.lax.rsqrt(vr2)
        rms = jnp.sqrt(jnp.mean(jnp.square(u)))
        u = u / jnp.maximum(1.0, rms / cfg.clip_threshold)
        new_p = (p.astype(jnp.float32) - cfg.lr * u
                 - cfg.lr * cfg.weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), vr2, vc2

    p_flat, treedef = jax.tree.flatten(params)
    g_flat = treedef.flatten_up_to(grads)
    vr_flat = treedef.flatten_up_to(state.vr)
    vc_flat = treedef.flatten_up_to(state.vc)
    res = [upd(p, g, r, c)
           for p, g, r, c in zip(p_flat, g_flat, vr_flat, vc_flat)]
    return (jax.tree.unflatten(treedef, [r[0] for r in res]),
            AdafactorState(step=step,
                           vr=jax.tree.unflatten(treedef, [r[1] for r in res]),
                           vc=jax.tree.unflatten(treedef, [r[2] for r in res])),
            {})
