"""AdamW + cosine schedule + global-norm clipping (pure pytree transforms)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.int32(0), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def update(cfg: AdamWConfig, params, grads, state: AdamWState):
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.beta1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.beta2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = cfg.beta1 * m + (1 - cfg.beta1) * g
        v2 = cfg.beta2 * v + (1 - cfg.beta2) * jnp.square(g)
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    p_flat, treedef = jax.tree.flatten(params)
    g_flat = treedef.flatten_up_to(grads)
    m_flat = treedef.flatten_up_to(state.m)
    v_flat = treedef.flatten_up_to(state.v)
    res = [upd(p, g, m, v) for p, g, m, v in zip(p_flat, g_flat, m_flat, v_flat)]
    new_params = jax.tree.unflatten(treedef, [r[0] for r in res])
    new_m = jax.tree.unflatten(treedef, [r[1] for r in res])
    new_v = jax.tree.unflatten(treedef, [r[2] for r in res])
    return new_params, AdamWState(step=step, m=new_m, v=new_v), \
        {"grad_norm": gnorm, "lr": lr}
