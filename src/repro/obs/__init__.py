"""Unified observability layer (DESIGN.md §15).

One schema, one collector, two exporters for every engine in the repo:

  * :mod:`~repro.obs.ring`   — the device-side per-round trace ring buffer
    threaded through every jitted drain loop (zero host syncs while
    tracing, drained once at run end);
  * :mod:`~repro.obs.schema` — the canonical metric schema every summary
    (`RunStats`, `ShardRunStats`, `ServerStats`, `StreamResult`,
    `JobTelemetry`) serializes into, plus the hand-rolled validators the
    bench-smoke CI guard runs;
  * :mod:`~repro.obs.hist`   — exact p50/p95/p99 latency histograms;
  * :mod:`~repro.obs.export` — atomic JSONL + Chrome-trace writers;
  * :mod:`~repro.obs.trace`  — the :class:`Trace` front door wired through
    ``runtime.execute(..., trace=...)``, the task server, the stream
    driver, and ``taskserver --trace-out/--metrics-out``.

Tracing is strictly opt-in: every entry point takes ``trace=None`` by
default and builds exactly the pre-observability computation when it is
absent — the disabled path is the identity, proven bit-for-bit by
tests/test_obs.py across all six policies plus the megakernel.
"""
from .export import (atomic_write_text, chrome_trace, read_jsonl,
                     write_chrome_trace, write_jsonl)
from .hist import LatencyHistogram
from .ring import (DEFAULT_CAPACITY, TraceRing, ring_rows, stacked_rings,
                   unstack_ring)
from .schema import (BENCH_META_KEYS, KINDS, NUM_FIELDS, SCHEMA_VERSION,
                     TRACE_FIELDS, metric_doc, validate_bench,
                     validate_chrome_trace, validate_metric,
                     validate_metrics_jsonl)
from .trace import Trace, default_meta

__all__ = [
    "atomic_write_text", "chrome_trace", "read_jsonl", "write_chrome_trace",
    "write_jsonl", "LatencyHistogram", "DEFAULT_CAPACITY", "TraceRing",
    "ring_rows", "stacked_rings", "unstack_ring", "BENCH_META_KEYS",
    "KINDS", "NUM_FIELDS", "SCHEMA_VERSION", "TRACE_FIELDS", "metric_doc",
    "validate_bench", "validate_chrome_trace", "validate_metric",
    "validate_metrics_jsonl", "Trace", "default_meta",
]
