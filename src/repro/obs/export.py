"""Exporters: JSONL metrics and Chrome trace-event JSON (DESIGN.md §15).

Both writers are **atomic** — temp-then-rename in the target directory,
the same crash-consistency discipline as the autotune cache and the
snapshot layer — so a reader (or a CI validator) never observes a
partially written file, even if the process dies mid-export.

The Chrome trace is the JSON-object form (``{"traceEvents": [...]}``)
that ``chrome://tracing`` and Perfetto load directly.  Two timebases
share one trace:

  * **device rounds** have no wall-clock timestamps by design (recording
    them would cost the host syncs the ring buffer exists to avoid), so
    round records are laid out on a *logical* timebase — round index ->
    microseconds, one round = :data:`ROUND_DUR_US` — as ``"X"`` complete
    events, one ``pid`` per engine and one ``tid`` per lane/shard, with
    the full record in ``args`` for Perfetto's inspector;
  * **host spans** (trace/compile/execute/exchange phases) carry real
    ``perf_counter`` microseconds relative to the Trace epoch, under a
    dedicated ``host`` process.

Perfetto renders both; the DESIGN.md §15 how-to documents that the round
lanes are schedule time, not wall time.
"""
from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path
from typing import Iterable, List, Mapping, Optional

from .schema import TRACE_FIELDS

#: logical duration of one scheduling round on the device timebase (µs)
ROUND_DUR_US = 10

#: pid of the host-span process lane in the Chrome trace
HOST_PID = 0


def atomic_write_text(path: str | Path, text: str) -> Path:
    """Write ``text`` to ``path`` via temp-then-rename (crash-consistent)."""
    path = Path(path)
    fd, tmp = tempfile.mkstemp(dir=path.parent or Path("."),
                               prefix=path.name + ".", suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return path


def write_jsonl(path: str | Path, docs: Iterable[Mapping]) -> Path:
    """Write metric documents as JSONL, atomically."""
    text = "".join(json.dumps(doc, sort_keys=True) + "\n" for doc in docs)
    return atomic_write_text(path, text)


def read_jsonl(path: str | Path) -> List[dict]:
    return [json.loads(line)
            for line in Path(path).read_text().splitlines() if line.strip()]


def chrome_trace(round_records: List[dict], spans: List[dict],
                 meta: Optional[dict] = None) -> dict:
    """Build a Chrome trace-event document from drained round records
    (each a TRACE_FIELDS dict + ``engine`` tag) and host span docs."""
    events: List[dict] = []
    # stable pid per engine: host is pid 0, engines 1..N in first-seen order
    pids = {}

    def pid_of(engine: str) -> int:
        if engine not in pids:
            pids[engine] = len(pids) + 1
            events.append({"name": "process_name", "ph": "M",
                           "pid": pids[engine], "tid": 0,
                           "args": {"name": engine}})
        return pids[engine]

    events.append({"name": "process_name", "ph": "M", "pid": HOST_PID,
                   "tid": 0, "args": {"name": "host"}})
    for span in spans:
        events.append({
            "name": span["name"], "cat": "host", "ph": "X",
            "pid": HOST_PID, "tid": 0,
            "ts": span["ts_us"], "dur": max(span["dur_us"], 1),
        })
    named_tids = set()
    for rec in round_records:
        engine = rec.get("engine", "run")
        pid = pid_of(engine)
        tid = int(rec.get("lane", 0))
        if (pid, tid) not in named_tids:
            named_tids.add((pid, tid))
            events.append({"name": "thread_name", "ph": "M", "pid": pid,
                           "tid": tid, "args": {"name": f"lane {tid}"}})
        events.append({
            "name": f"round {rec['round']}", "cat": "round", "ph": "X",
            "pid": pid, "tid": tid,
            # logical timebase: 1 round = ROUND_DUR_US µs of schedule time
            "ts": int(rec["round"]) * ROUND_DUR_US, "dur": ROUND_DUR_US,
            "args": {k: rec[k] for k in TRACE_FIELDS},
        })
    doc = {"traceEvents": events, "displayTimeUnit": "ms"}
    if meta:
        doc["otherData"] = dict(meta)
    return doc


def write_chrome_trace(path: str | Path, doc: Mapping) -> Path:
    """Write a Chrome trace document, atomically."""
    return atomic_write_text(path, json.dumps(doc) + "\n")
