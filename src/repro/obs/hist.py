"""Exact-percentile latency histograms (ROADMAP item 3, DESIGN.md §15).

The serving-scale roadmap asks for p50/p99 *latency-round* histograms for
server jobs.  Round counts are small integers (a job's latency is tens to
thousands of scheduling rounds), so there is no reason to approximate:
:class:`LatencyHistogram` stores the samples and computes **exact**
nearest-rank percentiles — ``p(q)`` is the smallest sample with at least
``q%`` of the distribution at or below it, the textbook definition, so
``p50`` of ``[1..100]`` is exactly 50 and ``p99`` is exactly 99.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List

from .schema import metric_doc


@dataclasses.dataclass
class LatencyHistogram:
    """Store-everything histogram with exact nearest-rank percentiles."""

    name: str
    samples: List[float] = dataclasses.field(default_factory=list)

    def add(self, value) -> None:
        self.samples.append(float(value))

    def extend(self, values) -> None:
        for v in values:
            self.add(v)

    @property
    def count(self) -> int:
        return len(self.samples)

    def percentile(self, q: float) -> float:
        """Exact nearest-rank percentile: the ``ceil(q/100 * n)``-th
        smallest sample (0.0 for an empty histogram)."""
        if not self.samples:
            return 0.0
        if not 0 < q <= 100:
            raise ValueError(f"percentile q must be in (0, 100], got {q}")
        ordered = sorted(self.samples)
        rank = max(1, math.ceil(q / 100.0 * len(ordered)))
        return ordered[rank - 1]

    def to_doc(self) -> dict:
        """Serialize into the canonical ``histogram`` metric kind."""
        s = self.samples
        return metric_doc(
            "histogram",
            name=self.name,
            count=len(s),
            min=min(s) if s else 0.0,
            max=max(s) if s else 0.0,
            mean=(sum(s) / len(s)) if s else 0.0,
            p50=self.percentile(50),
            p95=self.percentile(95),
            p99=self.percentile(99),
        )
