"""The canonical observability schema (DESIGN.md §15).

One schema for every metric the repo emits.  Before this layer the repo's
telemetry was five incompatible ad-hoc shapes (``WorkCounter``,
``JobTelemetry``, ``RunStats``, ``ShardRunStats``, per-bench JSON); now
every serialized metric document is a flat JSON object tagged with

    {"schema": SCHEMA_VERSION, "kind": <kind>, ...fields...}

and every kind's required fields (with their types) are declared in one
place — :data:`KINDS` — so exporters, the bench-smoke CI guard, and the
tests all validate against the same registry instead of each growing its
own notion of "what a run record looks like".

Two layers of records share the registry:

  * **device trace records** (kind ``round``): one row per scheduling
    round, recorded *inside* the jitted drain loop by the
    :class:`~repro.obs.ring.TraceRing` — the column layout is
    :data:`TRACE_FIELDS` and is identical across every engine (single,
    fused, sharded, server, stream, megakernel), with engine-specific
    columns (donations, exchange volume) simply zero where the engine has
    no such concept;
  * **host summary docs** (kinds ``run`` / ``shard_run`` / ``server`` /
    ``stream`` / ``job`` / ``span`` / ``histogram`` / ``meta``): the
    end-of-run shapes the engines' ``as_dict`` methods now serialize into.

Validation is hand-rolled (``jsonschema`` is not a dependency of this
repo): a kind declares required fields and a coarse type class per field;
:func:`validate_metric` checks presence and type and rejects unknown
kinds.  Extra fields are allowed — kinds are *floors*, so an engine can
attach topology-specific extras without a schema bump — but a missing or
mistyped required field fails loudly, which is exactly the field-drift
guard the bench-smoke CI job runs over every emitted document.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, Mapping, Tuple

#: bump when a kind's required fields change incompatibly
SCHEMA_VERSION = 1

#: column layout of one device-side trace-ring record (all int32).  The
#: same row shape serves every engine; columns an engine has no meter for
#: are zero.  ``round`` is the 0-based round index *within the traced
#: drain* (stream segments add their absolute offset at drain time);
#: ``lane`` is the shard index (sharded), the MultiQueue lane (server), or
#: 0 (single/fused single-tenant).
TRACE_FIELDS: Tuple[str, ...] = (
    "round",       # 0-based scheduling-round index
    "lane",        # shard / MultiQueue lane / 0
    "queue_size",  # live items visible to this engine before the pop
    "pops",        # valid tasks popped this round
    "pushes",      # tasks pushed this round (size delta + pops)
    "work",        # WorkCounter.work delta (vertices advanced)
    "splits",      # WorkCounter.splits delta (chunk-formation splits)
    "donated",     # steal donations shipped this round (sharded only)
    "exchanged",   # distinct tasks routed off-device this round (sharded)
    "exchanged_row",  # cross-device payload ints, row-axis hop (2-D mesh)
    "exchanged_col",  # cross-device payload ints, column-axis hop (or 1-D)
    "wire",        # metered wire ints (compressed words when codec is on)
    "deferred",    # staged overlap arrivals delivered this round
)

NUM_FIELDS = len(TRACE_FIELDS)

#: coarse type classes for validation: "int" | "num" | "str" | "bool" |
#: "list" | "dict" — presence + type, not value ranges.
_TYPES = {
    "int": (int,),
    "num": (int, float),
    "str": (str,),
    "bool": (bool,),
    "list": (list,),
    "dict": (dict,),
}

#: required fields per metric kind (beyond the implicit schema/kind tag).
#: Kinds are floors: extra fields are welcome, missing ones are drift.
KINDS: Dict[str, Dict[str, str]] = {
    # provenance stamp shared by metrics files and BENCH_*.json documents
    "meta": {
        "git_sha": "str",
        "jax_version": "str",
        "device_kind": "str",
        "python": "str",
    },
    # single/fused drain summary (core RunStats + runtime info)
    "run": {
        "policy": "str",
        "rounds": "int",
        "items_processed": "int",
        "dropped": "int",
        "work": "int",
        "splits": "int",
        "launches": "int",
    },
    # sharded drain summary (shard/driver.ShardRunStats)
    "shard_run": {
        "rounds": "int",
        "items_processed": "int",
        "dropped": "int",
        "route_dropped": "int",
        "exchanged": "int",
        "donated": "int",
        "stolen_executed": "int",
        "steal_rounds": "int",
        "mis_routed": "int",
        "per_device_items": "list",
        "occupancy_balance": "num",
        # wire accounting (DESIGN.md §16): per-axis cross-device payload,
        # true payload vs EMPTY padding, metered wire ints, and the overlap
        # pipeline's delivery counters
        "exchanged_row": "int",
        "exchanged_col": "int",
        "payload_ints": "int",
        "padding_ints": "int",
        "wire_ints": "int",
        "deferred_delivered": "int",
        "overlap_rounds": "int",
        "overlap_occupancy": "num",
    },
    # multi-tenant server summary (server/engine.ServerStats)
    "server": {
        "rounds": "int",
        "wall_seconds": "num",
        "items_processed": "int",
        "backpressure_events": "int",
        "deferred_admissions": "int",
        "wavefront": "int",
        "occupancy": "num",
    },
    # streaming-job summary (stream/driver.StreamResult)
    "stream": {
        "batches": "int",
        "batches_run": "int",
        "rounds": "int",
        "processed": "int",
        "work": "int",
        "dropped": "int",
        "incremental": "bool",
        "topology": "str",
        "touched_rows": "int",    # slab rows rewritten across all commits
        "compactions": "int",     # slotted-CSR re-packs across the run
    },
    # per-tenant telemetry (core/counters.JobTelemetry)
    "job": {
        "job_id": "int",
        "algorithm": "str",
        "wavefront": "int",
        "granularity": "int",
        "rounds_active": "int",
        "items_processed": "int",
        "vertices_processed": "int",
        "work": "int",
        "latency_rounds": "int",
        "queue_delay_rounds": "int",
        "occupancy": "num",
        "overwork": "num",
    },
    # one device-trace row, drained to host (TRACE_FIELDS + engine tag)
    "round": dict({f: "int" for f in TRACE_FIELDS}, engine="str"),
    # host wall-clock span (trace/compile/execute/exchange phases)
    "span": {
        "name": "str",
        "ts_us": "num",
        "dur_us": "num",
    },
    # exact-percentile latency histogram (server jobs, ROADMAP item 3)
    "histogram": {
        "name": "str",
        "count": "int",
        "min": "num",
        "max": "num",
        "mean": "num",
        "p50": "num",
        "p95": "num",
        "p99": "num",
    },
}

#: required keys of the ``meta`` block every BENCH_*.json carries
#: (benchmarks/harness.bench_meta)
BENCH_META_KEYS: Tuple[str, ...] = ("git_sha", "jax_version", "device_kind",
                                    "python", "schema")


def metric_doc(kind: str, **fields: Any) -> dict:
    """Build (and validate) one canonical metric document."""
    doc = {"schema": SCHEMA_VERSION, "kind": kind}
    doc.update(fields)
    validate_metric(doc)
    return doc


def validate_metric(doc: Mapping[str, Any]) -> None:
    """Raise ``ValueError`` listing every schema violation in ``doc``."""
    errors = []
    if not isinstance(doc, Mapping):
        raise ValueError(f"metric doc must be a mapping, got {type(doc)}")
    kind = doc.get("kind")
    if kind not in KINDS:
        raise ValueError(
            f"unknown metric kind {kind!r}; expected one of {sorted(KINDS)}")
    if doc.get("schema") != SCHEMA_VERSION:
        errors.append(f"schema={doc.get('schema')!r} != {SCHEMA_VERSION}")
    for field, tclass in KINDS[kind].items():
        if field not in doc:
            errors.append(f"missing required field {field!r}")
            continue
        want = _TYPES[tclass]
        value = doc[field]
        # bool is an int subclass; an int-typed field must not accept it
        if isinstance(value, bool) and tclass != "bool":
            errors.append(f"field {field!r} is bool, expected {tclass}")
        elif not isinstance(value, want):
            errors.append(
                f"field {field!r} is {type(value).__name__}, "
                f"expected {tclass}")
    if errors:
        raise ValueError(
            f"invalid {kind!r} metric doc: " + "; ".join(errors))


def validate_metrics_jsonl(lines: Iterable[str]) -> int:
    """Validate a metrics JSONL stream; returns the number of docs."""
    import json

    n = 0
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            doc = json.loads(line)
        except json.JSONDecodeError as e:
            raise ValueError(f"metrics line {i}: invalid JSON: {e}") from e
        try:
            validate_metric(doc)
        except ValueError as e:
            raise ValueError(f"metrics line {i}: {e}") from e
        n += 1
    return n


def validate_chrome_trace(doc: Mapping[str, Any]) -> int:
    """Validate a Chrome trace-event document (the JSON-object form that
    chrome://tracing and Perfetto load); returns the event count."""
    if not isinstance(doc, Mapping) or "traceEvents" not in doc:
        raise ValueError(
            "chrome trace must be an object with a 'traceEvents' array")
    events = doc["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be an array")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"traceEvents[{i}] is not an object")
        missing = [k for k in ("name", "ph", "pid", "tid") if k not in ev]
        if missing:
            raise ValueError(f"traceEvents[{i}] missing {missing}")
        if ev["ph"] in ("X", "B", "E") and "ts" not in ev:
            raise ValueError(f"traceEvents[{i}] ({ev['ph']!r}) missing ts")
        if ev["ph"] == "X" and "dur" not in ev:
            raise ValueError(f"traceEvents[{i}] ('X') missing dur")
    return len(events)


def validate_bench(doc: Mapping[str, Any], *, name: str = "BENCH") -> None:
    """Validate one ``BENCH_*.json`` document's canonical envelope: a
    ``meta`` provenance block (harness.bench_meta) with every required
    key present and string/int-typed.  Benchmark payloads keep their
    section-specific shapes; the envelope is what CI guards for drift."""
    if not isinstance(doc, Mapping):
        raise ValueError(f"{name}: document must be a JSON object")
    meta = doc.get("meta")
    if not isinstance(meta, Mapping):
        raise ValueError(f"{name}: missing 'meta' provenance block "
                         f"(benchmarks/harness.bench_meta)")
    errors = []
    for key in BENCH_META_KEYS:
        if key not in meta:
            errors.append(f"meta.{key} missing")
        elif key == "schema":
            if meta[key] != SCHEMA_VERSION:
                errors.append(f"meta.schema={meta[key]!r} != {SCHEMA_VERSION}")
        elif not isinstance(meta[key], str):
            errors.append(f"meta.{key} is {type(meta[key]).__name__}, "
                          f"expected str")
    if errors:
        raise ValueError(f"{name}: " + "; ".join(errors))
