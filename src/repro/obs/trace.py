"""The :class:`Trace` front door — one object per observed run.

A ``Trace`` ties the layer together (DESIGN.md §15):

  * hands fresh device rings to engines (:meth:`Trace.ring`) and collects
    their drained rows (:meth:`Trace.drain`), tagging each record with the
    engine name so one trace can hold a whole multi-engine session
    (server rounds + sharded phases + stream segments side by side);
  * records host wall-clock **spans** (:meth:`Trace.span` context
    manager) on a shared epoch, so trace/compile/execute phases line up
    in the exported timeline;
  * owns a **metrics registry** (:meth:`Trace.add_metric`): every
    end-of-run summary doc the engines serialize (run / shard_run /
    server / stream / job kinds) validated against ``obs/schema`` at
    insertion time, plus exact-percentile latency histograms
    (:meth:`Trace.histogram`);
  * exports everything (:meth:`Trace.write`) as a JSONL metrics file and
    a Perfetto-loadable Chrome trace, both written atomically.

Passing a ``Trace`` enables tracing; passing ``None`` (the default
everywhere) runs exactly today's code paths — the engines construct no
ring and wrap no step, so the disabled path is the identity by
construction (the parity tests in tests/test_obs.py pin this
bit-for-bit across every policy).
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Dict, List, Optional

from .export import chrome_trace, write_chrome_trace, write_jsonl
from .hist import LatencyHistogram
from .ring import DEFAULT_CAPACITY, TraceRing, ring_rows
from .schema import SCHEMA_VERSION, metric_doc, validate_metric


def default_meta() -> dict:
    """Provenance stamp: jax version, device kind, python — the metrics
    twin of the bench harness's ``bench_meta`` block."""
    import platform

    import jax

    try:
        device_kind = jax.devices()[0].device_kind
    except Exception:
        device_kind = "unknown"
    return {
        "git_sha": "unknown",  # CLI entry points stamp the real sha
        "jax_version": jax.__version__,
        "device_kind": str(device_kind),
        "python": platform.python_version(),
    }


class Trace:
    """Collector for one observed run: rings, spans, metrics, histograms."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 meta: Optional[dict] = None) -> None:
        self.capacity = capacity
        self.records: List[dict] = []     # drained round rows (+engine tag)
        self.spans: List[dict] = []       # host wall-clock span docs
        self.metrics: List[dict] = []     # validated summary docs
        self.histograms: Dict[str, LatencyHistogram] = {}
        self.truncated = 0                # ring rows lost to wraparound
        self.meta = default_meta()
        if meta:
            self.meta.update(meta)
        self._epoch = time.perf_counter()

    # ------------------------------------------------------------- device
    def ring(self) -> TraceRing:
        """A fresh device ring sized to this trace's capacity."""
        return TraceRing.make(self.capacity)

    def drain(self, ring: TraceRing, engine: str,
              round_offset: int = 0) -> int:
        """Pull a finished drain's ring to host (the one tracing sync).

        ``engine`` tags every record (it becomes the Chrome-trace process
        lane); ``round_offset`` shifts the in-ring round indices to
        absolute round numbers for segmented drains (stream snapshots).
        Returns the number of records appended.
        """
        rows, truncated = ring_rows(ring)
        self.truncated += truncated
        for row in rows:
            rec = dict(row)
            rec["round"] += round_offset
            rec["engine"] = engine
            self.records.append(rec)
        return len(rows)

    # --------------------------------------------------------------- host
    @contextmanager
    def span(self, name: str):
        """Record one host wall-clock span on the trace's shared epoch."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            t1 = time.perf_counter()
            self.spans.append(metric_doc(
                "span", name=name,
                ts_us=(t0 - self._epoch) * 1e6,
                dur_us=(t1 - t0) * 1e6))

    def histogram(self, name: str) -> LatencyHistogram:
        """Get-or-create a named latency histogram."""
        if name not in self.histograms:
            self.histograms[name] = LatencyHistogram(name)
        return self.histograms[name]

    def add_metric(self, doc: dict) -> dict:
        """Register one canonical summary doc (validated on insertion)."""
        validate_metric(doc)
        self.metrics.append(doc)
        return doc

    # ------------------------------------------------------------- export
    def metric_docs(self) -> List[dict]:
        """Every document this trace will export, canonical order: meta,
        summaries, histograms, spans, then the per-round records."""
        docs = [metric_doc("meta", **self.meta)]
        docs.extend(self.metrics)
        docs.extend(h.to_doc() for h in self.histograms.values())
        docs.extend(self.spans)
        for rec in self.records:
            docs.append(metric_doc("round", **rec))
        return docs

    def chrome(self) -> dict:
        """The Perfetto-loadable Chrome trace-event document."""
        meta = dict(self.meta, schema=SCHEMA_VERSION,
                    truncated_rounds=self.truncated)
        return chrome_trace(self.records, self.spans, meta=meta)

    def write(self, trace_path: Optional[str] = None,
              metrics_path: Optional[str] = None) -> None:
        """Atomically write the Chrome trace and/or the metrics JSONL."""
        if trace_path:
            write_chrome_trace(trace_path, self.chrome())
        if metrics_path:
            write_jsonl(metrics_path, self.metric_docs())
