"""The device-side trace ring buffer (DESIGN.md §15).

A :class:`TraceRing` is a fixed-size, preallocated ``(capacity,
NUM_FIELDS)`` int32 buffer plus a monotone cursor, registered as a pytree
so it threads through every jitted drain loop exactly like the queue does
— the single/fused ``lax.while_loop``, the sharded ``shard_map`` round,
the server's per-lane step, the stream driver's snapshot segments, and
the megakernel's in-kernel loop (whose ``make_fused_drain`` flattens an
arbitrary carry pytree, so a ring leaf rides into the fused kernel for
free).

:meth:`TraceRing.record` writes one row at ``cursor % capacity`` and
bumps the cursor — pure array ops on traced values, so tracing costs
**zero host syncs**: the buffer lives on device for the whole drain and
is drained to host exactly once, at run end (:func:`ring_rows`).  When a
drain outruns the capacity the ring wraps — the newest ``capacity``
records survive and :func:`ring_rows` reports how many older ones were
overwritten, the classic flight-recorder contract.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .schema import NUM_FIELDS, TRACE_FIELDS

_FIELD_INDEX = {name: i for i, name in enumerate(TRACE_FIELDS)}

#: default ring capacity (rounds) when the caller does not size it
DEFAULT_CAPACITY = 4096


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TraceRing:
    """Fixed-size per-round trace buffer, carried through jitted drains."""

    buf: jax.Array     # (capacity, NUM_FIELDS) int32
    cursor: jax.Array  # int32: total records ever written (monotone)

    @property
    def capacity(self) -> int:
        return int(self.buf.shape[0])

    @staticmethod
    def make(capacity: int = DEFAULT_CAPACITY) -> "TraceRing":
        if capacity < 1:
            raise ValueError(f"ring capacity must be >= 1, got {capacity}")
        return TraceRing(buf=jnp.zeros((capacity, NUM_FIELDS), jnp.int32),
                         cursor=jnp.int32(0))

    def record(self, **fields) -> "TraceRing":
        """Write one row (unnamed columns are 0) and advance the cursor.

        ``fields`` values may be traced scalars; the write is a single
        dynamic row update — no host sync, no shape change, safe inside
        ``while_loop`` / ``shard_map`` / the megakernel body.
        """
        unknown = set(fields) - set(TRACE_FIELDS)
        if unknown:
            raise ValueError(
                f"unknown trace fields {sorted(unknown)}; the row layout is "
                f"{TRACE_FIELDS} (obs/schema.TRACE_FIELDS)")
        row = jnp.zeros((NUM_FIELDS,), jnp.int32)
        for name, value in fields.items():
            row = row.at[_FIELD_INDEX[name]].set(
                jnp.asarray(value, jnp.int32))
        idx = jnp.mod(self.cursor, self.buf.shape[0])
        return TraceRing(buf=self.buf.at[idx].set(row),
                         cursor=self.cursor + 1)


def ring_rows(ring: TraceRing) -> Tuple[List[dict], int]:
    """Drain a ring to host: ``(records, truncated)``.

    Records come back oldest-first as ``{field: int}`` dicts over
    :data:`~repro.obs.schema.TRACE_FIELDS`; ``truncated`` is how many of
    the oldest rounds the wraparound overwrote (0 unless the drain ran
    longer than the capacity).  This is the run's ONE device->host sync
    for tracing.
    """
    cursor = int(ring.cursor)
    cap = ring.capacity
    buf = np.asarray(ring.buf)
    if cursor <= cap:
        data = buf[:cursor]
        truncated = 0
    else:
        k = cursor % cap
        data = np.concatenate([buf[k:], buf[:k]])
        truncated = cursor - cap
    records = [
        {name: int(row[i]) for i, name in enumerate(TRACE_FIELDS)}
        for row in data
    ]
    return records, truncated


def stacked_rings(ring: TraceRing, count: int) -> TraceRing:
    """``count`` device-replica rings as one stacked pytree (leading axis
    per device) — the sharded driver's ``shard_map`` operand shape."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (count,) + x.shape), ring)


def unstack_ring(ring_st: TraceRing, device: int) -> TraceRing:
    """One device's ring out of a stacked pytree (host side, post-drain)."""
    return jax.tree.map(lambda x: x[device], ring_st)
