"""GPipe-style pipeline parallelism over a 'stage' mesh axis.

Completes the parallelism matrix (DP/FSDP x TP x EP x SP x **PP**).  The
production 2-axis mesh doesn't need PP (depth fits via FSDP+TP), so this
executor targets deeper future meshes: stages hold disjoint layer slices
(params sharded over 'stage'), activations flow stage->stage through
``jax.lax.ppermute`` inside ``shard_map``, microbatches fill the pipeline
GPipe-style (bubble fraction (S-1)/(M+S-1)).

The schedule is the Atos theme in one more costume: stage workers consume a
queue of microbatch tasks; the pipeline's fill/drain bubbles are exactly the
small-frontier problem, and raising M is the fetch-size knob.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as PS


def pipeline_apply(stage_fn: Callable, stage_params, x_micro, *, mesh: Mesh,
                   axis: str = "stage"):
    """Run ``stage_fn(params_s, act)`` over S stages for M microbatches.

    stage_params: pytree with leading dim S (sharded over ``axis``).
    x_micro:      [M, mb, ...] microbatched input (replicated).
    Returns       [M, mb, ...] outputs of the final stage.
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def body(params_local, x_all):
        # params_local: leading dim 1 (this stage's slice); x_all replicated
        p = jax.tree.map(lambda a: a[0], params_local)
        s = jax.lax.axis_index(axis)
        # the carry is stage-varying (each stage holds a different
        # activation); mark the initial zeros accordingly.  jax < 0.5 has no
        # pvary (no varying-manual-axes tracking) and needs no annotation.
        pvary = getattr(jax.lax, "pvary", lambda v, _axes: v)
        zero_act = pvary(jnp.zeros_like(x_all[0]), (axis,))

        def tick(carry, t):
            act_in = carry
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            inp = jnp.where(s == 0, x_all[mb_idx], act_in)
            out = stage_fn(p, inp)
            # forward the activation ring; stage S-1 -> 0 wraps harmlessly
            nxt = jax.lax.ppermute(out, axis, perm)
            return nxt, out

        _, outs = jax.lax.scan(tick, zero_act, jnp.arange(ticks))
        # stage s produced microbatch (t - s) at tick t; keep the last
        # stage's window [S-1, S-1+M) — every stage returns its window so
        # out_specs can stack them; the caller slices stage S-1.
        start = jnp.clip(s, 0, ticks - n_micro)
        window = jax.lax.dynamic_slice_in_dim(outs, start, n_micro, axis=0)
        return window[None]  # [1, M, mb, ...] per stage

    out = shard_map(
        body, mesh=mesh,
        in_specs=(PS(axis), PS()),
        out_specs=PS(axis),
    )(stage_params, x_micro)
    return out[-1]  # last stage's microbatch outputs


def split_microbatches(x, n_micro: int):
    """[B, ...] -> [M, B//M, ...]"""
    b = x.shape[0]
    assert b % n_micro == 0
    return x.reshape((n_micro, b // n_micro) + x.shape[1:])
