"""Logical-axis -> mesh resolution + train/serve step builders with pjit.

Resolution rules (DESIGN.md section 6):
  'fsdp'   -> ('data',) or ('pod', 'data') when the mesh has a pod axis
  'tp'     -> 'model'        (Megatron-style row/col parallel pairs)
  'expert' -> 'model'        (EP shares the model axis)
  'layers' -> None           (scan axis)
Activations: batch over ('pod','data'); sequence optionally over 'model'
(SP) for long-context decode.

GQA note: when num_kv_heads < TP degree the KV projections would need a
sub-divisible shard; we keep KV on 'tp' only when divisible, else replicate
(the resolver checks divisibility per-leaf against the actual mesh).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

from ..configs.base import ModelConfig, ShapeConfig
from ..models import transformer as T
from ..models.params import P, abstract_params, init_params, param_shardings
from ..optim import adamw, adafactor


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How logical axes map onto the physical mesh (the hillclimb surface)."""
    fsdp_axis: tuple = ("data",)      # weight-shard axes (ZeRO-3); () = DDP
    tp_axis: tuple = ("model",)
    batch_axes: tuple = ("data",)     # activation batch axes (pod added auto)
    seq_axis: Optional[str] = None    # SP: shard sequence dim of activations
    kv_seq_axis: Optional[str] = None  # decode: shard the KV cache sequence
    moe_dispatch_tp: bool = False     # shard expert FFN ff dim over tp too


def make_resolver(mesh: Mesh, pc: ParallelConfig):
    """P(spec) -> NamedSharding, validated against mesh divisibility."""
    has_pod = "pod" in mesh.axis_names

    def axes_for(logical: Optional[str]):
        if logical == "fsdp":
            ax = (("pod",) if has_pod else ()) + tuple(pc.fsdp_axis)
            return ax if ax else None
        if logical == "tp":
            return tuple(pc.tp_axis) or None
        if logical == "expert":
            return tuple(pc.tp_axis) or None
        return None  # 'layers' / None -> replicated

    def mesh_size(ax) -> int:
        if ax is None:
            return 1
        n = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            n *= mesh.shape[a]
        return n

    def resolve(spec: P) -> NamedSharding:
        parts = []
        for dim, logical in zip(spec.shape, spec.axes):
            ax = axes_for(logical)
            if ax is not None and dim % mesh_size(ax) != 0:
                ax = None  # not divisible on this mesh: replicate this dim
            if ax is not None and len(ax) == 1:
                ax = ax[0]
            parts.append(ax)
        return NamedSharding(mesh, PS(*parts))

    return resolve


def batch_sharding(mesh: Mesh, pc: ParallelConfig, *, seq_dims=2):
    has_pod = "pod" in mesh.axis_names
    batch_ax = (("pod",) if has_pod else ()) + tuple(pc.batch_axes)
    batch_ax = batch_ax if len(batch_ax) > 1 else batch_ax[0]
    if seq_dims >= 2 and pc.seq_axis:
        return NamedSharding(mesh, PS(batch_ax, pc.seq_axis))
    parts = [batch_ax] + [None] * (seq_dims - 1)
    return NamedSharding(mesh, PS(*parts))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, PS())


# ------------------------------------------------------------ step builders


def abstract_train_state(cfg: ModelConfig, mesh: Mesh, pc: ParallelConfig):
    """(params, opt_state) as ShapeDtypeStructs with shardings — dry-run."""
    spec = T.model_spec(cfg)
    resolve = make_resolver(mesh, pc)
    dtype = jnp.dtype(cfg.dtype)
    params = abstract_params(spec, dtype, resolve)

    def f32_like(p):
        return jax.ShapeDtypeStruct(p.shape, jnp.float32, sharding=p.sharding)

    if cfg.use_adafactor:
        def vr_like(p):
            if len(p.shape) >= 2:
                sh = NamedSharding(mesh, PS(*p.sharding.spec[:-1]))
                return jax.ShapeDtypeStruct(p.shape[:-1], jnp.float32,
                                            sharding=sh)
            return f32_like(p)

        def vc_like(p):
            if len(p.shape) >= 2:
                sh = NamedSharding(
                    mesh, PS(*(p.sharding.spec[:-2] + p.sharding.spec[-1:])))
                return jax.ShapeDtypeStruct(p.shape[:-2] + p.shape[-1:],
                                            jnp.float32, sharding=sh)
            return jax.ShapeDtypeStruct((1,), jnp.float32,
                                        sharding=replicated(mesh))
        opt = adafactor.AdafactorState(
            step=jax.ShapeDtypeStruct((), jnp.int32,
                                      sharding=replicated(mesh)),
            vr=jax.tree.map(vr_like, params),
            vc=jax.tree.map(vc_like, params))
    else:
        opt = adamw.AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32,
                                      sharding=replicated(mesh)),
            m=jax.tree.map(f32_like, params),
            v=jax.tree.map(f32_like, params))
    return params, opt


def make_train_step(cfg: ModelConfig, opt_cfg=None, *, attn_impl="xla",
                    grad_compression: str = "none"):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``grad_compression``: none | int8 — int8 quantizes gradients before the
    data-parallel all-reduce (see distributed/compression.py).
    """
    if opt_cfg is None:
        opt_cfg = (adafactor.AdafactorConfig() if cfg.use_adafactor
                   else adamw.AdamWConfig())

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: T.loss_fn(p, cfg, batch, attn_impl=attn_impl))(params)
        if grad_compression == "int8":
            from .compression import fake_quant_grads
            grads = fake_quant_grads(grads)
        if cfg.use_adafactor:
            params, opt_state, om = adafactor.update(
                opt_cfg, params, grads, opt_state)
            om = dict(om)
        else:
            params, opt_state, om = adamw.update(
                opt_cfg, params, grads, opt_state)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return train_step


def make_serve_step(cfg: ModelConfig, *, attn_impl="xla"):
    def serve_step(params, cache, tokens):
        logits, cache = T.decode_step(params, cfg, cache, tokens)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, cache

    return serve_step


def make_prefill_step(cfg: ModelConfig, *, attn_impl="xla"):
    def prefill_step(params, batch):
        logits, _ = T.forward(params, cfg, batch, attn_impl=attn_impl)
        return logits[:, -1, :]

    return prefill_step


def cache_shardings(cfg: ModelConfig, mesh: Mesh, pc: ParallelConfig,
                    cache: Any):
    """Decode-cache shardings: batch over data axes, heads/d_inner over tp,
    optionally the KV sequence over ``pc.kv_seq_axis`` (flash-decode style —
    the weight-stationary serving layout)."""
    has_pod = "pod" in mesh.axis_names
    b_ax = (("pod",) if has_pod else ()) + tuple(pc.batch_axes)
    b_ax = (b_ax if len(b_ax) > 1 else (b_ax[0] if b_ax else None))
    tp = pc.tp_axis[0] if pc.tp_axis else None

    def sh(x):
        if not hasattr(x, "ndim") or x.ndim == 0:
            return replicated(mesh)
        nb = x.shape[0]

        def div(dim, ax):
            if ax is None:
                return None
            size = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                size *= mesh.shape[a]
            return ax if dim % size == 0 else None

        if x.ndim == 5:   # stacked kv [L, B, S, KVH, hd]
            return NamedSharding(
                mesh, PS(None, div(x.shape[1], b_ax),
                         div(x.shape[2], pc.kv_seq_axis),
                         div(x.shape[3], tp), None))
        if x.ndim == 4:
            ssm_fam = cfg.family in ("ssm", "hybrid")
            if ssm_fam and x.shape[-1] == cfg.ssm_state:
                # ssm state [L, B, d_inner, n]
                return NamedSharding(mesh, PS(None, div(x.shape[1], b_ax),
                                              div(x.shape[2], tp), None))
            if ssm_fam and x.shape[-1] == cfg.d_inner:
                # conv cache [L, B, k-1, d_inner]
                return NamedSharding(mesh, PS(None, div(x.shape[1], b_ax),
                                              None, div(x.shape[3], tp)))
            # encoder cross-kv [B, S_enc, KVH, hd]
            return NamedSharding(mesh, PS(div(x.shape[0], b_ax), None,
                                          div(x.shape[2], tp), None))
        if x.ndim == 3:
            return NamedSharding(mesh, PS(None, div(x.shape[1], b_ax), None))
        return replicated(mesh)

    return jax.tree.map(sh, cache)
