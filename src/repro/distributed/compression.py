"""Gradient compression: int8 quantization with error feedback.

Two entry points:

  * ``compressed_psum(x, axis_name)`` — for explicit-collective (shard_map)
    data parallelism: per-shard int8 quantization + all_gather(int8) + local
    dequant-reduce.  Wire bytes: n * 1B vs f32 ring all-reduce's ~8B/elem —
    an ~8x collective-term reduction, at the cost of quantization noise that
    error feedback (``ErrorFeedback``) keeps unbiased over steps.

  * ``fake_quant_grads(grads)`` — for the implicit-collective (pjit/GSPMD)
    path where the all-reduce is inserted by the partitioner and cannot be
    intercepted: applies the same quantize->dequantize numerics so the
    *convergence impact* of compression is measurable end-to-end, while the
    wire format is unchanged.  (Recorded honestly in DESIGN.md: on real
    hardware the shard_map path is the one that saves bandwidth.)
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


def fake_quant_grads(grads: Any) -> Any:
    def fq(g):
        q, s = quantize_int8(g.astype(jnp.float32))
        return dequantize_int8(q, s).astype(g.dtype)

    return jax.tree.map(fq, grads)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8 all-gather + local reduce == psum with 8x fewer wire bytes."""
    q, scale = quantize_int8(x.astype(jnp.float32))
    qs = jax.lax.all_gather(q, axis_name)            # [n_dev, ...] int8
    scales = jax.lax.all_gather(scale, axis_name)    # [n_dev]
    deq = qs.astype(jnp.float32) * scales.reshape(
        (-1,) + (1,) * (qs.ndim - 1))
    return jnp.sum(deq, axis=0).astype(x.dtype)


class ErrorFeedback(NamedTuple):
    """Residual accumulator making quantized updates unbiased over time."""
    residual: Any

    @staticmethod
    def init(grads):
        return ErrorFeedback(jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads))

    def compress(self, grads):
        def one(g, r):
            corrected = g.astype(jnp.float32) + r
            q, s = quantize_int8(corrected)
            deq = dequantize_int8(q, s)
            return deq.astype(g.dtype), corrected - deq

        flat_g, treedef = jax.tree.flatten(grads)
        flat_r = treedef.flatten_up_to(self.residual)
        res = [one(g, r) for g, r in zip(flat_g, flat_r)]
        return (jax.tree.unflatten(treedef, [a for a, _ in res]),
                ErrorFeedback(jax.tree.unflatten(treedef,
                                                 [b for _, b in res])))
