"""Fault-tolerance runtime pieces: straggler detection, restart, elasticity.

On a real cluster these hooks sit between the trainer and the scheduler
(Borg/SLURM/GKE).  Everything here is host-level and hardware-independent,
so it runs (and is tested) in this container:

  * ``StepMonitor``    — per-step wall-time tracking; flags stragglers when a
    step exceeds ``k x`` the trailing median (the signal used to trigger
    preemptive checkpoint + reschedule at scale).
  * ``run_with_restarts`` — crash-restart harness around a step function:
    on exception it restores the latest checkpoint and continues; the test
    suite kills a training run mid-flight and asserts bit-exact recovery.
  * ``elastic_remesh``  — re-lay-out a checkpointed pytree onto a different
    mesh (more/fewer pods) via device_put with the new shardings; this is
    the elastic-scaling path (checkpoints are device-layout-free).
"""
from __future__ import annotations

import statistics
import time
from typing import Any, Callable, Optional

import jax


class StepMonitor:
    def __init__(self, straggler_factor: float = 3.0, window: int = 50):
        self.factor = straggler_factor
        self.window = window
        self.durations: list[float] = []
        self.straggler_steps: list[int] = []
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> bool:
        """Record; returns True if this step was a straggler."""
        dt = time.perf_counter() - self._t0
        is_straggler = False
        recent = self.durations[-self.window:]
        if len(recent) >= 5:
            med = statistics.median(recent)
            if dt > self.factor * med:
                is_straggler = True
                self.straggler_steps.append(step)
        self.durations.append(dt)
        return is_straggler


def run_with_restarts(step_fn: Callable[[int, Any], Any], state: Any,
                      *, start_step: int, num_steps: int,
                      ckpt_manager, save_every: int,
                      restore_fn: Callable[[int], Any],
                      max_restarts: int = 3):
    """Drive ``state = step_fn(i, state)``, checkpointing every
    ``save_every``; on exception restore the latest checkpoint and resume."""
    restarts = 0
    i = start_step
    while i < num_steps:
        try:
            state = step_fn(i, state)
            if (i + 1) % save_every == 0:
                ckpt_manager.save(i + 1, state, blocking=False)
            i += 1
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            ckpt_manager.wait()
            latest = ckpt_manager.latest_step()
            if latest is None:
                raise
            state = restore_fn(latest)
            i = latest
    ckpt_manager.wait()
    return state, {"restarts": restarts}


def elastic_remesh(tree: Any, new_shardings: Any) -> Any:
    """Re-layout a host/device pytree onto new shardings (new mesh)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, new_shardings)
