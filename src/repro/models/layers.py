"""Transformer building blocks: norms, RoPE, GQA attention (bias/SWA), MLP.

All functions are pure; params are dicts produced from the spec trees in this
module.  Attention dispatches between the XLA einsum path (dry-run/roofline —
XLA cost analysis sees the FLOPs) and the Pallas flash kernel (TPU hot path,
validated in interpret mode).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .params import P

# ----------------------------------------------------------------- norms


def norm_spec(cfg: ModelConfig):
    if cfg.norm == "layernorm":
        return {"scale": P((cfg.d_model,), (None,), "ones"),
                "bias": P((cfg.d_model,), (None,), "zeros")}
    return {"scale": P((cfg.d_model,), (None,), "ones")}


def apply_norm(params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if "bias" in params:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        return (y * params["scale"] + params["bias"]).astype(x.dtype)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * params["scale"]).astype(x.dtype)


# ------------------------------------------------------------------ rope


def rope_freqs(cfg: ModelConfig, positions: jax.Array) -> tuple:
    """positions [*, T] -> (sin, cos) each [*, T, hd/2] f32."""
    hd = cfg.hd
    half = hd // 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x [B, T, H, hd]; sin/cos [B, T, hd/2] (broadcast over heads)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    s, c = sin[..., None, :], cos[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)


# ------------------------------------------------------------- attention


def attention_spec(cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.hd
    h = _eff_heads(cfg)
    spec = {
        "wq": P((d, h * hd), ("fsdp", "tp")),
        "wk": P((d, cfg.num_kv_heads * hd), ("fsdp", "tp")),
        "wv": P((d, cfg.num_kv_heads * hd), ("fsdp", "tp")),
        "wo": P((h * hd, d), ("tp", "fsdp")),
    }
    if cfg.qkv_bias:
        spec["bq"] = P((h * hd,), ("tp",), "zeros")
        spec["bk"] = P((cfg.num_kv_heads * hd,), ("tp",), "zeros")
        spec["bv"] = P((cfg.num_kv_heads * hd,), ("tp",), "zeros")
    return spec


def _eff_heads(cfg: ModelConfig) -> int:
    """TP-alignment hillclimb (section Perf): when num_heads % tp != 0, the
    flat->heads reshape forces GSPMD to repartition activations every layer.
    ``pad_heads_to`` widens the q projection to an aligned head count (the
    extra heads' wo rows contribute like ordinary heads of a slightly wider
    perf-variant; the assigned geometry stays 56q/8kv semantically)."""
    return cfg.pad_heads_to or cfg.num_heads


def _project_qkv(params, cfg: ModelConfig, x):
    b, t, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, t, _eff_heads(cfg), cfg.hd)
    k = k.reshape(b, t, cfg.num_kv_heads, cfg.hd)
    v = v.reshape(b, t, cfg.num_kv_heads, cfg.hd)
    return q, k, v


def _sdpa_xla(q, k, v, *, causal: bool, window: int,
              q_offset: int | jax.Array = 0):
    """Einsum attention (GQA-aware). q [B,Tq,H,hd]; k/v [B,Tk,KVH,hd].

    ``q_offset``: absolute position of q[0] (decode: Tk-1 or cache length).
    """
    b, tq, h, hd = q.shape
    tk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, tq, kvh, g, hd)
    s = jnp.einsum("btkgd,bskd->bktgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / (hd ** 0.5)
    q_pos = q_offset + jnp.arange(tq)[:, None]
    k_pos = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None, :, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bktgs,bskd->btkgd", p, v.astype(jnp.float32))
    return o.reshape(b, tq, h, hd).astype(q.dtype)


def _sdpa_blocked(q, k, v, *, causal: bool, window: int, block: int = 0):
    """Hillclimbed attention (section Perf): block-tiled with

      * causal / sliding-window BLOCK SKIPPING — fully-masked (qb, kb) block
        pairs are never emitted (~2x fewer logit bytes+flops for causal;
        ~tk/window for SWA at long context);
      * bf16 logits and probabilities (f32 running max/sum) — halves the
        dominant softmax traffic;
      * dots via ``preferred_element_type=f32`` — no materialized f32
        upcasts of q/k/v.

    Blocks are a static python loop (not a scan) so XLA cost analysis sees
    every byte honestly (scan bodies are counted once — DESIGN section 7).
    """
    b, tq, h, hd = q.shape
    tk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    if block <= 0:
        block = max(1024, tq // 8)
    block = min(block, tq, tk)
    nq, nk = -(-tq // block), -(-tk // block)
    scale = 1.0 / (hd ** 0.5)
    qg = q.reshape(b, tq, kvh, g, hd)

    out = []
    for qi in range(nq):
        q_blk = qg[:, qi * block:(qi + 1) * block]
        qb = q_blk.shape[1]
        m_run = jnp.full((b, kvh, qb, g), -jnp.inf, jnp.float32)
        l_run = jnp.zeros((b, kvh, qb, g), jnp.float32)
        acc = jnp.zeros((b, kvh, qb, g, hd), jnp.float32)
        q_lo, q_hi = qi * block, qi * block + qb - 1
        for ki in range(nk):
            k_lo, k_hi = ki * block, min((ki + 1) * block, tk) - 1
            if causal and k_lo > q_hi:
                continue  # block fully in the future
            if window > 0 and (q_lo - k_hi) >= window:
                continue  # block fully outside the window
            k_blk = k[:, k_lo:k_hi + 1]
            v_blk = v[:, k_lo:k_hi + 1]
            s = jax.lax.dot_general(
                q_blk, k_blk, (((4,), (3,)), ((0, 2), (0, 2))),
                preferred_element_type=jnp.float32) * scale
            # s: [b, kvh, qb, g, kb]
            q_pos = q_lo + jnp.arange(qb)[:, None]
            k_pos = k_lo + jnp.arange(k_blk.shape[1])[None, :]
            mask = jnp.ones((qb, k_blk.shape[1]), bool)
            if causal:
                mask &= q_pos >= k_pos
            if window > 0:
                mask &= (q_pos - k_pos) < window
            s = jnp.where(mask[None, None, :, None, :], s, -1e30)
            m_blk = jnp.max(s, axis=-1)
            m_new = jnp.maximum(m_run, m_blk)
            p = jnp.exp(s - m_new[..., None]).astype(jnp.bfloat16)
            corr = jnp.exp(m_run - m_new)
            l_run = l_run * corr + jnp.sum(p, axis=-1, dtype=jnp.float32)
            pv = jax.lax.dot_general(
                p, v_blk, (((4,), (1,)), ((0, 1), (0, 2))),
                preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            m_run = m_new
        o = acc / jnp.maximum(l_run, 1e-30)[..., None]
        out.append(o.transpose(0, 2, 1, 3, 4).reshape(b, qb, h, hd))
    return jnp.concatenate(out, axis=1).astype(q.dtype)


def apply_attention(params, cfg: ModelConfig, x, *, positions=None,
                    attn_impl: str = "xla", kv_cache=None, cache_len=None):
    """Full attention sub-layer.

    Training/prefill: kv_cache=None -> self-attention over x.
    Decode: kv_cache=(k, v) [B, S, KVH, hd] ring buffers + cache_len scalar;
            x is the single new token's hidden state [B, 1, d].
    Returns (out, new_kv_cache).
    """
    b, t, _ = x.shape
    if positions is None:
        if kv_cache is not None:
            # cache_len is PER-ROW [B] — continuous batching mixes depths
            positions = cache_len[:, None].astype(jnp.int32)
        else:
            positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    q, k, v = _project_qkv(params, cfg, x)
    sin, cos = rope_freqs(cfg, positions)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)

    if kv_cache is not None:
        ck, cv = kv_cache
        s_max = ck.shape[1]
        if cfg.sliding_window > 0 and s_max <= cfg.sliding_window:
            slot = cache_len % s_max          # ring buffer for SWA
        else:
            slot = jnp.minimum(cache_len, s_max - 1)
        rows = jnp.arange(b)
        ck = ck.at[rows, slot].set(k[:, 0])
        cv = cv.at[rows, slot].set(v[:, 0])
        # mask out unwritten cache tail via window/causal logic
        o = _sdpa_decode(q, ck, cv, cache_len, cfg.sliding_window)
        out = o.reshape(b, t, -1) @ params["wo"]
        return out, (ck, cv)

    if attn_impl == "pallas":
        from ..kernels.flash_attention.ops import multihead_attention
        o = multihead_attention(q, k, v, causal=True,
                                window=cfg.sliding_window, impl="pallas")
    elif attn_impl == "blocked":
        o = _sdpa_blocked(q, k, v, causal=True, window=cfg.sliding_window,
                          block=cfg.attn_block)
    else:
        o = _sdpa_xla(q, k, v, causal=True, window=cfg.sliding_window)
    out = o.reshape(b, t, -1) @ params["wo"]
    return out, None


def _sdpa_decode(q, ck, cv, cache_len, window: int):
    """One-token attention over the cache. q [B,1,H,hd], cache [B,S,KVH,hd],
    cache_len [B] (per-row depth)."""
    b, _, h, hd = q.shape
    s, kvh = ck.shape[1], ck.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, hd)
    # dots read the bf16 cache directly with f32 accumulation — materialized
    # f32 upcasts of the whole cache were the decode memory hot spot
    # (section Perf, hillclimb 3)
    logits = jax.lax.dot_general(
        qg, ck, (((3,), (3,)), ((0, 1), (0, 2))),
        preferred_element_type=jnp.float32) / (hd ** 0.5)  # [b, kvh, g, s]
    k_pos = jnp.arange(s)[None, None, None, :]
    lens = cache_len[:, None, None, None]
    valid = k_pos <= lens
    if window > 0 and s <= window:
        # ring buffer: every slot is live once the cache has wrapped
        valid = valid | (lens >= s)
    logits = jnp.where(valid, logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m).astype(ck.dtype)      # bf16 probabilities
    l = jnp.sum(p, axis=-1, keepdims=True, dtype=jnp.float32)
    o = jax.lax.dot_general(
        p, cv, (((3,), (1,)), ((0, 1), (0, 2))),
        preferred_element_type=jnp.float32)       # [b, kvh, g, hd]
    o = o / jnp.maximum(l, 1e-30)
    return o.reshape(b, 1, h, hd).astype(q.dtype)


# ------------------------------------------------------------------- mlp


def mlp_spec(cfg: ModelConfig, d_ff: int | None = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act == "swiglu":
        return {
            "wi": P((d, ff), ("fsdp", "tp")),
            "wg": P((d, ff), ("fsdp", "tp")),
            "wo": P((ff, d), ("tp", "fsdp")),
        }
    return {
        "wi": P((d, ff), ("fsdp", "tp")),
        "wo": P((ff, d), ("tp", "fsdp")),
    }


def apply_mlp(params, cfg: ModelConfig, x):
    if "wg" in params:
        h = jax.nn.silu(x @ params["wg"]) * (x @ params["wi"])
    else:
        h = jax.nn.gelu(x @ params["wi"])
    return h @ params["wo"]


# ------------------------------------------------------------- embeddings


def embedding_spec(cfg: ModelConfig):
    spec = {"tok": P((cfg.vocab_size, cfg.d_model), ("tp", "fsdp"), "small_normal",
                     scale=1.0)}
    if not cfg.tie_embeddings:
        spec["head"] = P((cfg.d_model, cfg.vocab_size), ("fsdp", "tp"))
    return spec


def embed_tokens(params, tokens):
    return params["tok"][tokens]


def lm_logits(params, cfg: ModelConfig, h):
    if cfg.tie_embeddings:
        return h @ params["tok"].T
    return h @ params["head"]
