"""Selective state-space blocks: Mamba1 (falcon-mamba) and Mamba2 (zamba2).

Time mixing runs as a ``lax.scan`` over time with a [B, d_inner, n] carry
(TPU-friendly: constant VMEM working set per step, activations shard over
batch x model so the saved-residual footprint is per-device small; see
DESIGN.md).  Decode is the single recurrence step with (conv_state, ssm_state)
caches.

Roofline note: the scan body's FLOPs are counted once by XLA cost analysis;
the roofline analyzer adds the analytic ``T x`` correction for the recurrence
(which is <1% of the block's FLOPs — the projections dominate).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .params import P


class SSMCache(NamedTuple):
    conv: jax.Array   # [B, conv_k - 1, d_inner]
    state: jax.Array  # [B, d_inner, n]


def mamba_spec(cfg: ModelConfig):
    d, di, n, k, r = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv,
                      cfg.dt_rank)
    return {
        "in_proj": P((d, 2 * di), ("fsdp", "tp")),
        "conv_w": P((k, di), (None, "tp")),
        "conv_b": P((di,), ("tp",), "zeros"),
        "x_proj": P((di, r + 2 * n), ("tp", None)),
        "dt_proj": P((r, di), (None, "tp")),
        "dt_bias": P((di,), ("tp",), "ones"),
        "a_log": P((di, n), ("tp", None), "ones"),
        "d_skip": P((di,), ("tp",), "ones"),
        "out_proj": P((di, d), ("tp", "fsdp")),
    }


def _ssm_params(params, cfg: ModelConfig, xz):
    """Shared pre-scan computation. xz [B, T, di] (post conv+silu)."""
    n, r = cfg.ssm_state, cfg.dt_rank
    proj = xz @ params["x_proj"]                      # [B, T, r + 2n]
    dt_r, b_mat, c_mat = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(dt_r @ params["dt_proj"] + params["dt_bias"])
    a = -jnp.exp(params["a_log"].astype(jnp.float32))  # [di, n]
    return dt, b_mat, c_mat, a


def _causal_conv(params, x, cache=None):
    """Depthwise causal conv1d. x [B, T, di] -> [B, T, di]."""
    k = params["conv_w"].shape[0]
    if cache is not None:
        ctx = jnp.concatenate([cache, x], axis=1)     # [B, k-1+T, di]
    else:
        ctx = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(ctx[:, i:i + x.shape[1], :] * params["conv_w"][i]
              for i in range(k))
    new_cache = ctx[:, -(k - 1):, :] if k > 1 else None
    return out + params["conv_b"], new_cache


def apply_mamba(params, cfg: ModelConfig, x, *, cache: SSMCache | None = None):
    """x [B, T, d] -> ([B, T, d], new_cache).  T=1 decode when cache given."""
    b, t, _ = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    xz = x @ params["in_proj"]                        # [B, T, 2di]
    xs, z = jnp.split(xz, 2, axis=-1)
    conv_cache = cache.conv if cache is not None else None
    xs, new_conv = _causal_conv(params, xs, conv_cache)
    xs = jax.nn.silu(xs)
    dt, b_mat, c_mat, a = _ssm_params(params, cfg, xs)

    h0 = (cache.state if cache is not None
          else jnp.zeros((b, di, n), jnp.float32))

    if t == 1:  # decode fast path: one recurrence step, no scan
        h, y = _ssm_step(h0, (xs[:, 0], dt[:, 0], b_mat[:, 0], c_mat[:, 0]), a)
        y = y[:, None, :]
        h_last = h
    else:
        def step(h, inp):
            h, y = _ssm_step(h, inp, a)
            return h, y

        h_last, ys = jax.lax.scan(
            step, h0,
            (xs.transpose(1, 0, 2), dt.transpose(1, 0, 2),
             b_mat.transpose(1, 0, 2), c_mat.transpose(1, 0, 2)))
        y = ys.transpose(1, 0, 2)                     # [B, T, di]

    y = y + xs * params["d_skip"]
    y = y * jax.nn.silu(z)
    out = y.astype(x.dtype) @ params["out_proj"]
    new_cache = SSMCache(conv=new_conv, state=h_last)
    return out, new_cache


def _ssm_step(h, inp, a):
    """h [B, di, n]; inp = (x, dt, b, c) at one time step."""
    x_t, dt_t, b_t, c_t = inp                         # [B,di],[B,di],[B,n],[B,n]
    dt_f = dt_t.astype(jnp.float32)
    da = jnp.exp(dt_f[..., None] * a[None])           # [B, di, n]
    dbx = (dt_f * x_t.astype(jnp.float32))[..., None] * \
        b_t.astype(jnp.float32)[:, None, :]           # [B, di, n]
    h = da * h + dbx
    y = jnp.einsum("bdn,bn->bd", h, c_t.astype(jnp.float32))
    return h, y.astype(x_t.dtype)


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> SSMCache:
    return SSMCache(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        state=jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    )
