"""Spec-first parameters: shapes + logical sharding axes declared up front.

Every module describes its parameters as a tree of ``P(shape, axes, init)``;
the same tree serves three consumers:

  * ``init_params``      — materialize real weights (smoke tests, examples);
  * ``abstract_params``  — ``ShapeDtypeStruct``s with ``NamedSharding``
                           attached (the multi-pod dry-run allocates nothing);
  * ``param_shardings``  — the in/out_shardings for pjit.

Logical axes used (resolved by ``distributed/sharding.py``):
  'fsdp'   -> mesh 'data' (+ 'pod' when multi-pod)   — ZeRO-3 weight shard
  'tp'     -> mesh 'model'                           — tensor parallel
  'expert' -> mesh 'model'                           — expert parallel
  'layers' -> None (scan axis)
  None     -> replicated
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class P:
    """Parameter spec: shape + logical axes (one per dim) + init kind."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"       # normal | zeros | ones | small_normal
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _initializer(spec: P, key, dtype):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    std = spec.scale / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)


def is_spec(x) -> bool:
    return isinstance(x, P)


def init_params(spec_tree, key, dtype) -> Any:
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_initializer(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(spec_tree, dtype, resolve: Callable[[P], Any] | None = None):
    """ShapeDtypeStructs (optionally with .sharding via ``resolve(spec)``)."""

    def mk(s: P):
        sharding = resolve(s) if resolve is not None else None
        return jax.ShapeDtypeStruct(s.shape, dtype, sharding=sharding)

    return jax.tree.map(mk, spec_tree, is_leaf=is_spec)


def param_shardings(spec_tree, resolve: Callable[[P], Any]):
    return jax.tree.map(resolve, spec_tree, is_leaf=is_spec)


def stack_layers(spec_tree, n: int):
    """Prepend a scanned 'layers' axis of size n to every spec."""

    def add(s: P) -> P:
        return P((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale)

    return jax.tree.map(add, spec_tree, is_leaf=is_spec)


def count_params(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=is_spec)
    return sum(int(math.prod(s.shape)) for s in leaves)
