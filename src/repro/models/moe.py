"""Mixture-of-Experts layer with Atos-style capacity dispatch.

Token->expert routing is a dynamic irregular scatter — the same pattern as
the paper's task queue.  Slot reservation inside each expert's capacity
buffer uses the *prefix-sum reservation* primitive from ``core/queue.py``
(DESIGN.md section 3): for expert e, the k-th routed token (in wavefront
order) takes slot k; tokens past capacity are dropped exactly like Atos
drops on a full queue (and counted, so tests can assert the capacity factor
is adequate).

Sharding: experts are laid out on the 'expert' logical axis (-> mesh
'model'), so dispatch/return lower to all-to-alls on the model axis — the
EP pattern.  The expert FFN itself is a batched einsum over [E, cap, d].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .params import P


def moe_spec(cfg: ModelConfig):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": P((d, e), (None, None)),
        "wi": P((e, d, ff), ("expert", "fsdp", None)),
        "wg": P((e, d, ff), ("expert", "fsdp", None)),
        "wo": P((e, ff, d), ("expert", None, "fsdp")),
    }


def apply_moe(params, cfg: ModelConfig, x, *, capacity: int | None = None):
    """x [B, T, d] -> ([B, T, d], aux) with top-k routing + capacity drop.

    Returns (out, metrics) where metrics carries load-balance aux loss and
    drop counts.
    """
    b, t, d = x.shape
    n_tok = b * t
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    if capacity is None:
        cf = cfg.moe_cap_factor_override or cfg.capacity_factor
        capacity = int(cf * n_tok * k / e)
        capacity = max(8, -(-capacity // 8) * 8)

    def ep(buf, spec_tail):
        """EP hillclimb: pin expert-major buffers to the expert mesh axis so
        GSPMD routes dispatch/return as all-to-alls instead of replicating
        the capacity buffers (section Perf, kimi-k2)."""
        if not cfg.moe_ep_axis:
            return buf
        from jax.sharding import PartitionSpec as _PS
        return jax.lax.with_sharding_constraint(
            buf, _PS(cfg.moe_ep_axis, *spec_tail))

    xf = x.reshape(n_tok, d)
    logits = (xf.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # [N, E]
    gate_vals, gate_idx = jax.lax.top_k(probs, k)               # [N, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- Atos prefix-sum slot reservation, sort-based so memory stays
    # O(N*k) (a dense [N*k, E] cumsum would be terabytes at kimi-k2 scale):
    # sort assignments by expert; a token's slot is its index within its
    # expert's run, recovered with a segmented iota.
    flat_expert = gate_idx.reshape(-1)                          # [N*k]
    nk = flat_expert.shape[0]
    order = jnp.argsort(flat_expert, stable=True)
    sorted_e = flat_expert[order]
    idx = jnp.arange(nk, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool),
                                sorted_e[1:] != sorted_e[:-1]])
    group_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, idx, 0))
    slot_sorted = idx - group_start
    slot = jnp.zeros((nk,), jnp.int32).at[order].set(slot_sorted)
    keep = slot < capacity
    dropped = jnp.sum((~keep).astype(jnp.int32))

    # dispatch: scatter tokens into [E, cap, d]
    tok_idx = jnp.repeat(jnp.arange(n_tok, dtype=jnp.int32), k)
    dst = jnp.where(keep, flat_expert * capacity + slot, e * capacity)
    buf = jnp.zeros((e * capacity, d), xf.dtype).at[dst].add(
        jnp.where(keep[:, None], xf[tok_idx], 0), mode="drop")
    buf = ep(buf.reshape(e, capacity, d), (None, None))

    # expert FFN (swiglu), batched over experts
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["wg"])) * \
        jnp.einsum("ecd,edf->ecf", buf, params["wi"])
    h = ep(h, (None, None))
    y = ep(jnp.einsum("ecf,efd->ecd", h, params["wo"]), (None, None))

    # return: gather each assignment's expert output, weight by gate
    y_flat = y.reshape(e * capacity, d)
    per_assign = jnp.where(keep[:, None],
                           y_flat[jnp.where(keep, dst, 0)], 0.0)
    out = jnp.zeros((n_tok, d), xf.dtype).at[tok_idx].add(
        per_assign * gate_vals.reshape(-1)[:, None].astype(xf.dtype))

    # load-balance aux (Switch-style; bincount instead of a dense one-hot)
    frac_tokens = jnp.zeros((e,), jnp.float32).at[flat_expert].add(1.0) / nk
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return out.reshape(b, t, d), {"aux_loss": aux, "dropped": dropped}
