"""Family-dispatched backbone: decoder-only dense/VLM/MoE, SSM, hybrid, enc-dec.

One spec tree + three entry points per family:
  * ``loss_fn``      — next-token CE (training)
  * ``prefill``      — forward pass producing logits + decode caches
  * ``decode_step``  — one-token step over the caches (serving)

Repeated layers are stacked on a leading 'layers' axis and executed with
``lax.scan`` (compile time independent of depth; remat policy per config).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from . import moe as M
from . import ssm as S
from .params import P, stack_layers

# ------------------------------------------------------------ spec trees


def block_spec(cfg: ModelConfig, kind: str):
    """kind: dense | moe | mamba | encdec_dec (self+cross attn)."""
    if kind == "mamba":
        return {"norm": L.norm_spec(cfg), "mamba": S.mamba_spec(cfg)}
    spec = {
        "norm1": L.norm_spec(cfg),
        "attn": L.attention_spec(cfg),
        "norm2": L.norm_spec(cfg),
    }
    if kind == "moe":
        spec["moe"] = M.moe_spec(cfg)
    else:
        spec["mlp"] = L.mlp_spec(cfg)
    if kind == "encdec_dec":
        spec["norm_x"] = L.norm_spec(cfg)
        spec["xattn"] = L.attention_spec(cfg)
    return spec


def model_spec(cfg: ModelConfig):
    spec: dict = {"embed": L.embedding_spec(cfg),
                  "final_norm": L.norm_spec(cfg)}
    fam = cfg.family
    if fam in ("dense", "vlm"):
        spec["layers"] = stack_layers(block_spec(cfg, "dense"), cfg.num_layers)
    elif fam == "moe":
        spec["layers"] = stack_layers(block_spec(cfg, "moe"), cfg.num_layers)
    elif fam == "ssm":
        spec["layers"] = stack_layers(block_spec(cfg, "mamba"), cfg.num_layers)
    elif fam == "hybrid":
        spec["layers"] = stack_layers(block_spec(cfg, "mamba"), cfg.num_layers)
        spec["shared"] = block_spec(cfg, "dense")   # one shared attn block
    elif fam == "encdec":
        spec["enc_layers"] = stack_layers(block_spec(cfg, "dense"),
                                          cfg.encoder_layers)
        spec["layers"] = stack_layers(block_spec(cfg, "encdec_dec"),
                                      cfg.num_layers)
    else:
        raise ValueError(fam)
    return spec


# ----------------------------------------------------------- block apply


def _apply_dense_block(p, cfg, x, *, causal=True, attn_impl="xla",
                       kv_cache=None, cache_len=None, positions=None):
    h, new_kv = L.apply_attention(
        p["attn"], cfg, L.apply_norm(p["norm1"], x), positions=positions,
        attn_impl=attn_impl, kv_cache=kv_cache, cache_len=cache_len)
    x = x + h
    x = x + L.apply_mlp(p["mlp"], cfg, L.apply_norm(p["norm2"], x))
    return x, new_kv


def _apply_moe_block(p, cfg, x, *, attn_impl="xla", kv_cache=None,
                     cache_len=None):
    h, new_kv = L.apply_attention(
        p["attn"], cfg, L.apply_norm(p["norm1"], x),
        attn_impl=attn_impl, kv_cache=kv_cache, cache_len=cache_len)
    x = x + h
    y, aux = M.apply_moe(p["moe"], cfg, L.apply_norm(p["norm2"], x))
    return x + y, new_kv, aux


def _apply_mamba_block(p, cfg, x, *, cache=None):
    h, new_cache = S.apply_mamba(p["mamba"], cfg,
                                 L.apply_norm(p["norm"], x), cache=cache)
    return x + h, new_cache


def _apply_xattn_block(p, cfg, x, enc_kv, *, kv_cache=None, cache_len=None):
    """Encoder-decoder decoder block: self-attn, cross-attn, mlp."""
    h, new_kv = L.apply_attention(
        p["attn"], cfg, L.apply_norm(p["norm1"], x),
        kv_cache=kv_cache, cache_len=cache_len)
    x = x + h
    # cross attention: q from x, kv precomputed from encoder output
    xq = L.apply_norm(p["norm_x"], x)
    b, t, _ = xq.shape
    q = (xq @ p["xattn"]["wq"]).reshape(b, t, cfg.num_heads, cfg.hd)
    ek, ev = enc_kv
    o = L._sdpa_xla(q, ek, ev, causal=False, window=0)
    x = x + o.reshape(b, t, -1) @ p["xattn"]["wo"]
    x = x + L.apply_mlp(p["mlp"], cfg, L.apply_norm(p["norm2"], x))
    return x, new_kv


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        policy = jax.checkpoint_policies.nothing_saveable
    else:  # "dots"
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint(fn, policy=policy)


# --------------------------------------------------------------- forward


def forward(params, cfg: ModelConfig, batch: dict, *, attn_impl="xla"):
    """Training/prefill forward -> (logits_on_tokens, aux_metrics).

    batch: tokens [B, T_text]; vlm: + patch_emb [B, P, d]; encdec: +
    frames [B, S_enc, d].
    """
    tokens = batch["tokens"]
    x = L.embed_tokens(params["embed"], tokens)
    n_prefix = 0
    if cfg.family == "vlm" and "patch_emb" in batch:
        x = jnp.concatenate([batch["patch_emb"].astype(x.dtype), x], axis=1)
        n_prefix = batch["patch_emb"].shape[1]

    aux_total = jnp.float32(0)
    fam = cfg.family

    if fam in ("dense", "vlm"):
        def body(x, p):
            y, _ = _apply_dense_block(p, cfg, x, attn_impl=attn_impl)
            return y, None
        x, _ = jax.lax.scan(_remat(cfg, body), x, params["layers"])
    elif fam == "moe":
        def body(carry, p):
            x, aux = carry
            y, _, m = _apply_moe_block(p, cfg, x, attn_impl=attn_impl)
            return (y, aux + m["aux_loss"]), None
        (x, aux_total), _ = jax.lax.scan(_remat(cfg, body), (x, aux_total),
                                         params["layers"])
    elif fam == "ssm":
        def body(x, p):
            y, _ = _apply_mamba_block(p, cfg, x)
            return y, None
        x, _ = jax.lax.scan(_remat(cfg, body), x, params["layers"])
    elif fam == "hybrid":
        x = _hybrid_forward(params, cfg, x, attn_impl=attn_impl)
    elif fam == "encdec":
        enc_kv = _encode(params, cfg, batch["frames"], attn_impl=attn_impl)
        def body(x, p):
            y, _ = _apply_xattn_block(p, cfg, x, enc_kv)
            return y, None
        x, _ = jax.lax.scan(_remat(cfg, body), x, params["layers"])
    else:
        raise ValueError(fam)

    x = L.apply_norm(params["final_norm"], x)
    if n_prefix:
        x = x[:, n_prefix:]
    logits = L.lm_logits(params["embed"], cfg, x)
    return logits, {"aux_loss": aux_total}


def _hybrid_forward(params, cfg, x, *, attn_impl="xla"):
    """zamba2: groups of `attn_every` mamba layers + one shared attn block."""
    every = cfg.attn_every or cfg.num_layers
    n_groups = cfg.num_layers // every

    def mamba_body(x, p):
        y, _ = _apply_mamba_block(p, cfg, x)
        return y, None

    grouped = jax.tree.map(
        lambda a: a.reshape((n_groups, every) + a.shape[1:]), params["layers"])
    for g in range(n_groups):
        pg = jax.tree.map(lambda a: a[g], grouped)
        x, _ = jax.lax.scan(_remat(cfg, mamba_body), x, pg)
        x, _ = _apply_dense_block(params["shared"], cfg, x,
                                  attn_impl=attn_impl)
    return x


def _encode(params, cfg, frames, *, attn_impl="xla"):
    """Encoder over stub frame embeddings -> cross-attn (k, v)."""
    x = frames.astype(jnp.dtype(cfg.dtype))

    def body(x, p):
        # bidirectional encoder: no causal mask
        xq = L.apply_norm(p["norm1"], x)
        q, k, v = L._project_qkv(p["attn"], cfg, xq)
        o = L._sdpa_xla(q, k, v, causal=False, window=0)
        x = x + o.reshape(x.shape[0], x.shape[1], -1) @ p["attn"]["wo"]
        x = x + L.apply_mlp(p["mlp"], cfg, L.apply_norm(p["norm2"], x))
        return x, None

    x, _ = jax.lax.scan(_remat(cfg, body), x, params["enc_layers"])
    # cross-attn kv from the LAST decoder-side xattn projection is per-layer;
    # we share one projection of encoder states for all layers (T5-style
    # would project per layer — we project with layer 0's weights to keep the
    # cache single; recorded as a simplification in DESIGN.md).
    p0 = jax.tree.map(lambda a: a[0], params["layers"])
    b, s, _ = x.shape
    ek = (x @ p0["xattn"]["wk"]).reshape(b, s, cfg.num_kv_heads, cfg.hd)
    ev = (x @ p0["xattn"]["wv"]).reshape(b, s, cfg.num_kv_heads, cfg.hd)
    return ek, ev


def loss_fn(params, cfg: ModelConfig, batch: dict, *, attn_impl="xla"):
    logits, aux = forward(params, cfg, batch, attn_impl=attn_impl)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1)
    if cfg.family == "moe":
        loss = loss + 0.01 * aux["aux_loss"] / max(cfg.num_layers, 1)
    return loss


# ----------------------------------------------------------- decode path


class DecodeCache(NamedTuple):
    """Family-polymorphic cache pytree.

    dense/moe/vlm : kv = (k, v) stacked [L, B, S, KVH, hd]
    ssm           : ssm = SSMCache with [L, ...] leaves
    hybrid        : ssm [L,...] + kv per shared-block invocation [G, ...]
    encdec        : kv (self) [L, ...] + enc (ek, ev)
    """
    kv: Any = None
    ssm: Any = None
    enc: Any = None
    length: jax.Array = None


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> DecodeCache:
    s = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    kvh, hd = cfg.num_kv_heads, cfg.hd

    def kv(n):
        return (jnp.zeros((n, batch, s, kvh, hd), dtype),
                jnp.zeros((n, batch, s, kvh, hd), dtype))

    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        return DecodeCache(kv=kv(cfg.num_layers), length=jnp.zeros((batch,), jnp.int32))
    if fam == "ssm":
        ssm = S.SSMCache(
            conv=jnp.zeros((cfg.num_layers, batch, cfg.ssm_conv - 1,
                            cfg.d_inner), dtype),
            state=jnp.zeros((cfg.num_layers, batch, cfg.d_inner,
                             cfg.ssm_state), jnp.float32))
        return DecodeCache(ssm=ssm, length=jnp.zeros((batch,), jnp.int32))
    if fam == "hybrid":
        every = cfg.attn_every or cfg.num_layers
        g = cfg.num_layers // every
        ssm = S.SSMCache(
            conv=jnp.zeros((cfg.num_layers, batch, cfg.ssm_conv - 1,
                            cfg.d_inner), dtype),
            state=jnp.zeros((cfg.num_layers, batch, cfg.d_inner,
                             cfg.ssm_state), jnp.float32))
        return DecodeCache(ssm=ssm, kv=kv(g), length=jnp.zeros((batch,), jnp.int32))
    if fam == "encdec":
        enc = (jnp.zeros((batch, cfg.frontend_len, kvh, hd), dtype),
               jnp.zeros((batch, cfg.frontend_len, kvh, hd), dtype))
        return DecodeCache(kv=kv(cfg.num_layers), enc=enc,
                           length=jnp.zeros((batch,), jnp.int32))
    raise ValueError(fam)


def decode_step(params, cfg: ModelConfig, cache: DecodeCache,
                tokens: jax.Array):
    """tokens [B, 1] -> (logits [B, V], new_cache). One serving step."""
    x = L.embed_tokens(params["embed"], tokens)
    fam = cfg.family
    clen = cache.length

    if fam in ("dense", "vlm", "moe"):
        def body(x, lp):
            p, kv = lp
            if fam == "moe":
                y, new_kv, _ = _apply_moe_block(p, cfg, x, kv_cache=kv,
                                                cache_len=clen)
            else:
                y, new_kv = _apply_dense_block(p, cfg, x, kv_cache=kv,
                                               cache_len=clen)
            return y, new_kv
        x, new_kv = jax.lax.scan(body, x, (params["layers"], cache.kv))
        new_cache = cache._replace(kv=new_kv, length=clen + 1)
    elif fam == "ssm":
        def body(x, lp):
            p, c = lp
            y, nc = _apply_mamba_block(p, cfg, x, cache=c)
            return y, nc
        x, new_ssm = jax.lax.scan(body, x, (params["layers"], cache.ssm))
        new_cache = cache._replace(ssm=new_ssm, length=clen + 1)
    elif fam == "hybrid":
        every = cfg.attn_every or cfg.num_layers
        g = cfg.num_layers // every
        grouped_p = jax.tree.map(
            lambda a: a.reshape((g, every) + a.shape[1:]), params["layers"])
        grouped_c = jax.tree.map(
            lambda a: a.reshape((g, every) + a.shape[1:]), cache.ssm)
        new_ssm_groups, new_kvs = [], []
        for gi in range(g):
            pg = jax.tree.map(lambda a: a[gi], grouped_p)
            cg = jax.tree.map(lambda a: a[gi], grouped_c)

            def body(x, lp):
                p, c = lp
                y, nc = _apply_mamba_block(p, cfg, x, cache=c)
                return y, nc
            x, nssm = jax.lax.scan(body, x, (pg, cg))
            kv_g = jax.tree.map(lambda a: a[gi], cache.kv)
            x, nkv = _apply_dense_block(params["shared"], cfg, x,
                                        kv_cache=kv_g, cache_len=clen)
            new_ssm_groups.append(nssm)
            new_kvs.append(nkv)
        new_ssm = jax.tree.map(
            lambda *xs: jnp.stack(xs).reshape((cfg.num_layers,) + xs[0].shape[1:]),
            *new_ssm_groups)
        new_kv = jax.tree.map(lambda *xs: jnp.stack(xs), *new_kvs)
        new_cache = cache._replace(ssm=new_ssm, kv=new_kv, length=clen + 1)
    elif fam == "encdec":
        def body(x, lp):
            p, kv = lp
            y, new_kv = _apply_xattn_block(p, cfg, x, cache.enc,
                                           kv_cache=kv, cache_len=clen)
            return y, new_kv
        x, new_kv = jax.lax.scan(body, x, (params["layers"], cache.kv))
        new_cache = cache._replace(kv=new_kv, length=clen + 1)
    else:
        raise ValueError(fam)

    x = L.apply_norm(params["final_norm"], x)
    logits = L.lm_logits(params["embed"], cfg, x[:, 0])
    return logits, new_cache


def prefill(params, cfg: ModelConfig, batch: dict, max_len: int, *,
            attn_impl="xla"):
    """Forward + build decode caches (returns last-token logits + cache).

    For simplicity the cache is rebuilt by replaying tokens through
    ``decode_step``-equivalent state updates where the family needs
    recurrent state; attention families fill the KV cache directly from the
    full-sequence projections.
    """
    logits, _ = forward(params, cfg, batch, attn_impl=attn_impl)
    return logits
