"""CSR graph container (a pytree) + degree statistics.

Graphs are stored exactly as the paper's workloads consume them: CSR with
int32 ``row_ptr`` [n+1] and ``col_idx`` [m].  ``max_degree`` and
``avg_degree`` drive the scheduler's static budgets (per-item expansion pad,
merge-path work budget) the same way the paper sizes FETCH_SIZE per dataset.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CSRGraph:
    row_ptr: jax.Array  # [n+1] int32
    col_idx: jax.Array  # [m] int32

    @property
    def num_vertices(self) -> int:
        return self.row_ptr.shape[0] - 1

    @property
    def num_edges(self) -> int:
        return self.col_idx.shape[0]

    def degrees(self) -> jax.Array:
        return self.row_ptr[1:] - self.row_ptr[:-1]


def from_edges(n: int, src: np.ndarray, dst: np.ndarray, symmetrize: bool = False) -> CSRGraph:
    """Build CSR from an edge list (numpy, host-side; dedupes + sorts)."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if symmetrize:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    keep = src != dst  # drop self-loops
    src, dst = src[keep], dst[keep]
    key = src * n + dst
    key = np.unique(key)
    src, dst = (key // n).astype(np.int32), (key % n).astype(np.int32)
    counts = np.bincount(src, minlength=n)
    row_ptr = np.zeros(n + 1, dtype=np.int32)
    np.cumsum(counts, out=row_ptr[1:])
    return CSRGraph(row_ptr=jnp.asarray(row_ptr), col_idx=jnp.asarray(dst))


def permute_vertices(g: CSRGraph, perm: np.ndarray) -> CSRGraph:
    """Relabel vertices by ``perm`` (old id -> new id).

    Reproduces the paper's section 6.4 experiment: random ID permutation
    breaks the "consecutive queue entries are neighbors" pathology in graph
    coloring.
    """
    n = g.num_vertices
    row_ptr = np.asarray(g.row_ptr)
    col = np.asarray(g.col_idx)
    src = np.repeat(np.arange(n, dtype=np.int32), np.diff(row_ptr))
    return from_edges(n, perm[src], perm[col])


def degree_stats(g: CSRGraph) -> dict:
    deg = np.asarray(g.degrees())
    return {
        "n": g.num_vertices,
        "m": g.num_edges,
        "max_degree": int(deg.max(initial=0)),
        "avg_degree": float(deg.mean()) if len(deg) else 0.0,
        "degree_std": float(deg.std()) if len(deg) else 0.0,
    }
