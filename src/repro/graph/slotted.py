"""Slotted CSR: the O(delta) commit representation for streaming graphs.

``graph/csr.from_edges`` is the *canonical* edge-set container — sorted
unique ``(src, dst)`` pairs, self-loops dropped — and rebuilding it per
delta batch costs O(m) no matter how small the batch.  This module keeps
the same edge set mutable in place (DESIGN.md §17):

  * every row owns a **slab**: a power-of-two-padded slot run inside one
    flat ``slab_col`` array, sized ``next_pow2(max(1, degree))`` at build /
    compaction time.  The live prefix (``slab_len[r]`` entries) holds the
    row's *smallest* neighbors in sorted order;
  * rows that outgrow their slab spill their sorted tail into a small
    **edge-log overlay** (``ovl_row/ovl_col``, lexsorted by ``(row,
    col)``), so commits never reallocate slabs;
  * a **compaction** pass re-packs everything into fresh right-sized slabs
    with an empty overlay — triggered by overlay occupancy, a fixed batch
    cadence, or a violated slab-slack bound (below).

Because each row reads as ``slab prefix ++ overlay tail`` — both sorted,
prefix strictly below tail — the materialized CSR (:meth:`SlottedCSR.
to_csr`) is **bit-identical to ``from_edges`` on the same edge set**, and
the device :class:`SlottedView` exposes the *canonical* ``row_ptr`` (plain
degree prefix sums), so every consumer of degree sums — the merge-path
LBS, ``chunk_degrees``/``chunk_row_of``, chunk formation, work budgets —
runs unchanged on a slotted graph.  Only the neighbor *gather* is
two-level (``core/frontier.gather_neighbors``).

Slab-slack invariant: after every commit, ``cap(r) <= 4 * max(1,
deg(r))`` for every row (deletes can shrink a row far below its slab; a
violating commit forces the next compaction).  This is what lets the
megakernel stream a chunk's whole slab span through a *static*-length DMA:
``span <= 4 * (degree_sum + width)`` (kernels/drain_loop/csr_stream).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .csr import CSRGraph

#: slab-slack bound: a row's slab capacity never exceeds this multiple of
#: its live degree (enforced lazily — a violating commit forces the next
#: compaction).  The megakernel's static DMA length relies on it.
SLAB_SLACK = 4


def _next_pow2(x: np.ndarray) -> np.ndarray:
    """Elementwise next power of two of ``max(1, x)`` (int64)."""
    x = np.maximum(np.asarray(x, dtype=np.int64), 1)
    return np.int64(1) << np.int64(np.ceil(np.log2(x + 0.0))).astype(np.int64)


def _seg_indices(starts: np.ndarray, lens: np.ndarray) -> np.ndarray:
    """Concatenated ``[starts[i], starts[i] + lens[i])`` ranges (int64)."""
    lens = np.asarray(lens, dtype=np.int64)
    total = int(lens.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64)
    ends = np.cumsum(lens)
    intra = np.arange(total, dtype=np.int64) - np.repeat(ends - lens, lens)
    return np.repeat(np.asarray(starts, dtype=np.int64), lens) + intra


class Overlay(NamedTuple):
    """Device-side two-level gather companion (``core/frontier``).

    The gather for within-row offset ``off`` of row ``r`` reads the slab
    (``slab_col[slab_ptr[r] + off]``) while ``off < slab_len[r]`` and the
    overlay tail (``ovl_col[ovl_ptr[r] + off - slab_len[r]]``) beyond.
    """

    slab_ptr: jax.Array   # [n+1] int32 slab slot offsets
    slab_len: jax.Array   # [n]   int32 live prefix length per row
    ovl_ptr: jax.Array    # [n+1] int32 overlay segment offsets
    ovl_col: jax.Array    # [>=1] int32 overlay neighbor ids (row-major)


@dataclasses.dataclass(frozen=True)
class SlottedView:
    """Immutable device snapshot of a :class:`SlottedCSR`.

    Duck-types the read side of :class:`~repro.graph.csr.CSRGraph` —
    ``row_ptr`` is the *canonical* degree prefix sum, ``num_vertices`` /
    ``num_edges`` / ``degrees()`` behave identically — but deliberately has
    **no** ``col_idx``: any consumer that would flat-gather neighbors must
    go through :func:`~repro.core.frontier.adjacency_of` and the two-level
    gather, so a missed call site fails loudly instead of reading slots.
    """

    row_ptr: jax.Array    # [n+1] int32, canonical (== from_edges row_ptr)
    slab_ptr: jax.Array   # [n+1] int32
    slab_len: jax.Array   # [n]   int32
    slab_col: jax.Array   # [S]   int32 slab slots (live prefixes + padding)
    ovl_ptr: jax.Array    # [n+1] int32
    ovl_col: jax.Array    # [>=1] int32
    m: int                # static edge count (pytree metadata)

    @property
    def num_vertices(self) -> int:
        return self.row_ptr.shape[0] - 1

    @property
    def num_edges(self) -> int:
        return self.m

    def degrees(self) -> jax.Array:
        return self.row_ptr[1:] - self.row_ptr[:-1]

    @property
    def overlay(self) -> Overlay:
        return Overlay(slab_ptr=self.slab_ptr, slab_len=self.slab_len,
                       ovl_ptr=self.ovl_ptr, ovl_col=self.ovl_col)


jax.tree_util.register_dataclass(
    SlottedView,
    data_fields=["row_ptr", "slab_ptr", "slab_len", "slab_col", "ovl_ptr",
                 "ovl_col"],
    meta_fields=["m"],
)


class SlottedCSR:
    """Mutable host-side slotted CSR (numpy); one instance per stream.

    All mutation happens through :meth:`apply` (one canonical
    :class:`~repro.stream.deltas.EdgeDelta`, O(touched rows)) and
    :meth:`compact` (full re-pack, O(n + m), amortized by its triggers).
    ``commits`` / ``compactions`` / ``touched_rows`` meter the commit cost
    the streaming benchmarks export.
    """

    def __init__(self, n: int, slab_ptr: np.ndarray, slab_col: np.ndarray,
                 slab_len: np.ndarray, deg: np.ndarray,
                 ovl_row: np.ndarray, ovl_col: np.ndarray,
                 symmetric: bool = False):
        self.n = int(n)
        self.slab_ptr = slab_ptr          # int64 [n+1]
        self.slab_col = slab_col          # int32 [slab_ptr[-1]]
        self.slab_len = slab_len          # int32 [n]
        self.deg = deg                    # int32 [n]
        self.ovl_row = ovl_row            # int32 [O] lexsorted (row, col)
        self.ovl_col = ovl_col            # int32 [O]
        #: the symmetric-workload contract (graph/generators.
        #: edge_delta_stream emits both directions of every pair); tracked
        #: per commit so the tight BFS invalidation rule can prove its
        #: regional seed search exhaustive (stream/incremental).
        self.symmetric = bool(symmetric)
        self.commits = 0
        self.compactions = 0
        self.touched_rows = 0             # cumulative, across commits
        self.last_touched = 0             # rows rewritten by the last apply
        self.last_compacted = False       # did the last commit() compact?
        self._slack_violated = False
        self._view: Optional[SlottedView] = None

    # ------------------------------------------------------------ build
    @classmethod
    def from_csr(cls, graph: CSRGraph) -> "SlottedCSR":
        """O(m) one-time build from a canonical CSR (stream start)."""
        n = graph.num_vertices
        rp = np.asarray(graph.row_ptr, dtype=np.int64)
        ci = np.asarray(graph.col_idx, dtype=np.int32)
        deg = np.diff(rp).astype(np.int32)
        caps = _next_pow2(deg)
        slab_ptr = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(caps, out=slab_ptr[1:])
        slab_col = np.zeros(int(slab_ptr[-1]), dtype=np.int32)
        slab_col[_seg_indices(slab_ptr[:-1], deg)] = ci
        # symmetric iff the directed edge set equals its transpose
        src = np.repeat(np.arange(n, dtype=np.int64), deg)
        keys = src * n + ci
        tkeys = ci.astype(np.int64) * n + src
        symmetric = bool(np.array_equal(keys, np.sort(tkeys)))
        return cls(n, slab_ptr, slab_col, deg.copy(), deg.copy(),
                   np.empty(0, np.int32), np.empty(0, np.int32),
                   symmetric=symmetric)

    # ------------------------------------------------------- properties
    @property
    def num_vertices(self) -> int:
        return self.n

    @property
    def num_edges(self) -> int:
        return int(self.deg.sum())

    @property
    def overlay_size(self) -> int:
        return int(self.ovl_row.size)

    def row_ptr64(self) -> np.ndarray:
        """Canonical int64 ``[n+1]`` degree prefix sums."""
        rp = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(self.deg, out=rp[1:])
        return rp

    def _ovl_ptr64(self) -> np.ndarray:
        op = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(np.bincount(self.ovl_row, minlength=self.n), out=op[1:])
        return op

    # ------------------------------------------------------------ reads
    def row_neighbors(self, r: int) -> np.ndarray:
        """Sorted unique neighbor ids of row ``r`` (host, O(deg))."""
        s = int(self.slab_ptr[r])
        head = self.slab_col[s:s + int(self.slab_len[r])]
        lo = np.searchsorted(self.ovl_row, r, side="left")
        hi = np.searchsorted(self.ovl_row, r, side="right")
        if lo == hi:
            return head
        return np.concatenate([head, self.ovl_col[lo:hi]])

    def has_edge(self, r: int, c: int) -> bool:
        """Membership test, O(log deg) against the sorted canonical row."""
        nb = self.row_neighbors(int(r))
        i = int(np.searchsorted(nb, c))
        return i < nb.size and int(nb[i]) == int(c)

    def range_cols(self, lo: int, hi: int) -> np.ndarray:
        """Concatenated canonical neighbor lists of rows ``[lo, hi)``
        (host, O(edges in range)) — the sharded per-owner patch's row
        extraction (stream/ingest.reshard)."""
        rp = self.row_ptr64()
        out = np.empty(int(rp[hi] - rp[lo]), dtype=np.int32)
        base = rp[lo:hi] - rp[lo]
        lens = self.slab_len[lo:hi]
        out[_seg_indices(base, lens)] = \
            self.slab_col[_seg_indices(self.slab_ptr[lo:hi], lens)]
        olo = np.searchsorted(self.ovl_row, lo, side="left")
        ohi = np.searchsorted(self.ovl_row, hi, side="left")
        if ohi > olo:
            op = np.bincount(self.ovl_row[olo:ohi] - lo, minlength=hi - lo)
            out[_seg_indices(base + lens, op)] = self.ovl_col[olo:ohi]
        return out

    def to_csr(self) -> CSRGraph:
        """Canonical materialization — bit-identical to ``from_edges`` on
        the same edge set (the parity contract the tests enforce)."""
        rp = self.row_ptr64()
        col = self.range_cols(0, self.n)
        return CSRGraph(row_ptr=jnp.asarray(rp.astype(np.int32)),
                        col_idx=jnp.asarray(col))

    def view(self) -> SlottedView:
        """Device snapshot (cached until the next mutation)."""
        if self._view is None:
            rp = self.row_ptr64()
            op = self._ovl_ptr64()
            ovl = self.ovl_col if self.ovl_col.size else \
                np.zeros(1, np.int32)
            slab = self.slab_col if self.slab_col.size else \
                np.zeros(1, np.int32)
            self._view = SlottedView(
                row_ptr=jnp.asarray(rp.astype(np.int32)),
                slab_ptr=jnp.asarray(self.slab_ptr.astype(np.int32)),
                slab_len=jnp.asarray(self.slab_len),
                slab_col=jnp.asarray(slab),
                ovl_ptr=jnp.asarray(op.astype(np.int32)),
                ovl_col=jnp.asarray(ovl),
                m=int(rp[-1]),
            )
        return self._view

    # ----------------------------------------------------------- commit
    def apply(self, src: np.ndarray, dst: np.ndarray,
              insert: np.ndarray) -> Tuple[np.ndarray, ...]:
        """Commit one canonical op batch in place, O(touched rows).

        ``(src, dst, insert)`` is an :class:`~repro.stream.deltas.
        EdgeDelta`'s payload: sorted unique ``(src, dst)`` with a net
        insert/delete verdict per pair (self-loops already rejected,
        duplicates already last-wins collapsed).  Inserting a present edge
        / deleting an absent one is a no-op.  Returns the *effective* ops
        ``(ins_src, ins_dst, del_src, del_dst)`` — exactly what the
        reference ``apply_delta`` set algebra computes.
        """
        src = np.asarray(src, dtype=np.int32)
        dst = np.asarray(dst, dtype=np.int32)
        insert = np.asarray(insert, dtype=bool)
        rows = np.unique(src)
        eff_is, eff_id, eff_ds, eff_dd = [], [], [], []
        new_ovl_rows, new_ovl_cols = [], []
        touched = []
        slack_hit = False
        for r in rows.tolist():
            sel = src == r
            ins_d = dst[sel & insert]
            del_d = dst[sel & ~insert]
            cur = self.row_neighbors(r)
            if ins_d.size:
                ins_d = ins_d[~np.isin(ins_d, cur, assume_unique=True)]
            if del_d.size:
                del_d = del_d[np.isin(del_d, cur, assume_unique=True)]
            if not (ins_d.size or del_d.size):
                continue
            new = cur
            if del_d.size:
                new = np.setdiff1d(new, del_d, assume_unique=True)
            if ins_d.size:
                new = np.union1d(new, ins_d)
            cap = int(self.slab_ptr[r + 1] - self.slab_ptr[r])
            k = min(new.size, cap)
            s = int(self.slab_ptr[r])
            self.slab_col[s:s + k] = new[:k]
            self.slab_len[r] = k
            self.deg[r] = new.size
            if new.size > k:
                new_ovl_rows.append(np.full(new.size - k, r, np.int32))
                new_ovl_cols.append(new[k:].astype(np.int32))
            touched.append(r)
            if cap > SLAB_SLACK * max(1, int(new.size)):
                slack_hit = True
            if ins_d.size:
                eff_is.append(np.full(ins_d.size, r, np.int32))
                eff_id.append(ins_d.astype(np.int32))
            if del_d.size:
                eff_ds.append(np.full(del_d.size, r, np.int32))
                eff_dd.append(del_d.astype(np.int32))
        if touched:
            # rebuild the flat overlay: untouched entries survive verbatim,
            # touched rows contribute their fresh tails — O(|overlay| +
            # touched tails), then one lexsort of the (small) overlay
            t = np.asarray(touched, dtype=np.int32)
            keep = ~np.isin(self.ovl_row, t)
            orow = np.concatenate([self.ovl_row[keep]] + new_ovl_rows) \
                if new_ovl_rows else self.ovl_row[keep]
            ocol = np.concatenate([self.ovl_col[keep]] + new_ovl_cols) \
                if new_ovl_cols else self.ovl_col[keep]
            order = np.lexsort((ocol, orow))
            self.ovl_row, self.ovl_col = orow[order], ocol[order]
            self._view = None
        self.commits += 1
        self.last_touched = len(touched)
        self.touched_rows += len(touched)
        self._slack_violated = self._slack_violated or slack_hit

        def cat(parts):
            return (np.concatenate(parts) if parts
                    else np.empty(0, np.int32))

        ins_s, ins_d = cat(eff_is), cat(eff_id)
        del_s, del_d = cat(eff_ds), cat(eff_dd)
        # maintain the symmetry flag per commit, O(delta log deg): the
        # post-commit graph stays symmetric iff every effective op's mirror
        # holds too (insert (r,c) needs (c,r) present, delete needs it
        # absent).  A batch can't restore a broken flag — compact() runs
        # the full re-detection instead (amortized by its triggers).
        if self.symmetric and (ins_s.size or del_s.size):
            sym = all(self.has_edge(c, r)
                      for r, c in zip(ins_s.tolist(), ins_d.tolist()))
            sym = sym and not any(
                self.has_edge(c, r)
                for r, c in zip(del_s.tolist(), del_d.tolist()))
            self.symmetric = sym
        return ins_s, ins_d, del_s, del_d

    # ------------------------------------------------------- compaction
    def should_compact(self, batch_index: int, compact_every: int,
                       overlay_slack: float) -> bool:
        """Deterministic compaction trigger (a pure function of the delta
        log + knobs, so SIGKILL-resume replays the identical schedule):
        violated slab-slack bound, every ``compact_every`` batches, or
        overlay occupancy above ``overlay_slack * m``."""
        if self._slack_violated:
            return True
        if compact_every > 0 and batch_index % compact_every == 0:
            return True
        return self.overlay_size > overlay_slack * max(1, self.num_edges)

    def compact(self) -> None:
        """Re-pack into fresh right-sized slabs; overlay empties; the
        materialized edge set is untouched (to_csr before == after)."""
        rp = self.row_ptr64()
        col = self.range_cols(0, self.n)
        caps = _next_pow2(self.deg)
        slab_ptr = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(caps, out=slab_ptr[1:])
        slab_col = np.zeros(int(slab_ptr[-1]), dtype=np.int32)
        slab_col[_seg_indices(slab_ptr[:-1], self.deg)] = col
        self.slab_ptr, self.slab_col = slab_ptr, slab_col
        self.slab_len = self.deg.copy()
        self.ovl_row = np.empty(0, np.int32)
        self.ovl_col = np.empty(0, np.int32)
        self.compactions += 1
        self._slack_violated = False
        self._view = None
        if not self.symmetric:
            # mirrored later ops may have restored symmetry; the per-commit
            # rule can only lower the flag, so re-detect exactly here
            src = np.repeat(np.arange(self.n, dtype=np.int64), self.deg)
            keys = src * self.n + col
            tkeys = col.astype(np.int64) * self.n + src
            self.symmetric = bool(np.array_equal(keys, np.sort(tkeys)))
        del rp
