"""Synthetic graph generators matching the paper's two dataset classes.

The paper evaluates on *scale-free* graphs (soc-LiveJournal, hollywood,
indochina: low diameter, heavy-tailed degrees) and *mesh-like* graphs
(road_usa, roadNet-CA: high diameter, degree <= ~12).  Offline we synthesize
the same two regimes:

  * ``rmat``   — Kronecker/R-MAT scale-free generator (a=0.57 b=c=0.19),
                 heavy-tailed in/out degrees, diameter O(log n).
  * ``grid2d`` — 2D lattice with optional diagonal jitter: max degree 4-8,
                 diameter O(sqrt n) — the road-network stand-in.
  * ``erdos``  — uniform random for property tests.
"""
from __future__ import annotations

import numpy as np

from .csr import CSRGraph, from_edges


def rmat(scale: int, edge_factor: int = 16, seed: int = 0,
         a: float = 0.57, b: float = 0.19, c: float = 0.19) -> CSRGraph:
    """R-MAT scale-free graph with 2**scale vertices."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for bit in range(scale):
        r = rng.random(m)
        # quadrant probabilities a, b, c, d
        src_bit = (r >= a + b).astype(np.int64)
        dst_bit = (((r >= a) & (r < a + b)) | (r >= a + b + c)).astype(np.int64)
        src = (src << 1) | src_bit
        dst = (dst << 1) | dst_bit
    return from_edges(n, src, dst, symmetrize=True)


def grid2d(rows: int, cols: int, seed: int = 0, extra_frac: float = 0.0) -> CSRGraph:
    """2D lattice (road-like).  ``extra_frac`` adds random shortcut edges."""
    n = rows * cols
    ids = np.arange(n, dtype=np.int64).reshape(rows, cols)
    right = np.stack([ids[:, :-1].ravel(), ids[:, 1:].ravel()])
    down = np.stack([ids[:-1, :].ravel(), ids[1:, :].ravel()])
    edges = np.concatenate([right, down], axis=1)
    if extra_frac > 0:
        rng = np.random.default_rng(seed)
        k = int(extra_frac * edges.shape[1])
        extra = rng.integers(0, n, size=(2, k))
        edges = np.concatenate([edges, extra], axis=1)
    return from_edges(n, edges[0], edges[1], symmetrize=True)


def erdos(n: int, m: int, seed: int = 0) -> CSRGraph:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    return from_edges(n, src, dst, symmetrize=True)


def edge_delta_stream(graph: CSRGraph, num_batches: int, batch_size: int,
                      seed: int = 0, insert_frac: float = 0.5) -> list:
    """Deterministic seeded stream of mixed insert/delete delta batches.

    Walks the evolving *undirected* edge set starting from ``graph``: each
    batch deletes ``~(1 - insert_frac) * batch_size`` existing pairs
    (sampled without replacement) and inserts ``~insert_frac * batch_size``
    currently-absent pairs (rejection-sampled, no self-loops), then emits
    both directions of every pair as one canonical
    :class:`~repro.stream.deltas.EdgeDelta` — so replaying the stream keeps
    the graph symmetric, matching the generators above.  Same
    ``(graph, num_batches, batch_size, seed, insert_frac)`` -> the same
    batches, bit for bit (the CI benches and tests rely on this).
    """
    from ..stream.deltas import make_delta  # lazy: stream imports graph

    if not 0.0 <= insert_frac <= 1.0:
        raise ValueError(f"insert_frac must be in [0, 1], got {insert_frac}")
    n = graph.num_vertices
    rng = np.random.default_rng(seed)
    rp = np.asarray(graph.row_ptr, dtype=np.int64)
    ci = np.asarray(graph.col_idx, dtype=np.int64)
    src = np.repeat(np.arange(n, dtype=np.int64), np.diff(rp))
    # undirected pair keys u*n+v with u < v (self-loops never in the CSR)
    u, v = np.minimum(src, ci), np.maximum(src, ci)
    present = set((u * n + v).tolist())

    n_ins = int(round(batch_size * insert_frac))
    n_del = batch_size - n_ins
    batches = []
    for _ in range(num_batches):
        dels = np.empty(0, dtype=np.int64)
        if n_del and present:
            pool = np.sort(np.fromiter(present, dtype=np.int64))
            dels = rng.choice(pool, size=min(n_del, pool.size),
                              replace=False)
            present.difference_update(dels.tolist())
        ins: list = []
        attempts = 0
        while len(ins) < n_ins and attempts < 64:
            a = rng.integers(0, n, size=2 * (n_ins - len(ins)))
            b = rng.integers(0, n, size=a.size)
            lo, hi = np.minimum(a, b), np.maximum(a, b)
            cand = (lo * n + hi)[lo != hi]
            for k in cand.tolist():
                if k not in present and len(ins) < n_ins:
                    present.add(k)
                    ins.append(k)
            attempts += 1
        keys = np.concatenate([dels, np.asarray(ins, dtype=np.int64)])
        flags = np.concatenate([np.zeros(dels.size, bool),
                                np.ones(len(ins), bool)])
        lo, hi = keys // n, keys % n
        batches.append(make_delta(
            n,
            np.concatenate([lo, hi]),
            np.concatenate([hi, lo]),
            np.concatenate([flags, flags]),
        ))
    return batches
