"""Synthetic graph generators matching the paper's two dataset classes.

The paper evaluates on *scale-free* graphs (soc-LiveJournal, hollywood,
indochina: low diameter, heavy-tailed degrees) and *mesh-like* graphs
(road_usa, roadNet-CA: high diameter, degree <= ~12).  Offline we synthesize
the same two regimes:

  * ``rmat``   — Kronecker/R-MAT scale-free generator (a=0.57 b=c=0.19),
                 heavy-tailed in/out degrees, diameter O(log n).
  * ``grid2d`` — 2D lattice with optional diagonal jitter: max degree 4-8,
                 diameter O(sqrt n) — the road-network stand-in.
  * ``erdos``  — uniform random for property tests.
"""
from __future__ import annotations

import numpy as np

from .csr import CSRGraph, from_edges


def rmat(scale: int, edge_factor: int = 16, seed: int = 0,
         a: float = 0.57, b: float = 0.19, c: float = 0.19) -> CSRGraph:
    """R-MAT scale-free graph with 2**scale vertices."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for bit in range(scale):
        r = rng.random(m)
        # quadrant probabilities a, b, c, d
        src_bit = (r >= a + b).astype(np.int64)
        dst_bit = (((r >= a) & (r < a + b)) | (r >= a + b + c)).astype(np.int64)
        src = (src << 1) | src_bit
        dst = (dst << 1) | dst_bit
    return from_edges(n, src, dst, symmetrize=True)


def grid2d(rows: int, cols: int, seed: int = 0, extra_frac: float = 0.0) -> CSRGraph:
    """2D lattice (road-like).  ``extra_frac`` adds random shortcut edges."""
    n = rows * cols
    ids = np.arange(n, dtype=np.int64).reshape(rows, cols)
    right = np.stack([ids[:, :-1].ravel(), ids[:, 1:].ravel()])
    down = np.stack([ids[:-1, :].ravel(), ids[1:, :].ravel()])
    edges = np.concatenate([right, down], axis=1)
    if extra_frac > 0:
        rng = np.random.default_rng(seed)
        k = int(extra_frac * edges.shape[1])
        extra = rng.integers(0, n, size=(2, k))
        edges = np.concatenate([edges, extra], axis=1)
    return from_edges(n, edges[0], edges[1], symmetrize=True)


def erdos(n: int, m: int, seed: int = 0) -> CSRGraph:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    return from_edges(n, src, dst, symmetrize=True)
