from .csr import CSRGraph, from_edges, permute_vertices, degree_stats
from .generators import rmat, grid2d, erdos
from .slotted import Overlay, SlottedCSR, SlottedView

__all__ = ["CSRGraph", "from_edges", "permute_vertices", "degree_stats",
           "rmat", "grid2d", "erdos",
           "Overlay", "SlottedCSR", "SlottedView"]
