"""Work-stealing rebalance over the shard ring.

Under shard_map every device pays the same per-round cost regardless of how
full its queue replica is (a wavefront is a fixed-shape masked computation),
so occupancy skew does not slow a round down — it inflates the *number* of
rounds: the drain ends when the richest shard finishes.  Stealing attacks
exactly that: when the gap between the richest and poorest replica exceeds
``steal_threshold x mean``, each shard donates up to ``steal_chunk`` of its
surplus to its ring successor, which can expand them because it carries the
donor's vertex block as a steal halo (shard/partition.py).

The donation plan is computed identically on every device from the
all-gathered occupancy vector (``plan_donations`` is a pure function of it),
so no extra coordination round is needed; the transfer itself is a single
``ppermute`` of a fixed-width buffer.  Donations come only from the LOCAL
lane (owned tasks by construction) and land in the receiver's STOLEN lane,
which is never re-donated — a task strays at most one ring hop from home,
and anything it produces is routed straight back to its owner by the next
exchange (shard/exchange.py).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..core.queue import EMPTY, MultiQueue
from .exchange import LANE_LOCAL, LANE_STOLEN


def plan_donations(sizes: jax.Array, threshold: float,
                   chunk: int) -> jax.Array:
    """Per-shard donation counts toward the ring successor.

    Pure function of the gathered occupancy vector, so every device computes
    the identical plan.  Donation ``d -> d+1`` moves surplus above the mean
    into the successor's deficit below it, capped at ``chunk``; nothing
    moves unless the max-min gap exceeds ``threshold x mean`` (so a
    balanced mesh pays no pop/push work, only the fixed ppermute).
    """
    sizes = jnp.asarray(sizes, jnp.int32)
    s = sizes.shape[0]
    total = jnp.sum(sizes)
    mean = total // s + jnp.where(total % s > 0, 1, 0)   # ceil
    gap = jnp.max(sizes) - jnp.min(sizes)
    trigger = gap.astype(jnp.float32) > (
        threshold * jnp.maximum(mean, 1).astype(jnp.float32))
    surplus = jnp.maximum(sizes - mean, 0)
    deficit = jnp.maximum(mean - jnp.roll(sizes, -1), 0)  # successor's need
    give = jnp.minimum(jnp.minimum(surplus, deficit), chunk)
    return jnp.where(trigger, give, 0).astype(jnp.int32)


def rebalance(
    mq: MultiQueue,
    *,
    axis_name,
    num_shards: int,
    threshold: float,
    chunk: int,
    backend: str = "jnp",
    width_of=None,
) -> Tuple[MultiQueue, jax.Array, jax.Array]:
    """One stealing step: donate surplus owned tasks to the ring successor.

    Returns ``(mq', n_donated, triggered)`` for this device (``n_donated``
    in vertices).  Runs unconditionally every round (the SPMD loop needs a
    uniform collective schedule); with an all-zero plan the ppermute
    carries only sentinels.

    ``width_of`` (a task -> chunk-width function, core/task.py) switches
    the accounting to vertex units: occupancies are chunk-width weighted,
    the donation plan moves *work* rather than slots, and the quota'd pop
    donates whole chunks only — a chunk is never split in flight, so the
    thief's halo expansion and the ownership meter stay exact.

    ``axis_name`` is the mesh axis (or axis tuple: on the 2-D
    ``("row", "col")`` mesh the gather, index, and ppermute all run over
    the linearized row-major device order, which is exactly the linear
    shard-id order ownership and halos are defined in — the steal ring is
    mesh-shape independent).
    """
    loads = mq.lane_loads(width_of)
    my_size = loads[LANE_LOCAL] + loads[LANE_STOLEN]
    sizes = jax.lax.all_gather(my_size, axis_name)
    give = plan_donations(sizes, threshold, chunk)
    me = jax.lax.axis_index(axis_name)
    k = give[me]

    items, valid, mq = mq.pop_lane(LANE_LOCAL, chunk, quota=k,
                                   width_of=width_of)
    buf = jnp.where(valid, items, EMPTY)
    perm = [(i, (i + 1) % num_shards) for i in range(num_shards)]
    recv = jax.lax.ppermute(buf, axis_name, perm=perm)
    mq = mq.push(LANE_STOLEN, recv, recv != EMPTY, backend=backend)
    if width_of is None:
        n_donated = jnp.sum(valid.astype(jnp.int32))
    else:
        n_donated = jnp.sum(jnp.where(valid, width_of(items), 0))
    return mq, n_donated, jnp.any(give > 0)
