"""Persistent and discrete sharded drivers — one Atos drain, many devices.

Mirrors ``core/scheduler.py`` across a device mesh: the 1-D ``("shard",)``
ring, or — with ``cfg.mesh_shape = (rows, cols)`` — a 2-D ``("row", "col")``
mesh whose exchange is dimension-ordered per axis (DESIGN.md §16).  Each
device carries a queue replica (a 2-lane :class:`~repro.core.queue.
MultiQueue`: owned tasks + freshly stolen ones) and a full-size state replica
that is authoritative for its vertex block and reconciled every round by the
program's declarative merge spec (``runtime/program.build_merge``).  One
**round** is, in lockstep on every device:

  1. *deliver*  — (overlap mode only) push the previous round's staged
                  exchange arrivals into the LOCAL lane;
  2. *steal*    — occupancy-skew-triggered ring donation (shard/steal.py);
  3. *pop*      — one ``num_workers x fetch_size`` wavefront, stolen first;
  4. *body*     — the algorithm's existing wavefront fn on the local CSR
                  slice via the backend layer (runs even when the pop is
                  empty: a zero-valid wavefront is a no-op for BFS/coloring
                  and exactly the ``on_empty`` re-scan for PageRank);
  5. *exchange* — owner-split + per-axis all-to-all routing
                  (shard/exchange.py), optionally delta-compressed;
                  arrivals are pushed immediately (strict,
                  ``defer_rounds=0`` — bit-for-bit the historical schedule)
                  or staged for step 1 of the *next* round
                  (``defer_rounds=1`` — the double-buffered overlap: the
                  collective's latency hides behind the next round's
                  expansion of already-delivered tasks.  Legal under Atos
                  semantics: tasks are idempotent re-checks, so delaying
                  delivery one round changes the schedule, never the
                  fixpoint);
  6. *merge*    — replica reconciliation (pmin / delta-psum);
  7. *stop*     — ``psum`` the replica sizes *plus staged arrivals*: no
                  device exits while any device still has live or staged
                  work, and converged-but-idle devices keep serving
                  collectives until the global predicate fires.

``persistent_run_sharded`` wraps the whole drain in a ``shard_map``-wrapped
``lax.while_loop`` (zero host round-trips — the multi-device persistent
kernel); ``discrete_run_sharded`` dispatches one jitted sharded round per
host-loop iteration and can trace per-round exchange volume and occupancy
for the benchmarks.  Both honor ``SchedulerConfig``: ``num_shards`` picks
the mesh width, ``mesh_shape`` folds it 2-D, ``persistent`` picks the
driver, ``backend`` threads through to the kernels exactly as in the
single-device path.  On either driver a ``max_rounds`` (or ``stop``) exit
flushes the staging buffer back into the queue so segmented callers (the
streaming snapshot layer) never lose staged tasks.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..core.queue import EMPTY, MultiQueue, TaskQueue
from ..core.scheduler import QueueOps, SchedulerConfig, wavefront_step
from ..graph.csr import CSRGraph
from ..launch.mesh import make_shard_mesh, make_shard_mesh2d
from ..obs import Trace, stacked_rings, unstack_ring
from ..runtime.program import AtosProgram, ProgramContext, build_merge
from .exchange import (LANE_LOCAL, NUM_LANES, delivered_width, pop_wavefront,
                       route_tasks)
from .partition import ShardedCSR, owner_of, partition_graph, split_seeds
from .steal import rebalance

AXIS = "shard"


def _shard_context(cfg: SchedulerConfig, shard, axes=AXIS) -> ProgramContext:
    """Context for building the body inside the shard_map trace.

    ``axes`` is the mesh axis name — the 1-D ``"shard"`` string or the 2-D
    ``("row", "col")`` tuple; jax collectives accept either form.
    """
    return ProgramContext(wavefront=cfg.wavefront,
                          num_workers=cfg.num_workers, backend=cfg.backend,
                          shard=shard, num_shards=cfg.num_shards,
                          axis_name=axes, granularity=cfg.granularity)


class ShardCounters(NamedTuple):
    """Per-device round accounting (int32 scalars inside the loop)."""

    rounds: jax.Array         # uniform by construction
    items: jax.Array          # valid tasks this device popped
    sent: jax.Array           # distinct tasks shipped to other owners
    route_dropped: jax.Array  # remote tasks lost to a narrow route buffer
    donated: jax.Array        # tasks this device donated to its successor
    stolen_run: jax.Array     # stolen tasks this device executed
    steal_rounds: jax.Array   # rounds the (uniform) steal trigger fired
    mis_routed: jax.Array     # popped tasks that violated ownership
    sent_row: jax.Array       # cross-device payload ints, row-axis hop
    sent_col: jax.Array       # cross-device payload ints, column-axis hop
    payload: jax.Array        # valid ints across all hop buffers
    padding: jax.Array        # EMPTY slots across all hop buffers
    wire: jax.Array           # metered wire ints (compressed words if on)
    deferred: jax.Array       # staged tasks delivered a round late
    overlap_rounds: jax.Array  # rounds that computed over a staged delivery

    @staticmethod
    def zero() -> "ShardCounters":
        z = jnp.int32(0)
        return ShardCounters(z, z, z, z, z, z, z, z, z, z, z, z, z, z, z)


@dataclasses.dataclass
class ShardRunStats:
    """Host-side run summary (per-device vectors are length num_shards)."""

    rounds: int
    items_processed: int
    dropped: int              # queue-replica overflow drops (sum)
    route_dropped: int
    exchanged: int            # distinct tasks delivered across shards (sum)
    donated: int              # tasks moved by stealing (sum)
    stolen_executed: int
    steal_rounds: int
    mis_routed: int           # must be 0: every task ran on its owner/thief
    per_device_items: np.ndarray
    per_device_sent: np.ndarray
    per_device_donated: np.ndarray
    final_sizes: np.ndarray
    # wire accounting (DESIGN.md §16) — a task relayed through both hops of
    # a 2-D mesh is carried twice, so payload_ints >= exchanged; 1-D runs
    # put all cross-device ints on the (single) column hop.
    exchanged_row: int = 0    # cross-device payload ints, row-axis hop
    exchanged_col: int = 0    # cross-device payload ints, column-axis hop
    payload_ints: int = 0     # valid ints carried by all hop buffers
    padding_ints: int = 0     # EMPTY slots those fixed-shape buffers carried
    wire_ints: int = 0        # metered wire: raw slots, or compressed words
    deferred_delivered: int = 0  # tasks that landed one round late (overlap)
    overlap_rounds: int = 0   # rounds overlapping compute with a delivery

    @property
    def occupancy_balance(self) -> float:
        """min/max of per-device processed items (1.0 = perfectly even)."""
        if self.per_device_items.size == 0:
            return 1.0
        hi = int(self.per_device_items.max())
        return float(self.per_device_items.min()) / hi if hi else 1.0

    @property
    def overlap_occupancy(self) -> float:
        """Fraction of rounds (busiest device) where staged arrivals were
        delivered while the wavefront also had work — the rounds whose
        exchange latency was actually hidden behind compute."""
        return self.overlap_rounds / self.rounds if self.rounds else 0.0

    def as_dict(self) -> dict:
        """Serialize into the canonical ``shard_run`` doc (obs/schema)."""
        from ..obs.schema import metric_doc  # lazy: obs is a leaf layer

        d = dataclasses.asdict(self)
        for k, v in d.items():
            if isinstance(v, np.ndarray):
                d[k] = v.tolist()
        d["occupancy_balance"] = self.occupancy_balance
        d["overlap_occupancy"] = self.overlap_occupancy
        return metric_doc("shard_run", **d)


# --------------------------------------------------------------- plumbing
def _make_queues(capacity: int, num_shards: int, seed_buf, seed_counts):
    """Stacked per-device 2-lane MultiQueue replicas, seeds pre-placed in
    each owner's LOCAL lane."""
    buf = np.full((num_shards, NUM_LANES, capacity), int(EMPTY),
                  dtype=np.int32)
    tails = np.zeros((num_shards, NUM_LANES), dtype=np.int32)
    seeds = np.asarray(seed_buf)
    counts = np.asarray(seed_counts)
    for d in range(num_shards):
        k = int(counts[d])
        if k > capacity:
            raise ValueError(
                f"shard {d} got {k} seed tasks > queue capacity {capacity}")
        buf[d, LANE_LOCAL, :k] = seeds[d, :k]
        tails[d, LANE_LOCAL] = k
    lanes = TaskQueue(
        buf=jnp.asarray(buf),
        head=jnp.zeros((num_shards, NUM_LANES), jnp.int32),
        tail=jnp.asarray(tails),
        dropped=jnp.zeros((num_shards, NUM_LANES), jnp.int32),
    )
    return MultiQueue(lanes=lanes, rr=jnp.zeros((num_shards,), jnp.int32))


def seed_queues(program: AtosProgram, seeds, num_vertices: int,
                num_shards: int, capacity: int) -> MultiQueue:
    """Owner-split ``seeds`` into stacked per-device queue replicas.

    Public piece of ``run_sharded``'s setup, used by the streaming driver
    (repro/stream) to place a dirty-seed frontier — or an empty one, as the
    snapshot-restore template — without re-running ``program.init()``.
    """
    seed_buf, seed_counts = split_seeds(seeds, num_vertices, num_shards,
                                        task_vertex=program.task_vertex)
    return _make_queues(capacity, num_shards, seed_buf, seed_counts)


def _local_view(tree):
    """Strip the leading per-device axis shard_map leaves on every leaf."""
    return jax.tree.map(lambda x: x[0], tree)


def _stacked_view(tree):
    return jax.tree.map(lambda x: x[None], tree)


def _mesh_axes(cfg: SchedulerConfig):
    """(axis name(s), mesh dims or None) for this config's mesh layout."""
    if cfg.mesh_shape is None:
        return AXIS, None
    rows, cols = cfg.mesh_shape
    if rows * cols != cfg.num_shards:
        raise ValueError(
            f"mesh_shape {cfg.mesh_shape} covers {rows * cols} devices but "
            f"num_shards is {cfg.num_shards}")
    return ("row", "col"), (rows, cols)


def _body_out_width(program: AtosProgram, parts: ShardedCSR,
                    cfg: SchedulerConfig, state0, mesh, axes) -> int:
    """Static width of the wavefront body's output buffer.

    Overlap mode needs the staged-arrivals buffer shape *before* the drain
    loop is built, and the default ``route_width`` is exactly the body's
    output width — recovered here by abstract evaluation (``eval_shape``
    traces nothing concrete and compiles nothing) of one body call under
    the real mesh, so bodies that consult the axis environment still trace.
    """
    w = cfg.wavefront

    def probe(row_ptr, col_idx, state):
        local_graph = CSRGraph(row_ptr=row_ptr[0], col_idx=col_idx[0])
        me = jax.lax.axis_index(axes)
        f = program.body(local_graph, _shard_context(cfg, me, axes))
        out, _, _ = f(jnp.zeros((w,), jnp.int32),
                      jnp.zeros((w,), jnp.bool_), state)
        return out

    fn = shard_map(probe, mesh=mesh, in_specs=(P(axes), P(axes), P()),
                   out_specs=P(), check_rep=False)
    shape = jax.eval_shape(fn, parts.row_ptr, parts.col_idx, state0)
    return shape.shape[0]


def _make_round(program: AtosProgram, cfg: SchedulerConfig, n: int,
                route_width: Optional[int], traced: bool = False,
                axes=AXIS, mesh_dims: Optional[Tuple[int, int]] = None):
    """The shared round body: deliver -> steal -> pop -> f -> exchange ->
    merge.

    The pop->body->push spine is the same :func:`~repro.core.scheduler.
    wavefront_step` the other engines drive; the sharded QueueOps wrap it
    with the 2-lane replica pop (stolen first, with the ownership meter)
    and the routed per-axis exchange, accumulating their telemetry in a
    trace-local ``aux`` dict.  ``always_run_body`` is set: a rescan folded
    into ``f`` must advance even on a drained replica, and SPMD lockstep
    forbids data-dependent branching across devices.

    ``round_step(f, mq, state, c, pending, ring)`` returns ``(mq, state,
    c, pending', ring)``; ``pending`` is the flat staged-arrivals buffer in
    overlap mode (``cfg.defer_rounds > 0``) and ``None`` in strict mode,
    where arrivals are pushed inside the round — the historical schedule,
    bit for bit.
    """
    s = cfg.num_shards
    w = cfg.wavefront
    steal_on = cfg.steal_threshold > 0
    defer = cfg.defer_rounds > 0
    merge = build_merge(program.merge)
    # chunked tasks (core/task.py): occupancy, donation plans, and the
    # processed meter all count vertices, so a coarse-chunk shard is charged
    # for the work it actually holds.  None keeps the slot-denominated
    # pre-granularity accounting bit-for-bit.
    width_of = program.task_width if cfg.granularity > 1 else None

    def round_step(f, mq: MultiQueue, state, c: ShardCounters,
                   pending=None, ring=None):
        me = jax.lax.axis_index(axes)
        deferred_n = jnp.int32(0)
        if pending is not None:
            # overlap delivery: last round's exchanged arrivals enter the
            # queue now — one round after a strict schedule would have
            # pushed them, while their collective ran behind that round.
            pv = pending != EMPTY
            deferred_n = jnp.sum(pv.astype(jnp.int32))
            mq = mq.push(LANE_LOCAL, pending, pv, backend=cfg.backend)
        if ring is not None:
            size_before = mq.size  # pre-steal, pre-pop replica occupancy
            work0 = program.work(state) if program.work is not None else 0
            splits0 = (program.splits(state)
                       if program.splits is not None else 0)
        donated = jnp.int32(0)
        triggered = jnp.bool_(False)
        if steal_on:
            mq, donated, triggered = rebalance(
                mq, axis_name=axes, num_shards=s,
                threshold=cfg.steal_threshold, chunk=cfg.steal_chunk,
                backend=cfg.backend, width_of=width_of)

        aux = {}

        def pop(mq):
            items, valid, n_stolen, mq2 = pop_wavefront(mq, w)
            # ownership meter: lanes [0, n_stolen) came off the stolen lane
            # and may belong to the ring predecessor; the rest must be ours.
            verts = program.task_vertex(jnp.where(valid, items, 0))
            verts = jnp.where(valid, verts, 0)
            owners = owner_of(verts, n, s)
            expected = jnp.where(jnp.arange(w, dtype=jnp.int32) < n_stolen,
                                 (me - 1) % s, me)
            aux["mis"] = jnp.sum((valid & (owners != expected))
                                 .astype(jnp.int32))
            aux["stolen"] = n_stolen
            return items, valid, mq2

        def push(mq, out, mask):
            mq2, delivered, meters = route_tasks(
                mq, out, mask, axis_name=axes, num_shards=s, num_vertices=n,
                task_vertex=program.task_vertex, route_width=route_width,
                backend=cfg.backend, mesh_dims=mesh_dims,
                compress=cfg.compress)
            aux.update(meters)
            if defer:
                aux["delivered"] = delivered   # staged for next round
            else:
                mq2 = mq2.push(LANE_LOCAL, delivered, delivered != EMPTY,
                               backend=cfg.backend)
            return mq2

        ops = QueueOps(pop=pop, push=push, size=lambda mq: mq.size)
        mq, new_state, _, n_valid = wavefront_step(
            f, None, ops, (mq, state, jnp.int32(0), jnp.int32(0)),
            always_run_body=True)
        if ring is not None:
            # one row per device per round, written in-trace (zero syncs):
            # work/splits are the device-local pre-merge deltas, so summing
            # a round's rows across lanes reassembles the global round.
            work1 = program.work(new_state) if program.work is not None else 0
            splits1 = (program.splits(new_state)
                       if program.splits is not None else 0)
            ring = ring.record(
                round=c.rounds, lane=me, queue_size=size_before,
                pops=n_valid, pushes=mq.size - size_before + n_valid,
                work=work1 - work0, splits=splits1 - splits0,
                donated=donated, exchanged=aux["sent"],
                exchanged_row=aux["sent_row"], exchanged_col=aux["sent_col"],
                wire=aux["wire"], deferred=deferred_n)
        # round-synchronous replica reconciliation: after this every device
        # holds the identical merged state, so next round's pops read
        # globally fresh values (the TREES-style epoch barrier).
        state = merge(state, new_state, axes)

        c = ShardCounters(
            rounds=c.rounds + 1,
            items=c.items + n_valid,
            sent=c.sent + aux["sent"],
            route_dropped=c.route_dropped + aux["rdrop"],
            donated=c.donated + donated,
            stolen_run=c.stolen_run + aux["stolen"],
            steal_rounds=c.steal_rounds + triggered.astype(jnp.int32),
            mis_routed=c.mis_routed + aux["mis"],
            sent_row=c.sent_row + aux["sent_row"],
            sent_col=c.sent_col + aux["sent_col"],
            payload=c.payload + aux["payload"],
            padding=c.padding + aux["padding"],
            wire=c.wire + aux["wire"],
            deferred=c.deferred + deferred_n,
            overlap_rounds=c.overlap_rounds
            + ((deferred_n > 0) & (n_valid > 0)).astype(jnp.int32),
        )
        pending_next = aux["delivered"] if defer else None
        return mq, state, c, pending_next, ring

    def keep_going(mq: MultiQueue, state, c: ShardCounters, pending=None):
        """Global continuation: psum'd live-task mass + the stop predicate.

        The psum is the no-early-exit guarantee — a drained device sees its
        neighbours' backlog and keeps taking rounds (serving the exchange
        and merge collectives, and potentially receiving routed or stolen
        work) until the whole mesh is done.  Staged overlap arrivals count
        as live: a device whose queue drained but whose staging buffer
        holds tasks has not finished.  ``empty_means_done=False`` programs
        (PageRank's rescan) drop the queue-mass term, exactly as in the
        shared :func:`~repro.core.scheduler.continuation`.
        """
        in_bounds = c.rounds < cfg.max_rounds
        if program.empty_means_done:
            live = mq.size
            if pending is not None:
                live = live + jnp.sum((pending != EMPTY).astype(jnp.int32))
            global_size = jax.lax.psum(live, axes)
            more = in_bounds & (global_size > 0)
        else:
            more = in_bounds
        if program.stop is not None:
            more &= ~program.stop(state)
        return more

    return round_step, keep_going


def _counters_out(c: ShardCounters):
    return jax.tree.map(lambda x: x[None], c)


# ----------------------------------------------------------------- drivers
def persistent_run_sharded(program, parts: ShardedCSR, mq0, state0,
                           cfg: SchedulerConfig, mesh, route_width=None,
                           ring0=None, axes=AXIS, mesh_dims=None,
                           pend_width=None):
    """Whole drain in one shard_map'd while_loop (multi-device persistent).

    ``ring0``, if given, is a *stacked* per-device
    :class:`~repro.obs.TraceRing` (leading axis ``num_shards``); each device
    appends one row per round inside the while_loop — the traced drain is
    otherwise identical, and the rings come back stacked for the caller to
    drain.  ``pend_width`` (overlap mode) sizes the in-carry staging
    buffer; it is flushed back into the queue after the loop, so a
    ``max_rounds`` exit loses nothing.
    """
    n = parts.num_vertices
    traced = ring0 is not None
    defer = cfg.defer_rounds > 0
    round_step, keep_going = _make_round(program, cfg, n, route_width,
                                         traced=traced, axes=axes,
                                         mesh_dims=mesh_dims)

    def drain(row_ptr, col_idx, mq_st, state, *maybe_ring):
        local_graph = CSRGraph(row_ptr=row_ptr[0], col_idx=col_idx[0])
        me = jax.lax.axis_index(axes)
        f = program.body(local_graph, _shard_context(cfg, me, axes))

        mq = _local_view(mq_st)
        c0 = ShardCounters.zero()
        ring = _local_view(maybe_ring[0]) if traced else None
        pending0 = (jnp.full((pend_width,), EMPTY, jnp.int32)
                    if defer else None)

        def pack(mq, state, c, more, pending, ring):
            out = (mq, state, c, more)
            if defer:
                out = out + (pending,)
            if traced:
                out = out + (ring,)
            return out

        def unpack(carry):
            mq, state, c, more = carry[:4]
            rest = carry[4:]
            pending = rest[0] if defer else None
            ring = rest[-1] if traced else None
            return mq, state, c, more, pending, ring

        def cond(carry):
            return carry[3]

        def body(carry):
            mq, state, c, _, pending, ring = unpack(carry)
            mq, state, c, pending, ring = round_step(
                f, mq, state, c, pending, ring)
            more = keep_going(mq, state, c, pending)
            return pack(mq, state, c, more, pending, ring)

        carry0 = pack(mq, state, c0,
                      keep_going(mq, state, c0, pending0), pending0, ring)
        mq, state, c, _, pending, ring = unpack(
            jax.lax.while_loop(cond, body, carry0))
        if defer:
            # max_rounds / stop exits leave one round's arrivals staged:
            # flush them so segmented callers resume from a complete queue.
            mq = mq.push(LANE_LOCAL, pending, pending != EMPTY,
                         backend=cfg.backend)
        out = (_stacked_view(mq), state, _counters_out(c))
        if traced:
            out = out + (_stacked_view(ring),)
        return out

    specs_q = jax.tree.map(lambda _: P(axes), mq0)
    specs_c = jax.tree.map(lambda _: P(axes), ShardCounters.zero())
    in_specs = (P(axes), P(axes), specs_q, P())
    out_specs = (specs_q, P(), specs_c)
    operands = (parts.row_ptr, parts.col_idx, mq0, state0)
    if traced:
        specs_r = jax.tree.map(lambda _: P(axes), ring0)
        in_specs = in_specs + (specs_r,)
        out_specs = out_specs + (specs_r,)
        operands = operands + (ring0,)
    fn = shard_map(drain, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    return jax.jit(fn)(*operands)


def discrete_run_sharded(program, parts: ShardedCSR, mq0, state0,
                         cfg: SchedulerConfig, mesh, route_width=None,
                         trace: Optional[list] = None, ring0=None,
                         axes=AXIS, mesh_dims=None, pend_width=None):
    """Host loop around one jitted sharded round (discrete kernels).

    ``trace`` collects per-round host-side dicts: global queue sizes,
    exchange volume (total and per axis), wire ints, donations — the
    benchmark's per-round telemetry.  ``ring0`` is the stacked per-device
    :class:`~repro.obs.TraceRing` as in :func:`persistent_run_sharded`: it
    rides the jitted round as a device operand, so in-loop tracing still
    costs zero extra host syncs.  In overlap mode the staging buffer rides
    the same way and is flushed after the loop.
    """
    n = parts.num_vertices
    traced = ring0 is not None
    defer = cfg.defer_rounds > 0
    round_step, keep_going = _make_round(program, cfg, n, route_width,
                                         traced=traced, axes=axes,
                                         mesh_dims=mesh_dims)

    def one_round(row_ptr, col_idx, mq_st, state, c_st, *rest):
        local_graph = CSRGraph(row_ptr=row_ptr[0], col_idx=col_idx[0])
        me = jax.lax.axis_index(axes)
        f = program.body(local_graph, _shard_context(cfg, me, axes))
        mq = _local_view(mq_st)
        c = _local_view(c_st)
        pending = rest[0][0] if defer else None
        ring = _local_view(rest[-1]) if traced else None
        mq, state, c, pending, ring = round_step(f, mq, state, c,
                                                 pending, ring)
        more = keep_going(mq, state, c, pending)
        size = mq.size
        out = (_stacked_view(mq), state, _counters_out(c), more, size[None])
        if defer:
            out = out + (pending[None],)
        if traced:
            out = out + (_stacked_view(ring),)
        return out

    specs_q = jax.tree.map(lambda _: P(axes), mq0)
    specs_c = jax.tree.map(lambda _: P(axes), ShardCounters.zero())
    in_specs = (P(axes), P(axes), specs_q, P(), specs_c)
    out_specs = (specs_q, P(), specs_c, P(), P(axes))
    if defer:
        in_specs = in_specs + (P(axes),)
        out_specs = out_specs + (P(axes),)
    if traced:
        specs_r = jax.tree.map(lambda _: P(axes), ring0)
        in_specs = in_specs + (specs_r,)
        out_specs = out_specs + (specs_r,)
    step = jax.jit(shard_map(one_round, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False))

    mq_st, state = mq0, state0
    ring_st = ring0
    pending_st = (jnp.full((cfg.num_shards, pend_width), EMPTY, jnp.int32)
                  if defer else None)
    c_st = jax.tree.map(
        lambda x: jnp.zeros((cfg.num_shards,), x.dtype), ShardCounters.zero())
    rounds = 0
    prev = {"sent": 0, "donated": 0, "wire": 0, "sent_row": 0, "sent_col": 0}
    # pre-round emptiness check mirrors discrete_run's host-synced predicate
    while rounds < cfg.max_rounds:
        if program.empty_means_done:
            live = int(np.asarray(_queue_sizes(mq_st)).sum())
            if defer:
                live += int((np.asarray(pending_st) != int(EMPTY)).sum())
            if live == 0:
                break
        if program.stop is not None and bool(program.stop(state)):
            break
        operands = [parts.row_ptr, parts.col_idx, mq_st, state, c_st]
        if defer:
            operands.append(pending_st)
        if traced:
            operands.append(ring_st)
        outs = step(*operands)
        mq_st, state, c_st, more, sizes_dev = outs[:5]
        rest = outs[5:]
        if defer:
            pending_st = rest[0]
        if traced:
            ring_st = rest[-1]
        rounds += 1
        if trace is not None:
            totals = {k: int(np.asarray(getattr(c_st, f)).sum())
                      for k, f in (("sent", "sent"), ("donated", "donated"),
                                   ("wire", "wire"), ("sent_row", "sent_row"),
                                   ("sent_col", "sent_col"))}
            trace.append({
                "round": rounds,
                "sizes": np.asarray(sizes_dev).tolist(),
                "exchanged": totals["sent"] - prev["sent"],
                "donated": totals["donated"] - prev["donated"],
                "wire": totals["wire"] - prev["wire"],
                "exchanged_row": totals["sent_row"] - prev["sent_row"],
                "exchanged_col": totals["sent_col"] - prev["sent_col"],
            })
            prev = totals
        if not bool(more):
            break
    if defer:
        mq_st = _flush_pending(mq_st, pending_st, mq0, mesh, axes,
                               cfg.backend)
    if traced:
        return mq_st, state, c_st, ring_st
    return mq_st, state, c_st


def _flush_pending(mq_st, pending_st, mq0, mesh, axes, backend):
    """Push any still-staged overlap arrivals into the LOCAL lanes (the
    discrete driver's analogue of the persistent driver's in-trace flush)."""

    def flush(mq_st, p_st):
        mq = _local_view(mq_st)
        p = p_st[0]
        mq = mq.push(LANE_LOCAL, p, p != EMPTY, backend=backend)
        return _stacked_view(mq)

    specs_q = jax.tree.map(lambda _: P(axes), mq0)
    fn = shard_map(flush, mesh=mesh, in_specs=(specs_q, P(axes)),
                   out_specs=specs_q, check_rep=False)
    return jax.jit(fn)(mq_st, pending_st)


def _queue_sizes(mq_st) -> jax.Array:
    """Per-device total replica occupancy from the stacked queue pytree."""
    return jnp.sum(mq_st.lanes.tail - mq_st.lanes.head, axis=-1)


# --------------------------------------------------------------- front door
def run_sharded(
    program: AtosProgram,
    graph: CSRGraph,
    cfg: SchedulerConfig,
    *,
    queue_capacity: Optional[int] = None,
    route_width: Optional[int] = None,
    mesh=None,
    trace=None,
    trace_engine: Optional[str] = None,
    trace_round_offset: int = 0,
    initial_queues: Optional[MultiQueue] = None,
    initial_state: Any = None,
    final_queues: Optional[list] = None,
    parts: Optional[ShardedCSR] = None,
) -> Tuple[Any, ShardRunStats]:
    """Drain ``program`` over a ``cfg.num_shards``-device mesh.

    Returns ``(final_state, ShardRunStats)``.  The final state is the merged
    (replicated) global state — ``program.result(state)`` is the answer.

    ``cfg.mesh_shape`` selects the 2-D ``("row", "col")`` mesh (and its
    dimension-ordered two-hop exchange); ``cfg.defer_rounds`` the overlap
    pipeline; ``cfg.compress`` the wire codec — see DESIGN.md §16.

    ``trace`` accepts an :class:`~repro.obs.Trace` (one stacked per-device
    ring rides the drain; every device appends one row per round in-trace,
    drained per shard at run end under ``trace_engine`` with absolute round
    numbers shifted by ``trace_round_offset``) or a legacy ``list``
    (discrete driver only: per-round host telemetry dicts, at the cost of
    host syncs).

    ``initial_state`` / ``initial_queues`` resume a drain from an explicit
    carry instead of ``program.init()`` (the streaming driver's dirty-seed
    re-seeds and snapshot restores; build queues via :func:`seed_queues`).
    ``final_queues``, if a list, receives the stacked end-of-drain queue
    pytree so a segmented caller can carry it into the next call.
    """
    s = cfg.num_shards
    axes, mesh_dims = _mesh_axes(cfg)
    if mesh is None:
        mesh = (make_shard_mesh(s) if mesh_dims is None
                else make_shard_mesh2d(*mesh_dims))
    n = graph.num_vertices
    steal_on = cfg.steal_threshold > 0
    if parts is None:
        # callers with a long-lived partition (the streaming driver's
        # per-owner patches, stream/ingest.reshard) pass it in; everyone
        # else pays the one-shot O(m) build here
        parts = partition_graph(graph, s, halo=steal_on)
    capacity = queue_capacity or max(4 * n, 1024)
    if initial_state is None or initial_queues is None:
        init_state, seeds = program.init()
        if initial_state is None:
            initial_state = init_state
        if initial_queues is None:
            initial_queues = seed_queues(program, seeds, n, s, capacity)
    state0, mq0 = initial_state, initial_queues

    route_w = route_width
    pend_width = None
    if cfg.defer_rounds > 0:
        if route_w is None:
            route_w = _body_out_width(program, parts, cfg, state0, mesh,
                                      axes)
        pend_width = delivered_width(route_w, s, mesh_dims)

    obs = trace if isinstance(trace, Trace) else None
    legacy = trace if isinstance(trace, list) else None
    ring0 = stacked_rings(obs.ring(), s) if obs is not None else None
    ring_st = None

    if cfg.persistent:
        out = persistent_run_sharded(
            program, parts, mq0, state0, cfg, mesh, route_width=route_w,
            ring0=ring0, axes=axes, mesh_dims=mesh_dims,
            pend_width=pend_width)
    else:
        out = discrete_run_sharded(
            program, parts, mq0, state0, cfg, mesh, route_width=route_w,
            trace=legacy, ring0=ring0, axes=axes, mesh_dims=mesh_dims,
            pend_width=pend_width)
    if obs is not None:
        mq_st, state, c_st, ring_st = out
    else:
        mq_st, state, c_st = out

    c = jax.tree.map(np.asarray, c_st)
    stats = ShardRunStats(
        rounds=int(c.rounds.max()),
        items_processed=int(c.items.sum()),
        dropped=int(np.asarray(mq_st.lanes.dropped).sum()),
        route_dropped=int(c.route_dropped.sum()),
        exchanged=int(c.sent.sum()),
        donated=int(c.donated.sum()),
        stolen_executed=int(c.stolen_run.sum()),
        steal_rounds=int(c.steal_rounds.max()),
        mis_routed=int(c.mis_routed.sum()),
        per_device_items=c.items,
        per_device_sent=c.sent,
        per_device_donated=c.donated,
        final_sizes=np.asarray(_queue_sizes(mq_st)),
        exchanged_row=int(c.sent_row.sum()),
        exchanged_col=int(c.sent_col.sum()),
        payload_ints=int(c.payload.sum()),
        padding_ints=int(c.padding.sum()),
        wire_ints=int(c.wire.sum()),
        deferred_delivered=int(c.deferred.sum()),
        overlap_rounds=int(c.overlap_rounds.max()),
    )
    if obs is not None:
        engine = trace_engine or (
            "sharded.persistent" if cfg.persistent else "sharded.discrete")
        for d in range(s):
            obs.drain(unstack_ring(ring_st, d), engine=engine,
                      round_offset=trace_round_offset)
        obs.add_metric(stats.as_dict())
    if final_queues is not None:
        final_queues.append(mq_st)
    return state, stats
