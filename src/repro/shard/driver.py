"""Persistent and discrete sharded drivers — one Atos drain, many devices.

Mirrors ``core/scheduler.py`` across a 1-D ``("shard",)`` mesh.  Each device
carries a queue replica (a 2-lane :class:`~repro.core.queue.MultiQueue`:
owned tasks + freshly stolen ones) and a full-size state replica that is
authoritative for its vertex block and reconciled every round by the
program's declarative merge spec (``runtime/program.build_merge``).  One
**round** is, in lockstep on every device:

  1. *steal*    — occupancy-skew-triggered ring donation (shard/steal.py);
  2. *pop*      — one ``num_workers x fetch_size`` wavefront, stolen first;
  3. *body*     — the algorithm's existing wavefront fn on the local CSR
                  slice via the backend layer (runs even when the pop is
                  empty: a zero-valid wavefront is a no-op for BFS/coloring
                  and exactly the ``on_empty`` re-scan for PageRank);
  4. *exchange* — owner-split + all-to-all task routing (shard/exchange.py);
  5. *merge*    — replica reconciliation (pmin / delta-psum);
  6. *stop*     — ``psum`` the replica sizes: no device exits while any
                  device still has work, and converged-but-idle devices keep
                  serving collectives until the global predicate fires.

``persistent_run_sharded`` wraps the whole drain in a ``shard_map``-wrapped
``lax.while_loop`` (zero host round-trips — the multi-device persistent
kernel); ``discrete_run_sharded`` dispatches one jitted sharded round per
host-loop iteration and can trace per-round exchange volume and occupancy
for the benchmarks.  Both honor ``SchedulerConfig``: ``num_shards`` picks
the mesh width, ``persistent`` picks the driver, ``backend`` threads through
to the kernels exactly as in the single-device path.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..core.queue import EMPTY, MultiQueue, TaskQueue
from ..core.scheduler import QueueOps, SchedulerConfig, wavefront_step
from ..graph.csr import CSRGraph
from ..launch.mesh import make_shard_mesh
from ..obs import Trace, stacked_rings, unstack_ring
from ..runtime.program import AtosProgram, ProgramContext, build_merge
from .exchange import LANE_LOCAL, NUM_LANES, pop_wavefront, route_tasks
from .partition import ShardedCSR, owner_of, partition_graph, split_seeds
from .steal import rebalance

AXIS = "shard"


def _shard_context(cfg: SchedulerConfig, shard) -> ProgramContext:
    """Context for building the body inside the shard_map trace."""
    return ProgramContext(wavefront=cfg.wavefront,
                          num_workers=cfg.num_workers, backend=cfg.backend,
                          shard=shard, num_shards=cfg.num_shards,
                          axis_name=AXIS, granularity=cfg.granularity)


class ShardCounters(NamedTuple):
    """Per-device round accounting (int32 scalars inside the loop)."""

    rounds: jax.Array         # uniform by construction
    items: jax.Array          # valid tasks this device popped
    sent: jax.Array           # tasks this device shipped to other owners
    route_dropped: jax.Array  # remote tasks lost to a narrow route buffer
    donated: jax.Array        # tasks this device donated to its successor
    stolen_run: jax.Array     # stolen tasks this device executed
    steal_rounds: jax.Array   # rounds the (uniform) steal trigger fired
    mis_routed: jax.Array     # popped tasks that violated ownership

    @staticmethod
    def zero() -> "ShardCounters":
        z = jnp.int32(0)
        return ShardCounters(z, z, z, z, z, z, z, z)


@dataclasses.dataclass
class ShardRunStats:
    """Host-side run summary (per-device vectors are length num_shards)."""

    rounds: int
    items_processed: int
    dropped: int              # queue-replica overflow drops (sum)
    route_dropped: int
    exchanged: int            # tasks delivered across shards (sum)
    donated: int              # tasks moved by stealing (sum)
    stolen_executed: int
    steal_rounds: int
    mis_routed: int           # must be 0: every task ran on its owner/thief
    per_device_items: np.ndarray
    per_device_sent: np.ndarray
    per_device_donated: np.ndarray
    final_sizes: np.ndarray

    @property
    def occupancy_balance(self) -> float:
        """min/max of per-device processed items (1.0 = perfectly even)."""
        if self.per_device_items.size == 0:
            return 1.0
        hi = int(self.per_device_items.max())
        return float(self.per_device_items.min()) / hi if hi else 1.0

    def as_dict(self) -> dict:
        """Serialize into the canonical ``shard_run`` doc (obs/schema)."""
        from ..obs.schema import metric_doc  # lazy: obs is a leaf layer

        d = dataclasses.asdict(self)
        for k, v in d.items():
            if isinstance(v, np.ndarray):
                d[k] = v.tolist()
        d["occupancy_balance"] = self.occupancy_balance
        return metric_doc("shard_run", **d)


# --------------------------------------------------------------- plumbing
def _make_queues(capacity: int, num_shards: int, seed_buf, seed_counts):
    """Stacked per-device 2-lane MultiQueue replicas, seeds pre-placed in
    each owner's LOCAL lane."""
    buf = np.full((num_shards, NUM_LANES, capacity), int(EMPTY),
                  dtype=np.int32)
    tails = np.zeros((num_shards, NUM_LANES), dtype=np.int32)
    seeds = np.asarray(seed_buf)
    counts = np.asarray(seed_counts)
    for d in range(num_shards):
        k = int(counts[d])
        if k > capacity:
            raise ValueError(
                f"shard {d} got {k} seed tasks > queue capacity {capacity}")
        buf[d, LANE_LOCAL, :k] = seeds[d, :k]
        tails[d, LANE_LOCAL] = k
    lanes = TaskQueue(
        buf=jnp.asarray(buf),
        head=jnp.zeros((num_shards, NUM_LANES), jnp.int32),
        tail=jnp.asarray(tails),
        dropped=jnp.zeros((num_shards, NUM_LANES), jnp.int32),
    )
    return MultiQueue(lanes=lanes, rr=jnp.zeros((num_shards,), jnp.int32))


def seed_queues(program: AtosProgram, seeds, num_vertices: int,
                num_shards: int, capacity: int) -> MultiQueue:
    """Owner-split ``seeds`` into stacked per-device queue replicas.

    Public piece of ``run_sharded``'s setup, used by the streaming driver
    (repro/stream) to place a dirty-seed frontier — or an empty one, as the
    snapshot-restore template — without re-running ``program.init()``.
    """
    seed_buf, seed_counts = split_seeds(seeds, num_vertices, num_shards,
                                        task_vertex=program.task_vertex)
    return _make_queues(capacity, num_shards, seed_buf, seed_counts)


def _local_view(tree):
    """Strip the leading per-device axis shard_map leaves on every leaf."""
    return jax.tree.map(lambda x: x[0], tree)


def _stacked_view(tree):
    return jax.tree.map(lambda x: x[None], tree)


def _make_round(program: AtosProgram, cfg: SchedulerConfig, n: int,
                route_width: Optional[int], traced: bool = False):
    """The shared round body: steal -> pop -> f -> exchange -> merge.

    The pop->body->push spine is the same :func:`~repro.core.scheduler.
    wavefront_step` the other engines drive; the sharded QueueOps wrap it
    with the 2-lane replica pop (stolen first, with the ownership meter)
    and the routed all-to-all push, accumulating their telemetry in a
    trace-local ``aux`` dict.  ``always_run_body`` is set: a rescan folded
    into ``f`` must advance even on a drained replica, and SPMD lockstep
    forbids data-dependent branching across devices.
    """
    s = cfg.num_shards
    w = cfg.wavefront
    steal_on = cfg.steal_threshold > 0
    merge = build_merge(program.merge)
    # chunked tasks (core/task.py): occupancy, donation plans, and the
    # processed meter all count vertices, so a coarse-chunk shard is charged
    # for the work it actually holds.  None keeps the slot-denominated
    # pre-granularity accounting bit-for-bit.
    width_of = program.task_width if cfg.granularity > 1 else None

    def round_step(f, mq: MultiQueue, state, c: ShardCounters, ring=None):
        me = jax.lax.axis_index(AXIS)
        if ring is not None:
            size_before = mq.size  # pre-steal, pre-pop replica occupancy
            work0 = program.work(state) if program.work is not None else 0
            splits0 = (program.splits(state)
                       if program.splits is not None else 0)
        donated = jnp.int32(0)
        triggered = jnp.bool_(False)
        if steal_on:
            mq, donated, triggered = rebalance(
                mq, axis_name=AXIS, num_shards=s,
                threshold=cfg.steal_threshold, chunk=cfg.steal_chunk,
                backend=cfg.backend, width_of=width_of)

        aux = {}

        def pop(mq):
            items, valid, n_stolen, mq2 = pop_wavefront(mq, w)
            # ownership meter: lanes [0, n_stolen) came off the stolen lane
            # and may belong to the ring predecessor; the rest must be ours.
            verts = program.task_vertex(jnp.where(valid, items, 0))
            verts = jnp.where(valid, verts, 0)
            owners = owner_of(verts, n, s)
            expected = jnp.where(jnp.arange(w, dtype=jnp.int32) < n_stolen,
                                 (me - 1) % s, me)
            aux["mis"] = jnp.sum((valid & (owners != expected))
                                 .astype(jnp.int32))
            aux["stolen"] = n_stolen
            return items, valid, mq2

        def push(mq, out, mask):
            mq2, n_sent, n_rdrop = route_tasks(
                mq, out, mask, axis_name=AXIS, num_shards=s, num_vertices=n,
                task_vertex=program.task_vertex, route_width=route_width,
                backend=cfg.backend)
            aux["sent"] = n_sent
            aux["rdrop"] = n_rdrop
            return mq2

        ops = QueueOps(pop=pop, push=push, size=lambda mq: mq.size)
        mq, new_state, _, n_valid = wavefront_step(
            f, None, ops, (mq, state, jnp.int32(0), jnp.int32(0)),
            always_run_body=True)
        if ring is not None:
            # one row per device per round, written in-trace (zero syncs):
            # work/splits are the device-local pre-merge deltas, so summing
            # a round's rows across lanes reassembles the global round.
            work1 = program.work(new_state) if program.work is not None else 0
            splits1 = (program.splits(new_state)
                       if program.splits is not None else 0)
            ring = ring.record(
                round=c.rounds, lane=me, queue_size=size_before,
                pops=n_valid, pushes=mq.size - size_before + n_valid,
                work=work1 - work0, splits=splits1 - splits0,
                donated=donated, exchanged=aux["sent"])
        # round-synchronous replica reconciliation: after this every device
        # holds the identical merged state, so next round's pops read
        # globally fresh values (the TREES-style epoch barrier).
        state = merge(state, new_state, AXIS)

        c = ShardCounters(
            rounds=c.rounds + 1,
            items=c.items + n_valid,
            sent=c.sent + aux["sent"],
            route_dropped=c.route_dropped + aux["rdrop"],
            donated=c.donated + donated,
            stolen_run=c.stolen_run + aux["stolen"],
            steal_rounds=c.steal_rounds + triggered.astype(jnp.int32),
            mis_routed=c.mis_routed + aux["mis"],
        )
        if ring is not None:
            return mq, state, c, ring
        return mq, state, c

    def keep_going(mq: MultiQueue, state, c: ShardCounters):
        """Global continuation: psum'd queue mass + the stop predicate.

        The psum is the no-early-exit guarantee — a drained device sees its
        neighbours' backlog and keeps taking rounds (serving the exchange
        and merge collectives, and potentially receiving routed or stolen
        work) until the whole mesh is done.  ``empty_means_done=False``
        programs (PageRank's rescan) drop the queue-mass term, exactly as
        in the shared :func:`~repro.core.scheduler.continuation`.
        """
        in_bounds = c.rounds < cfg.max_rounds
        if program.empty_means_done:
            global_size = jax.lax.psum(mq.size, AXIS)
            more = in_bounds & (global_size > 0)
        else:
            more = in_bounds
        if program.stop is not None:
            more &= ~program.stop(state)
        return more

    return round_step, keep_going


def _counters_out(c: ShardCounters):
    return jax.tree.map(lambda x: x[None], c)


# ----------------------------------------------------------------- drivers
def persistent_run_sharded(program, parts: ShardedCSR, mq0, state0,
                           cfg: SchedulerConfig, mesh, route_width=None,
                           ring0=None):
    """Whole drain in one shard_map'd while_loop (multi-device persistent).

    ``ring0``, if given, is a *stacked* per-device
    :class:`~repro.obs.TraceRing` (leading axis ``num_shards``); each device
    appends one row per round inside the while_loop — the traced drain is
    otherwise identical, and the rings come back stacked for the caller to
    drain.
    """
    n = parts.num_vertices
    traced = ring0 is not None
    round_builder = _make_round(program, cfg, n, route_width, traced=traced)

    def drain(row_ptr, col_idx, mq_st, state, *maybe_ring):
        local_graph = CSRGraph(row_ptr=row_ptr[0], col_idx=col_idx[0])
        me = jax.lax.axis_index(AXIS)
        f = program.body(local_graph, _shard_context(cfg, me))
        round_step, keep_going = round_builder

        mq = _local_view(mq_st)
        c0 = ShardCounters.zero()

        def cond(carry):
            return carry[3]

        if traced:
            ring = _local_view(maybe_ring[0])

            def body(carry):
                mq, state, c, _, ring = carry
                mq, state, c, ring = round_step(f, mq, state, c, ring)
                return mq, state, c, keep_going(mq, state, c), ring

            mq, state, c, _, ring = jax.lax.while_loop(
                cond, body,
                (mq, state, c0, keep_going(mq, state, c0), ring))
            return (_stacked_view(mq), state, _counters_out(c),
                    _stacked_view(ring))

        def body(carry):
            mq, state, c, _ = carry
            mq, state, c = round_step(f, mq, state, c)
            return mq, state, c, keep_going(mq, state, c)

        mq, state, c, _ = jax.lax.while_loop(
            cond, body, (mq, state, c0, keep_going(mq, state, c0)))
        return _stacked_view(mq), state, _counters_out(c)

    specs_q = jax.tree.map(lambda _: P(AXIS), mq0)
    specs_c = jax.tree.map(lambda _: P(AXIS), ShardCounters.zero())
    in_specs = (P(AXIS), P(AXIS), specs_q, P())
    out_specs = (specs_q, P(), specs_c)
    operands = (parts.row_ptr, parts.col_idx, mq0, state0)
    if traced:
        specs_r = jax.tree.map(lambda _: P(AXIS), ring0)
        in_specs = in_specs + (specs_r,)
        out_specs = out_specs + (specs_r,)
        operands = operands + (ring0,)
    fn = shard_map(drain, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    return jax.jit(fn)(*operands)


def discrete_run_sharded(program, parts: ShardedCSR, mq0, state0,
                         cfg: SchedulerConfig, mesh, route_width=None,
                         trace: Optional[list] = None, ring0=None):
    """Host loop around one jitted sharded round (discrete kernels).

    ``trace`` collects per-round host-side dicts: global queue sizes,
    exchange volume, donations — the benchmark's per-round telemetry.
    ``ring0`` is the stacked per-device :class:`~repro.obs.TraceRing` as in
    :func:`persistent_run_sharded`: it rides the jitted round as a device
    operand, so in-loop tracing still costs zero extra host syncs.
    """
    n = parts.num_vertices
    traced = ring0 is not None
    round_builder = _make_round(program, cfg, n, route_width, traced=traced)

    def one_round(row_ptr, col_idx, mq_st, state, c_st, *maybe_ring):
        local_graph = CSRGraph(row_ptr=row_ptr[0], col_idx=col_idx[0])
        me = jax.lax.axis_index(AXIS)
        f = program.body(local_graph, _shard_context(cfg, me))
        round_step, keep_going = round_builder
        mq = _local_view(mq_st)
        c = _local_view(c_st)
        if traced:
            ring = _local_view(maybe_ring[0])
            mq, state, c, ring = round_step(f, mq, state, c, ring)
        else:
            mq, state, c = round_step(f, mq, state, c)
        more = keep_going(mq, state, c)
        size = mq.size
        out = (_stacked_view(mq), state, _counters_out(c), more, size[None])
        if traced:
            out = out + (_stacked_view(ring),)
        return out

    specs_q = jax.tree.map(lambda _: P(AXIS), mq0)
    specs_c = jax.tree.map(lambda _: P(AXIS), ShardCounters.zero())
    in_specs = (P(AXIS), P(AXIS), specs_q, P(), specs_c)
    out_specs = (specs_q, P(), specs_c, P(), P(AXIS))
    if traced:
        specs_r = jax.tree.map(lambda _: P(AXIS), ring0)
        in_specs = in_specs + (specs_r,)
        out_specs = out_specs + (specs_r,)
    step = jax.jit(shard_map(one_round, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False))

    mq_st, state = mq0, state0
    ring_st = ring0
    c_st = jax.tree.map(
        lambda x: jnp.zeros((cfg.num_shards,), x.dtype), ShardCounters.zero())
    rounds = 0
    prev_sent = prev_donated = 0
    # pre-round emptiness check mirrors discrete_run's host-synced predicate
    while rounds < cfg.max_rounds:
        if program.empty_means_done:
            sizes = np.asarray(_queue_sizes(mq_st))
            if sizes.sum() == 0:
                break
        if program.stop is not None and bool(program.stop(state)):
            break
        operands = (parts.row_ptr, parts.col_idx, mq_st, state, c_st)
        if traced:
            (mq_st, state, c_st, more, sizes_dev, ring_st) = step(
                *operands, ring_st)
        else:
            mq_st, state, c_st, more, sizes_dev = step(*operands)
        rounds += 1
        if trace is not None:
            sent_total = int(np.asarray(c_st.sent).sum())
            donated_total = int(np.asarray(c_st.donated).sum())
            trace.append({
                "round": rounds,
                "sizes": np.asarray(sizes_dev).tolist(),
                "exchanged": sent_total - prev_sent,
                "donated": donated_total - prev_donated,
            })
            prev_sent = sent_total
            prev_donated = donated_total
        if not bool(more):
            break
    if traced:
        return mq_st, state, c_st, ring_st
    return mq_st, state, c_st


def _queue_sizes(mq_st) -> jax.Array:
    """Per-device total replica occupancy from the stacked queue pytree."""
    return jnp.sum(mq_st.lanes.tail - mq_st.lanes.head, axis=-1)


# --------------------------------------------------------------- front door
def run_sharded(
    program: AtosProgram,
    graph: CSRGraph,
    cfg: SchedulerConfig,
    *,
    queue_capacity: Optional[int] = None,
    route_width: Optional[int] = None,
    mesh=None,
    trace=None,
    trace_engine: Optional[str] = None,
    trace_round_offset: int = 0,
    initial_queues: Optional[MultiQueue] = None,
    initial_state: Any = None,
    final_queues: Optional[list] = None,
) -> Tuple[Any, ShardRunStats]:
    """Drain ``program`` over a ``cfg.num_shards``-device mesh.

    Returns ``(final_state, ShardRunStats)``.  The final state is the merged
    (replicated) global state — ``program.result(state)`` is the answer.

    ``trace`` accepts an :class:`~repro.obs.Trace` (one stacked per-device
    ring rides the drain; every device appends one row per round in-trace,
    drained per shard at run end under ``trace_engine`` with absolute round
    numbers shifted by ``trace_round_offset``) or a legacy ``list``
    (discrete driver only: per-round host telemetry dicts, at the cost of
    host syncs).

    ``initial_state`` / ``initial_queues`` resume a drain from an explicit
    carry instead of ``program.init()`` (the streaming driver's dirty-seed
    re-seeds and snapshot restores; build queues via :func:`seed_queues`).
    ``final_queues``, if a list, receives the stacked end-of-drain queue
    pytree so a segmented caller can carry it into the next call.
    """
    s = cfg.num_shards
    if mesh is None:
        mesh = make_shard_mesh(s)
    n = graph.num_vertices
    steal_on = cfg.steal_threshold > 0
    parts = partition_graph(graph, s, halo=steal_on)
    capacity = queue_capacity or max(4 * n, 1024)
    if initial_state is None or initial_queues is None:
        init_state, seeds = program.init()
        if initial_state is None:
            initial_state = init_state
        if initial_queues is None:
            initial_queues = seed_queues(program, seeds, n, s, capacity)
    state0, mq0 = initial_state, initial_queues

    obs = trace if isinstance(trace, Trace) else None
    legacy = trace if isinstance(trace, list) else None
    ring0 = stacked_rings(obs.ring(), s) if obs is not None else None
    ring_st = None

    if cfg.persistent:
        out = persistent_run_sharded(
            program, parts, mq0, state0, cfg, mesh, route_width=route_width,
            ring0=ring0)
    else:
        out = discrete_run_sharded(
            program, parts, mq0, state0, cfg, mesh, route_width=route_width,
            trace=legacy, ring0=ring0)
    if obs is not None:
        mq_st, state, c_st, ring_st = out
    else:
        mq_st, state, c_st = out

    c = jax.tree.map(np.asarray, c_st)
    stats = ShardRunStats(
        rounds=int(c.rounds.max()),
        items_processed=int(c.items.sum()),
        dropped=int(np.asarray(mq_st.lanes.dropped).sum()),
        route_dropped=int(c.route_dropped.sum()),
        exchanged=int(c.sent.sum()),
        donated=int(c.donated.sum()),
        stolen_executed=int(c.stolen_run.sum()),
        steal_rounds=int(c.steal_rounds.max()),
        mis_routed=int(c.mis_routed.sum()),
        per_device_items=c.items,
        per_device_sent=c.sent,
        per_device_donated=c.donated,
        final_sizes=np.asarray(_queue_sizes(mq_st)),
    )
    if obs is not None:
        engine = trace_engine or (
            "sharded.persistent" if cfg.persistent else "sharded.discrete")
        for d in range(s):
            obs.drain(unstack_ring(ring_st, d), engine=engine,
                      round_offset=trace_round_offset)
        obs.add_metric(stats.as_dict())
    if final_queues is not None:
        final_queues.append(mq_st)
    return state, stats
