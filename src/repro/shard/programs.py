"""Deprecation shims: sharded program construction moved to the runtime layer.

Before the runtime layer (DESIGN.md section 11) this module carried a
hand-written ``ShardProgram`` adapter per algorithm — its own copy of each
wavefront-body builder, replica merge, and stop predicate.  Those adapters
are absorbed into the single per-algorithm :class:`~repro.runtime.program.
AtosProgram` definitions (``algorithms/*.make_program``): the per-field
merge lattices (``pmin`` for BFS dist, delta-psum for single-writer /
additive PageRank + coloring state, or-delta for presence bits) are now
declarative ``merge`` specs compiled by :func:`repro.runtime.program.
build_merge`, and ``rescans`` became the explicit ``empty_means_done``
declaration.

Kept for one PR:

  * :func:`build_program` — same signature, now returns an ``AtosProgram``
    (which exposes the old ``ShardProgram`` attribute surface via
    deprecated aliases: ``algorithm``, ``rescans``).
  * ``ShardProgram`` — alias of ``AtosProgram``.
  * ``delta_psum`` — canonical home is :mod:`repro.runtime.program`.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from ..core.scheduler import SchedulerConfig
from ..graph.csr import CSRGraph
from ..runtime.program import AtosProgram, delta_psum  # noqa: F401 (re-export)
from ..runtime.programs import build_program as _build_runtime_program

#: Deprecated alias — the unified program type serves every engine.
ShardProgram = AtosProgram


def build_program(algorithm: str, graph: CSRGraph, cfg: SchedulerConfig,
                  params: Optional[Dict[str, Any]] = None,
                  queue_capacity: int | None = None) -> AtosProgram:
    """Deprecated: use :func:`repro.runtime.build_program`."""
    return _build_runtime_program(algorithm, graph, cfg, params=params,
                                  queue_capacity=queue_capacity)
