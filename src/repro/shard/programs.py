"""Per-algorithm adapters for the sharded driver.

A :class:`ShardProgram` packages what the sharded drain needs beyond the
plain wavefront body:

  * ``build(local_graph, shard, axis_name)`` — construct the wavefront body
    *inside* the shard_map trace, closed over the device-local CSR slice
    (budgets and degree bounds are precomputed from the global graph so
    every device traces the identical computation);
  * ``merge(prev, new, axis_name)`` — reconcile the per-device state
    replicas at the end of every round.  Each algorithm's state is a
    conflict-free merge under round-synchronous exchange:

      - BFS ``dist`` is a min-lattice: ``pmin`` of the replicas is exactly
        the union of all relaxations (order-free, idempotent).
      - PageRank / coloring fields are **single-writer per round** (tasks
        for a vertex exist once, rescans cover disjoint owned blocks), so
        ``prev + psum(new - prev)`` reassembles the global round exactly;
        residue scatter-adds are additive and sum across devices.

    ``WorkCounter`` merges by delta-psum too, so ``state.counter.work`` is
    the *global* processed count on every replica after each round.
  * ``task_vertex`` — task int -> vertex id, which is what ownership (and
    therefore routing and stealing) is defined on.

``rescans=True`` (PageRank) tells the driver the queue may legally run dry
before convergence: the body's rotating re-scan refills it, so only the
``stop`` predicate ends the drain — the sharded analogue of the scheduler's
``on_empty`` path (the re-scan is already folded into ``f``; a device with
an empty replica simply runs a zero-valid wavefront whose scan side still
advances).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..algorithms import bfs as _bfs
from ..algorithms import coloring as _coloring
from ..algorithms import pagerank as _pagerank
from ..algorithms.common import default_work_budget
from ..core.counters import WorkCounter
from ..core.scheduler import SchedulerConfig
from ..graph.csr import CSRGraph
from .partition import block_size


def delta_psum(prev: jax.Array, new: jax.Array, axis_name: str) -> jax.Array:
    """Exact cross-device merge for single-writer / additive round updates."""
    return prev + jax.lax.psum(new - prev, axis_name)


def _merge_bool(prev: jax.Array, new: jax.Array, axis_name: str) -> jax.Array:
    d = delta_psum(prev.astype(jnp.int32), new.astype(jnp.int32), axis_name)
    return d > 0


def _merge_counter(prev: WorkCounter, new: WorkCounter,
                   axis_name: str) -> WorkCounter:
    return WorkCounter(work=delta_psum(prev.work, new.work, axis_name))


@dataclasses.dataclass(frozen=True)
class ShardProgram:
    """Everything the sharded driver needs to drain one algorithm."""

    algorithm: str
    init: Callable[[], Tuple[Any, jax.Array]]
    build: Callable[..., Callable]           # (local_graph, shard, axis) -> f
    merge: Callable[[Any, Any, str], Any]
    task_vertex: Callable[[jax.Array], jax.Array]
    result: Callable[[Any], jax.Array]
    work: Callable[[Any], jax.Array]
    ideal_work: int
    stop: Optional[Callable[[Any], jax.Array]] = None
    rescans: bool = False                    # queue may run dry pre-stop


def _identity_vertex(items: jax.Array) -> jax.Array:
    return items


def build_program(algorithm: str, graph: CSRGraph, cfg: SchedulerConfig,
                  params: Optional[Dict[str, Any]] = None,
                  queue_capacity: int | None = None) -> ShardProgram:
    """Compile (algorithm, graph, config) into a :class:`ShardProgram`.

    ``params`` mirrors the single-tenant drivers' keyword arguments (BFS
    ``source``/``strategy``, PageRank ``damping``/``eps``/``check_size``,
    ...).  All static budgets come from the *global* graph so the traced
    body is structurally identical on every device.
    """
    p = dict(params or {})
    n = graph.num_vertices
    w = cfg.wavefront
    max_degree = int(jnp.max(graph.degrees()))

    if algorithm == "bfs":
        source = int(p.pop("source", 0))
        strategy = p.pop("strategy", "merge_path")
        work_budget = default_work_budget(graph, w, p.pop("work_budget", None),
                                          max_degree=max_degree)
        _reject_unknown(algorithm, p)

        def build(local_graph, shard, axis_name):
            return _bfs.make_wavefront_fn(local_graph, strategy, work_budget,
                                          max_degree, backend=cfg.backend)

        def merge(prev, new, axis_name):
            return _bfs.BFSState(
                dist=jax.lax.pmin(new.dist, axis_name),
                counter=_merge_counter(prev.counter, new.counter, axis_name))

        return ShardProgram(
            algorithm="bfs",
            init=lambda: (_bfs.init_state(graph, source),
                          jnp.array([source], jnp.int32)),
            build=build, merge=merge, task_vertex=_identity_vertex,
            result=lambda s: s.dist, work=lambda s: s.counter.work,
            ideal_work=n)

    if algorithm == "pagerank":
        damping = float(p.pop("damping", 0.85))
        eps = float(p.pop("eps", 1e-6))
        check_size = int(p.pop("check_size", 64))
        work_budget = default_work_budget(graph, w, p.pop("work_budget", None),
                                          max_degree=max_degree)
        seed_count = p.pop("seed_count", None)
        _reject_unknown(algorithm, p)
        n_check = min(cfg.num_workers * check_size, n)
        blk = block_size(n, cfg.num_shards)
        # stop reads only the (merged, replicated) state — build it once on
        # the host from the global graph; the bodies are rebuilt per device.
        _, _, stop = _pagerank.make_wavefront_fns(
            graph, w, n_check=n_check, damping=damping, eps=eps,
            work_budget=work_budget, backend=cfg.backend)

        def build(local_graph, shard, axis_name):
            start = shard * blk
            length = jnp.clip(jnp.int32(n) - start, 0, blk)
            f, _, _ = _pagerank.make_wavefront_fns(
                local_graph, w, n_check=n_check, damping=damping, eps=eps,
                work_budget=work_budget, backend=cfg.backend,
                check_block=(start, length), max_degree=max_degree)
            return f

        def merge(prev, new, axis_name):
            return _pagerank.PRState(
                rank=delta_psum(prev.rank, new.rank, axis_name),
                residue=delta_psum(prev.residue, new.residue, axis_name),
                in_queue=_merge_bool(prev.in_queue, new.in_queue, axis_name),
                # every device advances its cursor by n_check every round:
                # already identical, no collective needed.
                check_cursor=new.check_cursor,
                counter=_merge_counter(prev.counter, new.counter, axis_name))

        if seed_count is None:
            cap = queue_capacity or max(8 * n, 1024)
            seed_count = min(n, max(1, cap // 2))

        return ShardProgram(
            algorithm="pagerank",
            init=lambda: _pagerank.init_state(graph, damping,
                                              seed_count=seed_count),
            build=build, merge=merge, task_vertex=_identity_vertex,
            result=lambda s: s.rank, work=lambda s: s.counter.work,
            ideal_work=n, stop=stop, rescans=True)

    if algorithm == "coloring":
        _reject_unknown(algorithm, p)

        def build(local_graph, shard, axis_name):
            # unfused: detects read epoch-start colors, so detection does
            # not depend on which device a same-epoch neighbor assign ran on
            return _coloring.make_wavefront_fn(local_graph, fused=False,
                                               max_degree=max_degree)

        def merge(prev, new, axis_name):
            return _coloring.ColorState(
                colors=delta_psum(prev.colors, new.colors, axis_name),
                counter=_merge_counter(prev.counter, new.counter, axis_name))

        return ShardProgram(
            algorithm="coloring",
            init=lambda: _coloring.init_state(graph),
            build=build, merge=merge,
            task_vertex=lambda t: jnp.abs(jnp.asarray(t, jnp.int32)) - 1,
            result=lambda s: s.colors, work=lambda s: s.counter.work,
            ideal_work=n)

    raise ValueError(f"unknown algorithm {algorithm!r}; "
                     f"expected one of ('bfs', 'pagerank', 'coloring')")


def _reject_unknown(algorithm: str, params: Dict[str, Any]) -> None:
    if params:
        raise ValueError(
            f"unknown sharded {algorithm} params: {sorted(params)}")
