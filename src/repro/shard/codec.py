"""Delta compression for exchange payloads (DESIGN.md §16).

An exchange hop ships a fixed-shape ``[rows, width]`` int32 send buffer
whose valid task ints are a per-row prefix padded with the ``EMPTY``
sentinel (shard/exchange.py).  Task ints are vertex-correlated — a
destination row holds tasks bound for one vertex block — so sorting a
row's tasks and shipping first-order deltas packs most batches into 4–16
bits per int instead of 32.  The wire format (all int32 words):

    word 0          header: bits 0-1 mode (0=RAW, 1/2/3 = packed at
                    b=4/8/16 bits per delta), bits 2-3 layout (0=counts8,
                    1=bitmask, 2=counts16), bits 4.. total valid count
                    ``n``
    RAW             words 1..rows*width: the buffer verbatim (EMPTY
                    in-band); n_words = 1 + rows*width
    PACKED, n == 0  header only; n_words = 1
    PACKED, n >= 1  layout words  — which slots hold tasks:
                      counts8:  ceil(rows/4) words, one 8-bit valid count
                                per row (prefix-compact rows, width<=255)
                      counts16: ceil(rows/2) words, 16-bit counts — the
                                wide-buffer form of the same thing (the
                                exchange compaction always emits prefix-
                                compact rows, so O(rows) layout overhead
                                never degrades to O(slots) just because
                                the route width is large)
                      bitmask:  ceil(rows*width/32) words, bit j of the
                                flattened buffer (general scattered
                                validity — the EMPTY-padding bitmask)
                    base word     — the stream's first value, raw int32
                    data words    — the remaining ``n - 1`` deltas of the
                                    sorted-run stream (each row's valid
                                    values ascending, rows concatenated),
                                    zigzag-mapped and bit-packed at ``b``
                                    bits each (b divides 32: no straddle)

The encoder picks the smallest feasible ``b`` and the cheapest applicable
layout, and falls back to RAW whenever packing would not be *strictly*
smaller — an incompressible batch never expands: ``n_words <= 1 +
rows*width`` always.  Delta and cumsum arithmetic is two's-complement
int32 (wraparound), and the zigzag map runs on the uint32 bit pattern, so
the round trip is exact for every int32 value — including the boundary
values — not just small ones; the zigzag idiom itself is the server wire
codec's (server/encoding.py).

Decoding reconstructs valid slot positions exactly and each row's value
*multiset* exactly, delivered in ascending order within the row (the
sorted-run canonical form).  ``EMPTY`` is the padding sentinel and by
queue contract never a task value, so decoded tasks never collide with
it; the layout words — not the in-band sentinel — carry the validity, so
a PACKED stream is self-describing in exactly ``n_words`` words.

Like ``distributed/compression.py``'s quantized gradient exchange, the
SPMD collective itself still ships the fixed-shape buffer (XLA has no
variable-length all_to_all); the codec runs for real in the delivery
path — what the receiver enqueues is the *decoded* stream — and the
meters record ``n_words``, the ints a variable-length transport would
put on the wire.  Compression ratios in BENCH_shard.json are therefore
measured, not estimated, and honest about per-batch overheads.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.queue import EMPTY

#: packed-delta widths searched by the encoder (each divides 32, so a
#: delta never straddles a word boundary)
PACKED_WIDTHS: Tuple[int, ...] = (4, 8, 16)

_MODE_RAW = 0
_MODE_OF = {4: 1, 8: 2, 16: 3}
_LAYOUT_COUNTS8 = 0
_LAYOUT_BITMASK = 1
_LAYOUT_COUNTS16 = 2
_LAYOUTS = (_LAYOUT_COUNTS8, _LAYOUT_BITMASK, _LAYOUT_COUNTS16)
_N_SHIFT = 4


def _u32(x):
    return jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.int32),
                                        jnp.uint32)


def _i32(x):
    return jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.uint32),
                                        jnp.int32)


def zigzag(v):
    """Map int32 to uint32 with small-magnitude values small (wraparound-
    exact for every int32, boundaries included)."""
    return (_u32(v) << 1) ^ _u32(v >> 31)


def unzigzag(z):
    """Inverse of :func:`zigzag` (uint32 -> int32)."""
    z = jnp.asarray(z, jnp.uint32)
    return _i32((z >> 1) ^ (jnp.uint32(0) - (z & 1)))


def _counts8_words(rows: int) -> int:
    return -(-rows // 4)


def _counts16_words(rows: int) -> int:
    return -(-rows // 2)


def _mask_words(rows: int, width: int) -> int:
    return -(-(rows * width) // 32)


def _layout_words(layout: int, rows: int, width: int) -> int:
    if layout == _LAYOUT_COUNTS8:
        return _counts8_words(rows)
    if layout == _LAYOUT_COUNTS16:
        return _counts16_words(rows)
    return _mask_words(rows, width)


def _data_words_max(rows: int, width: int, b: int) -> int:
    return -(-((rows * width - 1) * b) // 32) if rows * width > 1 else 0


def codec_capacity(rows: int, width: int) -> int:
    """Static word capacity covering every mode's worst case."""
    f = rows * width
    raw = 1 + f
    lw = max(_layout_words(lay, rows, width) for lay in _LAYOUTS)
    packed = 2 + lw + _data_words_max(rows, width, max(PACKED_WIDTHS))
    return max(raw, packed)


def _sorted_rows(buf, valid):
    """Each row's valid values ascending in its leading lanes (EMPTY is
    int32 min, so a plain value sort front-loads the padding; a second
    stable sort on invalidity restores valid-first order for any input)."""
    perm1 = jnp.argsort(buf, axis=1, stable=True)
    sv = jnp.take_along_axis(buf, perm1, axis=1)
    svalid = jnp.take_along_axis(valid, perm1, axis=1)
    perm2 = jnp.argsort(~svalid, axis=1, stable=True)
    return (jnp.take_along_axis(sv, perm2, axis=1),
            jnp.take_along_axis(svalid, perm2, axis=1))


def encode_buffer(buf: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Encode a ``[rows, width]`` int32 buffer (EMPTY = padding).

    Returns ``(words, n_words)``: a ``codec_capacity(rows, width)``-wide
    int32 word buffer whose first ``n_words`` words are the stream (the
    rest is zero padding), and the traced metered length.  Pure fixed-
    shape array ops — safe inside jitted SPMD loops.
    """
    rows, width = buf.shape
    f = rows * width
    cap = codec_capacity(rows, width)
    buf = jnp.asarray(buf, jnp.int32)
    valid = buf != EMPTY
    k = jnp.sum(valid.astype(jnp.int32), axis=1)           # per-row counts
    n = jnp.sum(k)

    jidx = jnp.arange(width, dtype=jnp.int32)[None, :]
    prefix_ok = jnp.all(valid == (jidx < k[:, None]))
    # cheapest applicable layout: 8-bit counts, then 16-bit counts, then
    # the general bitmask (scattered validity, or rows wider than 2^16)
    use_c8 = prefix_ok & (width <= 255)
    use_c16 = prefix_ok & ~use_c8 & (width <= 65535)
    layout = jnp.where(use_c8, _LAYOUT_COUNTS8,
                       jnp.where(use_c16, _LAYOUT_COUNTS16, _LAYOUT_BITMASK))

    # ---- sorted-run stream: row-major concatenation of each row's
    # ascending valid values
    sv, svalid = _sorted_rows(buf, valid)
    off = jnp.cumsum(k) - k                                # exclusive
    pos = off[:, None] + jidx
    stream = jnp.zeros((f,), jnp.int32).at[
        jnp.where(svalid, pos, f).reshape(-1)
    ].set(jnp.where(svalid, sv, 0).reshape(-1), mode="drop")

    i = jnp.arange(f, dtype=jnp.int32)
    prev = jnp.concatenate([stream[:1], stream[:-1]])
    live_d = (i >= 1) & (i < n)                            # delta lanes
    dz = jnp.where(live_d, zigzag(stream - prev), jnp.uint32(0))
    max_dz = jnp.max(dz) if f > 1 else jnp.uint32(0)

    # ---- layout words
    ridx = np.arange(rows)
    c8w = jnp.zeros((_counts8_words(rows),), jnp.uint32).at[ridx // 4].add(
        _u32(jnp.minimum(k, 255)) << jnp.asarray(8 * (ridx % 4), jnp.uint32))
    c16w = jnp.zeros((_counts16_words(rows),), jnp.uint32).at[ridx // 2].add(
        _u32(jnp.minimum(k, 65535))
        << jnp.asarray(16 * (ridx % 2), jnp.uint32))
    fidx = np.arange(f)
    maskw = jnp.zeros((_mask_words(rows, width),), jnp.uint32).at[
        fidx // 32].add(valid.reshape(-1).astype(jnp.uint32)
                        << jnp.asarray(fidx % 32, jnp.uint32))
    layout_arrays = {_LAYOUT_COUNTS8: c8w, _LAYOUT_BITMASK: maskw,
                     _LAYOUT_COUNTS16: c16w}
    lw = jnp.where(use_c8, _counts8_words(rows),
                   jnp.where(use_c16, _counts16_words(rows),
                             _mask_words(rows, width)))

    # ---- mode selection: smallest feasible packed width, raw fallback
    feasible = {b: max_dz < jnp.uint32(1 << b) for b in PACKED_WIDTHS}
    n_data = {b: (jnp.maximum(n - 1, 0) * b + 31) // 32
              for b in PACKED_WIDTHS}
    n_packed = {b: jnp.where(n == 0, 1, 2 + lw + n_data[b])
                for b in PACKED_WIDTHS}
    best_b = jnp.int32(0)                                  # 0 = none
    best_words = jnp.int32(1 + f)                          # raw size
    for b in reversed(PACKED_WIDTHS):                      # prefer small b
        take = feasible[b] & (n_packed[b] < 1 + f)
        best_b = jnp.where(take, b, best_b)
        best_words = jnp.where(take, n_packed[b], best_words)
    mode = jnp.int32(0)
    for b in PACKED_WIDTHS:
        mode = jnp.where(best_b == b, _MODE_OF[b], mode)
    n_words = best_words

    # ---- assemble every candidate buffer at static offsets, select one
    header = (mode | (jnp.where(mode == 0, 0, layout) << 2)
              | (n << _N_SHIFT))
    out = jnp.zeros((cap,), jnp.int32).at[0].set(header)
    raw_out = out.at[1:1 + f].set(buf.reshape(-1))

    def packed_out(lay_flag, b):
        lwords = layout_arrays[lay_flag]
        lw_s = _layout_words(lay_flag, rows, width)
        didx = np.arange(f - 1) if f > 1 else np.arange(0)
        dataw = jnp.zeros((_data_words_max(rows, width, b),),
                          jnp.uint32).at[didx * b // 32].add(
            dz[1:] << jnp.asarray(didx * b % 32, jnp.uint32))
        o = out.at[1:1 + lw_s].set(_i32(lwords))
        o = o.at[1 + lw_s].set(stream[0])
        return o.at[2 + lw_s:2 + lw_s + dataw.shape[0]].set(_i32(dataw))

    res = raw_out
    for b in PACKED_WIDTHS:
        for lay in _LAYOUTS:
            pick = (mode == _MODE_OF[b]) & (layout == lay) & (n > 0)
            res = jnp.where(pick, packed_out(lay, b), res)
    # n == 0 packed: header only (the zero-filled template already is)
    res = jnp.where((mode != 0) & (n == 0), out, res)
    return res, n_words


def decode_buffer(words: jax.Array, rows: int, width: int) -> jax.Array:
    """Decode an :func:`encode_buffer` stream back to ``[rows, width]``.

    Reads only the stream's own ``n_words`` words (the rest of the word
    buffer may hold anything).  RAW mode reproduces the buffer verbatim;
    PACKED modes reproduce exact valid positions with each row's values
    ascending — the canonical sorted-run form.
    """
    f = rows * width
    words = jnp.asarray(words, jnp.int32)
    header = words[0]
    mode = header & 3
    lay = (header >> 2) & 3
    n = header >> _N_SHIFT

    raw_dec = words[1:1 + f].reshape(rows, width)

    jidx = jnp.arange(width, dtype=jnp.int32)[None, :]
    ridx = jnp.arange(rows, dtype=jnp.int32)
    fidx = jnp.arange(f, dtype=jnp.int32)

    # validity per layout
    k8 = _i32((_u32(words[1 + ridx // 4])
               >> _u32(8 * (ridx % 4))) & jnp.uint32(255))
    k16 = _i32((_u32(words[1 + ridx // 2])
                >> _u32(16 * (ridx % 2))) & jnp.uint32(65535))
    maskbits = (_u32(words[1 + fidx // 32]) >> _u32(fidx % 32)) & jnp.uint32(1)
    valid_of = {
        _LAYOUT_COUNTS8: jidx < k8[:, None],
        _LAYOUT_COUNTS16: jidx < k16[:, None],
        _LAYOUT_BITMASK: (maskbits == 1).reshape(rows, width),
    }

    def unpacked(lay_flag, b):
        lw_s = _layout_words(lay_flag, rows, width)
        valid = valid_of[lay_flag]
        base = words[1 + lw_s]
        didx = jnp.arange(max(f - 1, 0), dtype=jnp.int32)
        dz = (_u32(words[2 + lw_s + didx * b // 32])
              >> _u32(didx * b % 32)) & jnp.uint32((1 << b) - 1)
        deltas = jnp.where(didx < n - 1, unzigzag(dz), 0)
        vals = base + jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(deltas)])
        k = jnp.sum(valid.astype(jnp.int32), axis=1)
        off = jnp.cumsum(k) - k
        rank = jnp.cumsum(valid.astype(jnp.int32), axis=1) - valid
        g = off[:, None] + rank
        return jnp.where(valid & (n > 0),
                         vals[jnp.clip(g, 0, f - 1)], EMPTY)

    res = raw_dec
    for b in PACKED_WIDTHS:
        for lay_flag in _LAYOUTS:
            pick = (mode == _MODE_OF[b]) & (lay == lay_flag)
            res = jnp.where(pick, unpacked(lay_flag, b), res)
    return jnp.asarray(res, jnp.int32)
