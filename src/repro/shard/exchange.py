"""Routed wavefront delivery: owner-split + per-axis all-to-all exchange.

After a device runs the wavefront body, every produced task is routed to the
shard that owns its vertex (TREES-style round-synchronous epoch exchange):
locally-owned tasks go straight into the device's queue replica; remote ones
are compacted into per-destination send rows and shipped by ``lax.all_to_all``.
On the 1-D ``("shard",)`` mesh that is one ``num_shards``-wide collective; on
a 2-D ``("row", "col")`` mesh the exchange is dimension-ordered — a column
hop inside each row (keyed by the owner's column), then a row hop inside each
column (keyed by the owner's row) — so each collective spans only ``cols``
(resp. ``rows``) devices instead of all of them (DESIGN.md §16).  The EMPTY
queue sentinel doubles as the wire sentinel — no task encoding ever produces
it — and with ``compress=True`` each hop's buffer additionally runs through
the sorted-run delta codec (shard/codec.py) on its way to the wire.

``route_tasks`` pushes only the *locally owned* tasks itself and hands the
exchanged arrivals back as a flat EMPTY-padded ``delivered`` buffer: the
driver either pushes it immediately (strict mode — identical schedule to the
historical in-function push) or stages it one round (``defer_rounds=1``
overlap, shard/driver.py).  Alongside it returns a ``meters`` dict:

    sent       distinct tasks shipped off-device (each counted once)
    rdrop      tasks dropped by a too-narrow ``route_width``
    sent_col   cross-device payload ints on the column hop (the only hop,
               for 1-D meshes)
    sent_row   cross-device payload ints on the row hop (0 on 1-D meshes)
    payload    valid ints across all hop buffers (a task relayed through
               both hops is carried twice — it is on the wire twice)
    padding    EMPTY slots across all hop buffers
    wire       metered wire ints: ``payload + padding`` raw, or the codec's
               compressed word count when ``compress=True``

so the obs layer can separate true payload from the padding an EMPTY-padded
fixed-shape collective ships, per axis.

All functions here run *inside* shard_map (they use ``lax.axis_index`` and
collectives) and are uniform across devices: every shard executes the same
exchange every round, so the SPMD while_loop stays in lockstep.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from ..core.queue import EMPTY, MultiQueue
from .codec import decode_buffer, encode_buffer
from .partition import owner_of

#: lane of each per-device MultiQueue replica holding owned (seeded, routed,
#: or requeued) tasks — always expandable from the local CSR slice.
LANE_LOCAL = 0
#: lane holding tasks freshly donated by the ring predecessor — expandable
#: from the steal halo, never re-donated (see shard/steal.py).
LANE_STOLEN = 1
NUM_LANES = 2

AxisName = Union[str, Tuple[str, ...]]


def delivered_width(route_width: int, num_shards: int,
                    mesh_dims: Optional[Tuple[int, int]] = None) -> int:
    """Static width of the flat ``delivered`` buffer ``route_tasks`` returns
    (and of the driver's staging buffer in overlap mode).

    1-D: one ``[S, w]`` recv buffer.  2-D ``(R, C)``: the column hop's
    ``[C, w]`` recv plus the row hop's ``[R, C*w]`` recv — the row hop is
    ``C*w`` wide because in the worst case every task a device receives on
    the column hop (up to ``C*w``) must be forwarded to the same row, and
    that capacity guarantee is what makes hop-2 drops impossible.
    """
    if mesh_dims is None:
        return num_shards * route_width
    rows, cols = mesh_dims
    return cols * route_width + rows * (cols * route_width)


def _compact_send(items, take, key, nrows: int, width: int):
    """Scatter taken items into ``[nrows, width]`` destination rows.

    Task i's slot in row ``key[i]`` is the count of earlier taken tasks with
    the same key (the same exclusive-prefix-sum reservation the queue push
    uses, one column per destination).  Returns ``(send, n_taken, n_drop)``
    with each row a rank-compacted EMPTY-padded prefix.
    """
    k = items.shape[0]
    key = jnp.clip(jnp.asarray(key, jnp.int32), 0, nrows - 1)
    onehot = (key[:, None] == jnp.arange(nrows, dtype=jnp.int32)[None, :]
              ) & take[:, None]
    rank = (jnp.cumsum(onehot, axis=0) - onehot)[
        jnp.arange(k), key].astype(jnp.int32)
    fits = take & (rank < width)
    send = jnp.full((nrows, width), EMPTY, jnp.int32).at[
        jnp.where(fits, key, nrows), rank
    ].set(jnp.where(fits, items, EMPTY), mode="drop")
    n_fit = jnp.sum(fits.astype(jnp.int32))
    n_drop = jnp.sum(take.astype(jnp.int32)) - n_fit
    return send, n_fit, n_drop


def _ship(send, axis_name: str, compress: bool):
    """One hop: optionally delta-compress, then all_to_all the buffer.

    With ``compress=True`` the buffer is encoded and *decoded back* before
    the collective — XLA's all_to_all is fixed-shape, so (exactly like the
    quantized gradient exchange in distributed/compression.py) the physical
    primitive ships the decoded buffer while the meter records the codec's
    word count; the codec is load-bearing because what arrives (and is
    enqueued) is the decoded stream, canonical sorted-run order and all.
    Returns ``(recv, wire_ints)`` — row ``s`` of recv is what peer ``s``
    on this axis addressed to me.
    """
    nrows, width = send.shape
    if compress:
        words, n_words = encode_buffer(send)
        send = decode_buffer(words, nrows, width)
        wire = n_words
    else:
        wire = jnp.int32(nrows * width)
    recv = jax.lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0)
    return recv, wire


def _row_payload(send, self_row):
    """(total valid ints, valid ints in the self-addressed row)."""
    valid = (send != EMPTY).astype(jnp.int32)
    return jnp.sum(valid), jnp.sum(valid[self_row])


def route_tasks(
    mq: MultiQueue,
    items: jax.Array,
    mask: jax.Array,
    *,
    axis_name: AxisName,
    num_shards: int,
    num_vertices: int,
    task_vertex,
    route_width: int | None = None,
    backend: str = "jnp",
    mesh_dims: Optional[Tuple[int, int]] = None,
    compress: bool = False,
) -> Tuple[MultiQueue, jax.Array, Dict[str, jax.Array]]:
    """Deliver produced tasks toward their owners' queue replicas.

    Locally-owned tasks are pushed here; exchanged arrivals come back as the
    flat EMPTY-padded ``delivered`` buffer of static width
    ``delivered_width(route_width, num_shards, mesh_dims)`` for the caller
    to push (strict) or stage (overlap).  ``meters`` is the wire-accounting
    dict described in the module docstring.

    ``mesh_dims=None`` routes over the single ``axis_name`` collective (the
    1-D ring exchange, unchanged); ``mesh_dims=(rows, cols)`` with
    ``axis_name=(row_axis, col_axis)`` routes dimension-ordered over the
    2-D mesh.  ``route_width`` bounds tasks per destination on the *first*
    hop; the second hop is capacity-safe by construction.
    """
    k = items.shape[0]
    w1 = k if route_width is None else route_width

    if mesh_dims is None:
        axis = axis_name if isinstance(axis_name, str) else axis_name[0]
        me = jax.lax.axis_index(axis)
        verts = task_vertex(jnp.where(mask, items, 0))
        dest = owner_of(verts, num_vertices, num_shards)

        mq = mq.push(LANE_LOCAL, items, mask & (dest == me), backend=backend)
        send, n_sent, n_drop = _compact_send(
            items, mask & (dest != me), dest, num_shards, w1)
        payload, _self = _row_payload(send, me)
        recv, wire = _ship(send, axis, compress)
        delivered = recv.reshape(-1)
        meters = {
            "sent": n_sent,
            "rdrop": n_drop,
            "sent_col": payload - _self,
            "sent_row": jnp.int32(0),
            "payload": payload,
            "padding": jnp.int32(num_shards * w1) - payload,
            "wire": wire,
        }
        return mq, delivered, meters

    rows, cols = mesh_dims
    row_axis, col_axis = axis_name
    me_r = jax.lax.axis_index(row_axis)
    me_c = jax.lax.axis_index(col_axis)
    me = me_r * cols + me_c
    verts = task_vertex(jnp.where(mask, items, 0))
    dest = owner_of(verts, num_vertices, num_shards)

    mq = mq.push(LANE_LOCAL, items, mask & (dest == me), backend=backend)

    # hop 1 — column hop inside my row: every remote task moves to the
    # device in my row that sits in the owner's column (tasks already in
    # the right column ride the collective's self lane at zero wire cost).
    send1, n_sent, drop1 = _compact_send(
        items, mask & (dest != me), dest % cols, cols, w1)
    payload1, self1 = _row_payload(send1, me_c)
    recv1, wire1 = _ship(send1, col_axis, compress)

    # hop 2 — row hop inside the owner's column: arrivals whose owner row
    # is mine are delivered; the rest forward to the owner's row.  Width
    # cols*w1 holds every hop-1 arrival, so nothing can drop here.
    flat1 = recv1.reshape(-1)
    v1 = flat1 != EMPTY
    dest1 = owner_of(task_vertex(jnp.where(v1, flat1, 0)),
                     num_vertices, num_shards)
    mine1 = v1 & (dest1 // cols == me_r)
    send2, _, drop2 = _compact_send(
        flat1, v1 & ~mine1, dest1 // cols, rows, cols * w1)
    payload2, self2 = _row_payload(send2, me_r)
    recv2, wire2 = _ship(send2, row_axis, compress)

    delivered = jnp.concatenate(
        [jnp.where(mine1, flat1, EMPTY), recv2.reshape(-1)])
    slots = jnp.int32(cols * w1 + rows * cols * w1)
    meters = {
        "sent": n_sent,
        "rdrop": drop1 + drop2,
        "sent_col": payload1 - self1,
        "sent_row": payload2 - self2,
        "payload": payload1 + payload2,
        "padding": slots - payload1 - payload2,
        "wire": wire1 + wire2,
    }
    return mq, delivered, meters


def pop_wavefront(mq: MultiQueue, wavefront: int):
    """Pop one device wavefront, draining stolen tasks first.

    Stolen tasks are served before local ones so donations turn into
    progress immediately (they were donated because this device was idle).
    Both lane pops are static-width; the stolen prefix and the local
    remainder are fused into a single ``wavefront``-wide (items, valid)
    pair, preserving each lane's FIFO order.
    """
    s_items, s_valid, mq = mq.pop_lane(LANE_STOLEN, wavefront)
    k1 = jnp.sum(s_valid.astype(jnp.int32))
    l_items, l_valid, mq = mq.pop_lane(LANE_LOCAL, wavefront,
                                       quota=wavefront - k1)
    k0 = jnp.sum(l_valid.astype(jnp.int32))
    lane = jnp.arange(wavefront, dtype=jnp.int32)
    shifted = l_items[jnp.clip(lane - k1, 0, wavefront - 1)]
    items = jnp.where(lane < k1, s_items, shifted)
    valid = lane < (k1 + k0)
    items = jnp.where(valid, items, EMPTY)
    return items, valid, k1, mq
