"""Routed wavefront delivery: owner-split + all-to-all task exchange.

After a device runs the wavefront body, every produced task is routed to the
shard that owns its vertex (TREES-style round-synchronous epoch exchange):
locally-owned tasks go straight into the device's queue replica; remote ones
are compacted into per-destination send rows and delivered with one
``lax.all_to_all`` over the ``("shard",)`` mesh axis, landing in the owner's
queue before the next round.  The EMPTY queue sentinel doubles as the wire
sentinel — no task encoding ever produces it.

All functions here run *inside* shard_map (they use ``lax.axis_index`` and
collectives) and are uniform across devices: every shard executes the same
exchange every round, so the SPMD while_loop stays in lockstep.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..core.queue import EMPTY, MultiQueue
from .partition import owner_of

#: lane of each per-device MultiQueue replica holding owned (seeded, routed,
#: or requeued) tasks — always expandable from the local CSR slice.
LANE_LOCAL = 0
#: lane holding tasks freshly donated by the ring predecessor — expandable
#: from the steal halo, never re-donated (see shard/steal.py).
LANE_STOLEN = 1
NUM_LANES = 2


def route_tasks(
    mq: MultiQueue,
    items: jax.Array,
    mask: jax.Array,
    *,
    axis_name: str,
    num_shards: int,
    num_vertices: int,
    task_vertex,
    route_width: int | None = None,
    backend: str = "jnp",
) -> Tuple[MultiQueue, jax.Array, jax.Array]:
    """Deliver produced tasks to their owners' queue replicas.

    Returns ``(mq', n_sent, n_route_dropped)`` — tasks shipped off-device
    and tasks lost because more than ``route_width`` targeted one
    destination (impossible at the default width = full output width; the
    counter keeps narrower configurations honest).
    """
    k = items.shape[0]
    route_width = k if route_width is None else route_width
    me = jax.lax.axis_index(axis_name)
    verts = task_vertex(jnp.where(mask, items, 0))
    dest = owner_of(verts, num_vertices, num_shards)

    local = mask & (dest == me)
    mq = mq.push(LANE_LOCAL, items, local, backend=backend)

    remote = mask & (dest != me)
    # per-destination compaction: task i's slot in its destination row is
    # the count of earlier remote tasks with the same destination (the same
    # exclusive-prefix-sum reservation the queue push uses, one column per
    # destination shard).
    onehot = (dest[:, None] == jnp.arange(num_shards, dtype=jnp.int32)[None, :]
              ) & remote[:, None]
    rank = (jnp.cumsum(onehot, axis=0) - onehot)[
        jnp.arange(k), dest].astype(jnp.int32)
    sent = remote & (rank < route_width)
    send = jnp.full((num_shards, route_width), EMPTY, jnp.int32).at[
        jnp.where(sent, dest, num_shards), rank
    ].set(jnp.where(sent, items, EMPTY), mode="drop")

    # row s of recv = what shard s addressed to me this round
    recv = jax.lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0)
    flat = recv.reshape(-1)
    mq = mq.push(LANE_LOCAL, flat, flat != EMPTY, backend=backend)

    n_sent = jnp.sum(sent.astype(jnp.int32))
    n_dropped = jnp.sum(remote.astype(jnp.int32)) - n_sent
    return mq, n_sent, n_dropped


def pop_wavefront(mq: MultiQueue, wavefront: int):
    """Pop one device wavefront, draining stolen tasks first.

    Stolen tasks are served before local ones so donations turn into
    progress immediately (they were donated because this device was idle).
    Both lane pops are static-width; the stolen prefix and the local
    remainder are fused into a single ``wavefront``-wide (items, valid)
    pair, preserving each lane's FIFO order.
    """
    s_items, s_valid, mq = mq.pop_lane(LANE_STOLEN, wavefront)
    k1 = jnp.sum(s_valid.astype(jnp.int32))
    l_items, l_valid, mq = mq.pop_lane(LANE_LOCAL, wavefront,
                                       quota=wavefront - k1)
    k0 = jnp.sum(l_valid.astype(jnp.int32))
    lane = jnp.arange(wavefront, dtype=jnp.int32)
    shifted = l_items[jnp.clip(lane - k1, 0, wavefront - 1)]
    items = jnp.where(lane < k1, s_items, shifted)
    valid = lane < (k1 + k0)
    items = jnp.where(valid, items, EMPTY)
    return items, valid, k1, mq
