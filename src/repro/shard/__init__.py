"""Sharded multi-device task scheduler (DESIGN.md sections 10 and 16).

One Atos drain across every device of a mesh — the 1-D ``("shard",)`` ring,
or a 2-D ``("row", "col")`` mesh (``SchedulerConfig.mesh_shape``) whose
routed exchange decomposes into two per-axis all_to_alls: a vertex-block
partitioner reshards the CSR adjacency, each device runs a queue replica
plus the existing wavefront body on its local slice, produced tasks are
routed to their owner every round (optionally staged one round to overlap
the collective with compute, ``defer_rounds``; optionally delta-compressed
on the wire, ``compress`` + shard/codec.py), occupancy skew triggers ring
work stealing, and a psum'd stop predicate keeps the mesh in lockstep until
the global drain ends.  Fully testable on CPU via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Since the runtime layer (DESIGN.md section 11) the driver consumes the
unified :class:`~repro.runtime.program.AtosProgram`; program construction
lives in :mod:`repro.runtime` (``build_program``), and the one-PR
deprecation shim that used to forward it from here (``shard/programs.py``)
is gone.
"""
from .codec import codec_capacity, decode_buffer, encode_buffer
from .driver import (ShardCounters, ShardRunStats, discrete_run_sharded,
                     persistent_run_sharded, run_sharded)
from .exchange import (LANE_LOCAL, LANE_STOLEN, NUM_LANES, delivered_width,
                       pop_wavefront, route_tasks)
from .partition import (ShardedCSR, block_bounds, block_size, owner_coords,
                        owner_of, partition_graph, split_seeds)
from .steal import plan_donations, rebalance

__all__ = [
    "ShardCounters", "ShardRunStats", "discrete_run_sharded",
    "persistent_run_sharded", "run_sharded",
    "LANE_LOCAL", "LANE_STOLEN", "NUM_LANES", "delivered_width",
    "pop_wavefront", "route_tasks",
    "ShardedCSR", "block_bounds", "block_size", "owner_coords", "owner_of",
    "partition_graph", "split_seeds",
    "plan_donations", "rebalance",
    "codec_capacity", "decode_buffer", "encode_buffer",
]

_MOVED = {
    "ShardProgram": "repro.runtime.program.AtosProgram",
    "build_program": "repro.runtime.build_program",
    "delta_psum": "repro.runtime.program.delta_psum",
}


def __getattr__(name):
    if name in _MOVED:
        raise ImportError(
            f"repro.shard.{name} was a one-PR deprecation shim and has been "
            f"removed; import {_MOVED[name]} instead (the unified runtime "
            f"layer, DESIGN.md section 11)")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
