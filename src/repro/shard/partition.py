"""Vertex-block graph partitioner for the sharded task scheduler.

Ownership is by contiguous vertex block: shard ``d`` of ``S`` owns vertices
``[d*B, min(n, (d+1)*B))`` with ``B = ceil(n / S)`` — the static function
``owner_of`` is evaluated inside traced code to route every produced task to
the device that owns its vertex (DESIGN.md section 10).

The CSR adjacency — the O(m) payload — is *resharded*: each device holds
only the edges of its own block (plus, when stealing is enabled, a **steal
halo**: a replica of its ring predecessor's block, so donated tasks are
expandable by the thief at the cost of 2x edge storage).  The O(n) per-shard
``row_ptr`` keeps the *global* vertex index space so the existing wavefront
bodies run unchanged on a device-local :class:`~repro.graph.csr.CSRGraph`;
entries for rows a device neither owns nor halos are never read (every
popped task is owned or freshly stolen — an invariant the driver meters and
the tests assert).

Everything here is host-side numpy, run once per (graph, shard count); the
stacked ``[S, ...]`` arrays are what ``shard_map`` splits across the mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.csr import CSRGraph


def block_size(n: int, num_shards: int) -> int:
    """Vertices per shard (ceil split; trailing shards may be short/empty)."""
    return -(-n // num_shards)


def owner_of(vids, n: int, num_shards: int):
    """Owning shard of each vertex id (traced-friendly; callers mask
    invalid lanes to a safe id before calling)."""
    b = block_size(n, num_shards)
    return jnp.clip(jnp.asarray(vids, jnp.int32) // b, 0, num_shards - 1)


def block_bounds(shard: int, n: int, num_shards: int) -> Tuple[int, int]:
    """[start, end) vertex range owned by ``shard`` (host-side ints)."""
    b = block_size(n, num_shards)
    return min(n, shard * b), min(n, (shard + 1) * b)


def owner_coords(vids, n: int, rows: int, cols: int):
    """2-D mesh coordinates ``(row, col)`` of each vertex's owner.

    Ownership on the 2-D mesh is the *same* linear vertex-block split as
    the 1-D ring (``owner_of`` with ``num_shards = rows * cols``) mapped
    row-major onto the mesh: linear shard ``d`` sits at ``(d // cols,
    d % cols)`` — exactly the order jax linearizes ``("row", "col")``
    tuple-axis collectives in, so partitioning, steal halos (linear ring
    predecessor), and the replica merge are untouched by the mesh shape.
    The per-axis exchange (shard/exchange.py) routes dimension-ordered:
    first to the owner's column (a ``cols``-wide all_to_all inside the
    row), then to its row (a ``rows``-wide all_to_all inside the column).
    """
    d = owner_of(vids, n, rows * cols)
    return d // cols, d % cols


@dataclasses.dataclass(frozen=True)
class ShardedCSR:
    """Per-device CSR slices, stacked for shard_map.

    ``row_ptr[d]`` is a full ``[n+1]`` int32 vector whose entries are local
    edge offsets for shard ``d``'s own (and halo) rows and zeros elsewhere;
    ``col_idx[d]`` holds shard ``d``'s edges padded to the widest shard.
    ``local(d)`` reassembles the device view as a plain CSRGraph — the same
    container the wavefront bodies already consume.
    """

    row_ptr: jax.Array        # [S, n+1] int32 (global vertex index space)
    col_idx: jax.Array        # [S, E_pad] int32 (global neighbor ids)
    num_shards: int
    num_vertices: int
    halo: bool                # ring-predecessor block replicated (stealing)
    edges_per_shard: Tuple[int, ...]   # owned edges only (diagnostic)

    def local(self, shard) -> CSRGraph:
        """Device-local graph view (works on traced ``shard`` too)."""
        return CSRGraph(row_ptr=self.row_ptr[shard],
                        col_idx=self.col_idx[shard])


def partition_graph(graph: CSRGraph, num_shards: int,
                    halo: bool = True) -> ShardedCSR:
    """Reshard a CSR graph by vertex block.

    With ``halo=True`` shard ``d`` also carries a replica of shard
    ``(d-1) % S``'s rows, which is what makes ring work stealing legal: the
    only foreign tasks a device ever pops are donations from its ring
    predecessor (see shard/steal.py).
    """
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    n = graph.num_vertices
    rp = np.asarray(graph.row_ptr, dtype=np.int64)
    col = np.asarray(graph.col_idx, dtype=np.int32)
    use_halo = halo and num_shards > 1

    locals_rp, locals_col, owned_edges = [], [], []
    for d in range(num_shards):
        own_lo, own_hi = block_bounds(d, n, num_shards)
        e_lo, e_hi = int(rp[own_lo]), int(rp[own_hi])
        owned_edges.append(e_hi - e_lo)
        lrp = np.zeros(n + 1, dtype=np.int32)
        if use_halo and d > 0:
            # predecessor block immediately precedes the own block in vertex
            # (and therefore edge) space: one contiguous global slice.
            pre_lo, _ = block_bounds(d - 1, n, num_shards)
            ep_lo = int(rp[pre_lo])
            lcol = col[ep_lo:e_hi]
            lrp[pre_lo:own_hi + 1] = rp[pre_lo:own_hi + 1] - ep_lo
        elif use_halo:
            # shard 0's predecessor is the last block: wraps around, so the
            # local layout is [own edges | halo edges].
            pre_lo, pre_hi = block_bounds(num_shards - 1, n, num_shards)
            ep_lo, ep_hi = int(rp[pre_lo]), int(rp[pre_hi])
            lcol = np.concatenate([col[e_lo:e_hi], col[ep_lo:ep_hi]])
            lrp[own_lo:own_hi + 1] = rp[own_lo:own_hi + 1] - e_lo
            lrp[pre_lo:pre_hi + 1] = (e_hi - e_lo) + (rp[pre_lo:pre_hi + 1]
                                                      - ep_lo)
        else:
            lcol = col[e_lo:e_hi]
            lrp[own_lo:own_hi + 1] = rp[own_lo:own_hi + 1] - e_lo
        locals_rp.append(lrp)
        locals_col.append(lcol)

    e_pad = max(1, max(len(c) for c in locals_col))
    col_stack = np.zeros((num_shards, e_pad), dtype=np.int32)
    for d, c in enumerate(locals_col):
        col_stack[d, :len(c)] = c
    return ShardedCSR(
        row_ptr=jnp.asarray(np.stack(locals_rp)),
        col_idx=jnp.asarray(col_stack),
        num_shards=num_shards,
        num_vertices=n,
        halo=use_halo,
        edges_per_shard=tuple(owned_edges),
    )


def split_seeds(seeds, n: int, num_shards: int, task_vertex=None):
    """Host-side owner split of the initial tasks: ``[S, max_per_shard]``
    items plus a per-shard count — what seeds each device's queue replica.

    ``task_vertex`` maps a task int to its vertex id (identity by default;
    coloring passes ``|t| - 1``).
    """
    seeds = np.asarray(seeds, dtype=np.int32)
    verts = seeds if task_vertex is None else np.asarray(
        task_vertex(seeds), dtype=np.int32)
    owners = np.clip(verts // block_size(n, num_shards), 0, num_shards - 1)
    per = [seeds[owners == d] for d in range(num_shards)]
    width = max(1, max(len(p) for p in per))
    out = np.zeros((num_shards, width), dtype=np.int32)
    counts = np.zeros((num_shards,), dtype=np.int32)
    for d, p in enumerate(per):
        out[d, :len(p)] = p
        counts[d] = len(p)
    return jnp.asarray(out), jnp.asarray(counts)
