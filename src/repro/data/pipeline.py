"""Deterministic, sharded, resumable synthetic token pipeline.

Production posture (1000+ nodes):
  * sharding is by *logical shard id* — ``shard_id = process_index`` by
    default but decoupled, so a replacement host resumes the failed host's
    shard (straggler/fault story, DESIGN.md section 6);
  * the stream is a pure function of (seed, shard, step): resuming from a
    checkpointed step reproduces the exact batch sequence with no state
    files;
  * batches are built host-local ([local_batch, seq]) and assembled into a
    global array with ``jax.make_array_from_process_local_data`` in the
    trainer (single-process here: a plain device put with the right
    sharding).

The synthetic distribution is a deterministic Zipf-over-vocab with a
shifted-window structure so that next-token prediction has learnable signal
(the smoke trainer's loss must *drop*, proving the whole path end-to-end).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    num_shards: int = 1
    seed: int = 0


class SyntheticLM:
    """data[shard].batch(step) -> dict(tokens, labels) of np.int32."""

    def __init__(self, cfg: DataConfig, shard_id: int = 0):
        assert cfg.global_batch % cfg.num_shards == 0
        self.cfg = cfg
        self.shard_id = shard_id
        self.local_batch = cfg.global_batch // cfg.num_shards

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + self.shard_id) * 1_000_003 + step)
        # Markov-ish stream over a capped alphabet: next = (3*prev + noise)
        # mod A with Zipf(2.5) noise.  A << vocab keeps the number of
        # transitions small, so the smoke trainer's loss visibly drops in
        # tens of steps (tests assert this end-to-end learning signal).
        b, t = self.local_batch, cfg.seq_len
        alphabet = min(64, cfg.vocab_size)
        noise = rng.zipf(2.5, size=(b, t)).astype(np.int64)
        toks = np.zeros((b, t + 1), np.int64)
        toks[:, 0] = rng.integers(0, alphabet, size=b)
        for i in range(1, t + 1):
            toks[:, i] = (3 * toks[:, i - 1] + noise[:, i - 1]) % alphabet
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }


def global_batch_spec(cfg: DataConfig):
    """ShapeDtypeStructs of the global batch (dry-run input stand-ins)."""
    import jax
    import jax.numpy as jnp

    return {
        "tokens": jax.ShapeDtypeStruct((cfg.global_batch, cfg.seq_len),
                                       jnp.int32),
        "labels": jax.ShapeDtypeStruct((cfg.global_batch, cfg.seq_len),
                                       jnp.int32),
    }
