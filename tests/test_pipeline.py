"""Pipeline parallelism: staged execution == sequential layer execution."""
import json
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_pipeline_matches_sequential():
    prog = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            + textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_apply, split_microbatches

        S, M, mb, d = 4, 8, 2, 16
        mesh = jax.make_mesh((S,), ('stage',))
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (S, d, d)) * 0.3
        b = jax.random.normal(jax.random.PRNGKey(1), (S, d)) * 0.1
        params = {'w': w, 'b': b}

        def stage_fn(p, x):
            return jnp.tanh(x @ p['w'] + p['b'])

        x = jax.random.normal(jax.random.PRNGKey(2), (M * mb, d))
        xm = split_microbatches(x, M)

        out_pp = pipeline_apply(stage_fn, params, xm, mesh=mesh)
        out_pp = out_pp.reshape(M * mb, d)

        ref = x
        for s in range(S):
            ref = stage_fn({'w': w[s], 'b': b[s]}, ref)
        diff = float(jnp.max(jnp.abs(out_pp - ref)))
        print(json.dumps({'diff': diff}))
    """))
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["diff"] < 1e-5
