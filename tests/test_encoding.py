"""Packed (job_id, payload) encoding edge cases (DESIGN.md section 8).

Boundary coverage the batch tests in test_server.py skip: the last legal
job id, naturals at the zigzag/payload-width boundary, the host-side
admission validator at its exact limits, and a hypothesis round-trip
property over the full legal domain.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.server.encoding import (MAX_JOBS, MAX_NATURAL, PAYLOAD_BITS,
                                   check_job_fits, pack, unpack_job,
                                   unpack_natural, unzigzag, zigzag)


def _roundtrip(job_id, naturals):
    packed = pack(job_id, jnp.asarray(naturals, jnp.int32))
    return (np.asarray(unpack_job(packed)),
            np.asarray(unpack_natural(packed)),
            np.asarray(packed))


def test_last_job_id_roundtrips():
    """job_id == MAX_JOBS - 1 fills every job bit; payload must survive."""
    naturals = np.array([0, 1, -1, 5, -5, 1000, -1000], np.int32)
    jobs, nats, packed = _roundtrip(MAX_JOBS - 1, naturals)
    assert (jobs == MAX_JOBS - 1).all()
    assert np.array_equal(nats, naturals)
    # the sign bit stays clear even with all job bits set (queue-orderable)
    assert (packed >= 0).all()


def test_payload_boundary_naturals():
    """Largest magnitudes whose zigzag still fits PAYLOAD_BITS.

    zigzag maps t -> 2t (t >= 0) and -t -> 2|t|-1, so the width boundary is
    +MAX_NATURAL / -(MAX_NATURAL + 1): both must round-trip losslessly for
    every job id that borders the payload field.
    """
    edge = np.array([MAX_NATURAL, -MAX_NATURAL, -(MAX_NATURAL + 1)],
                    np.int32)
    assert int(zigzag(jnp.int32(-(MAX_NATURAL + 1)))) == (1 << PAYLOAD_BITS) - 1
    for job_id in (0, 1, MAX_JOBS - 1):
        jobs, nats, _ = _roundtrip(job_id, edge)
        assert (jobs == job_id).all()
        assert np.array_equal(nats, edge)


def test_beyond_boundary_wraps_not_corrupts_job_bits():
    """One past the payload boundary is lossy (documented), but the
    overflow must stay confined to the payload field — the tenant id can
    never be corrupted by a bad natural."""
    too_big = jnp.int32(MAX_NATURAL + 1)          # zigzag needs 25 bits
    packed = pack(MAX_JOBS - 1, too_big)
    assert int(unpack_job(packed)) == MAX_JOBS - 1
    assert int(unpack_natural(packed)) != int(too_big)


def test_check_job_fits_boundaries():
    # largest admissible graph: coloring naturals reach ±(n + 1)
    check_job_fits(0, MAX_NATURAL - 1)
    check_job_fits(MAX_JOBS - 1, MAX_NATURAL - 1)
    with pytest.raises(ValueError, match="too large"):
        check_job_fits(0, MAX_NATURAL)
    with pytest.raises(ValueError, match="out of range"):
        check_job_fits(MAX_JOBS, 16)
    with pytest.raises(ValueError, match="out of range"):
        check_job_fits(-1, 16)


def test_check_job_fits_granularity_shrinks_the_graph_bound():
    """Chunk codes are vertex ids shifted by the codec's width bits, so each
    doubling of the granularity roughly halves the admissible graph."""
    check_job_fits(0, (MAX_NATURAL >> 2) - 2, granularity=4)
    with pytest.raises(ValueError, match="granularity 4"):
        check_job_fits(0, MAX_NATURAL - 1, granularity=4)
    # granularity 1 keeps the original boundary exactly
    check_job_fits(0, MAX_NATURAL - 1, granularity=1)


def test_zigzag_boundary_bijection():
    t = jnp.asarray([0, -1, 1, MAX_NATURAL, -MAX_NATURAL,
                     -(MAX_NATURAL + 1)], jnp.int32)
    z = zigzag(t)
    assert int(jnp.max(z)) < (1 << PAYLOAD_BITS)
    assert np.array_equal(np.asarray(unzigzag(z)), np.asarray(t))


# ------------------------------------------------------------ property test
def test_roundtrip_property():
    """Hypothesis-gated (like test_queue/test_frontier): pack∘unpack is the
    identity over the entire legal (job_id, natural) domain."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=200, deadline=None)
    @given(job_id=st.integers(0, MAX_JOBS - 1),
           naturals=st.lists(
               st.integers(-(MAX_NATURAL + 1), MAX_NATURAL),
               min_size=1, max_size=64))
    def inner(job_id, naturals):
        jobs, nats, packed = _roundtrip(job_id, naturals)
        assert (jobs == job_id).all()
        assert np.array_equal(nats, np.asarray(naturals, np.int32))
        assert (packed >= 0).all()

    inner()
