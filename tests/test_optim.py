"""Optimizer math vs hand-rolled references + schedule/clip behaviour."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adafactor, adamw


def test_adamw_matches_manual_reference():
    cfg = adamw.AdamWConfig(lr=1e-2, beta1=0.9, beta2=0.999, eps=1e-8,
                            weight_decay=0.0, clip_norm=1e9,
                            warmup_steps=0, total_steps=1, min_lr_frac=1.0)
    params = {"w": jnp.array([1.0, -2.0, 3.0])}
    state = adamw.init(params)
    g = {"w": jnp.array([0.1, -0.2, 0.3])}
    new_params, state, _ = adamw.update(cfg, params, g, state)
    # manual AdamW, step 1 (bias-corrected)
    m = 0.1 * np.array([0.1, -0.2, 0.3])
    v = 0.001 * np.array([0.1, -0.2, 0.3]) ** 2
    mhat, vhat = m / 0.1, v / 0.001
    want = np.array([1.0, -2.0, 3.0]) - 1e-2 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_params["w"]), want, rtol=1e-5)


def test_adamw_weight_decay_decoupled():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.5, clip_norm=1e9,
                            warmup_steps=0, total_steps=1, min_lr_frac=1.0)
    params = {"w": jnp.array([2.0])}
    state = adamw.init(params)
    new_params, _, _ = adamw.update(cfg, params, {"w": jnp.array([0.0])},
                                    state)
    np.testing.assert_allclose(np.asarray(new_params["w"]), [2.0 - 0.1 * 0.5 * 2.0])


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    assert abs(float(total) - 1.0) < 1e-5


def test_schedule_warmup_and_cosine():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                            min_lr_frac=0.1)
    assert float(adamw.schedule(cfg, jnp.int32(5))) == 0.5
    assert abs(float(adamw.schedule(cfg, jnp.int32(10))) - 1.0) < 1e-6
    assert abs(float(adamw.schedule(cfg, jnp.int32(110))) - 0.1) < 1e-3


def test_adamw_converges_quadratic():
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                            total_steps=200, min_lr_frac=1.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw.init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = adamw.update(cfg, params, g, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_adafactor_converges_quadratic_matrix():
    cfg = adafactor.AdafactorConfig(lr=0.1)
    params = {"w": jnp.ones((8, 4)) * 3.0}
    state = adafactor.init(params)
    assert state.vr["w"].shape == (8,)   # factored rows
    assert state.vc["w"].shape == (4,)   # factored cols
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = adafactor.update(cfg, params, g, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_adafactor_state_is_factored_smaller():
    params = {"w": jnp.ones((512, 256))}
    af = adafactor.init(params)
    aw = adamw.init(params)
    af_elems = sum(x.size for x in jax.tree.leaves((af.vr, af.vc)))
    aw_elems = sum(x.size for x in jax.tree.leaves((aw.m, aw.v)))
    assert af_elems < aw_elems / 100
