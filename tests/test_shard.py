"""Sharded multi-device scheduler (DESIGN.md section 10).

Three tiers:

  * pure host math (partitioner, ownership, donation planning) — always;
  * degenerate 1-shard runs through the full shard_map machinery — always
    (a mesh of one device is valid);
  * real 8-device runs — spawned in subprocesses that force
    ``--xla_force_host_platform_device_count=8`` *before* jax initializes,
    so they run under plain tier-1 too (the in-process route would need the
    flag on the whole session; the CI ``multidevice`` job provides exactly
    that for tests/test_distributed_multidev.py).

The 8-device assertions are the acceptance bar: BFS depths and coloring
results bit-identical to the 1-device run, PageRank within tolerance,
every task landing on its owner (``mis_routed == 0``), stealing moving work
off a skewed shard without corrupting results, and the psum'd stop
predicate keeping drained devices in the collective until global
completion.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import SchedulerConfig
from repro.graph.generators import grid2d, rmat
from repro.runtime import build_program
from repro.shard import (block_bounds, block_size, owner_of,
                         partition_graph, plan_donations, run_sharded,
                         split_seeds)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------------------- host math
def test_blocks_partition_the_vertex_space():
    for n, s in [(128, 8), (9, 8), (7, 3), (1, 4), (256, 1)]:
        covered = []
        for d in range(s):
            lo, hi = block_bounds(d, n, s)
            covered.extend(range(lo, hi))
        assert covered == list(range(n)), (n, s)
        v = np.arange(n)
        owners = np.asarray(owner_of(v, n, s))
        for d in range(s):
            lo, hi = block_bounds(d, n, s)
            assert (owners[lo:hi] == d).all()


def test_partition_matches_global_csr():
    g = rmat(6, edge_factor=8, seed=3)
    n = g.num_vertices
    rp = np.asarray(g.row_ptr)
    col = np.asarray(g.col_idx)
    for s in (1, 2, 8):
        for halo in (False, True):
            parts = partition_graph(g, s, halo=halo)
            assert parts.halo == (halo and s > 1)
            assert sum(parts.edges_per_shard) == g.num_edges
            lrp = np.asarray(parts.row_ptr)
            lcol = np.asarray(parts.col_idx)
            for d in range(s):
                rows = list(range(*block_bounds(d, n, s)))
                if parts.halo:
                    rows += list(range(*block_bounds((d - 1) % s, n, s)))
                for v in rows:
                    deg = rp[v + 1] - rp[v]
                    assert lrp[d, v + 1] - lrp[d, v] == deg, (s, halo, d, v)
                    np.testing.assert_array_equal(
                        lcol[d, lrp[d, v]:lrp[d, v] + deg],
                        col[rp[v]:rp[v] + deg])


def test_partition_rejects_bad_shard_count():
    g = rmat(4, edge_factor=4, seed=0)
    with pytest.raises(ValueError, match="num_shards"):
        partition_graph(g, 0)


def test_split_seeds_places_tasks_on_owners():
    n, s = 40, 4
    seeds = np.arange(n, dtype=np.int32)
    buf, counts = split_seeds(seeds, n, s)
    assert int(np.asarray(counts).sum()) == n
    for d in range(s):
        lo, hi = block_bounds(d, n, s)
        got = np.sort(np.asarray(buf[d, :int(counts[d])]))
        np.testing.assert_array_equal(got, np.arange(lo, hi))
    # coloring tasks are ±(v+1): ownership follows the decoded vertex
    ctasks = np.array([1, -1, 11, -11, 40, -40], np.int32)  # v = 0,0,10,10,39,39
    buf, counts = split_seeds(ctasks, n, s,
                              task_vertex=lambda t: jnp.abs(t) - 1)
    assert list(np.asarray(counts)) == [2, 2, 0, 2]


def test_plan_donations_balanced_is_noop():
    give = np.asarray(plan_donations(jnp.asarray([10, 10, 10, 10]),
                                     threshold=0.5, chunk=8))
    assert (give == 0).all()


def test_plan_donations_rebalances_a_skewed_drain():
    """Skewed occupancy converges: a drain with donations finishes sooner.

    Models the driver's dynamics (each shard pops a wavefront per round,
    donations move queue mass one ring hop) on the round level: all work on
    shard 0, stealing must cut rounds-to-drain vs. no stealing.
    """
    s, w, chunk = 8, 16, 16

    def drain_rounds(steal: bool, start=400, max_rounds=200):
        sizes = np.zeros(s, np.int64)
        sizes[0] = start
        rounds = 0
        while sizes.sum() > 0 and rounds < max_rounds:
            if steal:
                give = np.asarray(plan_donations(
                    jnp.asarray(sizes, jnp.int32), 0.5, chunk),
                    dtype=np.int64)
                sizes = sizes - give + np.roll(give, 1)
            sizes = np.maximum(sizes - w, 0)
            rounds += 1
        return rounds

    without = drain_rounds(False)
    with_steal = drain_rounds(True)
    assert with_steal < without, (with_steal, without)


def test_plan_donations_respects_caps():
    sizes = jnp.asarray([100, 0, 0, 0], jnp.int32)
    give = np.asarray(plan_donations(sizes, threshold=0.5, chunk=8))
    assert give[0] <= 8          # chunk cap
    assert (give[1:] == 0).all()  # no surplus elsewhere
    # donation never exceeds the successor's deficit
    sizes = jnp.asarray([100, 24, 0, 0], jnp.int32)
    give = np.asarray(plan_donations(sizes, threshold=0.1, chunk=64))
    mean_ceil = -(-int(np.asarray(sizes).sum()) // 4)
    assert give[0] <= mean_ceil - 24


# -------------------------------------------- 1-shard runs (any device count)
def test_one_shard_run_matches_plain_bfs():
    """num_shards=1 drives the full shard_map/exchange/merge machinery on a
    single-device mesh; distances must equal the plain scheduler's."""
    from repro.algorithms.bfs import bfs_speculative

    g = rmat(6, edge_factor=8, seed=1)
    cfg = SchedulerConfig(num_workers=16, fetch_size=1)
    ref, _ = bfs_speculative(g, 0, cfg)
    program = build_program("bfs", g, cfg, params={"source": 0})
    state, stats = run_sharded(program, g, cfg)
    np.testing.assert_array_equal(np.asarray(state.dist), np.asarray(ref))
    assert stats.mis_routed == 0
    assert stats.exchanged == 0    # one shard: nothing to ship
    assert stats.dropped == 0


def test_one_shard_discrete_driver_traces():
    from repro.algorithms.bfs import bfs_bsp

    g = grid2d(8, 8, seed=0)
    ref, _ = bfs_bsp(g, 0)
    cfg = SchedulerConfig(num_workers=16, fetch_size=1, persistent=False)
    program = build_program("bfs", g, cfg, params={"source": 0})
    trace = []
    state, stats = run_sharded(program, g, cfg, trace=trace)
    np.testing.assert_array_equal(np.asarray(state.dist), np.asarray(ref))
    assert len(trace) == stats.rounds
    assert all(t["exchanged"] == 0 for t in trace)


# --------------------------------------------------- 8-device subprocesses
def _run(body: str, timeout=900) -> dict:
    """Run ``body`` in a subprocess with 8 forced host devices; expect JSON
    on the last stdout line."""
    prog = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            "os.environ.setdefault('JAX_PLATFORMS', 'cpu')\n"
            + textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_multidevice_parity_and_routing():
    """8 shards: BFS/coloring bit-identical to the 1-device run, PageRank
    within tolerance, every task on its owner, no overflow anywhere."""
    res = _run("""
        import json
        import numpy as np
        from repro.algorithms.bfs import bfs_bsp, bfs_speculative
        from repro.algorithms.coloring import coloring_async, validate_coloring
        from repro.algorithms.pagerank import pagerank_async, pagerank_reference
        from repro.core import SchedulerConfig
        from repro.graph.generators import rmat
        from repro import shard as SH
        from repro.runtime import build_program

        g = rmat(7, edge_factor=8, seed=2)
        n = g.num_vertices
        out = {}

        # BFS: depths are exact shortest hops on any schedule — the sharded
        # result must be bit-identical to both the BSP oracle and the plain
        # 1-device speculative run.
        ref, _ = bfs_bsp(g, 0)
        d1, _ = bfs_speculative(g, 0, SchedulerConfig(num_workers=32))
        bfs_ok, bfs_exchanged, bfs_mis = [], [], []
        for s in (2, 8):
            cfg = SchedulerConfig(num_workers=32, num_shards=s)
            d, info = bfs_speculative(g, 0, cfg)
            bfs_ok.append(bool((np.asarray(d) == np.asarray(ref)).all()
                               and (np.asarray(d) == np.asarray(d1)).all()))
            bfs_exchanged.append(info['exchanged'])
            bfs_mis.append(info['mis_routed'] + info['dropped'])
        out['bfs_ok'] = bfs_ok
        out['bfs_exchanged'] = bfs_exchanged
        out['bfs_mis'] = bfs_mis

        # coloring: the unfused sharded body reads epoch-start colors, so a
        # full-width drain is schedule-identical for every shard count
        W = 2 * n
        colors = {}
        for s in (1, 2, 8):
            cfg = SchedulerConfig(num_workers=W, num_shards=s)
            prog = build_program("coloring", g, cfg)
            st, stats = SH.run_sharded(prog, g, cfg)
            colors[s] = np.asarray(st.colors)
            out['color_mis_%d' % s] = stats.mis_routed + stats.dropped
        out['color_valid'] = bool(validate_coloring(g, colors[8]))
        out['color_identical'] = bool((colors[8] == colors[1]).all()
                                      and (colors[2] == colors[1]).all())

        # pagerank: schedule differs across meshes; ranks agree within the
        # eps*deg slack of the residual formulation
        ref_pr = np.asarray(pagerank_reference(g, iters=300))
        cfg = SchedulerConfig(num_workers=16, num_shards=8)
        rank, info = pagerank_async(g, cfg, eps=1e-6)
        out['pr_err'] = float(np.abs(np.asarray(rank) - ref_pr).max())
        out['pr_mis'] = info['mis_routed'] + info['dropped']
        print(json.dumps(out))
    """)
    assert all(res["bfs_ok"]), res
    assert all(m == 0 for m in res["bfs_mis"]), res
    assert res["bfs_exchanged"][1] > 0     # 8 shards really exchanged tasks
    assert res["color_valid"] and res["color_identical"], res
    assert res["color_mis_8"] == 0
    assert res["pr_err"] < 1e-4, res
    assert res["pr_mis"] == 0


def test_multidevice_steal_and_global_stop():
    """All seeds on shard 0: the psum'd stop predicate must keep the other
    seven (initially empty) shards in the drain until their blocks are
    reached, and stealing must move tasks without breaking ownership."""
    res = _run("""
        import json
        import numpy as np
        from repro.algorithms.bfs import bfs_bsp, bfs_speculative
        from repro.core import SchedulerConfig
        from repro.graph.generators import grid2d

        g = grid2d(16, 16, seed=0)   # vertex 0 sits in shard 0's block
        n = g.num_vertices
        ref, _ = bfs_bsp(g, 0)
        out = {}

        # no stealing: a drained shard may only receive work via routing —
        # if any shard bailed early its whole block would stay INF
        cfg = SchedulerConfig(num_workers=8, num_shards=8)
        d, info = bfs_speculative(g, 0, cfg)
        d = np.asarray(d)
        out['stop_ok'] = bool((d == np.asarray(ref)).all())
        INF = np.int32(0x7FFFFFFF)
        out['all_blocks_reached'] = bool((d < INF).all())
        out['exchanged'] = info['exchanged']

        # stealing on: donations happen, results stay exact, ownership
        # (owner or ring predecessor for stolen tasks) never violated
        cfg_s = SchedulerConfig(num_workers=8, num_shards=8,
                                steal_threshold=0.5, steal_chunk=16)
        ds, si = bfs_speculative(g, 0, cfg_s)
        out['steal_ok'] = bool((np.asarray(ds) == np.asarray(ref)).all())
        out['donated'] = si['donated']
        out['steal_rounds'] = si['steal_rounds']
        out['steal_mis'] = si['mis_routed'] + si['dropped']

        # discrete driver: per-round telemetry, same answer
        cfg_d = SchedulerConfig(num_workers=8, num_shards=8,
                                persistent=False, steal_threshold=0.5,
                                steal_chunk=16)
        trace = []
        dd, di = bfs_speculative(g, 0, cfg_d, trace=trace)
        out['discrete_ok'] = bool((np.asarray(dd) == np.asarray(ref)).all())
        out['discrete_rounds'] = di['rounds']
        out['trace_len'] = len(trace)
        out['trace_has_exchange'] = bool(
            sum(t['exchanged'] for t in trace) > 0)
        print(json.dumps(out))
    """)
    assert res["stop_ok"] and res["all_blocks_reached"], res
    assert res["exchanged"] > 0
    assert res["steal_ok"], res
    assert res["donated"] > 0 and res["steal_rounds"] > 0, res
    assert res["steal_mis"] == 0, res
    assert res["discrete_ok"], res
    assert res["trace_len"] == res["discrete_rounds"]
    assert res["trace_has_exchange"]


def test_multidevice_server_mixes_sharded_and_fused_jobs():
    """TaskServer batch with shards>1 BFS jobs alongside fused tenants."""
    res = _run("""
        import json
        import numpy as np
        from repro.algorithms.bfs import bfs_bsp
        from repro.core import SchedulerConfig
        from repro.graph.generators import grid2d, rmat
        from repro.server import JobRegistry, JobSpec, TaskServer

        reg = JobRegistry()
        reg.register_graph('rmat', rmat(6, edge_factor=8, seed=1))
        reg.register_graph('grid', grid2d(8, 8, seed=0))
        server = TaskServer(reg, num_lanes=4,
                            config=SchedulerConfig(num_workers=16))
        jid_sh = server.submit(JobSpec('bfs', 'rmat', {'source': 3},
                                       shards=8))
        jid_f1 = server.submit(JobSpec('coloring', 'grid'))
        jid_f2 = server.submit(JobSpec('bfs', 'grid', {'source': 0}))
        result = server.run()
        ref, _ = bfs_bsp(reg.graph('rmat'), 3)
        ref2, _ = bfs_bsp(reg.graph('grid'), 0)
        out = {
            'sharded_ok': bool((result.results[jid_sh]
                                == np.asarray(ref)).all()),
            'fused_ok': bool((result.results[jid_f2]
                              == np.asarray(ref2)).all()),
            'sharded_jobs': result.stats.sharded_jobs,
            'sharded_rounds': result.stats.sharded_rounds,
            'fused_rounds': result.stats.rounds,
            'sh_items': result.telemetry[jid_sh].items_processed,
        }
        print(json.dumps(out))
    """)
    assert res["sharded_ok"] and res["fused_ok"], res
    assert res["sharded_jobs"] == 1
    assert res["sharded_rounds"] > 0
    assert res["fused_rounds"] > 0      # fused tenants still ran rounds
    assert res["sh_items"] > 0


def test_owner_coords_factorizes_owner_of():
    """2-D ownership is the linear block owner split row-major: owner_of
    == row * cols + col for every vertex, on both checked mesh layouts."""
    from repro.shard import owner_coords

    n = 97
    vids = jnp.arange(n, dtype=jnp.int32)
    lin = np.asarray(owner_of(vids, n, 8))
    for rows, cols in ((2, 4), (4, 2)):
        r, c = owner_coords(vids, n, rows, cols)
        np.testing.assert_array_equal(np.asarray(r) * cols + np.asarray(c),
                                      lin)
        assert int(np.asarray(r).max()) == rows - 1
        assert int(np.asarray(c).max()) == cols - 1


def test_delivered_width_covers_both_hops():
    """The overlap staging buffer must hold everything one round can
    deliver: S*w on the ring, C*w + R*C*w on a 2-D mesh (hop-1 width w
    per col peer kept locally + hop-2 width C*w per row peer)."""
    from repro.shard import delivered_width

    assert delivered_width(5, 8) == 40
    assert delivered_width(5, 8, (2, 4)) == 4 * 5 + 2 * 4 * 5
    assert delivered_width(5, 8, (4, 2)) == 2 * 5 + 4 * 2 * 5


def test_multidevice_mesh2d_parity_and_per_axis_meters():
    """2-D ('row','col') meshes, strict delivery: BFS and coloring are
    bit-identical to the 1-device run on both 2x4 and 4x2 layouts,
    PageRank agrees within the residual formulation's slack, every task
    lands on its owner, and the exchange meters split by axis."""
    res = _run("""
        import json
        import numpy as np
        from repro.algorithms.coloring import validate_coloring
        from repro.algorithms.pagerank import pagerank_reference
        from repro.core import SchedulerConfig
        from repro.graph.generators import rmat
        from repro.runtime import build_program, execute

        g = rmat(7, edge_factor=8, seed=2)
        n = g.num_vertices
        out = {}

        ref_bfs = np.asarray(execute(
            build_program("bfs", g, SchedulerConfig(num_workers=32),
                          params={"source": 0}),
            g, SchedulerConfig(num_workers=32)).state.dist)
        cfg_c1 = SchedulerConfig(num_workers=2 * n)
        ref_col = np.asarray(execute(
            build_program("coloring", g, cfg_c1), g, cfg_c1).state.colors)
        ref_pr = np.asarray(pagerank_reference(g, iters=300))

        for mesh in ((2, 4), (4, 2)):
            tag = "%dx%d" % mesh
            cfg = SchedulerConfig(num_workers=32, num_shards=8,
                                  mesh_shape=mesh)
            r = execute(build_program("bfs", g, cfg, params={"source": 0}),
                        g, cfg)
            info = r.info
            out["bfs_ok_" + tag] = bool(
                (np.asarray(r.state.dist) == ref_bfs).all())
            out["mis_" + tag] = info["mis_routed"]
            out["row_" + tag] = info["exchanged_row"]
            out["col_" + tag] = info["exchanged_col"]
            out["exch_" + tag] = info["exchanged"]
            out["pay_" + tag] = info["payload_ints"]
            out["pad_" + tag] = info["padding_ints"]

            cfg_c = SchedulerConfig(num_workers=2 * n, num_shards=8,
                                    mesh_shape=mesh)
            rc = execute(build_program("coloring", g, cfg_c), g, cfg_c)
            out["col_ok_" + tag] = bool(
                (np.asarray(rc.state.colors) == ref_col).all()
                and validate_coloring(g, np.asarray(rc.state.colors)))

        cfg_pr = SchedulerConfig(num_workers=16, num_shards=8,
                                 mesh_shape=(2, 4))
        rp = execute(build_program("pagerank", g, cfg_pr,
                                   params={"eps": 1e-6}), g, cfg_pr)
        out["pr_err"] = float(
            np.abs(np.asarray(rp.state.rank) - ref_pr).max())
        print(json.dumps(out))
    """)
    for tag in ("2x4", "4x2"):
        assert res["bfs_ok_" + tag], res
        assert res["col_ok_" + tag], res
        assert res["mis_" + tag] == 0, res
        # the exchange really decomposed into two per-axis hops, and the
        # padding meter accounts for everything the payload doesn't
        assert res["row_" + tag] > 0 and res["col_" + tag] > 0, res
        assert res["pay_" + tag] > 0 and res["pad_" + tag] > 0, res
    # axis split depends on layout: more col-peers in 2x4, more row-peers
    # in 4x2 — both decompositions route the same distinct tasks
    assert res["col_2x4"] > res["row_2x4"], res
    assert res["row_4x2"] > res["col_4x2"], res
    assert res["pr_err"] < 1e-4, res


def test_multidevice_mesh2d_overlap_and_compression():
    """One-round-deferred delivery and the wire codec, separately and
    together, on both 2-D layouts: BFS stays bit-identical, overlap really
    stages deliveries (deferred > 0 on overlap rounds), and compression
    meters strictly fewer wire ints than the raw payload."""
    res = _run("""
        import json
        import numpy as np
        from repro.core import SchedulerConfig
        from repro.graph.generators import rmat
        from repro.runtime import build_program, execute

        g = rmat(7, edge_factor=8, seed=2)
        ref = np.asarray(execute(
            build_program("bfs", g, SchedulerConfig(num_workers=32),
                          params={"source": 0}),
            g, SchedulerConfig(num_workers=32)).state.dist)

        out = []
        for mesh in ((2, 4), (4, 2)):
            for defer in (0, 1):
                for comp in (False, True):
                    cfg = SchedulerConfig(num_workers=32, num_shards=8,
                                          mesh_shape=mesh,
                                          defer_rounds=defer, compress=comp)
                    r = execute(build_program("bfs", g, cfg,
                                              params={"source": 0}), g, cfg)
                    info = r.info
                    out.append({
                        "mesh": list(mesh), "defer": defer, "comp": comp,
                        "ok": bool((np.asarray(r.state.dist) == ref).all()),
                        "mis": info["mis_routed"],
                        "payload": info["payload_ints"],
                        "wire": info["wire_ints"],
                        "deferred": info["deferred"],
                        "overlap": info["overlap_rounds"]})
        print(json.dumps(out))
    """)
    for row in res:
        assert row["ok"] and row["mis"] == 0, row
        if row["comp"]:
            assert 0 < row["wire"] < row["payload"], row
        else:
            assert row["wire"] > row["payload"], row   # raw slots incl. padding
        if row["defer"]:
            assert row["deferred"] > 0 and row["overlap"] > 0, row
        else:
            assert row["deferred"] == 0 and row["overlap"] == 0, row
