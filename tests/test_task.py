"""Packed (vertex, width) chunk tasks — core/task.py (DESIGN.md section 12).

Acceptance bars:

  * the codec is a bijection over its legal (vertex, width) domain, the
    G = 1 codec is the bit-for-bit identity, and no legal encoding — plain
    or sign-wrapped (coloring) or server-packed — ever collides with the
    queue's EMPTY sentinel;
  * the push-side coalescer forms exactly the aligned, contiguous,
    threshold-respecting, owner-pure chunks and counts its splits;
  * chunk expansion (degree-sum LBS + member-row localization) produces
    the same (src, nbr) edge set as flattening the chunk into width-1
    tasks, on both kernel backends, bit-identically.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (ChunkCodec, EMPTY, MAX_GRANULARITY, chunk_seeds,
                        coalesce_chunks, expand_merge_path, flatten_chunks)
from repro.core.task import ChunkCodec as _CC
from repro.graph.generators import grid2d, rmat


@pytest.fixture(scope="module")
def g_mesh():
    return grid2d(8, 8, seed=0)


@pytest.fixture(scope="module")
def g_sf():
    return rmat(6, edge_factor=8, seed=3)


# ------------------------------------------------------------------- codec
def test_identity_codec_is_bit_for_bit():
    c = ChunkCodec(1)
    assert c.width_bits == 0
    v = jnp.arange(-4, 100, dtype=jnp.int32)  # negatives: coloring codes
    assert np.array_equal(np.asarray(c.encode(v, jnp.ones_like(v))),
                          np.asarray(v))
    assert np.array_equal(np.asarray(c.head(v)), np.asarray(v))
    assert (np.asarray(c.width(v)) == 1).all()


def test_codec_bounds():
    with pytest.raises(ValueError, match="granularity"):
        ChunkCodec(0)
    with pytest.raises(ValueError, match="granularity"):
        ChunkCodec(MAX_GRANULARITY + 1)
    assert ChunkCodec(MAX_GRANULARITY).width_bits == 6


def test_roundtrip_and_empty_safety_property():
    """pack∘unpack is the identity over the legal domain and the encoding
    can never produce the EMPTY sentinel — raw, sign-wrapped (coloring's
    ±(task+1)), or server-packed (zigzag payload is non-negative)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    from repro.server.encoding import pack, unpack_natural

    @settings(max_examples=200, deadline=None)
    @given(st.integers(1, MAX_GRANULARITY), st.data())
    def inner(g, data):
        c = _CC(g)
        # max vertex id that survives the server payload at this width
        vmax = min((1 << 20) - 1, (1 << (23 - c.width_bits)) - 2)
        v = data.draw(st.lists(st.integers(0, vmax), min_size=1,
                               max_size=32))
        w = data.draw(st.lists(st.integers(1, g), min_size=len(v),
                               max_size=len(v)))
        v = jnp.asarray(v, jnp.int32)
        w = jnp.asarray(w, jnp.int32)
        t = c.encode(v, w)
        assert np.array_equal(np.asarray(c.head(t)), np.asarray(v))
        assert np.array_equal(np.asarray(c.width(t)), np.asarray(w))
        assert (np.asarray(t) >= 0).all()          # never EMPTY (< 0)
        signed = jnp.concatenate([t + 1, -(t + 1)])  # coloring wrap
        assert (np.asarray(signed) != int(EMPTY)).all()
        packed = pack(3, t)
        assert (np.asarray(packed) != int(EMPTY)).all()
        assert np.array_equal(np.asarray(unpack_natural(packed)),
                              np.asarray(t))

    inner()


# --------------------------------------------------------------- coalescer
def _decode_all(codec, items, mask):
    h, w = codec.decode(items)
    return [(int(a), int(b)) for a, b, m in
            zip(np.asarray(h), np.asarray(w), np.asarray(mask)) if m]


def test_coalesce_forms_aligned_runs(g_mesh):
    c = ChunkCodec(4)
    vids = jnp.asarray([0, 1, 2, 3, 8, 9, 12, 20, 22, 23, 7, 7],
                       jnp.int32)
    mask = jnp.asarray([True] * 10 + [False] * 2)
    items, out, splits = coalesce_chunks(vids, mask, c, g_mesh.row_ptr)
    got = _decode_all(c, items, out)
    # [0..3] full aligned run; [8,9] partial run; 12 single; [20,22,23]
    # not contiguous in its window -> three singles; masked lanes dropped
    assert got == [(0, 4), (8, 2), (12, 1), (20, 1), (22, 1), (23, 1)]
    assert int(splits) == 0
    # vertex conservation: widths sum to the number of marked vertices
    _, w = c.decode(items)
    assert int(jnp.sum(jnp.where(out, w, 0))) == 10


def test_coalesce_split_threshold_counts(g_mesh):
    """A window over the degree-sum cap degrades to singles and is counted
    as one split — the granularity dial's engagement meter."""
    c = ChunkCodec(4)
    vids = jnp.asarray([0, 1, 2, 3], jnp.int32)
    mask = jnp.ones((4,), bool)
    degsum = int(g_mesh.row_ptr[4] - g_mesh.row_ptr[0])
    items, out, splits = coalesce_chunks(vids, mask, c, g_mesh.row_ptr,
                                         split_threshold=degsum - 1)
    assert _decode_all(c, items, out) == [(0, 1), (1, 1), (2, 1), (3, 1)]
    assert int(splits) == 1
    items, out, splits = coalesce_chunks(vids, mask, c, g_mesh.row_ptr,
                                         split_threshold=degsum)
    assert _decode_all(c, items, out) == [(0, 4)]
    assert int(splits) == 0


def test_coalesce_respects_owner_block(g_mesh):
    """A run crossing a shard-ownership boundary must not form: routing
    keys off the chunk head and the owner's CSR slice ends at the block."""
    c = ChunkCodec(4)
    vids = jnp.asarray([4, 5, 6, 7], jnp.int32)
    mask = jnp.ones((4,), bool)
    items, out, splits = coalesce_chunks(vids, mask, c, g_mesh.row_ptr,
                                         owner_block=6)
    assert _decode_all(c, items, out) == [(4, 1), (5, 1), (6, 1), (7, 1)]
    assert int(splits) == 1
    items, out, _ = coalesce_chunks(vids, mask, c, g_mesh.row_ptr,
                                    owner_block=8)
    assert _decode_all(c, items, out) == [(4, 4)]


def test_coalesce_identity_at_g1(g_mesh):
    c = ChunkCodec(1)
    vids = jnp.asarray([5, 9, 0, 13], jnp.int32)
    mask = jnp.asarray([True, False, True, True])
    items, out, splits = coalesce_chunks(vids, mask, c, g_mesh.row_ptr)
    assert np.array_equal(np.asarray(items),
                          np.asarray(jnp.where(mask, vids, 0)))
    assert np.array_equal(np.asarray(out), np.asarray(mask))
    assert int(splits) == 0


# --------------------------------------------------------------- seeds
def test_chunk_seeds_greedy_and_bounded(g_mesh):
    c = ChunkCodec(4)
    seeds = chunk_seeds(np.arange(10), c, g_mesh.row_ptr)
    h, w = c.decode(jnp.asarray(seeds))
    assert [(int(a), int(b)) for a, b in zip(h, w)] == \
        [(0, 4), (4, 4), (8, 2)]
    # owner boundary at 6: greedy runs break there
    seeds = chunk_seeds(np.arange(10), c, g_mesh.row_ptr, owner_block=6)
    h, w = c.decode(jnp.asarray(seeds))
    assert [(int(a), int(b)) for a, b in zip(h, w)] == \
        [(0, 4), (4, 2), (6, 4)]
    # degree-sum threshold: corner vertex 0 has degree 2, inner ones 3-4
    deg0 = int(g_mesh.row_ptr[1] - g_mesh.row_ptr[0])
    deg1 = int(g_mesh.row_ptr[2] - g_mesh.row_ptr[1])
    seeds = chunk_seeds(np.arange(4), c, g_mesh.row_ptr,
                        split_threshold=deg0 + deg1)
    h, w = c.decode(jnp.asarray(seeds))
    assert int(w[0]) == 2 and int(h[0]) == 0
    # G = 1: raw vertex ids, untouched
    assert np.array_equal(chunk_seeds(np.arange(5), ChunkCodec(1),
                                      g_mesh.row_ptr),
                          np.arange(5, dtype=np.int32))


# ------------------------------------------------------- chunk expansion
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_chunk_expansion_matches_flattened_oracle(g_sf, backend):
    """Chunk degree-sum LBS + member-row localization covers exactly the
    edge set of the equivalent width-1 expansion, on both backends."""
    heads = jnp.asarray([0, 5, 17, 40, 0], jnp.int32)
    widths = jnp.asarray([4, 3, 1, 4, 1], jnp.int32)
    valid = jnp.asarray([True, True, True, True, False])
    budget = 4 * int(jnp.max(g_sf.degrees())) * 4
    ex = expand_merge_path(heads, valid, g_sf.row_ptr, g_sf.col_idx,
                           budget, backend=backend, widths=widths,
                           max_width=4)
    fv, fm, _ = flatten_chunks(heads, widths, valid, 4)
    ref = expand_merge_path(fv, fm, g_sf.row_ptr, g_sf.col_idx, budget,
                            backend=backend)
    assert int(ex.total) == int(ref.total) > 0
    got = sorted(zip(np.asarray(ex.src)[np.asarray(ex.valid)],
                     np.asarray(ex.nbr)[np.asarray(ex.valid)]))
    want = sorted(zip(np.asarray(ref.src)[np.asarray(ref.valid)],
                      np.asarray(ref.nbr)[np.asarray(ref.valid)]))
    assert got == want


def test_chunk_expansion_backend_parity(g_sf):
    heads = jnp.asarray([3, 10, 30], jnp.int32)
    widths = jnp.asarray([2, 4, 3], jnp.int32)
    valid = jnp.ones((3,), bool)
    budget = 256
    a = expand_merge_path(heads, valid, g_sf.row_ptr, g_sf.col_idx, budget,
                          backend="jnp", widths=widths, max_width=4)
    b = expand_merge_path(heads, valid, g_sf.row_ptr, g_sf.col_idx, budget,
                          backend="pallas", widths=widths, max_width=4)
    for x, y in zip(a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_flatten_chunks_identity_at_width1():
    heads = jnp.asarray([7, 2, 9], jnp.int32)
    valid = jnp.asarray([True, False, True])
    fv, fm, fo = flatten_chunks(heads, jnp.ones((3,), jnp.int32), valid, 1)
    assert np.array_equal(np.asarray(fv),
                          np.asarray(jnp.where(valid, heads, 0)))
    assert np.array_equal(np.asarray(fm), np.asarray(valid))
    assert np.array_equal(np.asarray(fo), np.arange(3))
