"""Observability layer (DESIGN.md section 15): ring, schema, exporters,
and the tracing-disabled-is-identity contract.

Four tiers:

  * **ring model** — the device TraceRing against a ``deque(maxlen=cap)``
    reference model: wraparound keeps exactly the newest ``capacity``
    rows oldest-first and reports the overwritten count, driven by
    hypothesis when available and by a seeded deterministic sweep always;
  * **schema/exporters** — the canonical metric kinds, the hand-rolled
    validators (including the bool-is-not-int trap), exact nearest-rank
    percentiles, atomic temp-then-rename writes, and the Chrome-trace
    layout (one pid per engine, metadata naming, logical round timebase);
  * **parity** — for every POLICY_GRID cell x granularity {1, 4} (the
    sharded cells on a degenerate 1-device mesh, tier-1 safe), running
    with ``trace=Trace()`` returns bit-identical results/stats/info to
    ``trace=None`` while collecting one ring record per round — plus the
    empty-run (``max_rounds=0``) and capacity-truncation edges;
  * **integration** — WorkCounter.rounds as the single round source of
    truth, the vertex-denominated occupancy fix at granularity 4, and the
    traced task server / stream driver (records reconcile with stats,
    per-job latency histograms are exact).
"""
import json
import os
import random
from collections import deque

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import SchedulerConfig
from repro.core.counters import JobTelemetry, WorkCounter
from repro.graph.generators import grid2d, rmat
from repro.obs import (DEFAULT_CAPACITY, LatencyHistogram, Trace, TraceRing,
                       atomic_write_text, chrome_trace, metric_doc,
                       read_jsonl, ring_rows, stacked_rings, unstack_ring,
                       validate_bench, validate_chrome_trace,
                       validate_metric, validate_metrics_jsonl,
                       write_chrome_trace, write_jsonl)
from repro.obs.export import HOST_PID, ROUND_DUR_US
from repro.obs.schema import NUM_FIELDS, SCHEMA_VERSION, TRACE_FIELDS
from repro.runtime import (POLICY_GRID, build_program, config_for, execute,
                           parse_policy, stream_execute)

try:  # only the property-test section needs hypothesis
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - the seeded sweep still runs
    st = None


@pytest.fixture(scope="module")
def g_rmat():
    return rmat(6, edge_factor=4, seed=1)


@pytest.fixture(scope="module")
def g_grid():
    return grid2d(8, 8)


# ------------------------------------------------------------- ring model
def _check_against_model(capacity, values):
    """Drive a ring and a deque(maxlen=capacity) with the same rows."""
    ring = TraceRing.make(capacity)
    model = deque(maxlen=capacity)
    for i, v in enumerate(values):
        ring = ring.record(round=i, work=v)
        model.append((i, v))
    rows, truncated = ring_rows(ring)
    assert truncated == max(0, len(values) - capacity)
    assert [(r["round"], r["work"]) for r in rows] == list(model)
    # unnamed columns are zero
    for r in rows:
        assert all(r[f] == 0 for f in TRACE_FIELDS
                   if f not in ("round", "work"))


def test_ring_empty():
    rows, truncated = ring_rows(TraceRing.make(4))
    assert rows == [] and truncated == 0


def test_ring_partial_fill_keeps_order():
    _check_against_model(8, [10, 20, 30])


def test_ring_exact_fill_boundary():
    _check_against_model(4, [1, 2, 3, 4])


def test_ring_wraparound_keeps_newest():
    ring = TraceRing.make(3)
    for i in range(7):
        ring = ring.record(round=i, pops=i * 10)
    rows, truncated = ring_rows(ring)
    assert truncated == 4
    assert [r["round"] for r in rows] == [4, 5, 6]
    assert [r["pops"] for r in rows] == [40, 50, 60]


def test_ring_seeded_model_sweep():
    """Deterministic wraparound/truncation sweep (runs without hypothesis)."""
    rng = random.Random(0)
    for capacity in (1, 2, 3, 5, 8):
        for n in (0, 1, capacity - 1, capacity, capacity + 1,
                  3 * capacity + 2):
            if n < 0:
                continue
            _check_against_model(
                capacity, [rng.randrange(-2**31, 2**31) for _ in range(n)])


if st is not None:
    @settings(max_examples=40, deadline=None)
    @given(st.integers(min_value=1, max_value=7),
           st.lists(st.integers(min_value=-2**31, max_value=2**31 - 1),
                    max_size=30))
    def test_ring_matches_deque_model(capacity, values):
        _check_against_model(capacity, values)


def test_ring_rejects_unknown_field_and_bad_capacity():
    with pytest.raises(ValueError, match="unknown trace fields"):
        TraceRing.make(2).record(bogus=1)
    with pytest.raises(ValueError, match="capacity"):
        TraceRing.make(0)


def test_ring_records_inside_jit():
    """record() is pure array ops — safe inside a jitted loop."""
    def body(i, ring):
        return ring.record(round=i, work=2 * i)

    ring = jax.jit(
        lambda r: jax.lax.fori_loop(0, 5, body, r))(TraceRing.make(8))
    rows, truncated = ring_rows(ring)
    assert truncated == 0
    assert [(r["round"], r["work"]) for r in rows] == [
        (i, 2 * i) for i in range(5)]


def test_stacked_ring_round_trip():
    ring = TraceRing.make(4).record(round=0, work=7)
    stacked = stacked_rings(ring, 3)
    assert stacked.buf.shape == (3, 4, NUM_FIELDS)
    for d in range(3):
        rows, _ = ring_rows(unstack_ring(stacked, d))
        assert [(r["round"], r["work"]) for r in rows] == [(0, 7)]


# ---------------------------------------------------------------- schema
def test_metric_doc_tags_and_validates():
    doc = metric_doc("span", name="x", ts_us=0.0, dur_us=1.5)
    assert doc["schema"] == SCHEMA_VERSION and doc["kind"] == "span"
    validate_metric(doc)  # idempotent


def test_validate_metric_rejects_drift():
    with pytest.raises(ValueError, match="unknown metric kind"):
        validate_metric({"schema": SCHEMA_VERSION, "kind": "nope"})
    with pytest.raises(ValueError, match="missing required field"):
        validate_metric({"schema": SCHEMA_VERSION, "kind": "span",
                         "name": "x", "ts_us": 0.0})
    with pytest.raises(ValueError, match="schema"):
        validate_metric({"schema": 999, "kind": "span", "name": "x",
                         "ts_us": 0.0, "dur_us": 1.0})
    # bool is an int subclass — an int field must still reject it
    bad = metric_doc("span", name="x", ts_us=0.0, dur_us=1.0)
    bad = dict(bad, kind="round", engine="e",
               **{f: 0 for f in TRACE_FIELDS})
    validate_metric(bad)
    bad["pops"] = True
    with pytest.raises(ValueError, match="bool"):
        validate_metric(bad)


def test_validate_metric_allows_extra_fields():
    doc = metric_doc("span", name="x", ts_us=0.0, dur_us=1.0, extra="ok")
    validate_metric(doc)


def test_validate_metrics_jsonl_reports_line():
    good = json.dumps(metric_doc("span", name="a", ts_us=0.0, dur_us=1.0))
    assert validate_metrics_jsonl([good, "", good]) == 2
    with pytest.raises(ValueError, match="line 1"):
        validate_metrics_jsonl([good, "{not json"])
    with pytest.raises(ValueError, match="line 1"):
        validate_metrics_jsonl([good, json.dumps({"kind": "nope"})])


def test_validate_chrome_trace_shape():
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace({})
    with pytest.raises(ValueError, match="missing"):
        validate_chrome_trace({"traceEvents": [{"name": "x", "ph": "M"}]})
    with pytest.raises(ValueError, match="dur"):
        validate_chrome_trace({"traceEvents": [
            {"name": "x", "ph": "X", "pid": 1, "tid": 0, "ts": 0}]})


def test_validate_bench_envelope():
    meta = {"git_sha": "a", "jax_version": "b", "device_kind": "c",
            "python": "d", "schema": SCHEMA_VERSION}
    validate_bench({"meta": meta, "whatever": 1}, name="X")
    with pytest.raises(ValueError, match="meta"):
        validate_bench({"whatever": 1}, name="X")
    with pytest.raises(ValueError, match="meta.schema"):
        validate_bench({"meta": dict(meta, schema=0)}, name="X")


# ------------------------------------------------------------- histogram
def test_histogram_exact_nearest_rank():
    h = LatencyHistogram("t")
    h.extend(range(1, 101))
    assert h.percentile(50) == 50 and h.percentile(99) == 99
    assert h.percentile(100) == 100 and h.percentile(1) == 1
    doc = h.to_doc()
    validate_metric(doc)
    assert doc["count"] == 100 and doc["p95"] == 95
    single = LatencyHistogram("s")
    single.add(7)
    assert single.percentile(50) == 7 and single.percentile(99) == 7
    empty = LatencyHistogram("e")
    assert empty.percentile(99) == 0.0
    validate_metric(empty.to_doc())
    with pytest.raises(ValueError):
        h.percentile(0)


# ------------------------------------------------------------- exporters
def test_atomic_write_leaves_no_temp(tmp_path):
    path = tmp_path / "out.json"
    atomic_write_text(path, "one")
    atomic_write_text(path, "two")
    assert path.read_text() == "two"
    assert [p.name for p in tmp_path.iterdir()] == ["out.json"]


def test_jsonl_round_trip(tmp_path):
    docs = [metric_doc("span", name=f"s{i}", ts_us=float(i), dur_us=1.0)
            for i in range(3)]
    path = write_jsonl(tmp_path / "m.jsonl", docs)
    assert read_jsonl(path) == docs
    assert validate_metrics_jsonl(path.read_text().splitlines()) == 3


def test_chrome_trace_layout(tmp_path):
    recs = []
    for engine in ("alpha", "beta"):
        for rnd in range(2):
            rec = {f: 0 for f in TRACE_FIELDS}
            rec.update(round=rnd, lane=1, engine=engine)
            recs.append(rec)
    spans = [metric_doc("span", name="compile", ts_us=3.0, dur_us=9.0)]
    doc = chrome_trace(recs, spans, meta={"git_sha": "x"})
    validate_chrome_trace(doc)
    events = doc["traceEvents"]
    xs = [e for e in events if e["ph"] == "X" and e.get("cat") == "round"]
    assert len(xs) == len(recs)
    # one pid per engine, in first-seen order, disjoint from the host pid
    pids = {e["pid"] for e in xs}
    assert pids == {1, 2} and HOST_PID not in pids
    names = {e["args"]["name"] for e in events
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert names == {"host", "alpha", "beta"}
    # logical timebase: round index x ROUND_DUR_US
    assert {e["ts"] for e in xs} == {0, ROUND_DUR_US}
    host = [e for e in events if e["ph"] == "X" and e["pid"] == HOST_PID]
    assert len(host) == 1 and host[0]["dur"] == 9.0
    assert doc["otherData"]["git_sha"] == "x"
    path = write_chrome_trace(tmp_path / "t.json", doc)
    validate_chrome_trace(json.loads(path.read_text()))


def test_trace_collects_and_writes(tmp_path):
    trace = Trace(capacity=8, meta={"git_sha": "deadbeef"})
    ring = trace.ring()
    assert ring.capacity == 8
    for i in range(3):
        ring = ring.record(round=i, pops=i)
    assert trace.drain(ring, engine="e", round_offset=10) == 3
    assert [r["round"] for r in trace.records] == [10, 11, 12]
    with trace.span("compile"):
        pass
    trace.histogram("lat").extend([1, 2, 3])
    with pytest.raises(ValueError):
        trace.add_metric({"kind": "nope"})
    docs = trace.metric_docs()
    assert docs[0]["kind"] == "meta"
    assert docs[0]["git_sha"] == "deadbeef"
    assert validate_metrics_jsonl(json.dumps(d) for d in docs) == len(docs)
    trace.write(tmp_path / "t.json", tmp_path / "m.jsonl")
    validate_chrome_trace(json.loads((tmp_path / "t.json").read_text()))
    validate_metrics_jsonl((tmp_path / "m.jsonl").read_text().splitlines())


# ----------------------------------------------------- parity, all cells
def _cfg_for(cell: str) -> SchedulerConfig:
    # sharded cells run on a degenerate 1-device mesh (tier-1 safe; the
    # 8-device path is exercised by the benchmarks' subprocess children)
    return config_for(SchedulerConfig(num_workers=16, fetch_size=1),
                      parse_policy(cell))


ALL_CELLS = [str(p) for p in POLICY_GRID]


@pytest.mark.parametrize("granularity", [1, 4])
@pytest.mark.parametrize("cell", ALL_CELLS)
def test_tracing_disabled_is_identity(g_rmat, cell, granularity):
    """trace=Trace() is observation only: results, stats and info are
    bit-identical to trace=None, with one ring record per round (times
    the shard count under the sharded topology)."""
    policy = parse_policy(cell)
    if granularity > 1:
        cell = f"{cell}.g{granularity}"
    cfg = _cfg_for(cell)
    program = build_program("bfs", g_rmat, cfg, params={"source": 0})

    base_state, base_stats, base_info = execute(program, g_rmat, cfg)
    trace = Trace()
    tr_state, tr_stats, tr_info = execute(program, g_rmat, cfg, trace=trace)

    assert np.array_equal(np.asarray(program.result(tr_state)),
                          np.asarray(program.result(base_state)))
    assert tr_info == base_info
    assert tr_stats.rounds == base_stats.rounds
    assert tr_stats.items_processed == base_stats.items_processed
    shards = cfg.num_shards if policy.topology == "sharded" else 1
    assert len(trace.records) == base_info["rounds"] * shards
    assert all(r["engine"].startswith(policy.topology)
               for r in trace.records)
    # the records reconcile with the run's own counters
    assert sum(r["pops"] for r in trace.records) == \
        base_stats.items_processed
    if "work" in base_info:
        assert sum(r["work"] for r in trace.records) == base_info["work"]


def test_empty_run_edge(g_rmat):
    """max_rounds=0: the drain loop never iterates; tracing sees nothing
    and parity still holds."""
    import dataclasses

    cfg = dataclasses.replace(_cfg_for("single.persistent"), max_rounds=0)
    program = build_program("bfs", g_rmat, cfg, params={"source": 0})
    _, base_stats, base_info = execute(program, g_rmat, cfg)
    trace = Trace()
    _, tr_stats, tr_info = execute(program, g_rmat, cfg, trace=trace)
    assert base_info["rounds"] == 0 and tr_info == base_info
    assert trace.records == [] and trace.truncated == 0


def test_capacity_truncation_edge(g_rmat):
    """A ring smaller than the round count keeps the newest rounds and
    reports the overwritten count — the flight-recorder contract."""
    cfg = _cfg_for("single.persistent")
    program = build_program("bfs", g_rmat, cfg, params={"source": 0})
    _, _, info = execute(program, g_rmat, cfg)
    rounds = info["rounds"]
    assert rounds > 2, "need a multi-round drain for this edge"
    trace = Trace(capacity=2)
    execute(program, g_rmat, cfg, trace=trace)
    assert len(trace.records) == 2
    assert trace.truncated == rounds - 2
    assert [r["round"] for r in trace.records] == [rounds - 2, rounds - 1]


def test_run_doc_in_registry(g_rmat):
    cfg = _cfg_for("single.persistent")
    program = build_program("bfs", g_rmat, cfg, params={"source": 0})
    trace = Trace()
    execute(program, g_rmat, cfg, trace=trace)
    runs = [d for d in trace.metrics if d["kind"] == "run"]
    assert len(runs) == 1
    assert runs[0]["policy"] == "single.persistent"
    assert runs[0]["rounds"] == len(trace.records)
    assert any(s["name"].startswith("execute") for s in trace.spans)


def test_legacy_list_trace_still_works(g_rmat):
    """The discrete driver's pre-obs trace hook (a plain list collecting
    (size, items) tuples) is still honored."""
    cfg = _cfg_for("single.discrete")
    program = build_program("bfs", g_rmat, cfg, params={"source": 0})
    legacy = []
    _, _, info = execute(program, g_rmat, cfg, trace=legacy)
    assert len(legacy) == info["rounds"]


# ------------------------------------------------- counters & occupancy
def test_work_counter_rounds_single_source_of_truth(g_rmat):
    """WorkCounter.rounds is bumped once per wavefront_step — it matches
    the driver's round count without the driver maintaining it."""
    for cell in ("single.persistent", "single.discrete",
                 "sharded.persistent"):
        cfg = _cfg_for(cell)
        program = build_program("bfs", g_rmat, cfg, params={"source": 0})
        state, stats, info = execute(program, g_rmat, cfg)
        assert int(state.counter.rounds) == stats.rounds == \
            info["rounds"], cell


def test_occupancy_vertex_denominated_at_g4():
    """The granularity > 1 occupancy fix: the numerator counts vertices
    (chunk-width weighted), the denominator counts the vertex budget
    rounds_active x wavefront x G."""
    t = JobTelemetry(job_id=0, algorithm="bfs", graph="g", wavefront=8,
                     ideal_work=64, rounds_active=2, items_processed=10,
                     vertices_processed=40, granularity=4)
    assert t.occupancy == pytest.approx(40 / (2 * 8 * 4))
    # the pre-fix item-over-slot accounting would have read 10/(2*8) —
    # claiming 62% while the vertex budget was only 62.5% filled by luck;
    # make the distinction explicit with a chunk-heavy tenant:
    t2 = JobTelemetry(job_id=0, algorithm="bfs", graph="g", wavefront=8,
                      ideal_work=64, rounds_active=1, items_processed=8,
                      vertices_processed=32, granularity=4)
    assert t2.occupancy == pytest.approx(1.0)   # 8 width-4 chunks fill W*G
    assert t2.occupancy <= 1.0
    # granularity 1 reduces to the legacy item/slot accounting
    t3 = JobTelemetry(job_id=0, algorithm="bfs", graph="g", wavefront=8,
                      ideal_work=64, rounds_active=2, items_processed=10,
                      vertices_processed=10, granularity=1)
    assert t3.occupancy == pytest.approx(10 / 16)
    # legacy unmetered paths fall back to items
    t4 = JobTelemetry(job_id=0, algorithm="bfs", graph="g", wavefront=8,
                      ideal_work=64, rounds_active=2, items_processed=10,
                      vertices_processed=0, granularity=1)
    assert t4.occupancy == pytest.approx(10 / 16)
    validate_metric(t.as_dict())


def test_server_occupancy_bounded_at_g4(g_rmat):
    """Regression: at granularity 4 a server tenant's occupancy stays a
    fraction of the vertex budget (<= 1) and vertex metering engages."""
    from repro.server import JobRegistry, JobSpec, TaskServer

    reg = JobRegistry()
    reg.register_graph("g", g_rmat)
    cfg = SchedulerConfig(num_workers=16, fetch_size=1, granularity=4)
    server = TaskServer(reg, num_lanes=2, config=cfg)
    server.submit(JobSpec("bfs", "g", {"source": 0}))
    server.submit(JobSpec("coloring", "g"))
    result = server.run()
    for t in result.telemetry.values():
        assert 0.0 < t.occupancy <= 1.0, t
        assert t.granularity == 4
        assert t.vertices_processed >= t.items_processed > 0


# -------------------------------------------------- traced server/stream
def test_traced_server_reconciles(g_grid, g_rmat):
    from repro.server import JobRegistry, JobSpec, TaskServer

    reg = JobRegistry()
    reg.register_graph("grid", g_grid)
    reg.register_graph("rmat", g_rmat)
    specs = [JobSpec("bfs", "grid", {"source": 0}),
             JobSpec("pagerank", "rmat", {"eps": 1e-4}),
             JobSpec("coloring", "grid")]
    cfg = SchedulerConfig(num_workers=16, fetch_size=1)

    base = TaskServer(reg, num_lanes=2, config=cfg)
    for s in specs:
        base.submit(s)
    base_result = base.run()

    trace = Trace()
    traced = TaskServer(reg, num_lanes=2, config=cfg, trace=trace)
    for s in specs:
        traced.submit(s)
    tr_result = traced.run()

    # observation only: same rounds, same per-job telemetry
    assert tr_result.stats.rounds == base_result.stats.rounds
    for job_id, t in base_result.telemetry.items():
        t2 = tr_result.telemetry[job_id]
        assert (t2.items_processed, t2.latency_rounds, t2.work) == \
            (t.items_processed, t.latency_rounds, t.work)
    # ring rows reconcile with the server's own counters
    server_rows = [r for r in trace.records if r["engine"] == "server"]
    assert sum(r["pops"] for r in server_rows) == \
        tr_result.stats.items_processed
    assert {r["lane"] for r in server_rows} <= {0, 1}
    # registry: one server doc + one job doc per tenant, all schema-valid
    kinds = [d["kind"] for d in trace.metrics]
    assert kinds.count("server") == 1 and kinds.count("job") == len(specs)
    # per-job latency histograms with exact percentiles
    lat = trace.histograms["job_latency_rounds"]
    assert lat.count == len(specs)
    expected = sorted(t.latency_rounds
                      for t in tr_result.telemetry.values())
    assert lat.percentile(100) == expected[-1]
    for job_id in tr_result.telemetry:
        assert trace.histograms[
            f"job{job_id}_latency_rounds"].count == 1


def test_traced_stream_absolute_rounds(g_rmat):
    from repro.graph.generators import edge_delta_stream

    deltas = edge_delta_stream(g_rmat, 3, 16, seed=5)
    cfg = SchedulerConfig(num_workers=16, topology="single",
                          persistent=False)
    base = stream_execute("bfs", g_rmat, deltas, cfg,
                          params={"source": 0})
    trace = Trace()
    traced = stream_execute("bfs", g_rmat, deltas, cfg,
                            params={"source": 0}, trace=trace)
    assert np.array_equal(np.asarray(traced.result),
                          np.asarray(base.result))
    # schedule determinism: every counter identical; commit_seconds is the
    # one wall-clock meter in stream info, so it alone may differ
    drop = lambda d: {k: v for k, v in d.items() if k != "commit_seconds"}
    assert drop(traced.info) == drop(base.info)
    # one record per round across ALL batches, on an absolute round axis
    assert len(trace.records) == base.info["rounds"]
    assert sorted(r["round"] for r in trace.records) == \
        list(range(base.info["rounds"]))
    assert {r["engine"] for r in trace.records} == {"stream.bfs"}
    stream_docs = [d for d in trace.metrics if d["kind"] == "stream"]
    assert len(stream_docs) == 1
    assert stream_docs[0]["rounds"] == base.info["rounds"]
