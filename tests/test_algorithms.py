"""Case-study correctness + the paper's quantitative claims at test scale."""
import jax.numpy as jnp
import networkx as nx
import numpy as np
import pytest

from repro.algorithms.bfs import bfs_bsp, bfs_speculative
from repro.algorithms.coloring import coloring_async, coloring_bsp, \
    validate_coloring
from repro.algorithms.pagerank import pagerank_async, pagerank_bsp, \
    pagerank_reference
from repro.core import SchedulerConfig
from repro.graph import grid2d, permute_vertices, rmat


def _nx_dists(g, source):
    G = nx.Graph()
    G.add_nodes_from(range(g.num_vertices))
    rp, ci = np.asarray(g.row_ptr), np.asarray(g.col_idx)
    for v in range(g.num_vertices):
        for e in range(rp[v], rp[v + 1]):
            G.add_edge(v, int(ci[e]))
    ref = np.full(g.num_vertices, 0x7FFFFFFF, np.int64)
    for k, d in nx.single_source_shortest_path_length(G, source).items():
        ref[k] = d
    return ref


GRAPHS = {
    "scale_free": rmat(8, 8, seed=1),
    "mesh_like": grid2d(20, 20),
}

# the kernel-backend axis (DESIGN.md section 9): "pallas" runs the real
# Pallas kernels in interpret mode on CPU, so every correctness test below
# doubles as a backend-parity oracle.
BACKENDS = ("jnp", "pallas")


@pytest.mark.parametrize("gname", list(GRAPHS))
def test_bfs_bsp_correct(gname):
    g = GRAPHS[gname]
    dist, info = bfs_bsp(g, 0)
    np.testing.assert_array_equal(np.asarray(dist, np.int64), _nx_dists(g, 0))
    assert info["work"] > 0


@pytest.mark.parametrize("gname", list(GRAPHS))
@pytest.mark.parametrize("strategy", ["merge_path", "per_item"])
@pytest.mark.parametrize("persistent", [True, False])
@pytest.mark.parametrize("backend", BACKENDS)
def test_bfs_speculative_correct(gname, strategy, persistent, backend):
    g = GRAPHS[gname]
    cfg = SchedulerConfig(num_workers=8, fetch_size=4, persistent=persistent,
                          max_rounds=100000, backend=backend)
    dist, info = bfs_speculative(g, 0, cfg, strategy=strategy)
    np.testing.assert_array_equal(np.asarray(dist, np.int64), _nx_dists(g, 0))
    assert info["dropped"] == 0
    # overwork is bounded (paper: small constant factor over n)
    reached = int((_nx_dists(g, 0) < 0x7FFFFFFF).sum())
    assert info["work"] >= reached - 1
    assert info["work"] <= 4 * reached


def test_bfs_small_budget_still_correct():
    g = GRAPHS["scale_free"]
    cfg = SchedulerConfig(num_workers=4, fetch_size=2, max_rounds=100000)
    dist, info = bfs_speculative(g, 0, cfg, strategy="merge_path",
                                 work_budget=8)  # heavy truncation
    np.testing.assert_array_equal(np.asarray(dist, np.int64), _nx_dists(g, 0))


@pytest.mark.parametrize("gname", list(GRAPHS))
@pytest.mark.parametrize("backend", BACKENDS)
def test_pagerank_matches_power_iteration(gname, backend):
    g = GRAPHS[gname]
    ref = pagerank_reference(g, iters=300)
    r_bsp, _ = pagerank_bsp(g, eps=1e-7)
    cfg = SchedulerConfig(num_workers=8, fetch_size=4, max_rounds=100000,
                          backend=backend)
    r_async, info = pagerank_async(g, cfg, eps=1e-7)
    assert float(jnp.max(jnp.abs(r_bsp - ref))) < 1e-3
    assert float(jnp.max(jnp.abs(r_async - ref))) < 1e-3
    assert info["max_residue"] <= 1e-7


def test_pagerank_small_explicit_budget_still_converges():
    """An explicit work_budget below max_degree must be clamped up (the
    progress-guarantee floor): otherwise a hub row is truncated and
    re-queued forever, its residue never harvested, and the drain spins to
    max_rounds."""
    g = GRAPHS["scale_free"]
    cfg = SchedulerConfig(num_workers=4, fetch_size=2, max_rounds=100000)
    rank, info = pagerank_async(g, cfg, eps=1e-5, work_budget=1)
    assert info["rounds"] < 100000
    assert info["max_residue"] <= 1e-5
    ref = pagerank_reference(g, iters=300)
    assert float(jnp.max(jnp.abs(rank - ref))) < 1e-3


def test_pagerank_async_does_less_work_on_scale_free():
    """Paper Table 4: async PageRank workload ratio < 1 vs BSP."""
    g = GRAPHS["scale_free"]
    _, info_bsp = pagerank_bsp(g, eps=1e-6)
    cfg = SchedulerConfig(num_workers=8, fetch_size=4, max_rounds=100000)
    _, info_async = pagerank_async(g, cfg, eps=1e-6)
    assert info_async["work"] < info_bsp["work"]


@pytest.mark.parametrize("gname", list(GRAPHS))
def test_coloring_bsp_valid(gname):
    g = GRAPHS[gname]
    colors, info = coloring_bsp(g)
    assert validate_coloring(g, colors)
    assert int(jnp.max(colors)) + 1 <= int(jnp.max(g.degrees())) + 1


@pytest.mark.parametrize("gname", list(GRAPHS))
@pytest.mark.parametrize("persistent", [True, False])
@pytest.mark.parametrize("backend", BACKENDS)
def test_coloring_async_valid(gname, persistent, backend):
    g = GRAPHS[gname]
    cfg = SchedulerConfig(num_workers=8, fetch_size=4, persistent=persistent,
                          max_rounds=100000, backend=backend)
    colors, info = coloring_async(g, cfg)
    assert validate_coloring(g, colors)
    assert info["dropped"] == 0


def test_coloring_async_less_overwork_than_bsp():
    """Paper section 6.4: relaxed coloring reduces overwork vs BSP."""
    g = GRAPHS["scale_free"]
    _, bsp = coloring_bsp(g)
    cfg = SchedulerConfig(num_workers=8, fetch_size=4, max_rounds=100000)
    _, asy = coloring_async(g, cfg)
    assert asy["work"] < bsp["work"]


# ------------------------------------------------- backend parity oracle
# Beyond "both backends are correct": the backends must agree *bit for bit*
# — same results, same rounds, same work — so the autotuner may switch
# between them on wall time alone (DESIGN.md section 9).
@pytest.mark.parametrize("gname", list(GRAPHS))
def test_backends_bit_identical(gname):
    g = GRAPHS[gname]
    def cfg(backend):
        return SchedulerConfig(num_workers=8, fetch_size=4,
                               max_rounds=100000, backend=backend)

    d_j, i_j = bfs_speculative(g, 0, cfg("jnp"), strategy="merge_path")
    d_p, i_p = bfs_speculative(g, 0, cfg("pallas"), strategy="merge_path")
    np.testing.assert_array_equal(np.asarray(d_j), np.asarray(d_p))
    assert i_j == i_p

    r_j, pi_j = pagerank_async(g, cfg("jnp"), eps=1e-6)
    r_p, pi_p = pagerank_async(g, cfg("pallas"), eps=1e-6)
    np.testing.assert_array_equal(np.asarray(r_j), np.asarray(r_p))
    assert pi_j == pi_p

    c_j, ci_j = coloring_async(g, cfg("jnp"))
    c_p, ci_p = coloring_async(g, cfg("pallas"))
    np.testing.assert_array_equal(np.asarray(c_j), np.asarray(c_p))
    assert ci_j == ci_p


def test_coloring_permutation_reduces_overwork():
    """Paper section 6.4: random ID permutation cuts conflicts sharply."""
    g = grid2d(24, 24)
    perm = np.random.default_rng(0).permutation(g.num_vertices).astype(np.int32)
    gp = permute_vertices(g, perm)
    cfg = SchedulerConfig(num_workers=16, fetch_size=8, max_rounds=100000)
    _, sorted_info = coloring_async(g, cfg)
    _, permuted_info = coloring_async(gp, cfg)
    assert permuted_info["work"] < sorted_info["work"]
