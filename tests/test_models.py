"""Per-arch smoke tests: reduced config, one forward/train step, shapes, no
NaNs (the FULL configs are exercised via the dry-run only)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, supports_shape
from repro.configs.registry import ARCH_IDS, get_config, smoke_config
from repro.models import transformer as T
from repro.models.params import count_params, init_params

KEY = jax.random.PRNGKey(0)
B, TXT = 2, 16


def _batch(cfg):
    batch = {
        "tokens": jax.random.randint(KEY, (B, TXT), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (B, TXT), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["patch_emb"] = jax.random.normal(
            KEY, (B, cfg.frontend_len, cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            KEY, (B, cfg.frontend_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch):
    cfg = smoke_config(arch)
    params = init_params(T.model_spec(cfg), KEY, jnp.float32)
    batch = _batch(cfg)
    logits, aux = T.forward(params, cfg, batch)
    assert logits.shape == (B, TXT, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, grads = jax.value_and_grad(
        lambda p: T.loss_fn(p, cfg, batch))(params)
    assert bool(jnp.isfinite(loss))
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_steps(arch):
    cfg = smoke_config(arch)
    params = init_params(T.model_spec(cfg), KEY, jnp.float32)
    cache = T.init_cache(cfg, B, 32, jnp.float32)
    tok = jax.random.randint(KEY, (B, 1), 0, cfg.vocab_size)
    for _ in range(3):
        logits, cache = T.decode_step(params, cfg, cache, tok)
        assert logits.shape == (B, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    assert int(cache.length[0]) == 3


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_counts(arch):
    """The spec tree must agree with the analytic weight-matrix estimate to
    <0.1% (validates every config against its published size)."""
    cfg = get_config(arch)
    exact = cfg.param_count()
    assert exact == count_params(T.model_spec(cfg))
    approx = cfg._analytic_param_count()
    assert abs(exact - approx) / exact < 1e-3


def test_assigned_shape_skips():
    """long_500k runs only for sub-quadratic archs (DESIGN section 5)."""
    long = SHAPES["long_500k"]
    runs = {a for a in ARCH_IDS if supports_shape(get_config(a), long)}
    assert runs == {"zamba2-1.2b", "falcon-mamba-7b", "h2o-danube-3-4b"}


def test_prefill_decode_consistency_ssm():
    """Mamba: forward over T tokens == T sequential decode steps."""
    cfg = smoke_config("falcon-mamba-7b")
    params = init_params(T.model_spec(cfg), KEY, jnp.float32)
    toks = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    logits_fwd, _ = T.forward(params, cfg, {"tokens": toks})
    cache = T.init_cache(cfg, 1, 16, jnp.float32)
    for i in range(8):
        logits_dec, cache = T.decode_step(params, cfg, cache, toks[:, i:i + 1])
    np.testing.assert_allclose(np.asarray(logits_fwd[0, -1]),
                               np.asarray(logits_dec[0]), atol=1e-4)


def test_prefill_decode_consistency_dense():
    cfg = smoke_config("minitron-4b")
    params = init_params(T.model_spec(cfg), KEY, jnp.float32)
    toks = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    logits_fwd, _ = T.forward(params, cfg, {"tokens": toks})
    cache = T.init_cache(cfg, 1, 16, jnp.float32)
    for i in range(8):
        logits_dec, cache = T.decode_step(params, cfg, cache, toks[:, i:i + 1])
    np.testing.assert_allclose(np.asarray(logits_fwd[0, -1]),
                               np.asarray(logits_dec[0]), atol=1e-4)


def test_swa_matches_full_attention_within_window():
    """Sliding-window == full attention while T <= window."""
    import dataclasses
    cfg = smoke_config("h2o-danube-3-4b")
    cfg_full = dataclasses.replace(cfg, sliding_window=0)
    params = init_params(T.model_spec(cfg), KEY, jnp.float32)
    toks = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)  # 8 < 32 window
    a, _ = T.forward(params, cfg, {"tokens": toks})
    b, _ = T.forward(params, cfg_full, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_moe_capacity_drops_counted():
    import dataclasses
    from repro.models.moe import apply_moe
    from repro.models.params import init_params as ip
    from repro.models import transformer as TT

    cfg = smoke_config("olmoe-1b-7b")
    spec = TT.block_spec(cfg, "moe")["moe"]
    params = ip(spec, KEY, jnp.float32)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    _, m_tight = apply_moe(params, cfg, x, capacity=1)
    _, m_ample = apply_moe(params, cfg, x, capacity=2 * 16 * 2)
    assert int(m_tight["dropped"]) > 0
    assert int(m_ample["dropped"]) == 0
