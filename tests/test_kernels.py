"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

rng = np.random.default_rng(0)


# ----------------------------------------------------------- LBS kernel
@pytest.mark.parametrize("w,budget", [(1, 128), (7, 64), (32, 1024),
                                      (100, 2048), (257, 4096), (1000, 1024)])
def test_lbs_kernel_matches_ref(w, budget):
    from repro.kernels.frontier_expand.kernel import lbs_pallas
    from repro.kernels.frontier_expand.ref import lbs_ref

    deg = rng.integers(0, 9, size=w).astype(np.int32)
    scan = jnp.cumsum(jnp.asarray(deg))
    o1, r1 = lbs_pallas(scan, budget)
    o2, r2 = lbs_ref(scan, budget)
    total = min(int(scan[-1]), budget)
    np.testing.assert_array_equal(np.asarray(o1[:total]), np.asarray(o2[:total]))
    np.testing.assert_array_equal(np.asarray(r1[:total]), np.asarray(r2[:total]))


def test_lbs_kernel_zero_degrees():
    from repro.kernels.frontier_expand.kernel import lbs_pallas
    from repro.kernels.frontier_expand.ref import lbs_ref
    deg = np.array([0, 0, 5, 0, 3, 0], np.int32)
    scan = jnp.cumsum(jnp.asarray(deg))
    o1, r1 = lbs_pallas(scan, 16)
    o2, r2 = lbs_ref(scan, 16)
    np.testing.assert_array_equal(np.asarray(o1[:8]), np.asarray(o2[:8]))
    assert set(np.asarray(o1[:8]).tolist()) <= {2, 4}  # only nonzero rows own


def test_frontier_expand_op_equals_core():
    from repro.core.frontier import expand_merge_path
    from repro.kernels.frontier_expand.ops import frontier_expand
    from repro.graph import rmat

    g = rmat(7, 4, seed=5)
    items = jnp.array([1, 4, 9, 16, 25, 36, 49, 64], jnp.int32)
    valid = jnp.array([True] * 7 + [False])
    budget = 8 * int(jnp.max(g.degrees()))
    a = frontier_expand(items, valid, g.row_ptr, g.col_idx, budget)
    b = expand_merge_path(items, valid, g.row_ptr, g.col_idx, budget)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_frontier_expand_multi_tile_chunked_wraparound_parity():
    """The regime the smoke test above never reaches: a width-4 chunked
    wavefront popped across a wrapped ring head, whose degree-sum spills
    past one LBS tile (budget 4096 > TILE) — all three expansion backends
    (jnp reference, Pallas kernel, megakernel DMA stream) must agree on
    every output lane, exactly as the drain loops interleave them."""
    from repro.core import ChunkCodec, make_queue
    from repro.core.backend import STREAM
    from repro.core.frontier import chunk_degrees, expand_merge_path
    from repro.kernels.frontier_expand.ops import frontier_expand
    from repro.graph import rmat

    g = rmat(8, 8, seed=3)
    codec = ChunkCodec(4)
    n, W, cap, budget = g.num_vertices, 64, 64, 4096

    local = np.random.default_rng(7)
    def chunks(k, base):
        heads = local.integers(0, n - 4, size=k).astype(np.int32) + base
        widths = local.integers(1, 5, size=k).astype(np.int32)
        return codec.encode(jnp.asarray(heads % (n - 4)), jnp.asarray(widths))

    # rotate the ring so the popped wavefront physically wraps: after
    # push 48 / pop 40 / push 48 the live window is slots 40..95 (mod 64)
    q = make_queue(cap)
    q = q.push_dense(chunks(48, 0))
    _, _, q = q.pop(40)
    q = q.push_dense(chunks(48, 100))
    head_before = int(q.head)
    items, valid, q = q.pop(W)
    n_popped = int(np.asarray(valid).sum())
    assert n_popped == 56
    assert head_before + n_popped > cap  # the pop really crossed the seam

    safe = jnp.where(valid, items, 0)          # EMPTY lanes, as bfs.py does
    heads, widths = codec.decode(safe)
    assert int(jnp.cumsum(chunk_degrees(heads, widths, valid,
                                        g.row_ptr))[-1]) > 1024  # multi-tile
    ref = expand_merge_path(heads, valid, g.row_ptr, g.col_idx, budget,
                            widths=widths, max_width=4)
    pal = frontier_expand(heads, valid, g.row_ptr, g.col_idx, budget,
                          widths=widths, max_width=4)
    stream = expand_merge_path(heads, valid, g.row_ptr, g.col_idx, budget,
                               backend=STREAM, widths=widths, max_width=4)
    for got in (pal, stream):
        for x, y in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------- compact kernel
@pytest.mark.parametrize("n", [1, 5, 255, 256, 257, 1000, 2048])
@pytest.mark.parametrize("p", [0.0, 0.3, 1.0])
def test_compact_matches_ref(n, p):
    from repro.kernels.queue_compact.ops import compact
    from repro.kernels.queue_compact.ref import compact_ref

    items = jnp.asarray(rng.integers(-1000, 1000, size=n), jnp.int32)
    mask = jnp.asarray(rng.random(n) < p)
    o1, c1 = compact(items, mask)
    o2, c2 = compact_ref(items, mask)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    assert int(c1) == int(c2)


def test_compact_is_stable():
    from repro.kernels.queue_compact.ops import compact
    items = jnp.arange(600, dtype=jnp.int32)
    mask = jnp.asarray(np.arange(600) % 3 == 0)
    out, cnt = compact(items, mask)
    got = np.asarray(out)[:int(cnt)]
    assert (np.diff(got) > 0).all()  # order preserved


# ------------------------------------------------------- flash attention
@pytest.mark.parametrize("bh,bkv,s,d", [(2, 2, 128, 128), (4, 2, 256, 128),
                                        (4, 1, 256, 256)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref_f32(bh, bkv, s, d, causal):
    from repro.kernels.flash_attention.kernel import flash_attention_pallas
    from repro.kernels.flash_attention.ref import attention_ref

    q = jnp.asarray(rng.standard_normal((bh, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((bkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((bkv, s, d)), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=causal)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_bf16():
    from repro.kernels.flash_attention.kernel import flash_attention_pallas
    from repro.kernels.flash_attention.ref import attention_ref

    q = jnp.asarray(rng.standard_normal((2, 128, 128)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((2, 128, 128)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((2, 128, 128)), jnp.bfloat16)
    out = flash_attention_pallas(q, k, v)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, dtype=np.float32),
                               np.asarray(ref, dtype=np.float32),
                               atol=3e-2, rtol=3e-2)


def test_flash_sliding_window():
    from repro.kernels.flash_attention.kernel import flash_attention_pallas
    from repro.kernels.flash_attention.ref import attention_ref

    q = jnp.asarray(rng.standard_normal((2, 256, 128)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 256, 128)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 256, 128)), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=True, window=64)
    ref = attention_ref(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_mha_wrapper_xla_vs_pallas():
    from repro.kernels.flash_attention.ops import multihead_attention

    b, s, h, kvh, d = 2, 128, 4, 2, 128
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kvh, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kvh, d)), jnp.float32)
    a = multihead_attention(q, k, v, impl="xla")
    p = multihead_attention(q, k, v, impl="pallas")
    np.testing.assert_allclose(np.asarray(a), np.asarray(p), atol=2e-5,
                               rtol=2e-5)
