"""Docs stay true: the README's python quickstart block must execute.

CI runs the same check as a separate job (`.github/workflows/ci.yml`,
``docs``); keeping a copy in tier-1 means a PR can't merge a README that
doesn't run even when CI config changes.
"""
import os
import re
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def test_readme_exists_and_is_the_declared_front_door():
    readme = REPO / "README.md"
    assert readme.exists()
    assert 'readme = "README.md"' in (REPO / "pyproject.toml").read_text()


def test_readme_python_block_runs():
    text = (REPO / "README.md").read_text(encoding="utf-8")
    blocks = re.findall(r"```python\n(.*?)```", text, re.S)
    assert blocks, "README.md lost its ```python quickstart block"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(REPO / "src")] + ([env["PYTHONPATH"]] if "PYTHONPATH" in env
                               else []))
    out = ""
    for block in blocks:
        proc = subprocess.run(
            [sys.executable, "-c", block], capture_output=True, text=True,
            cwd=REPO, env=env, timeout=600)
        assert proc.returncode == 0, proc.stderr
        out += proc.stdout
    assert "backends agree bit-for-bit" in out  # the parity demo really ran
