"""Streaming-graph subsystem (DESIGN.md section 13).

Four tiers:

  * delta canonicalization + ingestion units and hypothesis properties
    (idempotency, insert-then-delete cancellation, CSR rebuild vs a dense
    adjacency-matrix oracle) — pure host math, always run;
  * the seeded delta-stream generator's determinism and symmetry contract;
  * the incremental-vs-from-scratch parity matrix: after every delta batch
    the streamed state must match a cold run on the final graph — BFS and
    coloring(recolor) bit-identical, PageRank within the eps slack,
    coloring(conflicts) a *valid* (cheaper) coloring — across the
    single/sharded topologies and granularities 1 and 4;
  * snapshot/resume determinism in-process, plus one real 8-device
    sharded streaming run in a subprocess (same idiom as tests/test_shard).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import SchedulerConfig
from repro.graph.csr import from_edges
from repro.graph.generators import edge_delta_stream, erdos, grid2d, rmat
from repro.runtime import build_program, execute, stream_execute
from repro.stream import (EdgeDelta, StreamSpec, apply_delta, make_delta,
                          replay, reshard, symmetrized)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------- delta units
def test_make_delta_validates():
    with pytest.raises(ValueError, match="out of range"):
        make_delta(4, [0], [7], [True])
    with pytest.raises(ValueError, match="self-loop"):
        make_delta(4, [2], [2], [True])
    with pytest.raises(ValueError, match="disagree"):
        make_delta(4, [0, 1], [1], [True])
    with pytest.raises(ValueError, match="positive"):
        make_delta(0, [], [], [])


def test_make_delta_last_wins_and_sorted():
    # (1,2) appears three times: insert, delete, insert -> nets to insert;
    # (0,3) delete stands; output sorted by (src, dst)
    d = make_delta(5,
                   [1, 0, 1, 1], [2, 3, 2, 2],
                   [True, False, False, True])
    assert d.num_ops == 2
    assert d.src.tolist() == [0, 1]
    assert d.dst.tolist() == [3, 2]
    assert d.insert.tolist() == [False, True]
    assert d.num_inserts == 1 and d.num_deletes == 1


def test_symmetrized_mirrors_every_op():
    d = symmetrized(make_delta(6, [1, 4], [2, 3], [True, False]))
    pairs = set(zip(d.src.tolist(), d.dst.tolist(), d.insert.tolist()))
    assert pairs == {(1, 2, True), (2, 1, True), (3, 4, False), (4, 3, False)}


def test_apply_delta_noops_filtered():
    g = from_edges(4, [0, 1], [1, 0])
    # inserting an existing edge and deleting an absent one are both no-ops
    a = apply_delta(g, make_delta(4, [0, 2], [1, 3], [True, False]))
    assert a.num_effective == 0
    np.testing.assert_array_equal(np.asarray(a.new_graph.row_ptr),
                                  np.asarray(g.row_ptr))
    np.testing.assert_array_equal(np.asarray(a.new_graph.col_idx),
                                  np.asarray(g.col_idx))


def test_apply_delta_rejects_vertex_mismatch():
    g = from_edges(4, [0], [1])
    with pytest.raises(ValueError, match="vertices"):
        apply_delta(g, make_delta(5, [0], [1], [True]))


def test_replay_prefix_matches_stepwise():
    g = erdos(24, 60, seed=1)
    deltas = edge_delta_stream(g, 3, 10, seed=7)
    step = g
    for d in deltas:
        step = apply_delta(step, d).new_graph
    rep = replay(g, deltas)
    np.testing.assert_array_equal(np.asarray(rep.row_ptr),
                                  np.asarray(step.row_ptr))
    np.testing.assert_array_equal(np.asarray(rep.col_idx),
                                  np.asarray(step.col_idx))


def test_reshard_preserves_ownership_blocks():
    from repro.shard import block_bounds

    g = erdos(32, 90, seed=2)
    d = edge_delta_stream(g, 1, 16, seed=3)[0]
    new_g = apply_delta(g, d).new_graph
    sh = reshard(new_g, 4)
    # same n -> same ownership blocks; each shard's slice of the global
    # [n+1] row_ptr re-covers its owned rows' post-delta degrees
    deg = np.diff(np.asarray(new_g.row_ptr))
    for dev in range(4):
        lo, hi = block_bounds(dev, new_g.num_vertices, 4)
        deg_local = np.diff(np.asarray(sh.row_ptr[dev]))[lo:hi]
        np.testing.assert_array_equal(deg_local, deg[lo:hi])


# ------------------------------------------------- hypothesis properties
def _dense(graph):
    n = graph.num_vertices
    rp = np.asarray(graph.row_ptr)
    ci = np.asarray(graph.col_idx)
    adj = np.zeros((n, n), dtype=bool)
    src = np.repeat(np.arange(n), np.diff(rp))
    adj[src, ci] = True
    return adj


def test_delta_properties_vs_dense_oracle():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @st.composite
    def graph_and_ops(draw):
        n = draw(st.integers(min_value=2, max_value=12))
        m = draw(st.integers(min_value=0, max_value=30))
        pairs = st.tuples(st.integers(0, n - 1), st.integers(0, n - 1))
        edges = [e for e in draw(st.lists(pairs, max_size=m))
                 if e[0] != e[1]]
        ops = draw(st.lists(st.tuples(st.integers(0, n - 1),
                                      st.integers(0, n - 1),
                                      st.booleans()), max_size=20))
        ops = [o for o in ops if o[0] != o[1]]
        return n, edges, ops

    @settings(max_examples=60, deadline=None)
    @given(graph_and_ops())
    def check(case):
        n, edges, ops = case
        src = np.array([e[0] for e in edges], dtype=np.int64)
        dst = np.array([e[1] for e in edges], dtype=np.int64)
        g = from_edges(n, src, dst)
        d = make_delta(n, [o[0] for o in ops], [o[1] for o in ops],
                       [o[2] for o in ops])

        # dense oracle: apply the *original* op list in order
        adj = _dense(g)
        for s, t, ins in ops:
            adj[s, t] = ins
        new_g = apply_delta(g, d).new_graph
        np.testing.assert_array_equal(_dense(new_g), adj)

        # idempotency: canonical batches are functions edge -> final op
        twice = apply_delta(new_g, d).new_graph
        np.testing.assert_array_equal(np.asarray(twice.row_ptr),
                                      np.asarray(new_g.row_ptr))
        np.testing.assert_array_equal(np.asarray(twice.col_idx),
                                      np.asarray(new_g.col_idx))

        # insert-then-delete within one batch cancels (nets to delete)
        if ops:
            s, t, _ = ops[0]
            cancel = make_delta(n, [s, s], [t, t], [True, False])
            assert cancel.num_ops == 1 and not bool(cancel.insert[0])

    check()


# ------------------------------------------------------ delta generator
def test_edge_delta_stream_deterministic_and_symmetric():
    g = rmat(5, edge_factor=4, seed=0)
    a = edge_delta_stream(g, 3, 12, seed=9)
    b = edge_delta_stream(g, 3, 12, seed=9)
    c = edge_delta_stream(g, 3, 12, seed=10)
    assert len(a) == 3
    for da, db in zip(a, b):
        np.testing.assert_array_equal(da.src, db.src)
        np.testing.assert_array_equal(da.dst, db.dst)
        np.testing.assert_array_equal(da.insert, db.insert)
    assert any(x.src.tolist() != y.src.tolist() for x, y in zip(a, c))
    for d in a:
        # both directions of every pair, same operation
        fwd = set(zip(d.src.tolist(), d.dst.tolist(), d.insert.tolist()))
        assert fwd == {(t, s, i) for s, t, i in fwd}
        # deletes touch existing edges, inserts genuinely new pairs
        assert d.num_ops > 0


def test_edge_delta_stream_keeps_graph_symmetric():
    g = grid2d(6, 6)
    cur = replay(g, edge_delta_stream(g, 4, 10, seed=3))
    adj = _dense(cur)
    np.testing.assert_array_equal(adj, adj.T)


# ----------------------------------------------------- parity matrix
# topology x granularity cells; persistent/discrete alternates so both
# kernel strategies are exercised without doubling the matrix. Sharded
# cells run the full shard_map machinery on a 1-device mesh (valid, and
# in-process); the real 8-device run is the subprocess test below.
PARITY_CELLS = [
    ("single", 1, True), ("single", 4, False),
    ("sharded", 1, False), ("sharded", 4, True),
]


def _cfg(topology, g, persistent):
    return SchedulerConfig(num_workers=32, topology=topology,
                           persistent=persistent, granularity=g,
                           num_shards=1 if topology != "sharded" else 1)


def _scratch(algorithm, graph, cfg, params):
    prog = build_program(algorithm, graph, cfg, params=dict(params))
    res = execute(prog, graph, cfg)
    return prog, res.state


@pytest.mark.parametrize("topology,g,persistent", PARITY_CELLS)
def test_bfs_stream_parity(topology, g, persistent):
    base = rmat(6, edge_factor=6, seed=1)
    deltas = edge_delta_stream(base, 3, 12, seed=4)
    cfg = _cfg(topology, g, persistent)
    params = {"source": 3}
    res = stream_execute("bfs", base, deltas, cfg, params=params)
    final_graph = replay(base, deltas)
    prog, state = _scratch("bfs", final_graph, cfg, params)
    np.testing.assert_array_equal(np.asarray(res.result),
                                  np.asarray(prog.result(state)))
    assert res.info["dropped"] == 0
    assert len(res.batches) == 4
    assert all(r.incremental for r in res.batches[1:])


@pytest.mark.parametrize("topology,g,persistent", PARITY_CELLS)
def test_pagerank_stream_parity(topology, g, persistent):
    base = rmat(6, edge_factor=6, seed=2)
    deltas = edge_delta_stream(base, 2, 10, seed=5)
    eps = 1e-5
    cfg = _cfg(topology, g, persistent)
    res = stream_execute("pagerank", base, deltas, cfg,
                         params={"eps": eps})
    final_graph = replay(base, deltas)
    prog, state = _scratch("pagerank", final_graph, cfg, {"eps": eps})
    ref = np.asarray(prog.result(state), dtype=np.float64)
    got = np.asarray(res.result, dtype=np.float64)
    # both runs stop at residue < eps; they agree to the eps slack
    assert np.abs(got - ref).max() < 10 * eps
    assert all(r.incremental for r in res.batches[1:])


@pytest.mark.parametrize("topology,g,persistent", PARITY_CELLS)
def test_coloring_recolor_stream_bit_identical(topology, g, persistent):
    from repro.algorithms.coloring import validate_coloring

    base = rmat(6, edge_factor=6, seed=3)
    deltas = edge_delta_stream(base, 2, 10, seed=6)
    cfg = _cfg(topology, g, persistent)
    # recolor mode disables the dirty-seed rule -> conservative full
    # reseed every batch -> bit-identical to a cold run on the final graph
    res = stream_execute("coloring", base, deltas, cfg,
                         params={"dirty": "recolor"})
    final_graph = replay(base, deltas)
    prog, state = _scratch("coloring", final_graph, cfg, {})
    np.testing.assert_array_equal(np.asarray(res.result),
                                  np.asarray(prog.result(state)))
    assert validate_coloring(final_graph, res.result)
    assert not any(r.incremental for r in res.batches)


def test_coloring_conflicts_stream_valid_and_cheaper():
    from repro.algorithms.coloring import validate_coloring

    base = rmat(7, edge_factor=6, seed=4)
    deltas = edge_delta_stream(base, 3, 16, seed=7)
    cfg = _cfg("single", 1, True)
    inc = stream_execute("coloring", base, deltas, cfg)  # default: conflicts
    full = stream_execute("coloring", base, deltas, cfg, incremental=False)
    final_graph = replay(base, deltas)
    assert validate_coloring(final_graph, inc.result)
    assert validate_coloring(final_graph, full.result)
    # repair work (re-color conflict losers only) << full recolor work
    inc_w = sum(r.work for r in inc.batches[1:])
    full_w = sum(r.work for r in full.batches[1:])
    assert inc_w < full_w
    assert all(r.incremental for r in inc.batches[1:])
    assert not any(r.incremental for r in full.batches)


def test_full_reseed_matches_incremental_bfs():
    """incremental=False is the correctness baseline: both must equal the
    from-scratch run, hence each other."""
    base = grid2d(10, 10)
    deltas = edge_delta_stream(base, 2, 8, seed=8)
    cfg = _cfg("single", 1, False)
    inc = stream_execute("bfs", base, deltas, cfg, params={"source": 0})
    full = stream_execute("bfs", base, deltas, cfg, params={"source": 0},
                          incremental=False)
    np.testing.assert_array_equal(np.asarray(inc.result),
                                  np.asarray(full.result))
    assert not any(r.incremental for r in full.batches)


def test_fused_topology_stream_parity():
    base = rmat(6, edge_factor=6, seed=5)
    deltas = edge_delta_stream(base, 2, 10, seed=9)
    cfg = SchedulerConfig(num_workers=32, topology="fused", persistent=True)
    res = stream_execute("bfs", base, deltas, cfg, params={"source": 1})
    final_graph = replay(base, deltas)
    prog, state = _scratch("bfs", final_graph, cfg, {"source": 1})
    np.testing.assert_array_equal(np.asarray(res.result),
                                  np.asarray(prog.result(state)))


# ------------------------------------------------- snapshot / resume
def test_snapshot_resume_bit_identical(tmp_path):
    base = rmat(6, edge_factor=6, seed=6)
    deltas = edge_delta_stream(base, 3, 12, seed=11)
    cfg = _cfg("single", 1, False)
    params = {"source": 2}
    ref = stream_execute("bfs", base, deltas, cfg, params=params)

    # run with snapshots, then resume from an *older* snapshot by
    # truncating the directory to simulate a crash after tick K
    d = str(tmp_path / "snaps")
    full = stream_execute("bfs", base, deltas, cfg, params=params,
                          snapshot_every=2, checkpoint_dir=d, keep=100)
    ticks = sorted(int(p.split("_")[1]) for p in os.listdir(d)
                   if p.startswith("snap_"))
    assert len(ticks) >= 3
    for t in ticks[len(ticks) // 2:]:  # drop the newer half
        import shutil
        shutil.rmtree(os.path.join(d, f"snap_{t}"))
    res = stream_execute("bfs", base, deltas, cfg, params=params,
                         snapshot_every=2, checkpoint_dir=d, keep=100,
                         resume=True)
    assert res.info["resumed_at"] is not None
    np.testing.assert_array_equal(np.asarray(res.result),
                                  np.asarray(ref.result))
    np.testing.assert_array_equal(np.asarray(res.result),
                                  np.asarray(full.result))


def test_snapshot_resume_sharded(tmp_path):
    base = rmat(6, edge_factor=6, seed=7)
    deltas = edge_delta_stream(base, 2, 10, seed=12)
    cfg = _cfg("sharded", 1, True)
    params = {"source": 0}
    ref = stream_execute("bfs", base, deltas, cfg, params=params)
    d = str(tmp_path / "snaps")
    stream_execute("bfs", base, deltas, cfg, params=params,
                   snapshot_every=2, checkpoint_dir=d, keep=100)
    # resume from the second-newest snapshot
    ticks = sorted(int(p.split("_")[1]) for p in os.listdir(d)
                   if p.startswith("snap_"))
    import shutil
    shutil.rmtree(os.path.join(d, f"snap_{ticks[-1]}"))
    res = stream_execute("bfs", base, deltas, cfg, params=params,
                         snapshot_every=2, checkpoint_dir=d, keep=100,
                         resume=True)
    np.testing.assert_array_equal(np.asarray(res.result),
                                  np.asarray(ref.result))


def test_snapshot_fingerprint_guards_graph_identity(tmp_path):
    from repro.stream import SnapshotManager, graph_fingerprint

    g1 = grid2d(5, 5)
    g2 = grid2d(6, 6)
    mgr = SnapshotManager(str(tmp_path))
    state = {"x": jnp.arange(4, dtype=jnp.int32)}
    queue = {"q": jnp.zeros(3, jnp.int32)}
    cursor = {k: 0 for k in ("batch", "rounds", "processed", "pre_work",
                             "pre_splits", "seeds", "eff")}
    mgr.save(0, cursor=cursor, graph=g1, num_deltas=0,
             queue=queue, state=state)
    assert mgr.peek(0)["batch"] == 0
    fp = graph_fingerprint(g1, 0)
    assert mgr.peek(0)["fingerprint"] == {k: int(v) for k, v in fp.items()}
    with pytest.raises(ValueError, match="fingerprint"):
        mgr.restore(0, queue_template=queue, state_template=state,
                    graph=g2, num_deltas=0)
    out = mgr.restore(0, queue_template=queue, state_template=state,
                      graph=g1, num_deltas=0)
    np.testing.assert_array_equal(np.asarray(out["state"]["x"]),
                                  np.asarray(state["x"]))


def test_stream_spec_validation():
    with pytest.raises(ValueError, match="checkpoint_dir"):
        StreamSpec(deltas=(), resume=True)
    with pytest.raises(ValueError, match="checkpoint_dir"):
        StreamSpec(deltas=(), snapshot_every=4)
    s = StreamSpec(deltas=[make_delta(4, [0], [1], [True])])
    assert isinstance(s.deltas, tuple) and len(s.deltas) == 1


# ------------------------------------------------- server integration
def test_server_streaming_job_parity():
    from repro.server import JobRegistry, JobSpec, TaskServer

    base = grid2d(8, 8)
    deltas = edge_delta_stream(base, 2, 8, seed=13)
    reg = JobRegistry()
    reg.register_graph("g", base)
    server = TaskServer(reg, num_lanes=2)
    server.submit(JobSpec("bfs", "g", {"source": 0},
                          stream=StreamSpec(deltas=tuple(deltas))))
    server.submit(JobSpec("coloring", "g"))  # fused batch job alongside
    result = server.run()
    assert result.stats.streaming_jobs == 1
    assert result.stats.stream_batches == 3

    cfg = SchedulerConfig(num_workers=64, topology="single")
    final_graph = replay(base, deltas)
    prog, state = _scratch("bfs", final_graph, cfg, {"source": 0})
    job = server._jobs[0]
    np.testing.assert_array_equal(np.asarray(job.result),
                                  np.asarray(prog.result(state)))


# --------------------------------------------- 8-device subprocess
def _run(body: str, timeout=900) -> dict:
    prog = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            "os.environ.setdefault('JAX_PLATFORMS', 'cpu')\n"
            + textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_multidevice_stream_parity():
    """8-shard streaming BFS: bit-identical to the single-topology stream
    AND to a cold sharded run on the final graph."""
    res = _run("""
        import json
        import numpy as np
        from repro.core import SchedulerConfig
        from repro.graph.generators import edge_delta_stream, rmat
        from repro.runtime import build_program, execute, stream_execute
        from repro.stream import replay

        base = rmat(7, edge_factor=8, seed=2)
        deltas = edge_delta_stream(base, 2, 16, seed=3)
        params = {"source": 0}

        scfg = SchedulerConfig(num_workers=32, topology="sharded",
                               num_shards=8)
        sres = stream_execute("bfs", base, deltas, scfg, params=params)

        cfg1 = SchedulerConfig(num_workers=32, topology="single")
        r1 = stream_execute("bfs", base, deltas, cfg1, params=params)

        final = replay(base, deltas)
        prog = build_program("bfs", final, scfg, params=dict(params))
        cold = execute(prog, final, scfg)

        print(json.dumps({
            "vs_single": bool((np.asarray(sres.result)
                               == np.asarray(r1.result)).all()),
            "vs_cold": bool((np.asarray(sres.result)
                             == np.asarray(prog.result(cold.state))).all()),
            "dropped": int(sres.info["dropped"]),
            "mis": int(sres.info.get("mis_routed", 0)),
        }))
    """)
    assert res["vs_single"] and res["vs_cold"]
    assert res["dropped"] == 0 and res["mis"] == 0


# ------------------------------ slotted commit path (DESIGN.md section 17)
def test_stream_commit_counters_and_compaction_schedule():
    """Every commit is O(delta): touched rows stay strictly below m, the
    overlay stays bounded, and --compact-every drives a deterministic
    compaction schedule surfaced in the per-batch records."""
    base = rmat(6, edge_factor=6, seed=17)
    deltas = edge_delta_stream(base, 4, 12, seed=18)
    cfg = _cfg("single", 1, True)
    res = stream_execute("bfs", base, deltas, cfg, params={"source": 0},
                         compact_every=2)
    m = base.num_edges
    assert res.batches[0].touched_rows == 0          # cold batch, no commit
    for r in res.batches[1:]:
        assert 0 < r.touched_rows < m
        assert r.commit_seconds >= 0.0
    assert res.info["touched_rows"] == sum(r.touched_rows
                                           for r in res.batches)
    # compact_every=2: exactly the even batches re-pack
    assert [r.compacted for r in res.batches] == \
        [b > 0 and b % 2 == 0 for b in range(len(res.batches))]
    assert res.info["compactions"] == sum(r.compacted for r in res.batches)


def test_bfs_tight_rule_resets_only_disconnected_region():
    """Satellite regression for the region-pruned delete rule: on two
    chains hanging off the source, deleting one chain's first tree edge
    must invalidate only that chain — the conservative level-cut resets
    the other chain's equal-or-deeper levels too (and then re-derives
    them).  Both rules' outputs re-drain to the same fixed point; the
    tight rule provably touches a strict subset."""
    from repro.core.task import ChunkCodec
    from repro.graph import SlottedCSR
    from repro.stream.incremental import (BFS_INF, bfs_dirty_seeds,
                                          bfs_dirty_seeds_conservative)

    # 0-1-2-3-4 and 0-5-6-7-8, symmetric
    und = [(0, 1), (1, 2), (2, 3), (3, 4), (0, 5), (5, 6), (6, 7), (7, 8)]
    src = [e[0] for e in und] + [e[1] for e in und]
    dst = [e[1] for e in und] + [e[0] for e in und]
    g = from_edges(9, src, dst)
    cfg = _cfg("single", 1, True)
    prog, state = _scratch("bfs", g, cfg, {"source": 0})
    assert np.asarray(state.dist).tolist() == [0, 1, 2, 3, 4, 1, 2, 3, 4]

    slotted = SlottedCSR.from_csr(g)
    assert slotted.symmetric
    applied = apply_delta(slotted, make_delta(9, [1, 2], [2, 1],
                                              [False, False]))
    kw = dict(codec=ChunkCodec(1), split_threshold=None, owner_block=None)
    st_t, seeds_t = bfs_dirty_seeds(applied, state, **kw)
    st_c, seeds_c = bfs_dirty_seeds_conservative(applied, state, **kw)

    inf_t = set(np.flatnonzero(np.asarray(st_t.dist) == BFS_INF).tolist())
    inf_c = set(np.flatnonzero(np.asarray(st_c.dist) == BFS_INF).tolist())
    assert inf_t == {2, 3, 4}            # the disconnected chain only
    assert inf_c == {2, 3, 4, 6, 7, 8}   # level-cut collateral
    assert inf_t < inf_c
    # nothing can relax back into the detached region; the conservative
    # rule must reseed vertex 5 to rebuild the chain it reset
    assert np.asarray(seeds_t).size == 0
    assert 5 in np.asarray(seeds_c).tolist()
    # untouched entries carry over bit-for-bit
    keep = [0, 1, 5]
    assert np.asarray(st_t.dist)[keep].tolist() == [0, 1, 1]


def test_bfs_tight_rule_stream_parity_and_work(monkeypatch):
    """End-to-end: the tight rule and the conservative oracle both land on
    the from-scratch distances; the tight rule does no more re-drain work
    (the BENCH_stream work-ratio gap this rule closes)."""
    base = rmat(6, edge_factor=6, seed=21)
    deltas = edge_delta_stream(base, 3, 12, seed=22)
    cfg = _cfg("single", 1, True)
    params = {"source": 0}
    tight = stream_execute("bfs", base, deltas, cfg, params=params)

    import repro.stream.incremental as inc
    monkeypatch.setattr(inc, "bfs_dirty_seeds",
                        inc.bfs_dirty_seeds_conservative)
    cons = stream_execute("bfs", base, deltas, cfg, params=params)

    final_graph = replay(base, deltas)
    prog, state = _scratch("bfs", final_graph, cfg, params)
    ref = np.asarray(prog.result(state))
    np.testing.assert_array_equal(np.asarray(tight.result), ref)
    np.testing.assert_array_equal(np.asarray(cons.result), ref)
    t_work = sum(r.work for r in tight.batches[1:])
    c_work = sum(r.work for r in cons.batches[1:])
    assert t_work <= c_work


def test_asymmetric_stream_falls_back_to_conservative():
    """Directed (asymmetric) deltas break the tight rule's in-neighbor
    scan; the dispatch must quietly use the conservative rule and still
    match the from-scratch drain."""
    base = rmat(5, edge_factor=6, seed=23)
    # one *directed* delete: the graph goes asymmetric at batch 1
    rp = np.asarray(base.row_ptr)
    ci = np.asarray(base.col_idx)
    s0 = int(np.flatnonzero(np.diff(rp) > 0)[0])
    t0 = int(ci[rp[s0]])
    deltas = [make_delta(base.num_vertices, [s0], [t0], [False])]
    cfg = _cfg("single", 1, False)
    res = stream_execute("bfs", base, deltas, cfg, params={"source": 0})
    final_graph = replay(base, deltas)
    prog, state = _scratch("bfs", final_graph, cfg, {"source": 0})
    np.testing.assert_array_equal(np.asarray(res.result),
                                  np.asarray(prog.result(state)))
    assert all(r.incremental for r in res.batches[1:])


# ------------------------- SIGKILL through the slotted commit (resume)
_SLOTTED_CRASH_CHILD = """
    import json
    import os
    import signal
    import numpy as np
    from repro.core import SchedulerConfig
    from repro.graph.generators import edge_delta_stream, rmat
    from repro.runtime import stream_execute

    base = rmat(6, edge_factor=6, seed=19)
    deltas = edge_delta_stream(base, 4, 12, seed=20)
    cfg = SchedulerConfig(num_workers=32, topology="single",
                          persistent=False)
    kill_at = int(os.environ.get("KILL_AT_TICK", "-1"))

    def hook(tick, batch):
        if tick == kill_at:
            os.kill(os.getpid(), signal.SIGKILL)

    res = stream_execute(
        "bfs", base, deltas, cfg, params={"source": 2},
        compact_every=2, overlay_slack=0.05,
        snapshot_every=2, checkpoint_dir=os.environ["SNAP_DIR"],
        keep=100, resume=os.environ.get("RESUME") == "1",
        snapshot_hook=hook)
    print(json.dumps({
        "result": np.asarray(res.result).tolist(),
        "resumed_at": res.info["resumed_at"],
        "batches_run": res.info["batches_run"],
        "compactions": res.info["compactions"],
        "touched": res.info["touched_rows"],
    }))
"""


def _slotted_crash_child(snap_dir, kill_at=-1, resume=False):
    prog = ("import os\n"
            "os.environ.setdefault('JAX_PLATFORMS', 'cpu')\n"
            + textwrap.dedent(_SLOTTED_CRASH_CHILD))
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               SNAP_DIR=str(snap_dir), KILL_AT_TICK=str(kill_at),
               RESUME="1" if resume else "0")
    return subprocess.run([sys.executable, "-c", prog],
                          capture_output=True, text=True, env=env,
                          timeout=900)


def test_sigkill_resume_replays_slotted_commits(tmp_path):
    """SIGKILL a streaming drain whose commits run through the slotted
    path with compactions every 2 batches; the resumed process replays
    the delta prefix through the *same* commit schedule
    (ingest.replay_commits) and reproduces the uninterrupted run bit for
    bit — including the compaction count, which is a pure function of
    the delta log and the knobs."""
    import signal

    ref_dir = tmp_path / "ref"
    out = _slotted_crash_child(ref_dir)
    assert out.returncode == 0, out.stderr[-3000:]
    ref = json.loads(out.stdout.strip().splitlines()[-1])
    assert ref["resumed_at"] is None
    assert ref["compactions"] >= 2       # the schedule actually fired

    crash_dir = tmp_path / "crash"
    killed = _slotted_crash_child(crash_dir, kill_at=3)
    assert killed.returncode == -signal.SIGKILL
    assert any(p.startswith("snap_") for p in os.listdir(crash_dir))

    resumed = _slotted_crash_child(crash_dir, resume=True)
    assert resumed.returncode == 0, resumed.stderr[-3000:]
    got = json.loads(resumed.stdout.strip().splitlines()[-1])
    assert got["resumed_at"] is not None
    assert got["batches_run"] < ref["batches_run"]
    assert got["result"] == ref["result"]
    assert got["compactions"] == ref["compactions"]
