"""Backend dispatch layer: resolution rules + kernel-backed push parity.

The contract under test (DESIGN.md section 9): ``backend`` is a pure
performance axis — every dispatch site must produce *bit-identical* results
whether it runs the jnp reference or the Pallas kernels (interpret mode on
CPU).  The queue tests here deliberately avoid hypothesis so they always run.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BACKENDS, SchedulerConfig, default_interpret,
                        expand_merge_path, has_tpu, make_multiqueue,
                        make_queue, resolve_backend, resolve_interpret)


# ------------------------------------------------------------- resolution
def test_resolve_backend_values():
    assert resolve_backend("jnp") == "jnp"
    assert resolve_backend("pallas") == "pallas"
    auto = resolve_backend("auto")
    assert auto in ("jnp", "pallas")
    assert auto == ("pallas" if has_tpu() else "jnp")


def test_resolve_backend_rejects_unknown():
    with pytest.raises(ValueError, match="unknown backend"):
        resolve_backend("cuda")


def test_interpret_resolution_tracks_hardware():
    # off-TPU the kernels must interpret; on TPU they must compile.
    assert default_interpret() == (not has_tpu())
    assert resolve_interpret(None) == default_interpret()
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False


def test_scheduler_config_carries_backend_axis():
    assert SchedulerConfig().backend == "jnp"
    assert "auto" in BACKENDS
    cfg = dataclasses.replace(SchedulerConfig(), backend="pallas")
    assert cfg.backend == "pallas"
    assert cfg != SchedulerConfig()  # backend is part of config identity


# ------------------------------------------------- queue push parity (jnp
# prefix-sum reservation is the oracle for the queue_compact-backed push)
def _assert_queues_equal(qa, qb, ctx=""):
    for field in ("buf", "head", "tail", "dropped"):
        np.testing.assert_array_equal(
            np.asarray(getattr(qa, field)), np.asarray(getattr(qb, field)),
            err_msg=f"{field} diverged {ctx}")


@pytest.mark.parametrize("mask", [
    [True, True, True, True, True, True],       # dense
    [True, False, True, False, True, False],    # holes to compact
    [False] * 6,                                # nothing valid
])
def test_pallas_push_matches_prefix_sum_oracle(mask):
    items = jnp.arange(10, 16, dtype=jnp.int32)
    mask = jnp.asarray(mask)
    q0 = make_queue(16, jnp.array([1, 2, 3]))
    _assert_queues_equal(q0.push(items, mask),
                         q0.push(items, mask, backend="pallas"))


def test_pallas_push_dropped_counter_path():
    """Overflow: 5 valid items into 3 free slots — both backends must keep
    the same survivors (the first 3 valid, in order) and count 2 drops."""
    q0 = make_queue(8, jnp.array([1, 2, 3, 4, 5]))
    items = jnp.arange(10, 16, dtype=jnp.int32)
    mask = jnp.array([True, False, True, True, True, True])
    qa = q0.push(items, mask)
    qb = q0.push(items, mask, backend="pallas")
    _assert_queues_equal(qa, qb, "on overflow")
    assert int(qb.dropped) == 2
    got, valid, _ = qb.pop(8)
    assert [int(x) for x, v in zip(np.asarray(got), np.asarray(valid)) if v] \
        == [1, 2, 3, 4, 5, 10, 12, 13]


def test_pallas_push_wraparound_sequence():
    """Interleaved pops/pushes drive the ring cursors past the buffer edge;
    the two backends must stay in lockstep at every step."""
    qa = make_queue(4, jnp.array([0, 1]))
    qb = make_queue(4, jnp.array([0, 1]))
    for i in range(10):
        _, _, qa = qa.pop(1)
        _, _, qb = qb.pop(1)
        items = jnp.array([100 + i, 200 + i], jnp.int32)
        mask = jnp.array([True, i % 2 == 0])
        qa = qa.push(items, mask)
        qb = qb.push(items, mask, backend="pallas")
        _assert_queues_equal(qa, qb, f"at step {i}")


def test_pallas_push_spans_multiple_tiles():
    """Widths past the kernel TILE exercise the phase-2 cross-tile stitch."""
    from repro.kernels.queue_compact.kernel import TILE

    n = 2 * TILE + 37
    rng = np.random.default_rng(3)
    items = jnp.asarray(rng.integers(0, 1 << 20, size=n), jnp.int32)
    mask = jnp.asarray(rng.random(n) < 0.4)
    q0 = make_queue(2 * n)
    _assert_queues_equal(q0.push(items, mask),
                         q0.push(items, mask, backend="pallas"))


def test_multiqueue_push_backend_parity():
    mqa = make_multiqueue(8, 3)
    mqb = make_multiqueue(8, 3)
    for lane in range(3):
        items = jnp.arange(lane * 10, lane * 10 + 12, dtype=jnp.int32)
        mask = jnp.asarray(np.arange(12) % (lane + 2) == 0)
        mqa = mqa.push(lane, items, mask)
        mqb = mqb.push(lane, items, mask, backend="pallas")
    _assert_queues_equal(mqa.lanes, mqb.lanes)


def test_push_dense_backend_parity():
    q0 = make_queue(8)
    _assert_queues_equal(q0.push_dense(jnp.arange(5, dtype=jnp.int32)),
                         q0.push_dense(jnp.arange(5, dtype=jnp.int32),
                                       backend="pallas"))


# -------------------------------------------------------- expand dispatch
def test_expand_merge_path_backend_parity():
    from repro.graph import rmat

    g = rmat(7, 4, seed=5)
    items = jnp.array([1, 4, 9, 16, 25, 36, 49, 64], jnp.int32)
    valid = jnp.array([True] * 7 + [False])
    budget = 8 * int(jnp.max(g.degrees()))
    ref = expand_merge_path(items, valid, g.row_ptr, g.col_idx, budget)
    for backend in ("pallas", "auto"):
        got = expand_merge_path(items, valid, g.row_ptr, g.col_idx, budget,
                                backend=backend)
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
