"""Scheduler semantics: persistent == discrete; knobs behave as documented."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SchedulerConfig, discrete_run, make_queue, persistent_run


def countdown(items, valid, state):
    new = items - 1
    mask = valid & (new > 0)
    return new, mask, state + jnp.sum(valid.astype(jnp.int32))


@pytest.mark.parametrize("workers,fetch", [(1, 1), (2, 2), (8, 4)])
def test_persistent_equals_discrete(workers, fetch):
    seeds = jnp.array([5, 3, 1, 7, 2])
    cfg = SchedulerConfig(num_workers=workers, fetch_size=fetch,
                          max_rounds=1000)
    q1, s1, st1 = persistent_run(countdown, make_queue(256, seeds),
                                 jnp.int32(0), cfg)
    q2, s2, st2 = discrete_run(countdown, make_queue(256, seeds),
                               jnp.int32(0), cfg)
    assert int(s1) == int(s2) == int(jnp.sum(seeds))  # total work
    assert int(st1.rounds) == int(st2.rounds)
    assert int(st1.dropped) == int(st2.dropped) == 0


def test_wavefront_width_reduces_rounds():
    seeds = jnp.arange(1, 20, dtype=jnp.int32)
    small = SchedulerConfig(num_workers=1, fetch_size=1, max_rounds=10000)
    large = SchedulerConfig(num_workers=16, fetch_size=4, max_rounds=10000)
    _, _, st_small = persistent_run(countdown, make_queue(1024, seeds),
                                    jnp.int32(0), small)
    _, _, st_large = persistent_run(countdown, make_queue(1024, seeds),
                                    jnp.int32(0), large)
    assert int(st_large.rounds) < int(st_small.rounds)


def test_stop_condition():
    cfg = SchedulerConfig(num_workers=2, fetch_size=1, max_rounds=1000)
    _, s, st = persistent_run(
        countdown, make_queue(64, jnp.array([100, 100])), jnp.int32(0), cfg,
        stop=lambda s: s >= 10)
    assert int(s) >= 10 and int(st.rounds) < 100


def test_on_empty_runs_until_stop():
    cfg = SchedulerConfig(num_workers=1, fetch_size=1, max_rounds=1000)

    def f(items, valid, state):
        return items, jnp.zeros_like(valid), state

    def on_empty(state):
        return (jnp.zeros((1,), jnp.int32), jnp.zeros((1,), bool), state + 1)

    _, s, st = persistent_run(f, make_queue(8), jnp.int32(0), cfg,
                              stop=lambda s: s >= 5, on_empty=on_empty)
    assert int(s) == 5


def test_max_rounds_bounds_runaway():
    def forever(items, valid, state):
        return items, valid, state  # re-push everything

    cfg = SchedulerConfig(num_workers=1, fetch_size=1, max_rounds=17)
    _, _, st = persistent_run(forever, make_queue(8, jnp.array([1])),
                              jnp.int32(0), cfg)
    assert int(st.rounds) == 17
