"""End-to-end behaviour: training learns; checkpoint-resume is bit-exact;
the serving driver completes all requests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import smoke_config
from repro.launch.train import train
from repro.optim import adamw


def test_training_reduces_loss():
    """The whole stack (data -> model -> optimizer) learns the synthetic
    stream: loss must drop substantially."""
    cfg = smoke_config("stablelm-1.6b")
    _, _, info = train(cfg, steps=30, global_batch=8, seq_len=32,
                       opt_cfg=adamw.AdamWConfig(lr=2e-3, warmup_steps=5,
                                                 total_steps=30),
                       log=lambda *a: None)
    first = np.mean(info["losses"][:3])
    last = np.mean(info["losses"][-3:])
    assert last < first - 0.5, (first, last)


def test_train_checkpoint_resume_bit_exact(tmp_path):
    cfg = smoke_config("olmoe-1b-7b")
    kw = dict(global_batch=4, seq_len=16, save_every=5, log=lambda *a: None)
    # uninterrupted 10 steps
    p_ref, _, _ = train(cfg, steps=10, **kw)
    # 10 steps with a stop at 5 + resume
    p1, _, _ = train(cfg, steps=5, ckpt_dir=str(tmp_path), **kw)
    p2, _, _ = train(cfg, steps=10, ckpt_dir=str(tmp_path), **kw)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_moe_training_reduces_loss():
    cfg = smoke_config("olmoe-1b-7b")
    _, _, info = train(cfg, steps=25, global_batch=8, seq_len=32,
                       opt_cfg=adamw.AdamWConfig(lr=2e-3, warmup_steps=5,
                                                 total_steps=25),
                       log=lambda *a: None)
    assert np.mean(info["losses"][-3:]) < np.mean(info["losses"][:3]) - 0.3


def test_ssm_training_reduces_loss():
    cfg = smoke_config("falcon-mamba-7b")
    _, _, info = train(cfg, steps=25, global_batch=8, seq_len=32,
                       opt_cfg=adamw.AdamWConfig(lr=2e-3, warmup_steps=5,
                                                 total_steps=25),
                       log=lambda *a: None)
    assert np.mean(info["losses"][-3:]) < np.mean(info["losses"][:3]) - 0.3
