"""Slotted-CSR commit path (graph/slotted.py, DESIGN.md §17).

The contract under test: a slotted CSR fed any canonical delta log is
**bit-identical to the ``from_edges`` oracle on the same edge set** — at
every commit, before and after compaction — and every read path (jnp
reference, Pallas LBS wrapper, megakernel DMA stream, sharded per-owner
patch) sees exactly the canonical adjacency through the slab + overlay
two-level gather.

Tiers:

  * structural units: build/round-trip, slab sizing, overlay spill,
    slack-forced compaction, effective-op parity with the reference path;
  * seeded-fuzz parity battery (always runs) plus its hypothesis twin
    (gated): random insert/delete/duplicate logs vs the oracle;
  * read-path parity: expansion bit-equality vs the canonical gather for
    g in {1, 4} on jnp / pallas / megakernel-stream backends, and
    end-to-end drains on a slotted view;
  * sharded per-owner patch vs full repartition;
  * representation-independent snapshot fingerprints.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.graph import CSRGraph, SlottedCSR, from_edges
from repro.graph.generators import edge_delta_stream, erdos, grid2d, rmat
from repro.graph.slotted import SLAB_SLACK
from repro.stream import apply_delta, commit, make_delta, replay

TOPOLOGIES = [
    ("rmat", lambda: rmat(5, edge_factor=6, seed=1)),
    ("grid", lambda: grid2d(6, 6)),
    ("erdos", lambda: erdos(40, 160, seed=2)),
]


def _assert_csr_equal(got: CSRGraph, want: CSRGraph, msg=""):
    np.testing.assert_array_equal(np.asarray(got.row_ptr),
                                  np.asarray(want.row_ptr), err_msg=msg)
    np.testing.assert_array_equal(np.asarray(got.col_idx),
                                  np.asarray(want.col_idx), err_msg=msg)


def _oracle(n, edge_set):
    if edge_set:
        e = np.array(sorted(edge_set), dtype=np.int64)
        return from_edges(n, e[:, 0], e[:, 1])
    return from_edges(n, np.empty(0, np.int64), np.empty(0, np.int64))


def _edge_set(graph):
    rp = np.asarray(graph.row_ptr, np.int64)
    ci = np.asarray(graph.col_idx, np.int64)
    src = np.repeat(np.arange(graph.num_vertices, dtype=np.int64),
                    np.diff(rp))
    return set(zip(src.tolist(), ci.tolist()))


# ------------------------------------------------------------ structure
@pytest.mark.parametrize("name,make", TOPOLOGIES)
def test_from_csr_round_trip_bit_identical(name, make):
    g = make()
    s = SlottedCSR.from_csr(g)
    _assert_csr_equal(s.to_csr(), g, name)
    # pow2 slabs, fully live, empty overlay at build time
    caps = np.diff(s.slab_ptr)
    deg = np.diff(np.asarray(g.row_ptr, np.int64))
    assert (caps >= np.maximum(deg, 1)).all()
    assert ((caps & (caps - 1)) == 0).all()          # powers of two
    np.testing.assert_array_equal(s.slab_len, deg)
    assert s.overlay_size == 0


def test_symmetry_tracked():
    assert SlottedCSR.from_csr(grid2d(4, 4)).symmetric
    assert not SlottedCSR.from_csr(from_edges(4, [0, 1], [1, 2])).symmetric


def test_symmetry_maintained_per_commit():
    s = SlottedCSR.from_csr(grid2d(4, 4))
    # mirrored ops keep the flag up
    s.apply(np.array([0, 5]), np.array([5, 0]), np.array([True, True]))
    assert s.symmetric
    # a directed delete breaks it — the tight BFS rule must not fire now
    s.apply(np.array([0]), np.array([5]), np.array([False]))
    assert not s.symmetric
    # a single commit can't raise the flag back...
    s.apply(np.array([5]), np.array([0]), np.array([False]))
    assert not s.symmetric
    # ...but compaction re-detects the (now again symmetric) edge set
    s.compact()
    assert s.symmetric
    _assert_csr_equal(s.to_csr(), grid2d(4, 4))


def test_overlay_spill_and_slab_prefix_order():
    # row 0 has slab cap 1; inserting more neighbors must spill the LARGER
    # ones to the overlay, keeping slab prefix + overlay tail sorted
    g = from_edges(6, [0], [3])
    s = SlottedCSR.from_csr(g)
    s.apply(np.array([0, 0, 0]), np.array([5, 1, 4]),
            np.array([True, True, True]))
    assert s.overlay_size == 3
    np.testing.assert_array_equal(s.row_neighbors(0), [1, 3, 4, 5])
    assert int(s.slab_len[0]) == 1
    assert int(s.slab_col[s.slab_ptr[0]]) == 1       # smallest stays in-slab
    _assert_csr_equal(s.to_csr(), from_edges(6, [0] * 4, [1, 3, 4, 5]))


def test_slack_violation_forces_compaction():
    # one high-degree row deleted down to almost nothing: cap / deg blows
    # past SLAB_SLACK, so should_compact fires regardless of the knobs
    n = 34
    src = np.zeros(32, np.int64)
    dst = np.arange(1, 33, dtype=np.int64)
    s = SlottedCSR.from_csr(from_edges(n, src, dst))
    cap0 = int(s.slab_ptr[1] - s.slab_ptr[0])
    s.apply(src[:-1], dst[:-1], np.zeros(31, bool))  # delete all but one
    assert s.should_compact(batch_index=1, compact_every=0,
                            overlay_slack=1e9)
    s.compact()
    cap1 = int(s.slab_ptr[1] - s.slab_ptr[0])
    assert cap1 <= SLAB_SLACK and cap1 < cap0
    assert not s.should_compact(batch_index=1, compact_every=0,
                                overlay_slack=1e9)
    _assert_csr_equal(s.to_csr(), from_edges(n, src[-1:], dst[-1:]))


def test_slotted_effective_ops_match_reference():
    g = erdos(30, 100, seed=3)
    s = SlottedCSR.from_csr(g)
    d = edge_delta_stream(g, 1, 24, seed=4)[0]
    ref = apply_delta(g, d)
    got = apply_delta(s, d)
    for f in ("ins_src", "ins_dst", "del_src", "del_dst"):
        np.testing.assert_array_equal(getattr(got, f), getattr(ref, f), f)
    assert got.touched_rows > 0
    assert got.touched_rows < g.num_vertices
    _assert_csr_equal(got.csr(), ref.new_graph)


def test_commit_compaction_schedule_is_deterministic():
    g = rmat(5, edge_factor=4, seed=5)
    deltas = edge_delta_stream(g, 6, 20, seed=6)
    runs = []
    for _ in range(2):
        s = SlottedCSR.from_csr(g)
        runs.append([commit(s, d, b + 1, 2, 0.25).compacted
                     for b, d in enumerate(deltas)])
    assert runs[0] == runs[1]
    assert any(runs[0])  # compact_every=2 fires


# ----------------------------------------------------- seeded-fuzz twin
def _fuzz_case(rng, n):
    k = int(rng.integers(1, 40))
    src = rng.integers(0, n, k)
    dst = rng.integers(0, n, k)
    ins = rng.random(k) < 0.55
    keep = src != dst             # make_delta rejects self-loops by contract
    if not keep.any():
        return None
    return make_delta(n, src[keep], dst[keep], ins[keep])


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fuzz_delta_log_parity_vs_oracle(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 48))
    m0 = int(rng.integers(0, 4 * n))
    base = from_edges(n, rng.integers(0, n, m0), rng.integers(0, n, m0))
    s = SlottedCSR.from_csr(base)
    edges = _edge_set(base)
    for b in range(1, 25):
        d = _fuzz_case(rng, n)
        if d is None:
            continue
        commit(s, d, b, compact_every=int(rng.integers(0, 4)),
               overlay_slack=float(rng.choice([0.05, 0.25, 1.0])))
        for ss, dd, ii in zip(d.src.tolist(), d.dst.tolist(),
                              d.insert.tolist()):
            (edges.add if ii else edges.discard)((ss, dd))
        want = _oracle(n, edges)
        _assert_csr_equal(s.to_csr(), want, f"seed={seed} batch={b}")
        # slab-slack invariant holds after every commit+schedule step
        caps = np.diff(s.slab_ptr)
        assert (caps <= SLAB_SLACK * np.maximum(s.deg, 1)).all() or \
            s.should_compact(b, 0, 1e9)
    assert s.commits >= 1


def test_hypothesis_delta_log_parity():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @st.composite
    def log(draw):
        n = draw(st.integers(min_value=2, max_value=14))
        pairs = st.tuples(st.integers(0, n - 1), st.integers(0, n - 1))
        edges = [e for e in draw(st.lists(pairs, max_size=40))
                 if e[0] != e[1]]
        batches = draw(st.lists(
            st.lists(st.tuples(st.integers(0, n - 1),
                               st.integers(0, n - 1), st.booleans()),
                     max_size=16),
            min_size=1, max_size=6))
        every = draw(st.integers(min_value=0, max_value=3))
        return n, edges, batches, every

    @settings(max_examples=50, deadline=None)
    @given(log())
    def check(case):
        n, edges, batches, every = case
        base = _oracle(n, set(edges))
        s = SlottedCSR.from_csr(base)
        cur = _edge_set(base)
        for b, ops in enumerate(batches, start=1):
            ops = [o for o in ops if o[0] != o[1]]
            if not ops:
                continue
            d = make_delta(n, [o[0] for o in ops], [o[1] for o in ops],
                           [o[2] for o in ops])
            commit(s, d, b, compact_every=every)
            for ss, dd, ii in ops:          # in-order replay = last wins
                (cur.add if ii else cur.discard)((ss, dd))
            _assert_csr_equal(s.to_csr(), _oracle(n, cur))

    check()


# ------------------------------------------------------- read-path parity
def _mutated_slotted(seed=7):
    """A slotted graph with a non-trivial overlay + mixed slab occupancy."""
    g = rmat(5, edge_factor=6, seed=seed)
    s = SlottedCSR.from_csr(g)
    for b, d in enumerate(edge_delta_stream(g, 4, 24, seed=seed + 1),
                          start=1):
        apply_delta(s, d)     # no compaction: keep the overlay populated
    return s


@pytest.mark.parametrize("g", [1, 4])
@pytest.mark.parametrize("backend", ["jnp", "pallas", "stream"])
def test_expand_parity_slotted_vs_canonical(g, backend):
    from repro.core.frontier import adjacency_of, expand_merge_path

    s = _mutated_slotted()
    assert s.overlay_size > 0, "fixture must exercise the overlay tail"
    view = s.view()
    canon = s.to_csr()
    n = canon.num_vertices
    heads = jnp.asarray(np.arange(0, n - g, g, dtype=np.int32)[:24])
    widths = jnp.full(heads.shape, g, jnp.int32) if g > 1 else None
    valid = jnp.ones(heads.shape, bool)
    budget = 1024
    rp, cols, ovl = adjacency_of(view)
    ref = expand_merge_path(heads, valid, canon.row_ptr, canon.col_idx,
                            budget, widths=widths, max_width=g)
    got = expand_merge_path(heads, valid, rp, cols, budget, backend=backend,
                            widths=widths, max_width=g, overlay=ovl)
    for name, a, b in zip(ref._fields, ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{backend} g={g} {name}")


def test_expand_per_item_parity_slotted():
    from repro.core.frontier import adjacency_of, expand_per_item

    s = _mutated_slotted(seed=9)
    view = s.view()
    canon = s.to_csr()
    rp, cols, ovl = adjacency_of(view)
    items = jnp.asarray(np.arange(view.num_vertices, dtype=np.int32))
    valid = jnp.ones(items.shape, bool)
    md = int(np.diff(np.asarray(canon.row_ptr)).max())
    ref = expand_per_item(items, valid, canon.row_ptr, canon.col_idx, md)
    got = expand_per_item(items, valid, rp, cols, md, overlay=ovl)
    for name, a, b in zip(ref._fields, ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


def test_view_has_no_flat_col_idx():
    # any consumer reaching for .col_idx on a slotted view is reading the
    # wrong representation — it must fail loudly, not read slab slots
    s = _mutated_slotted()
    with pytest.raises(AttributeError):
        _ = s.view().col_idx


@pytest.mark.parametrize("g", [1, 4])
def test_bfs_drain_on_slotted_view_bit_identical(g):
    from repro.core import SchedulerConfig
    from repro.runtime import build_program, execute

    s = _mutated_slotted(seed=11)
    assert s.overlay_size > 0
    canon = s.to_csr()
    cfg = SchedulerConfig(num_workers=32, granularity=g)
    params = {"source": 0}
    prog_c = build_program("bfs", canon, cfg, params=dict(params))
    res_c = execute(prog_c, canon, cfg)
    prog_s = build_program("bfs", s.view(), cfg, params=dict(params))
    res_s = execute(prog_s, s.view(), cfg)
    np.testing.assert_array_equal(
        np.asarray(prog_c.result(res_c.state)),
        np.asarray(prog_s.result(res_s.state)))
    assert res_c.stats.rounds == res_s.stats.rounds


# --------------------------------------------------------- sharded patch
@pytest.mark.parametrize("halo", [True, False])
def test_reshard_patch_matches_full_partition(halo):
    from repro.shard.partition import partition_graph
    from repro.stream import reshard

    g = erdos(48, 200, seed=5)
    s = SlottedCSR.from_csr(g)
    parts = reshard(s, 4, halo=halo)
    rng = np.random.default_rng(6)
    for b in range(1, 6):
        d = _fuzz_case(rng, 48)
        if d is None:
            continue
        applied = commit(s, d, b, compact_every=2)
        touched = np.concatenate([applied.ins_src, applied.del_src])
        parts = reshard(s, 4, halo=halo, parts=parts, touched_rows=touched)
        full = partition_graph(s.to_csr(), 4, halo=halo)
        assert parts.edges_per_shard == full.edges_per_shard
        np.testing.assert_array_equal(np.asarray(parts.row_ptr),
                                      np.asarray(full.row_ptr))
        # patched stack may carry wider (monotone) padding than a fresh
        # build; compare the meaningful prefix, require zero tail
        w = full.col_idx.shape[1]
        np.testing.assert_array_equal(np.asarray(parts.col_idx)[:, :w],
                                      np.asarray(full.col_idx))
        assert not np.asarray(parts.col_idx)[:, w:].any()


def test_reshard_patch_untouched_shards_not_rewritten():
    from repro.stream import reshard

    g = grid2d(8, 8)
    s = SlottedCSR.from_csr(g)
    parts = reshard(s, 4, halo=False)
    before = np.asarray(parts.col_idx).copy()
    # delete an edge inside shard 0 only (deletes can never overflow the
    # per-shard padding, so the patch path is guaranteed — no restack)
    d = make_delta(64, [0, 8], [8, 0], [False, False])
    applied = commit(s, d, 1)
    touched = np.concatenate([applied.ins_src, applied.del_src])
    assert set(np.unique(touched)) <= {0, 8}
    patched = reshard(s, 4, halo=False, parts=parts, touched_rows=touched)
    after = np.asarray(patched.col_idx)
    np.testing.assert_array_equal(after[1:], before[1:])  # shards 1..3 clean
    assert not np.array_equal(after[0], before[0])


# ----------------------------------------------------------- fingerprint
def test_fingerprint_representation_independent():
    from repro.stream import graph_fingerprint

    s = _mutated_slotted(seed=13)
    assert s.overlay_size > 0
    canon = s.to_csr()
    fp_view = graph_fingerprint(s.view(), num_deltas=4)
    fp_csr = graph_fingerprint(canon, num_deltas=4)
    assert {k: int(v) for k, v in fp_view.items()} == \
        {k: int(v) for k, v in fp_csr.items()}
    s.compact()
    fp_compacted = graph_fingerprint(s.view(), num_deltas=4)
    assert {k: int(v) for k, v in fp_compacted.items()} == \
        {k: int(v) for k, v in fp_csr.items()}


def test_replay_slotted_matches_replay():
    from repro.stream import replay_commits

    g = rmat(5, edge_factor=6, seed=14)
    deltas = edge_delta_stream(g, 5, 16, seed=15)
    want = replay(g, deltas)
    s = replay_commits(SlottedCSR.from_csr(g), deltas, compact_every=2)
    _assert_csr_equal(s.to_csr(), want)
