"""Unit tests for MultiQueue lanes — fairness, drop accounting, cursors.

These run without hypothesis (the property-test variants live in
tests/test_queue.py and are skipped when hypothesis is absent); MultiQueue is
the backbone of the multi-tenant task server, so its semantics are pinned
down here with plain unit tests.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import EMPTY, make_multiqueue, make_queue


def test_rr_cursor_stays_bounded():
    """The round-robin cursor must be stored modulo num_lanes."""
    mq = make_multiqueue(8, 3)
    for i in range(50):
        mq = mq.push(i % 3, jnp.array([i]), jnp.array([True]))
        _, _, mq = mq.pop(1)
        assert 0 <= int(mq.rr) < mq.num_lanes
    assert int(mq.size) == 0


def test_rr_cycles_fairly_across_nonempty_lanes():
    """With every lane populated, successive pops visit lanes round-robin."""
    num_lanes = 4
    mq = make_multiqueue(16, num_lanes)
    for lane in range(num_lanes):
        mq = mq.push(lane, jnp.array([100 * lane, 100 * lane + 1]),
                     jnp.array([True, True]))
    visited = []
    for _ in range(2 * num_lanes):
        items, valid, mq = mq.pop(1)
        assert bool(valid[0])
        visited.append(int(items[0]) // 100)
    # each lane served exactly twice, in rotating order
    assert visited[:num_lanes] == list(range(num_lanes))
    assert visited[num_lanes:] == list(range(num_lanes))


def test_rr_skips_empty_lanes():
    mq = make_multiqueue(8, 3)
    mq = mq.push(1, jnp.array([7]), jnp.array([True]))
    items, valid, mq = mq.pop(2)
    assert bool(valid[0]) and int(items[0]) == 7
    assert not bool(valid[1])
    # all lanes empty: pop returns nothing valid and leaves size at 0
    items, valid, mq = mq.pop(2)
    assert not bool(valid.any())
    assert int(mq.size) == 0


def test_per_lane_drop_accounting():
    """Overflowing one lane must not disturb another lane's counters."""
    mq = make_multiqueue(4, 2)
    mq = mq.push(0, jnp.arange(6, dtype=jnp.int32),
                 jnp.ones((6,), bool))  # 2 dropped in lane 0
    mq = mq.push(1, jnp.arange(3, dtype=jnp.int32), jnp.ones((3,), bool))
    dropped = np.asarray(mq.lane_dropped())
    assert list(dropped) == [2, 0]
    sizes = np.asarray(mq.lane_sizes())
    assert list(sizes) == [4, 3]


def test_pop_lane_respects_quota():
    mq = make_multiqueue(16, 2)
    mq = mq.push(0, jnp.arange(10, dtype=jnp.int32), jnp.ones((10,), bool))
    items, valid, mq = mq.pop_lane(0, 8, quota=3)
    assert int(jnp.sum(valid.astype(jnp.int32))) == 3
    assert list(np.asarray(items[:3])) == [0, 1, 2]
    assert int(items[3]) == int(EMPTY)
    assert int(mq.lane(0).size) == 7
    assert int(mq.lane(1).size) == 0


def test_reset_lane_recycles_for_new_tenant():
    mq = make_multiqueue(4, 2)
    mq = mq.push(0, jnp.arange(6, dtype=jnp.int32), jnp.ones((6,), bool))
    assert int(mq.lane(0).dropped) == 2
    mq = mq.reset_lane(0)
    assert int(mq.lane(0).size) == 0
    assert int(mq.lane(0).dropped) == 0
    # lane is immediately reusable
    mq = mq.push(0, jnp.array([42]), jnp.array([True]))
    items, valid, mq = mq.pop_lane(0, 1)
    assert bool(valid[0]) and int(items[0]) == 42


def test_taskqueue_pop_upto_quota_clamps():
    q = make_queue(16, jnp.arange(5, dtype=jnp.int32))
    items, valid, q = q.pop_upto(4, 2)
    assert list(np.asarray(valid)) == [True, True, False, False]
    assert int(q.size) == 3
    # quota larger than size: bounded by size
    items, valid, q = q.pop_upto(4, 99)
    assert int(jnp.sum(valid.astype(jnp.int32))) == 3
    # negative quota is treated as zero
    q = make_queue(8, jnp.array([1]))
    items, valid, q = q.pop_upto(2, -1)
    assert not bool(valid.any())
    assert int(q.size) == 1
