"""Property tests for the wavefront TaskQueue (hypothesis) + unit tests."""
import collections

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import EMPTY, make_multiqueue, make_queue


def test_basic_roundtrip():
    q = make_queue(16, jnp.array([1, 2, 3]))
    items, valid, q = q.pop(2)
    assert list(np.asarray(items)) == [1, 2]
    assert list(np.asarray(valid)) == [True, True]
    assert int(q.size) == 1


def test_pop_pads_with_empty():
    q = make_queue(8, jnp.array([7]))
    items, valid, q = q.pop(4)
    assert list(np.asarray(valid)) == [True, False, False, False]
    assert int(items[1]) == int(EMPTY)
    assert int(q.size) == 0


def test_masked_push_compacts():
    q = make_queue(8)
    q = q.push(jnp.array([10, 11, 12, 13]), jnp.array([True, False, True, False]))
    items, valid, q = q.pop(4)
    assert list(np.asarray(items))[:2] == [10, 12]
    assert list(np.asarray(valid)) == [True, True, False, False]


def test_overflow_drops_and_counts():
    q = make_queue(4, jnp.array([1, 2, 3]))
    q = q.push_dense(jnp.array([4, 5, 6]))
    assert int(q.size) == 4
    assert int(q.dropped) == 2


def test_wraparound():
    q = make_queue(4)
    seen = []
    q = q.push_dense(jnp.array([0, 1]))
    for i in range(10):
        items, valid, q = q.pop(1)
        assert bool(valid[0])
        seen.append(int(items[0]))
        q = q.push(jnp.array([100 + i]), jnp.array([True]))
    assert seen[:2] == [0, 1]
    assert int(q.size) == 2


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["push", "pop"]),
                          st.integers(0, 7)), max_size=40))
def test_matches_deque_model(ops):
    """The queue must behave exactly like a FIFO deque with drop-on-full."""
    cap = 8
    q = make_queue(cap)
    model = collections.deque()
    counter = 0
    for kind, n in ops:
        if kind == "push":
            vals = list(range(counter, counter + n))
            counter += n
            q = q.push_dense(jnp.asarray(vals, dtype=jnp.int32)) if n else q
            for v in vals:
                if len(model) < cap:
                    model.append(v)
        else:
            if n == 0:
                continue
            items, valid, q = q.pop(n)
            got = [int(x) for x, v in zip(np.asarray(items), np.asarray(valid))
                   if v]
            want = [model.popleft() for _ in range(min(n, len(model)))]
            assert got == want
        assert int(q.size) == len(model)


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 4), st.lists(st.integers(0, 100), min_size=0,
                                   max_size=30))
def test_multiqueue_conserves_items(num_lanes, values):
    mq = make_multiqueue(64, num_lanes)
    for i, v in enumerate(values):
        mq = mq.push(i % num_lanes, jnp.array([v]), jnp.array([True]))
    assert int(mq.size) == len(values)
    got = []
    for _ in range(len(values)):
        items, valid, mq = mq.pop(1)
        if bool(valid[0]):
            got.append(int(items[0]))
    assert sorted(got) == sorted(values)
    assert int(mq.size) == 0


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 4), st.integers(1, 8), st.integers(1, 40))
def test_multiqueue_round_robin_fairness(num_lanes, per_lane, pops):
    """While every lane is non-empty, pops rotate lanes; over any window the
    per-lane service counts differ by at most one (Atos's num_queues
    fairness).  The rr cursor always stays in [0, num_lanes)."""
    mq = make_multiqueue(32, num_lanes)
    for lane in range(num_lanes):
        vals = jnp.arange(per_lane, dtype=jnp.int32) + 1000 * lane
        mq = mq.push(lane, vals, jnp.ones((per_lane,), bool))
    served = [0] * num_lanes
    for _ in range(min(pops, num_lanes * per_lane)):
        items, valid, mq = mq.pop(1)
        assert bool(valid[0])
        served[int(items[0]) // 1000] += 1
        assert 0 <= int(mq.rr) < num_lanes
        if min(np.asarray(mq.lane_sizes())) > 0:
            assert max(served) - min(served) <= 1


# ---------------------------------------------- quota'd pops (pop_upto)
@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["push", "pop"]),
                          st.integers(0, 7), st.integers(-1, 12)),
                max_size=40))
def test_pop_upto_quota_matches_deque_model(ops):
    """pop_upto(n, quota) must serve exactly min(n, quota, size) items in
    FIFO order for *every* quota — 0, negative, above the occupancy, and
    across wraparound.  EMPTY-sentinel padding must never leak as valid."""
    cap = 8
    q = make_queue(cap)
    model = collections.deque()
    counter = 0
    for kind, n, quota in ops:
        if kind == "push":
            vals = list(range(counter, counter + n))
            counter += n
            q = q.push_dense(jnp.asarray(vals, dtype=jnp.int32)) if n else q
            for v in vals:
                if len(model) < cap:
                    model.append(v)
        else:
            if n == 0:
                continue
            items, valid, q = q.pop_upto(n, quota)
            got = [int(x) for x, v in zip(np.asarray(items),
                                          np.asarray(valid)) if v]
            want = [model.popleft()
                    for _ in range(min(n, max(quota, 0), len(model)))]
            assert got == want
            # invalid lanes are EMPTY-padded, valid ones never EMPTY
            lanes = np.asarray(items)
            assert (lanes[~np.asarray(valid)] == int(EMPTY)).all()
            assert (lanes[np.asarray(valid)] != int(EMPTY)).all()
        assert int(q.size) == len(model)


@settings(max_examples=150, deadline=None)
@given(st.lists(st.integers(1, 4), min_size=0, max_size=10),
       st.lists(st.integers(-1, 30), min_size=1, max_size=8))
def test_pop_upto_vertex_quota_takes_whole_chunk_prefix(widths, quotas):
    """With ``width_of`` the quota counts vertices: each pop serves the
    longest FIFO prefix of whole chunks whose summed widths fit the quota
    (quota 0 or negative pops nothing; a quota beyond the occupancy drains
    the queue).  Chunks are never split, and the vertex occupancy meter
    stays consistent throughout — including across ring wraparound."""
    from repro.core import ChunkCodec

    codec = ChunkCodec(4)
    cap = 16
    q = make_queue(cap)
    model = collections.deque()
    for i, w in enumerate(widths):
        q = q.push(codec.encode(jnp.asarray([4 * i]), jnp.asarray([w])),
                   jnp.asarray([True]))
        model.append((4 * i, w))
    for quota in quotas:
        assert int(q.vertex_size(codec.width)) == sum(w for _, w in model)
        items, valid, q = q.pop_upto(6, quota, width_of=codec.width)
        got = [(int(h), int(w)) for h, w, v in
               zip(np.asarray(codec.head(items)),
                   np.asarray(codec.width(items)), np.asarray(valid)) if v]
        want, budget = [], max(quota, 0)
        while model and len(want) < 6 and model[0][1] <= budget:
            budget -= model[0][1]
            want.append(model.popleft())
        assert got == want
        # wraparound exercise: re-push one popped chunk to rotate the ring
        if got:
            h, w = got[0]
            q = q.push(codec.encode(jnp.asarray([h]), jnp.asarray([w])),
                       jnp.asarray([True]))
            model.append((h, w))


def test_pop_upto_quota_edges_unit():
    q = make_queue(8, jnp.arange(5))
    items, valid, q1 = q.pop_upto(4, 0)          # quota 0: nothing
    assert not np.asarray(valid).any()
    assert int(q1.size) == 5
    items, valid, q2 = q.pop_upto(4, 99)         # quota > occupancy
    assert list(np.asarray(items)[np.asarray(valid)]) == [0, 1, 2, 3]
    items, valid, q3 = q.pop_upto(8, -3)         # negative quota: nothing
    assert not np.asarray(valid).any()


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 3), st.integers(0, 10),
       st.lists(st.integers(-1, 9), min_size=1, max_size=6))
def test_pop_lane_quota_isolates_lanes(num_lanes, per_lane, quotas):
    """pop_lane's quota must only ever drain the named lane, with the same
    min(n, quota, size) contract as pop_upto."""
    mq = make_multiqueue(16, num_lanes)
    model = {lane: collections.deque() for lane in range(num_lanes)}
    for lane in range(num_lanes):
        vals = jnp.arange(per_lane, dtype=jnp.int32) + 100 * lane
        if per_lane:
            mq = mq.push(lane, vals, jnp.ones((per_lane,), bool))
            model[lane].extend(int(v) for v in vals)
    for i, quota in enumerate(quotas):
        lane = i % num_lanes
        items, valid, mq = mq.pop_lane(lane, 4, quota=quota)
        got = [int(x) for x, v in zip(np.asarray(items),
                                      np.asarray(valid)) if v]
        want = [model[lane].popleft()
                for _ in range(min(4, max(quota, 0), len(model[lane])))]
        assert got == want
        assert list(np.asarray(mq.lane_sizes())) == \
            [len(model[lane]) for lane in range(num_lanes)]


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 4),
       st.lists(st.tuples(st.integers(0, 3), st.integers(0, 12)),
                max_size=12))
def test_multiqueue_per_lane_drop_accounting(num_lanes, pushes):
    """Each lane's dropped counter tracks exactly its own overflow."""
    cap = 8
    mq = make_multiqueue(cap, num_lanes)
    model_size = [0] * num_lanes
    model_drop = [0] * num_lanes
    for lane, n in pushes:
        lane = lane % num_lanes
        if n == 0:
            continue
        mq = mq.push(lane, jnp.arange(n, dtype=jnp.int32),
                     jnp.ones((n,), bool))
        fit = min(n, cap - model_size[lane])
        model_size[lane] += fit
        model_drop[lane] += n - fit
    assert list(np.asarray(mq.lane_sizes())) == model_size
    assert list(np.asarray(mq.lane_dropped())) == model_drop
