"""The megakernel drain-loop battery (DESIGN.md section 14).

Three proof obligations for ``ExecutionPolicy(kernel="megakernel")`` — the
single-launch Pallas drain in ``repro/kernels/drain_loop``:

  * **parity** — the megakernel cells of the policy grid reproduce the
    persistent/discrete drains bit-for-bit (BFS, coloring; PageRank within
    eps and bitwise vs persistent, which runs the identical jaxpr) across
    single|fused topologies x granularities {1, 4}, and report exactly one
    kernel launch per drain;
  * **protocol** — hypothesis property tests drive scripted claim/push op
    tapes *inside* the fused kernel against the host-eager TaskQueue
    oracle: the claim cursor never passes the push cursor, ring wraparound
    is exact, invalid lanes are EMPTY-padded, and the dropped counter
    saturates precisely;
  * **fault tolerance** — SIGKILL a megakernel streaming drain at a
    snapshot boundary; the resumed process reproduces the uninterrupted
    run bit for bit (mirrors tests/test_checkpoint_fault.py).

Everything runs in interpret mode off-TPU, so the battery is CI-portable.
"""
import collections
import json
import os
import signal
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms.bfs import bfs_bsp, bfs_speculative
from repro.algorithms.coloring import coloring_async
from repro.algorithms.pagerank import pagerank_async, pagerank_reference
from repro.core import EMPTY, SchedulerConfig, make_queue
from repro.graph.generators import rmat
from repro.kernels.drain_loop import fused_drain_pallas
from repro.runtime import (ExecutionPolicy, POLICY_GRID, build_program,
                           config_for, execute)

try:  # only the property-test section needs hypothesis
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - parity/fault tests still run
    st = None

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MEGA_CELLS = tuple(p for p in POLICY_GRID if p.kernel == "megakernel")
GRANULARITIES = (1, 4)


@pytest.fixture(scope="module")
def g_rmat():
    return rmat(6, edge_factor=8, seed=2)


def _cfg(topology, kernel, granularity=1, **kw):
    policy = ExecutionPolicy(topology, kernel, granularity)
    return config_for(SchedulerConfig(**kw), policy)


# ------------------------------------------------ parity: one launch, same bits
def test_grid_has_the_two_megakernel_cells():
    # sharded.megakernel is invalid (the sharded round is a cross-device
    # collective; the megakernel is one device-resident launch)
    assert {(p.topology, p.kernel) for p in MEGA_CELLS} == \
        {("single", "megakernel"), ("fused", "megakernel")}


@pytest.mark.parametrize("granularity", GRANULARITIES)
def test_bfs_megakernel_bit_identical(g_rmat, granularity):
    ref = np.asarray(bfs_bsp(g_rmat, 0)[0])
    for policy in MEGA_CELLS:
        for baseline_kernel in ("persistent", "discrete"):
            base, _ = bfs_speculative(
                g_rmat, 0,
                _cfg(policy.topology, baseline_kernel, granularity,
                     num_workers=16))
            dist, info = bfs_speculative(
                g_rmat, 0,
                _cfg(policy.topology, "megakernel", granularity,
                     num_workers=16))
            assert (np.asarray(dist) == np.asarray(base)).all(), \
                (str(policy), baseline_kernel, granularity)
            assert (np.asarray(dist) == ref).all(), str(policy)
            assert info["dropped"] == 0, str(policy)


@pytest.mark.parametrize("granularity", GRANULARITIES)
def test_coloring_megakernel_bit_identical(g_rmat, granularity):
    W = 2 * g_rmat.num_vertices
    base, _ = coloring_async(
        g_rmat, _cfg("single", "persistent", granularity, num_workers=W))
    for policy in MEGA_CELLS:
        colors, _ = coloring_async(
            g_rmat, _cfg(policy.topology, "megakernel", granularity,
                         num_workers=W))
        assert (np.asarray(colors) == np.asarray(base)).all(), \
            (str(policy), granularity)


@pytest.mark.parametrize("granularity", GRANULARITIES)
def test_pagerank_megakernel_matches_persistent_bitwise(g_rmat, granularity):
    eps = 1e-5
    ref = np.asarray(pagerank_reference(g_rmat, iters=300))
    for policy in MEGA_CELLS:
        base, _ = pagerank_async(
            g_rmat, _cfg(policy.topology, "persistent", granularity,
                         num_workers=16), eps=eps)
        rank, info = pagerank_async(
            g_rmat, _cfg(policy.topology, "megakernel", granularity,
                         num_workers=16), eps=eps)
        # the megakernel body is the persistent while-loop's own jaxpr
        # evaluated in-kernel, so even float accumulation is bit-identical
        assert (np.asarray(rank) == np.asarray(base)).all(), \
            (str(policy), granularity)
        assert np.abs(np.asarray(rank) - ref).max() < 1e-3, str(policy)
        assert info["max_residue"] <= eps, str(policy)


def test_megakernel_is_one_launch_per_drain(g_rmat):
    """The whole point: kernel-entry events per drain collapse from
    O(rounds) to exactly 1."""
    program = build_program("bfs", g_rmat, SchedulerConfig(num_workers=16),
                            params={"source": 0})
    for kernel, want_one in [("persistent", False), ("discrete", False),
                             ("megakernel", True)]:
        _, stats, info = execute(program, g_rmat,
                                 _cfg("single", kernel, num_workers=16))
        assert int(stats.rounds) > 1, kernel
        if want_one:
            assert info["launches"] == 1, kernel
        else:
            assert info["launches"] == int(stats.rounds), kernel


# ------------------------- protocol: in-kernel claim/push vs TaskQueue oracle
# A scripted op tape (push k | claim k) is baked into the drain jaxpr as
# hoisted constants and replayed entirely inside ONE fused_drain_pallas
# launch, tracing per-op wavefronts and cursor snapshots.  The oracle runs
# the identical tape host-eagerly on TaskQueue (tests/test_queue.py's
# model-checked implementation).
_W = 4  # static wavefront width for every pop


def _run_tape_in_kernel(cap, ops):
    """Replay ``ops`` = [(kind, n)] in-kernel; return the trace arrays."""
    n_ops = len(ops)
    kinds = jnp.asarray([0 if k == "push" else 1 for k, _ in ops], jnp.int32)
    counts = jnp.asarray([n for _, n in ops], jnp.int32)

    q0 = make_queue(cap)
    carry0 = (q0, jnp.int32(0), jnp.int32(0),       # queue, op index, counter
              jnp.full((n_ops, _W), EMPTY, jnp.int32),   # popped items
              jnp.zeros((n_ops, _W), jnp.bool_),         # popped valid
              jnp.zeros((n_ops, 3), jnp.int32))          # (head, tail, dropped)

    def step(carry):
        q, i, counter, items_tr, valid_tr, cursor_tr = carry
        n = counts[i]

        def do_push(q):
            lane = jnp.arange(_W, dtype=jnp.int32)
            q2 = q.push(counter + lane, lane < n)
            return q2, jnp.full((_W,), EMPTY, jnp.int32), \
                jnp.zeros((_W,), jnp.bool_), counter + n

        def do_claim(q):
            items, valid, q2 = q.pop_upto(_W, n)
            return q2, items, valid, counter

        q, items, valid, counter = jax.lax.cond(
            kinds[i] == 0, do_push, do_claim, q)
        cursors = jnp.stack([q.head, q.tail, q.dropped])
        return (q, i + 1, counter, items_tr.at[i].set(items),
                valid_tr.at[i].set(valid), cursor_tr.at[i].set(cursors))

    def cond(carry):
        return carry[1] < n_ops

    q, i, _, items_tr, valid_tr, cursor_tr = fused_drain_pallas(
        step, cond, carry0)
    assert int(i) == n_ops
    return q, np.asarray(items_tr), np.asarray(valid_tr), \
        np.asarray(cursor_tr)


def _run_tape_oracle(cap, ops):
    """Host-eager replay on TaskQueue plus an independent deque model."""
    q = make_queue(cap)
    model = collections.deque()
    counter = 0
    rows = []
    for kind, n in ops:
        if kind == "push":
            lane = jnp.arange(_W, dtype=jnp.int32)
            q = q.push(counter + lane, lane < n)
            for v in range(counter, counter + n):
                if len(model) < cap:
                    model.append(v)
            counter += n
            rows.append(([int(EMPTY)] * _W, [False] * _W))
        else:
            items, valid, q = q.pop_upto(_W, n)
            want = [model.popleft() for _ in range(min(_W, n, len(model)))]
            got = [int(x) for x, v in zip(np.asarray(items),
                                          np.asarray(valid)) if v]
            assert got == want  # the oracle itself is model-checked
            rows.append((np.asarray(items).tolist(),
                         np.asarray(valid).tolist()))
        assert 0 <= int(q.size) <= cap
    return q, rows


def _check_tape(cap, ops):
    qk, items_tr, valid_tr, cursor_tr = _run_tape_in_kernel(cap, ops)
    qo, rows = _run_tape_oracle(cap, ops)

    # in-kernel wavefronts match the oracle bit for bit
    for i, (items, valid) in enumerate(rows):
        assert items_tr[i].tolist() == items, (i, ops)
        assert valid_tr[i].tolist() == valid, (i, ops)
    # final queue pytree identical: ring contents, cursors, drop counter
    assert (np.asarray(qk.buf) == np.asarray(qo.buf)).all()
    for field in ("head", "tail", "dropped"):
        assert int(getattr(qk, field)) == int(getattr(qo, field)), field

    heads, tails, drops = cursor_tr.T
    # the claim cursor never passes the push cursor, and the live window
    # never exceeds capacity — at every op, not just at the end
    assert (heads <= tails).all(), ops
    assert (tails - heads <= cap).all(), ops
    # cursors and the drop counter are monotone (no un-claim, no un-drop)
    assert (np.diff(heads, prepend=0) >= 0).all()
    assert (np.diff(tails, prepend=0) >= 0).all()
    assert (np.diff(drops, prepend=0) >= 0).all()
    # EMPTY-sentinel discipline on every claimed wavefront
    assert (items_tr[~valid_tr] == int(EMPTY)).all()
    assert (items_tr[valid_tr] != int(EMPTY)).all()


if st is not None:
    _OPS = st.lists(st.tuples(st.sampled_from(["push", "claim"]),
                              st.integers(0, _W)), min_size=1, max_size=20)

    @settings(max_examples=25, deadline=None)
    @given(_OPS)
    def test_in_kernel_claim_push_matches_oracle(ops):
        """Arbitrary claim/push tapes inside one kernel launch == TaskQueue."""
        _check_tape(8, ops)

    @settings(max_examples=15, deadline=None)
    @given(st.lists(st.integers(1, _W), min_size=4, max_size=12))
    def test_in_kernel_wraparound_is_exact(widths):
        """Tiny ring, long tape: the cursors lap the capacity several times
        in-kernel and FIFO order still matches the oracle exactly."""
        ops = []
        for n in widths:
            ops += [("push", n), ("claim", n)]
        _check_tape(4, ops)


def test_in_kernel_dropped_counter_saturates():
    """Overflow pushed inside the kernel is dropped and counted exactly:
    capacity 8, five width-4 pushes => 12 drops, then claims drain the 8
    survivors in FIFO order."""
    ops = [("push", _W)] * 5 + [("claim", _W)] * 3
    _check_tape(8, ops)
    qk, items_tr, valid_tr, _ = _run_tape_in_kernel(8, ops)
    assert int(qk.dropped) == 5 * _W - 8
    claimed = items_tr[5:][valid_tr[5:]]
    assert claimed.tolist() == list(range(8))  # survivors, in order
    assert int(qk.size) == 0


def test_in_kernel_claim_on_empty_is_all_empty():
    ops = [("claim", _W), ("push", 2), ("claim", _W), ("claim", _W)]
    qk, items_tr, valid_tr, _ = _run_tape_in_kernel(8, ops)
    assert not valid_tr[0].any() and not valid_tr[3].any()
    assert (items_tr[0] == int(EMPTY)).all()
    assert valid_tr[2].tolist() == [True, True, False, False]


# -------------------- TPU gating, build-once segments, legacy-path honesty
def _count_up_step(c):
    return (c[0] + 1,)


def test_explicit_compile_request_is_rejected():
    """The fused body has no Mosaic lowering (nested pallas_call +
    whole-array operands), so a demand to compile must raise, not hand
    Mosaic an un-lowerable program."""
    with pytest.raises(NotImplementedError, match="interpret-mode"):
        fused_drain_pallas(_count_up_step, lambda c: c[0] < 3,
                           (jnp.int32(0),), interpret=False)


def test_tpu_auto_warns_and_falls_back_to_interpret(monkeypatch):
    """On a real TPU the repo-wide interpret rule would compile; the
    megakernel must warn and run through the interpreter instead."""
    from repro.kernels.drain_loop import kernel as K
    monkeypatch.setattr(K, "resolve_interpret",
                        lambda i: False if i is None else bool(i))
    with pytest.warns(UserWarning, match="interpret-mode prototype"):
        out, = fused_drain_pallas(_count_up_step, lambda c: c[0] < 5,
                                  (jnp.int32(0),))
    assert int(out) == 5


def test_segment_builder_traces_once_across_limits():
    """The snapshot layer drives one fused drain through many round
    limits: the limit rides as a kernel operand, so segments 2..N reuse
    the first segment's traced jaxpr / pallas_call."""
    from repro.core.scheduler import megakernel_segment
    traces = []

    def step(c):
        traces.append(1)  # fires once per trace of the drain body
        return (c[0], c[1], c[2] + 1, c[3] + c[0])

    def cond(c):
        return c[2] < c[1]

    carry = (jnp.int32(2), jnp.int32(9), jnp.int32(0), jnp.int32(0))
    seg = megakernel_segment(step, cond, carry)
    baseline = len(traces)
    assert baseline >= 1
    for _ in range(4):  # limits 3, 6, 9, 12 — last two hit the cond cap
        carry = seg(carry, int(carry[2]) + 3)
    assert len(traces) == baseline, "segment retraced the fused drain"
    assert int(carry[2]) == 9 and int(carry[3]) == 18


def test_stream_row_slices_zero_items():
    """n_items == 0 must not issue the prologue DMA against an empty
    starts array."""
    from repro.kernels.drain_loop import stream_row_slices
    col = jnp.arange(16, dtype=jnp.int32)
    out = stream_row_slices(col, jnp.zeros((0,), jnp.int32), 4)
    assert out.shape == (0, 4)


def test_legacy_scheduler_run_honors_megakernel(monkeypatch):
    """core.scheduler.run must route kernel='megakernel' to the fused
    driver — not silently degrade to the persistent strategy through the
    legacy bool (policy.persistent is True for both)."""
    from repro.core import scheduler as S
    calls = []
    real = S.megakernel_drive
    monkeypatch.setattr(
        S, "megakernel_drive",
        lambda *a, **k: (calls.append(1), real(*a, **k))[1])

    def f(items, valid, state):
        drained = jnp.sum(jnp.where(valid, items, 0))
        return jnp.zeros_like(items), jnp.zeros_like(valid), state + drained

    cfg = S.SchedulerConfig(num_workers=8, kernel="megakernel")
    q = make_queue(16, jnp.arange(5, dtype=jnp.int32))
    _, s, stats = S.run(f, q, jnp.int32(0), cfg)
    assert calls, "run() bypassed the megakernel driver"
    assert int(s) == 10 and int(stats.items_processed) == 5
    assert int(stats.dropped) == 0


def test_taskserver_warns_on_megakernel_config(caplog):
    """The multi-tenant server loop is host-driven and cannot fuse a
    tenant's drain; a megakernel config must warn, never degrade
    silently."""
    import logging
    from repro.server.engine import TaskServer
    server = TaskServer(None, num_lanes=2,
                        config=SchedulerConfig(num_workers=4,
                                               kernel="megakernel"))
    with caplog.at_level(logging.WARNING, logger="repro.server"):
        server.run()  # no jobs: the config check still fires
    assert any("megakernel" in rec.getMessage() for rec in caplog.records)


# --------------------------- fault injection: SIGKILL the megakernel drain
# Mirror of tests/test_checkpoint_fault.py's streaming crash test, with the
# drain segments executed by the megakernel: stream/driver.py bakes each
# snapshot window's round limit into the in-kernel cond, so the checkpoint
# boundaries land on the same absolute rounds as the persistent driver's.
_MEGA_CHILD = """
    import json
    import os
    import signal
    import numpy as np
    from repro.core import SchedulerConfig
    from repro.graph.generators import edge_delta_stream, rmat
    from repro.runtime import stream_execute

    base = rmat(6, edge_factor=6, seed=5)
    deltas = edge_delta_stream(base, 3, 12, seed=6)
    cfg = SchedulerConfig(num_workers=32, topology="single",
                          kernel="megakernel")
    kill_at = int(os.environ.get("KILL_AT_TICK", "-1"))

    def hook(tick, batch):
        if tick == kill_at:
            os.kill(os.getpid(), signal.SIGKILL)

    res = stream_execute(
        "bfs", base, deltas, cfg, params={"source": 2},
        snapshot_every=2, checkpoint_dir=os.environ["SNAP_DIR"],
        keep=100, resume=os.environ.get("RESUME") == "1",
        snapshot_hook=hook)
    print(json.dumps({
        "result": np.asarray(res.result).tolist(),
        "resumed_at": res.info["resumed_at"],
        "batches_run": res.info["batches_run"],
    }))
"""


def _mega_child(snap_dir, kill_at=-1, resume=False):
    prog = ("import os\n"
            "os.environ.setdefault('JAX_PLATFORMS', 'cpu')\n"
            + textwrap.dedent(_MEGA_CHILD))
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               SNAP_DIR=str(snap_dir), KILL_AT_TICK=str(kill_at),
               RESUME="1" if resume else "0")
    return subprocess.run([sys.executable, "-c", prog],
                          capture_output=True, text=True, env=env,
                          timeout=900)


def test_sigkill_megakernel_drain_resume_bit_exact(tmp_path):
    """SIGKILL between two megakernel launches (at a snapshot boundary);
    the resumed process must reproduce the uninterrupted run bit for bit."""
    ref_dir = tmp_path / "ref"
    out = _mega_child(ref_dir)
    assert out.returncode == 0, out.stderr[-3000:]
    ref = json.loads(out.stdout.strip().splitlines()[-1])
    assert ref["resumed_at"] is None

    crash_dir = tmp_path / "crash"
    killed = _mega_child(crash_dir, kill_at=3)
    assert killed.returncode == -signal.SIGKILL
    assert any(p.startswith("snap_") for p in os.listdir(crash_dir))

    resumed = _mega_child(crash_dir, resume=True)
    assert resumed.returncode == 0, resumed.stderr[-3000:]
    got = json.loads(resumed.stdout.strip().splitlines()[-1])
    assert got["resumed_at"] is not None
    assert got["batches_run"] < ref["batches_run"]
    assert got["result"] == ref["result"]
