"""Gradient compression numerics: quantization error, error feedback."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.compression import (ErrorFeedback, dequantize_int8,
                                           fake_quant_grads, quantize_int8)


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000) * 3, jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
    assert float(err) <= float(s) * 0.5 + 1e-6  # half-ULP of the int8 grid


def test_fake_quant_preserves_tree():
    g = {"a": jnp.ones((4, 4)), "b": {"c": jnp.full((3,), -2.0)}}
    out = fake_quant_grads(g)
    assert jax.tree.structure(out) == jax.tree.structure(g)
    np.testing.assert_allclose(np.asarray(out["b"]["c"]), -2.0, rtol=1e-2)


def test_error_feedback_is_unbiased_over_steps():
    """Sum of compressed updates converges to the sum of true gradients."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.standard_normal(256), jnp.float32) * 0.01
    ef = ErrorFeedback.init({"w": g_true})
    acc = jnp.zeros_like(g_true)
    for _ in range(50):
        comp, ef = ef.compress({"w": g_true})
        acc = acc + comp["w"]
    np.testing.assert_allclose(np.asarray(acc), np.asarray(g_true) * 50,
                               atol=float(jnp.max(jnp.abs(g_true))) * 1.1)


def test_compressed_psum_single_device():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as PS
    from repro.distributed.compression import compressed_psum

    mesh = jax.make_mesh((1,), ("d",))
    x = jnp.arange(8.0)

    f = shard_map(lambda x: compressed_psum(x, "d"), mesh=mesh,
                  in_specs=PS("d"), out_specs=PS("d"))
    np.testing.assert_allclose(np.asarray(f(x)), np.asarray(x), rtol=2e-2,
                               atol=0.05)
