"""Checkpoint/restart + fault-tolerance behaviour."""
import json
import os
import signal
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.distributed.fault import StepMonitor, run_with_restarts

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.int32(7)}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(1, tree)
    out = mgr.restore(1, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_async_save_and_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, _tree(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 5


def test_retention_keeps_newest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in [1, 2, 3, 4]:
        mgr.save(s, _tree())
    assert mgr.all_steps() == [3, 4]


def test_atomic_commit_ignores_tmp(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    os.makedirs(tmp_path / "step_2.tmp")  # simulated crash mid-save
    assert mgr.latest_step() == 1


def test_run_with_restarts_recovers_bit_exact(tmp_path):
    """Kill training mid-flight; resumed run must match an uninterrupted one."""
    mgr = CheckpointManager(str(tmp_path))

    def make_step(crash_at=None):
        def step(i, state):
            if crash_at is not None and i == crash_at and not state.get("crashed"):
                state["crashed"] = True
                raise RuntimeError("injected node failure")
            return {"x": state["x"] * 1.5 + i, "crashed": state.get("crashed", False)}
        return step

    # uninterrupted reference
    ref = {"x": jnp.float32(1.0)}
    for i in range(10):
        ref = {"x": ref["x"] * 1.5 + i}

    state = {"x": jnp.float32(1.0), "crashed": False}
    seen_crash = {"flag": False}

    def step(i, state):
        if i == 6 and not seen_crash["flag"]:
            seen_crash["flag"] = True
            raise RuntimeError("injected node failure")
        return {"x": state["x"] * 1.5 + i}

    final, info = run_with_restarts(
        step, {"x": jnp.float32(1.0)}, start_step=0, num_steps=10,
        ckpt_manager=mgr, save_every=2,
        restore_fn=lambda s: mgr.restore(s, {"x": jnp.float32(0.0)}))
    assert info["restarts"] == 1
    np.testing.assert_allclose(float(final["x"]), float(ref["x"]), rtol=1e-6)


def test_step_monitor_flags_stragglers():
    mon = StepMonitor(straggler_factor=3.0)
    for i in range(8):
        mon.start()
        time.sleep(0.01)
        assert not mon.stop(i)
    mon.start()
    time.sleep(0.2)
    assert mon.stop(99)
    assert mon.straggler_steps == [99]


# --------------------------------------------- prefixed checkpoint files
def test_prefix_isolates_retention(tmp_path):
    """Two managers with different prefixes share a directory without
    touching each other's files — the streaming subsystem's drain
    snapshots (prefix='snap') coexist with training checkpoints."""
    steps = CheckpointManager(str(tmp_path), keep=2)           # "step"
    snaps = CheckpointManager(str(tmp_path), keep=2, prefix="snap")
    for s in [1, 2, 3]:
        steps.save(s, _tree())
    for s in [10, 11, 12]:
        snaps.save(s, _tree())
    assert steps.all_steps() == [2, 3]
    assert snaps.all_steps() == [11, 12]
    # each restores its own files
    out = snaps.restore(12, _tree())
    np.testing.assert_array_equal(
        np.asarray(out["step"]), np.asarray(_tree()["step"]))


def test_prefix_validated(tmp_path):
    with pytest.raises(ValueError):
        CheckpointManager(str(tmp_path), prefix="../evil")
    with pytest.raises(ValueError):
        CheckpointManager(str(tmp_path), prefix="")


# ------------------------------------------- SIGKILL a streaming drain
_STREAM_CHILD = """
    import json
    import os
    import signal
    import numpy as np
    from repro.core import SchedulerConfig
    from repro.graph.generators import edge_delta_stream, rmat
    from repro.runtime import stream_execute

    base = rmat(6, edge_factor=6, seed=5)
    deltas = edge_delta_stream(base, 3, 12, seed=6)
    cfg = SchedulerConfig(num_workers=32, topology="single",
                          persistent=False)
    kill_at = int(os.environ.get("KILL_AT_TICK", "-1"))

    def hook(tick, batch):
        if tick == kill_at:
            os.kill(os.getpid(), signal.SIGKILL)

    res = stream_execute(
        "bfs", base, deltas, cfg, params={"source": 2},
        snapshot_every=2, checkpoint_dir=os.environ["SNAP_DIR"],
        keep=100, resume=os.environ.get("RESUME") == "1",
        snapshot_hook=hook)
    print(json.dumps({
        "result": np.asarray(res.result).tolist(),
        "resumed_at": res.info["resumed_at"],
        "batches_run": res.info["batches_run"],
    }))
"""


def _stream_child(snap_dir, kill_at=-1, resume=False):
    prog = ("import os\n"
            "os.environ.setdefault('JAX_PLATFORMS', 'cpu')\n"
            + textwrap.dedent(_STREAM_CHILD))
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               SNAP_DIR=str(snap_dir), KILL_AT_TICK=str(kill_at),
               RESUME="1" if resume else "0")
    return subprocess.run([sys.executable, "-c", prog],
                          capture_output=True, text=True, env=env,
                          timeout=900)


def test_sigkill_mid_drain_resume_bit_exact(tmp_path):
    """SIGKILL a streaming drain inside its snapshot hook; the resumed
    process must reproduce the uninterrupted run's result bit for bit."""
    ref_dir = tmp_path / "ref"
    out = _stream_child(ref_dir)
    assert out.returncode == 0, out.stderr[-3000:]
    ref = json.loads(out.stdout.strip().splitlines()[-1])
    assert ref["resumed_at"] is None

    crash_dir = tmp_path / "crash"
    killed = _stream_child(crash_dir, kill_at=3)
    assert killed.returncode == -signal.SIGKILL
    # the atomic commit left a loadable snapshot behind
    assert any(p.startswith("snap_") for p in os.listdir(crash_dir))

    resumed = _stream_child(crash_dir, resume=True)
    assert resumed.returncode == 0, resumed.stderr[-3000:]
    got = json.loads(resumed.stdout.strip().splitlines()[-1])
    assert got["resumed_at"] is not None
    assert got["batches_run"] < ref["batches_run"]
    assert got["result"] == ref["result"]
