"""Checkpoint/restart + fault-tolerance behaviour."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.distributed.fault import StepMonitor, run_with_restarts


def _tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.int32(7)}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(1, tree)
    out = mgr.restore(1, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_async_save_and_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, _tree(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 5


def test_retention_keeps_newest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in [1, 2, 3, 4]:
        mgr.save(s, _tree())
    assert mgr.all_steps() == [3, 4]


def test_atomic_commit_ignores_tmp(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    os.makedirs(tmp_path / "step_2.tmp")  # simulated crash mid-save
    assert mgr.latest_step() == 1


def test_run_with_restarts_recovers_bit_exact(tmp_path):
    """Kill training mid-flight; resumed run must match an uninterrupted one."""
    mgr = CheckpointManager(str(tmp_path))

    def make_step(crash_at=None):
        def step(i, state):
            if crash_at is not None and i == crash_at and not state.get("crashed"):
                state["crashed"] = True
                raise RuntimeError("injected node failure")
            return {"x": state["x"] * 1.5 + i, "crashed": state.get("crashed", False)}
        return step

    # uninterrupted reference
    ref = {"x": jnp.float32(1.0)}
    for i in range(10):
        ref = {"x": ref["x"] * 1.5 + i}

    state = {"x": jnp.float32(1.0), "crashed": False}
    seen_crash = {"flag": False}

    def step(i, state):
        if i == 6 and not seen_crash["flag"]:
            seen_crash["flag"] = True
            raise RuntimeError("injected node failure")
        return {"x": state["x"] * 1.5 + i}

    final, info = run_with_restarts(
        step, {"x": jnp.float32(1.0)}, start_step=0, num_steps=10,
        ckpt_manager=mgr, save_every=2,
        restore_fn=lambda s: mgr.restore(s, {"x": jnp.float32(0.0)}))
    assert info["restarts"] == 1
    np.testing.assert_allclose(float(final["x"]), float(ref["x"]), rtol=1e-6)


def test_step_monitor_flags_stragglers():
    mon = StepMonitor(straggler_factor=3.0)
    for i in range(8):
        mon.start()
        time.sleep(0.01)
        assert not mon.stop(i)
    mon.start()
    time.sleep(0.2)
    assert mon.stop(99)
    assert mon.straggler_steps == [99]
