"""Data pipeline determinism + continuous-batching engine correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import smoke_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving.engine import (ContinuousBatchingEngine, Request,
                                  decode_single)

KEY = jax.random.PRNGKey(1)


def test_data_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4)
    a = SyntheticLM(cfg).batch(7)
    b = SyntheticLM(cfg).batch(7)  # fresh pipeline, same step
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(cfg).batch(8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_labels_shifted():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2)
    b = SyntheticLM(cfg).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_data_shards_disjoint():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8,
                     num_shards=4)
    batches = [SyntheticLM(cfg, shard_id=s).batch(0) for s in range(4)]
    assert all(b["tokens"].shape == (2, 32) for b in batches)
    flat = [tuple(b["tokens"].ravel()) for b in batches]
    assert len(set(flat)) == 4  # different streams per shard


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "olmoe-1b-7b",
                                  "falcon-mamba-7b", "zamba2-1.2b",
                                  "seamless-m4t-medium"])
def test_engine_matches_single_decode(arch):
    cfg = smoke_config(arch)
    params = init_params(T.model_spec(cfg), KEY, jnp.float32)
    reqs = [Request(uid=i, prompt=[(7 * i + 3) % cfg.vocab_size,
                                   (11 * i + 5) % cfg.vocab_size],
                    max_new_tokens=2 + (i % 3)) for i in range(4)]
    eng = ContinuousBatchingEngine(cfg, params, num_slots=2, max_len=32)
    res = eng.run(reqs)
    for r in reqs:
        ref = decode_single(cfg, params, r.prompt, r.max_new_tokens, 32)
        assert res["outputs"][r.uid] == ref, r.uid


def test_continuous_beats_bsp_occupancy():
    """The Atos scheduler admits into freed slots -> higher occupancy and
    fewer wavefronts than the barrier baseline (small-frontier claim)."""
    cfg = smoke_config("stablelm-1.6b")
    params = init_params(T.model_spec(cfg), KEY, jnp.float32)
    # skewed lengths -> convoy effect under BSP
    reqs = [Request(uid=i, prompt=[i + 1], max_new_tokens=(8 if i % 4 == 0
                                                           else 2))
            for i in range(8)]
    stats = {}
    for mode in ["continuous", "bsp"]:
        eng = ContinuousBatchingEngine(cfg, params, num_slots=4,
                                       max_len=32, mode=mode)
        stats[mode] = eng.run(reqs)["stats"]
    assert stats["continuous"].wavefronts < stats["bsp"].wavefronts
    assert stats["continuous"].mean_occupancy > stats["bsp"].mean_occupancy
