"""Expansion strategies: merge-path LBS vs per-item produce the same work."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import expand_merge_path, expand_per_item
from repro.graph import erdos, rmat


def _edge_set(ex):
    return sorted(
        (int(s), int(n))
        for s, n, v in zip(np.asarray(ex.src), np.asarray(ex.nbr),
                           np.asarray(ex.valid)) if v)


@pytest.mark.parametrize("gen,seed", [(rmat, 0), (erdos, 1)])
def test_strategies_agree(gen, seed):
    g = rmat(6, 4, seed=seed) if gen is rmat else erdos(64, 256, seed=seed)
    items = jnp.array([0, 5, 9, 13, 21, 33], dtype=jnp.int32)
    valid = jnp.array([True, True, False, True, True, True])
    max_deg = int(jnp.max(g.degrees()))
    ex_mp = expand_merge_path(items, valid, g.row_ptr, g.col_idx,
                              work_budget=6 * max_deg)
    ex_pi = expand_per_item(items, valid, g.row_ptr, g.col_idx,
                            max_degree=max_deg)
    assert _edge_set(ex_mp) == _edge_set(ex_pi)
    assert int(ex_mp.total) == int(ex_pi.total)


def test_merge_path_truncates_at_budget():
    g = rmat(6, 4, seed=0)
    items = jnp.arange(16, dtype=jnp.int32)
    valid = jnp.ones(16, bool)
    ex = expand_merge_path(items, valid, g.row_ptr, g.col_idx, work_budget=8)
    assert int(jnp.sum(ex.valid.astype(jnp.int32))) == min(8, int(ex.total))


def test_owner_maps_back_to_wavefront_index():
    g = erdos(32, 128, seed=3)
    items = jnp.array([3, 7, 11], dtype=jnp.int32)
    valid = jnp.ones(3, bool)
    ex = expand_merge_path(items, valid, g.row_ptr, g.col_idx, 64)
    src = np.asarray(ex.src)[np.asarray(ex.valid)]
    owner = np.asarray(ex.owner)[np.asarray(ex.valid)]
    assert (src == np.asarray(items)[owner]).all()


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 20), min_size=1, max_size=16))
def test_lbs_owner_rank_invariants(degs):
    """LBS over an arbitrary degree vector: every work unit maps to the row
    that owns it, with in-row rank < degree."""
    scan = jnp.cumsum(jnp.asarray(degs, dtype=jnp.int32))
    total = int(scan[-1])
    from repro.kernels.frontier_expand.ref import lbs_ref
    owner, rank = lbs_ref(scan, max(total, 1))
    owner, rank = np.asarray(owner)[:total], np.asarray(rank)[:total]
    excl = np.concatenate([[0], np.asarray(scan)[:-1]])
    for k in range(total):
        o = owner[k]
        assert degs[o] > 0
        assert 0 <= rank[k] < degs[o]
        assert excl[o] + rank[k] == k
