"""End-to-end: int8 gradient compression barely affects convergence."""
import numpy as np

from repro.configs.registry import smoke_config
from repro.launch.train import train
from repro.optim import adamw


def test_int8_compression_convergence_parity():
    cfg = smoke_config("stablelm-1.6b")
    kw = dict(steps=25, global_batch=8, seq_len=32,
              opt_cfg=adamw.AdamWConfig(lr=2e-3, warmup_steps=5,
                                        total_steps=25),
              log=lambda *a: None)
    _, _, plain = train(cfg, grad_compression="none", **kw)
    _, _, comp = train(cfg, grad_compression="int8", **kw)
    # both must learn, and the compressed run must track the exact one
    assert np.mean(comp["losses"][-3:]) < np.mean(comp["losses"][:3]) - 0.4
    gap = abs(np.mean(comp["losses"][-3:]) - np.mean(plain["losses"][-3:]))
    assert gap < 0.35, (plain["losses"][-3:], comp["losses"][-3:])
