"""Multi-tenant task server: encoding, policies, correctness, autotuning.

The heavyweight fixtures (a fused 8-job mixed batch + its sequential
baseline) run once per module; most assertions read from them.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms.bfs import bfs_bsp
from repro.algorithms.coloring import validate_coloring
from repro.algorithms.pagerank import pagerank_reference
from repro.core import SchedulerConfig
from repro.graph import grid2d, rmat
from repro.server import (Autotuner, JobRegistry, JobSpec, Program,
                          TaskServer, graph_class, make_policy, pack,
                          serve_sequential, unpack_job, unpack_natural,
                          unzigzag, zigzag)

CFG = SchedulerConfig(num_workers=16, fetch_size=1)


@pytest.fixture(scope="module")
def registry():
    reg = JobRegistry()
    reg.register_graph("grid", grid2d(8, 8))
    reg.register_graph("rmat", rmat(6, edge_factor=4, seed=1))
    return reg


@pytest.fixture(scope="module")
def mixed_specs():
    return [
        JobSpec("bfs", "grid", {"source": 0}),
        JobSpec("bfs", "rmat", {"source": 3}),
        JobSpec("pagerank", "grid", {"eps": 1e-5}),
        JobSpec("coloring", "rmat"),
        JobSpec("bfs", "grid", {"source": 17}, weight=2.0),
        JobSpec("coloring", "grid"),
        JobSpec("pagerank", "rmat", {"eps": 1e-5}),
        JobSpec("bfs", "rmat", {"source": 9}),
    ]


@pytest.fixture(scope="module")
def fused(registry, mixed_specs):
    server = TaskServer(registry, num_lanes=8, config=CFG, policy="weighted")
    for spec in mixed_specs:
        server.submit(spec)
    return server.run()


@pytest.fixture(scope="module")
def sequential(registry, mixed_specs):
    return serve_sequential(registry, mixed_specs, config=CFG)


# ----------------------------------------------------------------- encoding
def test_encoding_roundtrip():
    naturals = jnp.array([0, 1, -1, 63, -64, 4000, -4001], jnp.int32)
    for job_id in (0, 1, 7, 127):
        packed = pack(job_id, naturals)
        assert bool(jnp.all(packed >= 0))  # sign bit free for queue use
        assert list(np.asarray(unpack_job(packed))) == [job_id] * len(naturals)
        assert np.array_equal(np.asarray(unpack_natural(packed)),
                              np.asarray(naturals))


def test_zigzag_is_a_bijection_near_zero():
    t = jnp.arange(-1000, 1000, dtype=jnp.int32)
    z = zigzag(t)
    assert bool(jnp.all(z >= 0))
    assert np.array_equal(np.asarray(unzigzag(z)), np.asarray(t))


# ----------------------------------------------------------------- policies
def test_weighted_policy_water_fills():
    pol = make_policy("weighted")
    sizes, weights = np.array([10, 0, 2, 5]), np.ones(4)
    q = pol.allocate(sizes, weights, np.zeros(4, bool), 8)
    assert q.sum() == 8
    assert q[1] == 0
    assert (q <= sizes).all()
    # unused share of the small lane spills to the hungry one
    q = pol.allocate(np.array([10, 1]), np.ones(2), np.zeros(2, bool), 8)
    assert list(q) == [7, 1]


def test_weighted_policy_respects_weights():
    pol = make_policy("weighted")
    q = pol.allocate(np.array([100, 100]), np.array([3.0, 1.0]),
                     np.zeros(2, bool), 8)
    assert q.sum() == 8
    assert q[0] >= 3 * q[1] - 1  # integer rounding slack


def test_round_robin_policy_rotates():
    pol = make_policy("round_robin")
    sizes, weights = np.array([3, 3, 0]), np.ones(3)
    q1 = pol.allocate(sizes, weights, np.zeros(3, bool), 8)
    assert list(q1) == [3, 0, 0]  # whole wavefront to one lane (Atos)
    q2 = pol.allocate(sizes, weights, np.zeros(3, bool), 8)
    assert list(q2) == [0, 3, 0]
    q3 = pol.allocate(sizes, weights, np.zeros(3, bool), 8)
    assert list(q3) == [3, 0, 0]  # lane 2 empty -> skipped


def test_longest_queue_first_policy():
    pol = make_policy("longest_queue_first")
    q = pol.allocate(np.array([3, 9, 2]), np.ones(3), np.zeros(3, bool), 8)
    assert list(q) == [0, 8, 0]


def test_weighted_policy_rotates_under_scarce_budget():
    """budget < hungry lanes: truncation must not starve the same lanes
    every round — the service order rotates."""
    pol = make_policy("weighted")
    sizes, weights = np.full(8, 100), np.ones(8)
    served = np.zeros(8, dtype=np.int64)
    for _ in range(16):
        served += pol.allocate(sizes, weights, np.zeros(8, bool), 4)
    assert (served > 0).all()


def test_backpressured_lane_served_first():
    for name in ("weighted", "round_robin", "longest_queue_first"):
        pol = make_policy(name)
        boosted = np.array([False, True])
        q = pol.allocate(np.array([9, 6]), np.ones(2), boosted, 8)
        assert q[1] == 6, name  # drained up to demand before policy logic
        assert q.sum() <= 8


# -------------------------------------------------- multi-tenant correctness
def test_fused_results_match_solo_and_references(registry, mixed_specs,
                                                 fused, sequential):
    grid, rm = registry.graph("grid"), registry.graph("rmat")
    for i, spec in enumerate(mixed_specs):
        g = registry.graph(spec.graph)
        if spec.algorithm == "bfs":
            # BFS is schedule-invariant: exact equality with the job run
            # alone AND with the BSP oracle.
            ref, _ = bfs_bsp(g, spec.params["source"])
            assert np.array_equal(fused.results[i], np.asarray(ref)), i
            assert np.array_equal(fused.results[i], sequential.results[i]), i
        elif spec.algorithm == "coloring":
            # any proper coloring is correct; both schedules must produce one
            assert validate_coloring(g, fused.results[i]), i
            assert validate_coloring(g, sequential.results[i]), i
        else:  # pagerank: converged to the same fixed point within eps slack
            ref = np.asarray(pagerank_reference(g))
            assert np.abs(fused.results[i] - ref).max() < 1e-3, i
            assert np.allclose(fused.results[i], sequential.results[i],
                               atol=1e-3), i
    assert grid.num_vertices == rm.num_vertices == 64


def test_no_routing_mismatches(fused, sequential):
    for res in (fused, sequential):
        for tel in res.telemetry.values():
            assert tel.routing_mismatches == 0
            assert tel.dropped == 0


def test_fused_beats_sequential_rounds(fused, sequential):
    """The acceptance bar: fused wavefronts finish the batch in fewer
    scheduler rounds than tenant-at-a-time execution."""
    assert fused.stats.rounds < sequential.stats.rounds
    assert fused.stats.occupancy > sequential.stats.occupancy


def test_telemetry_is_coherent(fused):
    for tel in fused.telemetry.values():
        assert tel.completed_round > 0
        assert 0 <= tel.queue_delay_rounds <= tel.latency_rounds
        assert 0 < tel.occupancy <= 1.0
        assert tel.rounds_active <= tel.latency_rounds
        assert tel.items_processed > 0
        d = tel.as_dict()
        assert d["occupancy"] == tel.occupancy


def test_round_robin_fused_is_bit_identical_to_solo(registry):
    """Whole-wavefront rotation never changes a job's own wavefront
    boundaries, so every algorithm — including schedule-sensitive coloring
    — must match tenant-at-a-time execution bit for bit."""
    specs = [
        JobSpec("bfs", "grid", {"source": 5}),
        JobSpec("pagerank", "grid", {"eps": 1e-5}),
        JobSpec("coloring", "rmat"),
        JobSpec("coloring", "grid"),
    ]
    server = TaskServer(registry, num_lanes=4, config=CFG,
                        policy="round_robin")
    for s in specs:
        server.submit(s)
    fused_rr = server.run()
    solo = serve_sequential(registry, specs, config=CFG)
    for i in range(len(specs)):
        assert np.array_equal(fused_rr.results[i], solo.results[i]), i
    # ...and rotation adds no rounds: it is exactly sequential, interleaved
    assert fused_rr.stats.rounds == solo.stats.rounds


# ------------------------------------------- admission control/backpressure
def _flood_program(limit: int, fanout: int = 3) -> Program:
    """Synthetic generator: every popped task v < limit emits ``fanout``
    copies of v+1 — overwhelms a small lane to exercise backpressure."""

    def init():
        return jnp.int32(0), jnp.array([1], jnp.int32)

    def f(items, valid, state):
        emit = valid & (items < limit)
        out = jnp.concatenate([jnp.where(emit, items + 1, 0)] * fanout)
        mask = jnp.concatenate([emit] * fanout)
        return out, mask, state + jnp.sum(valid.astype(jnp.int32))

    return Program(
        algorithm="flood", graph_name="synthetic", graph=None,
        init=init, wavefront_fn=f,
        result=lambda s: np.asarray([int(s)]),
        work=lambda s: s, ideal_work=limit,
    )


def test_strict_drops_fail_loudly_by_default():
    """An overflowing lane means lost tasks and a silently wrong result;
    the default posture must refuse to report success."""
    server = TaskServer(JobRegistry(), num_lanes=1,
                        config=SchedulerConfig(num_workers=4, fetch_size=1),
                        lane_capacity=8)
    server.submit_program(_flood_program(limit=16))
    with pytest.raises(RuntimeError, match="dropped .* lane overflow"):
        server.run()


def test_backpressure_detected_and_drained():
    server = TaskServer(JobRegistry(), num_lanes=1,
                        config=SchedulerConfig(num_workers=4, fetch_size=1),
                        lane_capacity=8, strict_drops=False)
    server.submit_program(_flood_program(limit=16))
    out = server.run()
    tel = out.telemetry[0]
    assert tel.dropped > 0                   # the lane really overflowed
    assert tel.backpressure_events > 0       # ...and the server noticed
    assert out.stats.backpressure_events == tel.backpressure_events
    assert tel.completed_round > 0           # drain-boost still finished it


def test_admission_control_defers_under_backpressure():
    server = TaskServer(JobRegistry(), num_lanes=2,
                        config=SchedulerConfig(num_workers=4, fetch_size=1),
                        lane_capacity=8, strict_drops=False)
    for _ in range(3):
        server.submit_program(_flood_program(limit=16))
    out = server.run()
    # only 2 lanes: the third tenant must have waited for admission
    assert out.telemetry[2].queue_delay_rounds > 0
    # drops while it waited -> admission was deferred at least once
    assert out.stats.deferred_admissions > 0
    for tel in out.telemetry.values():
        assert tel.completed_round > 0


def test_admission_fifo_order():
    server = TaskServer(JobRegistry(), num_lanes=1,
                        config=SchedulerConfig(num_workers=4, fetch_size=1),
                        lane_capacity=64)
    for _ in range(3):
        server.submit_program(_flood_program(limit=4, fanout=1))
    out = server.run()
    admitted = [out.telemetry[i].admitted_round for i in range(3)]
    assert admitted == sorted(admitted)
    assert admitted[0] < admitted[1] < admitted[2]


# ----------------------------------------------------------------- autotune
def test_graph_class_split(registry):
    assert graph_class(registry.graph("grid")) == "mesh"
    assert graph_class(registry.graph("rmat")) == "scale_free"


def test_autotuner_selects_caches_and_logs(registry, tmp_path, caplog):
    import time

    calls = []

    def fake_runner(algorithm, graph, cfg):
        calls.append((algorithm, cfg.num_workers))
        # deterministic "measurements": narrow wavefront is faster here
        time.sleep(0.02 if cfg.num_workers == 16 else 0.06)

    candidates = [SchedulerConfig(), SchedulerConfig(num_workers=16)]
    cache = tmp_path / "tune.json"
    tuner = Autotuner(cache_path=cache, candidates=candidates,
                      warmup=0, iters=1, runner=fake_runner)
    with caplog.at_level("INFO", logger="repro.server.autotune"):
        chosen = tuner.tune("bfs", registry.graph("grid"))
    assert chosen.num_workers == 16
    assert any("autotune decision" in r.message for r in caplog.records)

    entry = json.loads(cache.read_text())["bfs|mesh"]
    assert entry["chosen"] == "persistent|workers=16|fetch=1|backend=jnp"
    assert entry["config"]["backend"] == "jnp"  # 4th axis persisted
    # chosen config is at least as fast as the default on calibration data
    assert entry["trials"][entry["chosen"]] <= entry["default_wall"]

    # cache hit: no new measurements, same answer — across processes too
    n_calls = len(calls)
    again = tuner.tune("bfs", registry.graph("grid"))
    assert again == chosen and len(calls) == n_calls
    fresh = Autotuner(cache_path=cache, candidates=candidates,
                      warmup=0, iters=1, runner=fake_runner)
    assert fresh.tune("bfs", registry.graph("grid")) == chosen
    assert len(calls) == n_calls


def test_autotuner_mix_recommendation(registry, tmp_path):
    def fake_runner(algorithm, graph, cfg):
        import time
        time.sleep(0.05 if cfg.persistent else 0.01)

    tuner = Autotuner(
        cache_path=tmp_path / "tune.json",
        candidates=[SchedulerConfig(),
                    SchedulerConfig(num_workers=16, persistent=False)],
        warmup=0, iters=1, runner=fake_runner)
    cfg = tuner.recommend_for_mix([
        ("bfs", registry.graph("grid")),
        ("coloring", registry.graph("rmat")),
    ])
    assert cfg.persistent is False and cfg.num_workers == 16


def test_autotuner_mix_survives_disjoint_cached_trials(registry, tmp_path):
    """Cache entries measured under disjoint candidate lists (e.g. written
    by an older run) share no trials: recommend_for_mix must fall back to
    the majority per-workload winner, not crash on an empty intersection."""
    cache = tmp_path / "tune.json"
    entry = {"config": {"num_workers": 16, "fetch_size": 1,
                        "persistent": False},
             "calibration_graph": {"n": 64, "m": 224}}
    cache.write_text(json.dumps({
        "bfs|mesh": {**entry, "chosen": "discrete|workers=16|fetch=1",
                     "trials": {"discrete|workers=16|fetch=1": 0.1},
                     "default_wall": 0.1},
        "coloring|mesh": {**entry, "chosen": "persistent|workers=64|fetch=1",
                          "trials": {"persistent|workers=64|fetch=1": 0.2},
                          "default_wall": 0.2},
    }))
    tuner = Autotuner(cache_path=cache, warmup=0, iters=1,
                      runner=lambda *a: None)
    cfg = tuner.recommend_for_mix([
        ("bfs", registry.graph("grid")),
        ("coloring", registry.graph("grid")),
    ])
    # both are "chosen" once each; majority tie resolves to one of them
    assert cfg in (SchedulerConfig(num_workers=16, fetch_size=1,
                                   persistent=False),
                   SchedulerConfig(num_workers=64, fetch_size=1))


def test_autotuner_real_calibration_smoke(registry, tmp_path):
    """End-to-end: real runner, tiny graph, two candidates — the winner's
    measured wall must not exceed the default's."""
    tuner = Autotuner(
        cache_path=tmp_path / "tune.json",
        candidates=[SchedulerConfig(),
                    SchedulerConfig(num_workers=16, fetch_size=1)],
        warmup=1, iters=1)
    tuner.tune("bfs", registry.graph("grid"))
    entry = json.loads((tmp_path / "tune.json").read_text())["bfs|mesh"]
    assert entry["trials"][entry["chosen"]] <= entry["default_wall"]


def test_default_candidate_grid_spans_backends():
    """The tuner's 4th axis: every launch shape is measured on both
    backends, and the plain default stays first (always measured)."""
    from repro.server import BACKEND_GRID, DEFAULT_CANDIDATES
    assert set(BACKEND_GRID) == {"jnp", "pallas"}
    assert {c.backend for c in DEFAULT_CANDIDATES} == {"jnp", "pallas"}
    per_backend = len(DEFAULT_CANDIDATES) // len(BACKEND_GRID)
    assert per_backend * len(BACKEND_GRID) == len(DEFAULT_CANDIDATES)
    assert DEFAULT_CANDIDATES[0] == SchedulerConfig()


def test_autotuner_can_choose_pallas_and_persists_it(registry, tmp_path):
    import time

    def fake_runner(algorithm, graph, cfg):
        time.sleep(0.01 if cfg.backend == "pallas" else 0.04)

    cache = tmp_path / "tune.json"
    candidates = [SchedulerConfig(),
                  SchedulerConfig(backend="pallas")]
    tuner = Autotuner(cache_path=cache, candidates=candidates,
                      warmup=0, iters=1, runner=fake_runner)
    chosen = tuner.tune("coloring", registry.graph("grid"))
    assert chosen.backend == "pallas"
    entry = json.loads(cache.read_text())["coloring|mesh"]
    assert entry["config"]["backend"] == "pallas"
    assert entry["chosen"].endswith("|backend=pallas")
    # a fresh process reloads the backend choice from the JSON cache
    fresh = Autotuner(cache_path=cache, candidates=candidates,
                      warmup=0, iters=1, runner=fake_runner)
    assert fresh.tune("coloring", registry.graph("grid")).backend == "pallas"


def test_pre_backend_cache_entries_still_load(registry, tmp_path):
    """Caches written before the backend axis existed (no "backend" field,
    3-part keys) must load as jnp-backed measurements, not crash."""
    cache = tmp_path / "tune.json"
    cache.write_text(json.dumps({
        "bfs|mesh": {
            "chosen": "persistent|workers=16|fetch=1",
            "config": {"num_workers": 16, "fetch_size": 1,
                       "persistent": True},
            "trials": {"persistent|workers=16|fetch=1": 0.1},
            "default_wall": 0.1,
            "calibration_graph": {"n": 64, "m": 224},
        }}))
    tuner = Autotuner(cache_path=cache, warmup=0, iters=1,
                      runner=lambda *a: None)
    cfg = tuner.tune("bfs", registry.graph("grid"))
    assert cfg == SchedulerConfig(num_workers=16, fetch_size=1)
    assert cfg.backend == "jnp"


def test_fused_server_backend_parity(registry, mixed_specs, fused):
    """The whole multi-tenant batch, re-run on the Pallas backend, must be
    bit-identical to the jnp fixture — results, rounds, and telemetry."""
    import dataclasses as dc

    server = TaskServer(registry, num_lanes=8,
                        config=dc.replace(CFG, backend="pallas"),
                        policy="weighted")
    for spec in mixed_specs:
        server.submit(spec)
    out = server.run()
    assert out.stats.rounds == fused.stats.rounds
    for i in fused.results:
        assert np.array_equal(out.results[i], fused.results[i]), i
    for i, tel in fused.telemetry.items():
        assert out.telemetry[i].items_processed == tel.items_processed
        assert out.telemetry[i].work == tel.work


def test_job_id_space_bounded_at_submit_time():
    """The packed-task bitfield holds 128 job ids; the 129th submit must
    fail immediately, not mid-run after other jobs finished."""
    server = TaskServer(JobRegistry(), num_lanes=1)
    prog = _flood_program(limit=2, fanout=1)
    for _ in range(128):
        server.submit_program(prog)
    with pytest.raises(ValueError, match="job id space exhausted"):
        server.submit_program(prog)


# ---------------------------------------------------------------- registry
def test_registry_rejects_unknowns(registry):
    with pytest.raises(KeyError):
        registry.graph("nope")
    with pytest.raises(ValueError):
        JobSpec("dijkstra", "grid")
    with pytest.raises(ValueError):
        JobSpec("bfs", "grid", weight=0.0)
    with pytest.raises(ValueError):
        registry.build(JobSpec("bfs", "grid", {"bogus": 1}), 0, 16, 16, 512)


def test_kernel_cache_shared_across_sources(registry):
    p1 = registry.build(JobSpec("bfs", "grid", {"source": 1}), 0, 16, 16, 512)
    p2 = registry.build(JobSpec("bfs", "grid", {"source": 2}), 1, 16, 16, 512)
    assert p1.wavefront_fn is p2.wavefront_fn  # one compiled kernel
    s1, _ = p1.init()
    s2, _ = p2.init()
    assert int(s1.dist[1]) == 0 and int(s2.dist[2]) == 0  # distinct states


def test_autotuner_sh_matches_grid_on_quarter_budget(registry, tmp_path):
    """The section-16 search: cost-model-seeded successive halving over the
    full 56-cell DEFAULT_CANDIDATES grid must reproduce the exhaustive
    grid's winner while measuring at most a quarter of the cells, under the
    deterministic structural runner on both graph regimes."""
    from repro.server import (AUTOTUNE_SCHEMA, DEFAULT_CANDIDATES,
                              structural_cost_runner)

    for gname in ("grid", "rmat"):
        g = registry.graph(gname)
        grid_pick = Autotuner(
            cache_path=tmp_path / f"{gname}_grid.json", warmup=0, iters=1,
            runner=structural_cost_runner, search="grid").tune("bfs", g)
        sh = Autotuner(
            cache_path=tmp_path / f"{gname}_sh.json", warmup=0, iters=1,
            runner=structural_cost_runner, search="sh")
        assert sh.tune("bfs", g) == grid_pick

        entry = json.loads(
            (tmp_path / f"{gname}_sh.json").read_text())[f"bfs|{graph_class(g)}"]
        assert entry["schema"] == AUTOTUNE_SCHEMA
        assert entry["search"] == "sh"
        assert entry["cells_total"] == len(DEFAULT_CANDIDATES)
        assert entry["cells_measured"] <= entry["cells_total"] // 4
        # cost-model provenance rides the cache: the features that seeded
        # the halving and the predicted score of every measured cell
        stats = entry["cost_model"]["stats"]
        for feat in ("num_vertices", "num_edges", "avg_degree", "degree_cv",
                     "frontier_growth", "diameter_proxy"):
            assert feat in stats
        assert set(entry["cost_model"]["predicted"]) == set(entry["trials"])
        # the default is always among the measured cells
        assert "default_wall" in entry


def test_autotuner_grid_search_still_measures_everything(registry, tmp_path):
    """search="grid" preserves the exhaustive pre-section-16 behaviour:
    every candidate appears in the trials."""
    candidates = [SchedulerConfig(), SchedulerConfig(num_workers=16),
                  SchedulerConfig(num_workers=16, persistent=False)]
    tuner = Autotuner(cache_path=tmp_path / "t.json", candidates=candidates,
                      warmup=0, iters=1, runner=lambda *a: 1.0,
                      search="grid")
    tuner.tune("bfs", registry.graph("grid"))
    entry = json.loads((tmp_path / "t.json").read_text())["bfs|mesh"]
    assert len(entry["trials"]) == len(candidates)
    assert entry["cells_measured"] == entry["cells_total"] == len(candidates)


def test_pr5_era_grid_cache_fixture_still_loads(registry, tmp_path):
    """Schema regression: the checked-in six-axis grid-era cache (no
    "schema" field, no kernel axis, no cost-model block) must keep
    loading — cache hits return the stored config without re-measuring,
    and the mix recommendation still aggregates its trials."""
    import shutil
    from pathlib import Path

    fixture = Path(__file__).parent / "data" / "autotune_cache_pr5.json"
    cache = tmp_path / "tune.json"
    shutil.copy(fixture, cache)

    def exploding_runner(*a):
        raise AssertionError("legacy cache hit must not re-measure")

    tuner = Autotuner(cache_path=cache, warmup=0, iters=1,
                      runner=exploding_runner)
    cfg = tuner.tune("bfs", registry.graph("grid"))
    assert cfg == SchedulerConfig(num_workers=64, fetch_size=4,
                                  backend="pallas", topology="fused",
                                  granularity=4)
    assert tuner.tune("coloring", registry.graph("grid")).backend == "jnp"

    mix = tuner.recommend_for_mix([("bfs", registry.graph("grid")),
                                   ("coloring", registry.graph("grid"))])
    # summed legacy trials: the pallas fused granularity-4 cell wins
    assert mix == SchedulerConfig(num_workers=64, fetch_size=4,
                                  backend="pallas", topology="fused",
                                  granularity=4)
