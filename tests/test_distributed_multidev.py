"""Multi-device tests — run in-process against whatever mesh is visible.

These need 8 devices.  They no longer assume the XLA host-device override:
when fewer than 8 devices are visible they skip with an actionable reason
instead of spawning flag-setting subprocesses.  The CI ``multidevice`` job
(and any local run) provides the devices with

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m pytest tests/test_distributed_multidev.py tests/test_shard.py

set *before* the first jax import.
"""
import dataclasses
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices; set "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8 before jax "
           "initializes (the CI 'multidevice' job does)")


def test_sharded_train_step_runs_and_matches_single_device():
    """2x4 mesh FSDP+TP train step == unsharded train step (same numbers)."""
    from repro.configs.registry import smoke_config
    from repro.distributed import sharding as SH
    from repro.models import transformer as T
    from repro.models.params import init_params, param_shardings
    from repro.optim import adamw

    cfg = smoke_config("stablelm-1.6b")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    pc = SH.ParallelConfig()
    params = init_params(T.model_spec(cfg), jax.random.PRNGKey(0),
                         jnp.float32)
    opt = adamw.init(params)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0,
                                     cfg.vocab_size)}
    step = SH.make_train_step(cfg)

    # single-device reference
    p1, o1, m1 = jax.jit(step)(params, opt, batch)

    # sharded
    resolve = SH.make_resolver(mesh, pc)
    shardings = param_shardings(T.model_spec(cfg), resolve)
    sharded_params = jax.device_put(params, shardings)
    sharded_opt = adamw.AdamWState(
        step=jax.device_put(opt.step, SH.replicated(mesh)),
        m=jax.device_put(opt.m, shardings),
        v=jax.device_put(opt.v, shardings))
    b_sh = SH.batch_sharding(mesh, pc)
    sharded_batch = {k: jax.device_put(v, b_sh) for k, v in batch.items()}
    with mesh:
        p2, o2, m2 = jax.jit(step)(sharded_params, sharded_opt,
                                   sharded_batch)
    diff = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    assert diff < 1e-4


def test_compressed_psum_multidevice():
    """int8 compressed psum across 8 devices approximates the exact psum."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as PS

    from repro.distributed.compression import compressed_psum

    mesh = jax.make_mesh((8,), ("d",))
    x = jnp.asarray(np.random.default_rng(0).standard_normal(8 * 64),
                    jnp.float32)
    exact = shard_map(lambda v: jax.lax.psum(v, "d"), mesh=mesh,
                      in_specs=PS("d"), out_specs=PS("d"))(x)
    approx = shard_map(lambda v: compressed_psum(v, "d"), mesh=mesh,
                       in_specs=PS("d"), out_specs=PS("d"))(x)
    rel = float(jnp.max(jnp.abs(exact - approx)) /
                (jnp.max(jnp.abs(exact)) + 1e-9))
    assert rel < 0.05


def test_elastic_remesh_resume():
    """Checkpoint on a 2x4 mesh, restore onto 4x2 — elastic scaling."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.configs.registry import smoke_config
    from repro.distributed import sharding as SH
    from repro.models import transformer as T
    from repro.models.params import init_params, param_shardings

    cfg = smoke_config("olmoe-1b-7b")
    params = init_params(T.model_spec(cfg), jax.random.PRNGKey(0),
                         jnp.float32)
    mesh_a = jax.make_mesh((2, 4), ("data", "model"))
    sh_a = param_shardings(T.model_spec(cfg),
                           SH.make_resolver(mesh_a, SH.ParallelConfig()))
    p_a = jax.device_put(params, sh_a)
    d = tempfile.mkdtemp()
    mgr = CheckpointManager(d)
    mgr.save(3, p_a)

    mesh_b = jax.make_mesh((4, 2), ("data", "model"))
    sh_b = param_shardings(T.model_spec(cfg),
                           SH.make_resolver(mesh_b, SH.ParallelConfig()))
    p_b = mgr.restore(3, params, shardings=sh_b)
    diff = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(params),
                               jax.tree.leaves(p_b)))
    assert diff == 0.0
    assert all(pb.sharding == sb for pb, sb in
               zip(jax.tree.leaves(p_b), jax.tree.leaves(sh_b)))


def test_dryrun_mini_mesh():
    """End-to-end dry-run machinery on an 8-device mesh (2x4)."""
    from repro.configs.base import SHAPES
    from repro.configs.registry import get_config
    from repro.distributed import sharding as SH
    from repro.launch import roofline as RL
    from repro.launch.specs import input_specs

    cfg = dataclasses.replace(get_config("stablelm-1.6b"), num_layers=2)
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=128,
                                global_batch=8)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    pc = SH.ParallelConfig()
    specs = input_specs(cfg, shape, mesh, pc)
    params, opt = SH.abstract_train_state(cfg, mesh, pc)
    step = SH.make_train_step(cfg)
    with mesh:
        compiled = jax.jit(step).lower(params, opt, specs).compile()
    terms = RL.cost_terms(compiled)
    assert terms.flops > 0
    assert terms.coll_bytes > 0
