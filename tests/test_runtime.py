"""The runtime layer: AtosProgram x ExecutionPolicy (DESIGN.md section 11).

Acceptance bars:

  * one program definition per algorithm drains under every cell of the
    (single | fused | sharded) x (persistent | discrete) policy matrix with
    bit-identical BFS/coloring results and eps-slack PageRank;
  * a program whose ``stop`` never fires terminates at ``max_rounds`` with
    identical RunStats under all six policies;
  * the discrete driver folds ``stop`` into the jitted step (no host
    evaluation per round);
  * the empty-queue/``on_empty`` interaction is an explicit declaration
    (``empty_means_done``), not an inference.

Sharded policies run on a single-device mesh here — the full 8-device
parity suite lives in tests/test_shard.py.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms.bfs import bfs_bsp, bfs_speculative
from repro.algorithms.coloring import coloring_async, validate_coloring
from repro.algorithms.pagerank import pagerank_async, pagerank_reference
from repro.core import SchedulerConfig, discrete_run, make_queue, persistent_run
from repro.graph.generators import grid2d, rmat
from repro.runtime import (AtosProgram, ExecutionPolicy, POLICY_GRID,
                           build_program, config_for, execute, parse_policy,
                           policy_of)


@pytest.fixture(scope="module")
def g_rmat():
    return rmat(6, edge_factor=8, seed=2)


@pytest.fixture(scope="module")
def g_grid():
    return grid2d(8, 8, seed=0)


# ---------------------------------------------------------------- policies
def test_policy_grid_is_complete_and_parses():
    # 8 cells: 3 topologies x 3 kernels minus the invalid sharded.megakernel
    assert len(POLICY_GRID) == 8
    assert len(set(POLICY_GRID)) == 8
    for p in POLICY_GRID:
        assert parse_policy(str(p)) == p
    with pytest.raises(ValueError, match="topology"):
        ExecutionPolicy("multi", "persistent")
    with pytest.raises(ValueError, match="kernel"):
        ExecutionPolicy("single", "eager")
    with pytest.raises(ValueError, match="policy"):
        parse_policy("persistent")
    # the one hole in the matrix: a megakernel is one device-resident
    # launch, the sharded round is a cross-device collective
    with pytest.raises(ValueError, match="sharded.megakernel"):
        parse_policy("sharded.megakernel")
    assert ExecutionPolicy("single", "megakernel") in POLICY_GRID


def test_policy_granularity_axis_parses_and_prints():
    p = parse_policy("sharded.persistent.g4")
    assert p == ExecutionPolicy("sharded", "persistent", 4)
    assert str(p) == "sharded.persistent.g4"
    # granularity 1 is the default and stays invisible in the name, so
    # pre-granularity policy strings and cache keys keep round-tripping
    assert parse_policy("fused.discrete").granularity == 1
    assert str(ExecutionPolicy("fused", "discrete", 1)) == "fused.discrete"
    cfg = config_for(SchedulerConfig(), p)
    assert cfg.granularity == 4
    assert policy_of(cfg) == p


def test_policy_errors_enumerate_the_full_matrix():
    """Bad policy input must teach the full topology x kernel x granularity
    matrix (the errors predate the third axis)."""
    for bad in (lambda: parse_policy("mesh.persistent"),
                lambda: parse_policy("single.eager"),
                lambda: parse_policy("single.persistent.q4"),
                lambda: parse_policy("single"),
                lambda: ExecutionPolicy("single", "persistent", 0),
                lambda: policy_of(SchedulerConfig(topology="fused",
                                                  num_shards=4))):
        with pytest.raises(ValueError) as e:
            bad()
        msg = str(e.value)
        for cell in ("single.persistent", "single.discrete",
                     "single.megakernel",
                     "fused.persistent", "fused.discrete",
                     "fused.megakernel",
                     "sharded.persistent", "sharded.discrete"):
            assert cell in msg, (msg, cell)
        assert "sharded.megakernel" not in msg  # never advertised as valid
        assert "g<width>" in msg
    with pytest.raises(ValueError, match="granularity"):
        parse_policy("single.persistent.g0")


def test_policy_resolution_from_config():
    assert str(policy_of(SchedulerConfig())) == "single.persistent"
    assert str(policy_of(SchedulerConfig(persistent=False,
                                         topology="fused"))) \
        == "fused.discrete"
    assert policy_of(SchedulerConfig(num_shards=4)).topology == "sharded"
    # an explicit non-sharded topology must not silently drop the mesh
    with pytest.raises(ValueError, match="num_shards"):
        policy_of(SchedulerConfig(topology="single", num_shards=4))


def test_merge_spec_must_be_total(g_grid):
    """A field-spec that omits a state field would silently keep ``prev``
    for it after every sharded round — reject at merge time instead."""
    from repro.algorithms.bfs import init_state
    from repro.runtime import build_merge

    state = init_state(g_grid, 0)
    with pytest.raises(ValueError, match="missing rules.*counter"):
        build_merge({"dist": "pmin"})(state, state, "shard")
    with pytest.raises(ValueError, match="unknown state fields"):
        build_merge({"dist": "pmin", "counter": "sum_delta",
                     "bogus": "pmin"})(state, state, "shard")


def test_build_program_rejects_unknowns(g_grid):
    with pytest.raises(ValueError, match="unknown algorithm"):
        build_program("dijkstra", g_grid, SchedulerConfig())
    with pytest.raises(ValueError, match="unknown bfs params"):
        build_program("bfs", g_grid, SchedulerConfig(),
                      params={"bogus": 1})


# ------------------------------- parity: one program, 8 policies x 2 widths
# The matrix mirrors PR 4's six-cell block with the third (granularity)
# axis — g=1 is the pre-granularity task stream bit-for-bit, g=4 packs
# (vertex, width) chunks into the same int32 slots (DESIGN.md section 12) —
# plus the megakernel kernel strategy (DESIGN.md section 14), whose deeper
# battery (claim/push property tests, SIGKILL fault injection) lives in
# tests/test_megakernel.py.
GRANULARITIES = (1, 4)


def _cfg(policy, granularity=1, **kw):
    policy = ExecutionPolicy(policy.topology, policy.kernel, granularity)
    return config_for(SchedulerConfig(**kw), policy)


@pytest.mark.parametrize("granularity", GRANULARITIES)
def test_bfs_bit_identical_under_all_six_policies(g_rmat, granularity):
    ref = np.asarray(bfs_bsp(g_rmat, 0)[0])
    for policy in POLICY_GRID:
        dist, info = bfs_speculative(
            g_rmat, 0, _cfg(policy, granularity, num_workers=16))
        assert (np.asarray(dist) == ref).all(), (str(policy), granularity)
        assert info["dropped"] == 0, str(policy)
        assert info["work"] > 0, str(policy)


@pytest.mark.parametrize("granularity", GRANULARITIES)
def test_coloring_valid_under_all_six_policies(g_rmat, granularity):
    # full-width wavefront: rounds stay homogeneous (all-assign or
    # all-detect), so the fused and unfused (sharded) bodies see the same
    # reads and every policy produces the identical coloring.
    W = 2 * g_rmat.num_vertices
    results = {}
    for policy in POLICY_GRID:
        colors, info = coloring_async(
            g_rmat, _cfg(policy, granularity, num_workers=W))
        assert validate_coloring(g_rmat, colors), (str(policy), granularity)
        results[str(policy)] = np.asarray(colors)
    base = results[str(POLICY_GRID[0])]
    for name, colors in results.items():
        assert (colors == base).all(), (name, granularity)


@pytest.mark.parametrize("granularity", GRANULARITIES)
def test_pagerank_within_eps_under_all_six_policies(g_rmat, granularity):
    eps = 1e-5
    ref = np.asarray(pagerank_reference(g_rmat, iters=300))
    ranks = {}
    for policy in POLICY_GRID:
        rank, info = pagerank_async(
            g_rmat, _cfg(policy, granularity, num_workers=16), eps=eps)
        assert np.abs(np.asarray(rank) - ref).max() < 1e-3, \
            (str(policy), granularity)
        assert info["max_residue"] <= eps, str(policy)
        ranks[str(policy)] = np.asarray(rank)
    # the single and fused topologies drive the identical schedule (same
    # pop/push order through one lane), so their ranks agree bitwise.
    for kernel in ("persistent", "discrete"):
        assert (ranks[f"single.{kernel}"] == ranks[f"fused.{kernel}"]).all()


def test_granularity_coarsens_the_schedule(g_grid):
    """The dial does something: on the mesh graph a width-4 PageRank drain
    takes materially fewer rounds than width-1 (the dense seed frontier and
    the rotating rescan both ride in chunks), with the same converged
    ranks.  This is the paper's coarse-tasks-win-on-mesh regime; the
    opposite regime is pinned by benchmarks/bench_granularity.py."""
    eps = 1e-5
    cfgs = {gr: _cfg(POLICY_GRID[1], gr, num_workers=8)
            for gr in GRANULARITIES}
    rounds, ranks = {}, {}
    for gr, cfg in cfgs.items():
        rank, info = pagerank_async(g_grid, cfg, eps=eps)
        rounds[gr], ranks[gr] = info["rounds"], np.asarray(rank)
    assert rounds[4] < rounds[1], rounds
    assert np.abs(ranks[4] - ranks[1]).max() < 1e-3


def test_sharded_info_carries_exchange_telemetry(g_grid):
    program = build_program("bfs", g_grid, SchedulerConfig(num_workers=16))
    _, stats, info = execute(program, g_grid,
                             _cfg(ExecutionPolicy("sharded", "persistent"),
                                  num_workers=16))
    for key in ("exchanged", "donated", "mis_routed", "occupancy_balance",
                "shards"):
        assert key in info
    assert info["mis_routed"] == 0
    assert int(stats.rounds) == info["rounds"]


# -------------------------------------- satellite: max_rounds safety bound
def _forever_program(n_tasks=8, capacity=256):
    """A program whose stop never fires: every popped task is re-pushed."""

    def make_body(graph, ctx):
        def f(items, valid, state):
            return items, valid, state + jnp.sum(valid.astype(jnp.int32))

        return f

    return AtosProgram(
        name="forever",
        init=lambda: (jnp.int32(0), jnp.arange(n_tasks, dtype=jnp.int32)),
        make_body=make_body,
        result=lambda s: s,
        merge="sum_delta",
        default_queue_capacity=capacity,
    )


def test_max_rounds_identical_runstats_under_all_six_policies(g_grid):
    """A runaway drain must terminate at exactly ``max_rounds`` with the
    same RunStats no matter which policy drives it."""
    program = _forever_program()
    observed = {}
    for policy in POLICY_GRID:
        cfg = _cfg(policy, num_workers=4, fetch_size=1, max_rounds=9)
        state, stats, info = execute(program, g_grid, cfg)
        observed[str(policy)] = (int(stats.rounds),
                                 int(stats.items_processed),
                                 int(stats.dropped))
        # the state saw exactly the processed items (merge-exactness too)
        assert int(state) == int(stats.items_processed), str(policy)
    assert len(set(observed.values())) == 1, observed
    rounds, items, dropped = next(iter(observed.values()))
    assert rounds == 9
    assert items == 9 * 4  # every round popped a full wavefront
    assert dropped == 0


# ------------------------------- satellite: stop folded into the jitted step
def test_discrete_stop_is_traced_not_evaluated_per_round():
    """The discrete driver must not call ``stop(state)`` on the host every
    round (a device->host sync + retrace hazard): it is traced into the
    jitted step, so the Python callable runs only during the pre-loop check
    and tracing."""
    calls = {"n": 0}

    def stop(state):
        calls["n"] += 1
        return state >= jnp.int32(1 << 30)  # never fires

    def f(items, valid, state):
        return items, valid, state + jnp.sum(valid.astype(jnp.int32))

    cfg = SchedulerConfig(num_workers=2, fetch_size=1, persistent=False,
                          max_rounds=50)
    _, _, stats = discrete_run(f, make_queue(64, jnp.arange(4)),
                               jnp.int32(0), cfg, stop=stop)
    assert int(stats.rounds) == 50
    # pre-loop eager check + one trace (+ possibly one retrace) — never 50
    assert calls["n"] <= 3, calls["n"]


def test_discrete_equals_persistent_with_stop():
    def f(items, valid, state):
        new = items - 1
        return new, valid & (new > 0), state + jnp.sum(
            valid.astype(jnp.int32))

    stop = lambda s: s >= 7
    cfg_p = SchedulerConfig(num_workers=2, fetch_size=1, max_rounds=100)
    cfg_d = SchedulerConfig(num_workers=2, fetch_size=1, max_rounds=100,
                            persistent=False)
    seeds = jnp.array([5, 3, 6, 2])
    _, s1, st1 = persistent_run(f, make_queue(64, seeds), jnp.int32(0),
                                cfg_p, stop=stop)
    _, s2, st2 = discrete_run(f, make_queue(64, seeds), jnp.int32(0),
                              cfg_d, stop=stop)
    assert int(s1) == int(s2)
    assert int(st1.rounds) == int(st2.rounds)


# --------------------------- satellite: empty queue vs on_empty, explicitly
def _consume(items, valid, state):
    """Body that consumes tasks without producing any."""
    return items, jnp.zeros_like(valid), state + jnp.sum(
        valid.astype(jnp.int32))


def _refill_once(state):
    # an on_empty that never actually produces work
    return jnp.zeros((1,), jnp.int32), jnp.zeros((1,), bool), state + 1000


@pytest.mark.parametrize("runner", [persistent_run, discrete_run])
def test_empty_means_done_true_ends_drain_despite_on_empty(runner):
    """Regression (DESIGN.md §11): with ``on_empty`` set, the old
    continuation silently dropped the queue-size term, so a drain with no
    ``stop`` ran to max_rounds after the queue emptied for good.  A program
    declaring ``empty_means_done=True`` must end when the queue drains —
    ``on_empty`` never fires."""
    cfg = SchedulerConfig(num_workers=2, fetch_size=1, max_rounds=100)
    _, state, stats = runner(_consume, make_queue(64, jnp.arange(4)),
                             jnp.int32(0), cfg, on_empty=_refill_once,
                             empty_means_done=True)
    assert int(stats.rounds) == 2          # 4 seeds / wavefront 2
    assert int(state) == 4                 # on_empty's +1000 never ran


@pytest.mark.parametrize("runner", [persistent_run, discrete_run])
def test_empty_means_done_default_keeps_legacy_inference(runner):
    """``empty_means_done=None`` preserves the old behavior: the presence
    of ``on_empty`` keeps the drain alive past queue exhaustion (bounded by
    stop/max_rounds) — PageRank's rescan contract."""
    cfg = SchedulerConfig(num_workers=2, fetch_size=1, max_rounds=10)
    _, state, stats = runner(_consume, make_queue(64, jnp.arange(4)),
                             jnp.int32(0), cfg, on_empty=_refill_once)
    assert int(stats.rounds) == 10         # ran to max_rounds
    assert int(state) == 4 + 8 * 1000      # on_empty ticked every dry round


def test_fused_server_honors_empty_means_done():
    """The multi-tenant engine obeys the same declaration as the other two
    engines: a drained lane finishes the job only when the program says an
    empty queue means done; ``empty_means_done=False`` keeps its
    ``on_empty`` refills running until stop/max_rounds."""
    from repro.server import JobRegistry, Program, TaskServer

    def make_prog(emd):
        def f(items, valid, state):
            return items, jnp.zeros_like(valid), state + jnp.sum(
                valid.astype(jnp.int32))

        def on_empty(state):
            return (jnp.zeros((1,), jnp.int32), jnp.zeros((1,), bool),
                    state + 1000)

        return Program(
            algorithm="drain", graph_name="synthetic", graph=None,
            init=lambda: (jnp.int32(0), jnp.array([1], jnp.int32)),
            wavefront_fn=f, on_empty=on_empty,
            result=lambda s: np.asarray([int(s)]),
            work=lambda s: s, ideal_work=1, empty_means_done=emd)

    server = TaskServer(JobRegistry(), num_lanes=1,
                        config=SchedulerConfig(num_workers=2),
                        lane_capacity=16)
    server.submit_program(make_prog(True))
    out = server.run()
    assert out.results[0][0] == 1          # finished at drain; no refill ran

    server = TaskServer(JobRegistry(), num_lanes=1,
                        config=SchedulerConfig(num_workers=2),
                        lane_capacity=16, max_rounds=5)
    server.submit_program(make_prog(False))
    with pytest.raises(RuntimeError, match="max_rounds"):
        server.run()                       # refills ran; nothing ended it


def test_programs_declare_empty_semantics(g_grid):
    cfg = SchedulerConfig(num_workers=8)
    assert build_program("bfs", g_grid, cfg).empty_means_done is True
    assert build_program("coloring", g_grid, cfg).empty_means_done is True
    pr = build_program("pagerank", g_grid, cfg)
    assert pr.empty_means_done is False    # the rescan refills the queue
    assert pr.stop is not None             # ...so convergence must bound it
