"""Property tests for the exchange wire codec (shard/codec.py, §16).

The codec is the lossy-looking-but-lossless half of the compressed
exchange: a route buffer is a *set* of tasks per destination row, so the
canonical decode — the same valid slots, each row's values sorted
ascending — carries exactly the information the receiving queue consumes.
Five properties pin the format:

  1. round-trip at every granularity width (valid mask + per-row multiset
     preserved; packed rows come back sorted),
  2. EMPTY-sentinel collision safety (values adjacent to the int32-min
     sentinel survive; padding never turns into a value),
  3. zigzag boundary behaviour (bijective on all of int32, including the
     wraparound deltas between extreme values),
  4. the raw fallback never expands (n_words <= 1 + rows*width, always),
  5. self-containedness (words beyond n_words are dead — zeroing them
     cannot change the decode).

Runs under Hypothesis when the library is installed; this container ships
without it, so the same properties also run over a seeded deterministic
fuzz corpus (the ``_cases`` generator) that covers the regimes Hypothesis
would shrink toward: every packed bit width, both count layouts + bitmask,
scattered vs prefix-compacted validity, int32 boundary values, all-EMPTY
and single-value buffers, and incompressible noise.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.queue import EMPTY
from repro.shard.codec import (PACKED_WIDTHS, codec_capacity, decode_buffer,
                               encode_buffer, unzigzag, zigzag)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # container has no hypothesis; seeded corpus below
    HAVE_HYPOTHESIS = False

E = int(EMPTY)
I32_MIN, I32_MAX = -2**31, 2**31 - 1

#: (rows, width) shapes spanning every layout's win region: narrow rows
#: (counts8), wide prefix-compact rows (counts16), scattered (bitmask).
SHAPES = [(1, 1), (1, 4), (2, 3), (4, 8), (8, 16), (3, 33), (2, 300),
          (4, 1024)]


def _roundtrip(buf: np.ndarray):
    """Encode, zero the dead tail, decode; return (decoded, mode, n_words)."""
    rows, width = buf.shape
    words, n_words = encode_buffer(jnp.asarray(buf, jnp.int32))
    n_words = int(n_words)
    # property 4: the raw fallback bounds every encoding
    assert n_words <= 1 + rows * width
    assert words.shape[0] == codec_capacity(rows, width)
    # property 5: the encoding is self-contained in its first n_words
    words = jnp.where(jnp.arange(words.shape[0]) < n_words, words, 0)
    dec = np.asarray(decode_buffer(words, rows, width))
    return dec, int(words[0]) & 3, n_words


def _check(buf: np.ndarray) -> None:
    """Properties 1+2 on one buffer: mask preserved, multiset preserved,
    packed rows sorted, no EMPTY slot ever becomes a value."""
    dec, mode, _ = _roundtrip(buf)
    if mode == 0:      # RAW reproduces the buffer verbatim
        assert (dec == buf).all()
        return
    for r in range(buf.shape[0]):
        ref_valid = buf[r] != E
        assert (ref_valid == (dec[r] != E)).all()
        vals = dec[r][ref_valid]
        assert (np.sort(buf[r][ref_valid]) == vals).all()


def _cases(seed: int = 0, n: int = 120):
    """Deterministic fuzz corpus over SHAPES x value regimes."""
    rng = np.random.default_rng(seed)
    for i in range(n):
        rows, width = SHAPES[i % len(SHAPES)]
        buf = np.full((rows, width), E, np.int64)
        regime = i % 5
        for r in range(rows):
            k = int(rng.integers(0, width + 1))
            if regime == 0:      # small local values (delta-friendly)
                vals = rng.integers(0, 512, k)
            elif regime == 1:    # full int32 range (raw fallback territory)
                vals = rng.integers(I32_MIN + 1, I32_MAX, k)
            elif regime == 2:    # sentinel-adjacent values
                vals = rng.choice([I32_MIN + 1, I32_MIN + 2, I32_MAX - 1,
                                   I32_MAX, 0, -1, 1], size=k)
            elif regime == 3:    # constant runs (best case: all-zero deltas)
                vals = np.full(k, int(rng.integers(-100, 100)))
            else:                # mixed magnitudes
                vals = rng.integers(-2**16, 2**16, k)
            if rng.random() < 0.5:     # prefix-compacted (counts layouts)
                buf[r, :k] = vals
            else:                      # scattered validity (bitmask layout)
                pos = rng.choice(width, size=k, replace=False)
                buf[r, pos] = vals
        yield buf.astype(np.int32)


# ------------------------------------------------------------ properties
def test_roundtrip_every_granularity_width():
    """Property 1 over the deterministic corpus: every shape in SHAPES is
    visited across every value regime."""
    for buf in _cases(seed=1):
        _check(buf)


@pytest.mark.parametrize("width", [1, 2, 3, 4, 7, 8, 16, 33])
def test_roundtrip_dense_rows_each_width(width):
    """Property 1, dense rows: a full buffer (no padding at all) at every
    chunk-granularity width the task layer can produce."""
    rng = np.random.default_rng(width)
    buf = rng.integers(0, 10_000, (4, width)).astype(np.int32)
    _check(buf)


def test_empty_sentinel_collision_safety():
    """Property 2: values one off the EMPTY sentinel round-trip, an
    all-EMPTY buffer encodes to the header alone, and padding positions
    never decode into values."""
    buf = np.full((4, 8), E, np.int32)
    buf[0, :3] = [I32_MIN + 1, I32_MIN + 2, I32_MAX]
    buf[2, 5] = I32_MIN + 1
    _check(buf)

    empty = np.full((4, 8), E, np.int32)
    dec, _, n_words = _roundtrip(empty)
    assert n_words == 1 and (dec == E).all()


def test_zigzag_boundary_values():
    """Property 3: zigzag is a bijection on int32, including both extremes
    and the wraparound deltas between them."""
    vals = jnp.asarray([0, -1, 1, -2, 2, I32_MAX, I32_MIN, I32_MIN + 1],
                       jnp.int32)
    assert (np.asarray(unzigzag(zigzag(vals))) == np.asarray(vals)).all()
    # small magnitudes map to small codes — the property packing relies on
    assert int(zigzag(jnp.int32(0))) == 0
    assert int(zigzag(jnp.int32(-1))) == 1
    assert int(zigzag(jnp.int32(1))) == 2
    # the extreme wraparound delta (MAX - MIN == -1 mod 2^32) stays coherent
    d = jnp.asarray(np.int32(np.int64(I32_MAX - I32_MIN) & 0xFFFFFFFF))
    assert int(unzigzag(zigzag(d))) == int(d)


def test_raw_fallback_never_expands():
    """Property 4: adversarially incompressible buffers (full-range noise,
    scattered) cost at most the raw 1 + rows*width words."""
    rng = np.random.default_rng(9)
    for rows, width in SHAPES:
        buf = rng.integers(I32_MIN + 1, I32_MAX, (rows, width))
        buf = buf.astype(np.int32)
        _check(buf)


def test_compressible_buffer_beats_raw():
    """The reason the codec exists: a sparse prefix-compacted buffer of
    local values costs far fewer words than its slot count."""
    buf = np.full((4, 1024), E, np.int32)
    buf[0, :7] = np.sort(np.arange(7) * 3)
    buf[2, :5] = np.sort(64 + np.arange(5))
    _, mode, n_words = _roundtrip(buf)
    assert mode != 0
    assert n_words < 12 + 1          # payload ints + header, not 4096 slots


def test_single_value_and_tiny_shapes():
    """Degenerate shapes: one slot, one row, one value."""
    for buf in ([[5]], [[E]], [[E, 7]], [[7], [E]]):
        _check(np.asarray(buf, np.int32))


# ----------------------------------------------- hypothesis twin (gated)
if HAVE_HYPOTHESIS:
    @settings(max_examples=200, deadline=None)
    @given(st.data())
    def test_hypothesis_roundtrip(data):
        rows = data.draw(st.integers(1, 6))
        width = data.draw(st.sampled_from([1, 2, 4, 8, 16, 33, 300]))
        buf = np.full((rows, width), E, np.int64)
        for r in range(rows):
            k = data.draw(st.integers(0, width))
            vals = data.draw(st.lists(
                st.integers(I32_MIN + 1, I32_MAX), min_size=k, max_size=k))
            pos = data.draw(st.permutations(range(width)))[:k]
            buf[r, list(pos)] = vals
        _check(buf.astype(np.int32))

    @settings(max_examples=100, deadline=None)
    @given(st.integers(I32_MIN, I32_MAX))
    def test_hypothesis_zigzag_bijection(v):
        x = jnp.int32(v)
        assert int(unzigzag(zigzag(x))) == v
