"""Streaming benchmark: incremental recompute vs full recompute, measured.

  PYTHONPATH=src python -m benchmarks.run stream

Runs the three algorithms over the same R-MAT graph + seeded delta log
(``graph/generators.edge_delta_stream``: small mixed insert/delete batches)
twice — once with the per-algorithm dirty-seed rules
(``stream/incremental``), once with the conservative full reseed — and
emits ``BENCH_stream.json`` with, per algorithm and mode, the per-batch
rounds / work-counter / seed and effective-op counts.  The headline
``findings`` block pins the subsystem's reason to exist as data:
**incremental work is strictly below full-recompute work on small-delta
batches** for every algorithm (coloring's conflict-repair rule is the
dramatic case: it re-colors only the losing endpoints of newly conflicted
edges).

Every run commits its deltas through the slotted-CSR path
(``graph/slotted``, ``--compact-every`` = :data:`COMPACT_EVERY` here), so
the per-batch rows also carry the O(delta) commit-cost columns — rows
touched by the commit, overlay occupancy after it, whether it compacted —
and each mode totals its commit wall seconds / touched rows / compactions.
The ``findings`` block asserts the tentpole property as data: **every
commit touches strictly fewer rows than the graph has edges** (the old
path rebuilt all m edges per batch).

Also recorded:

  * ``sharded_bfs`` — the same streamed BFS over the 8-device mesh,
    asserted bit-identical to the single-topology stream (the owner-aware
    delta rebuild preserves the ownership blocks);
  * ``snapshot`` — wall-second overhead of crash-consistent mid-drain
    snapshots (save-enabled run vs plain run, plus one resume), excluded
    from the CI guard like every other wall measurement.

All rounds/work/seed counters are schedule-deterministic, so
``benchmarks/smoke.py`` recomputes them in CI and fails on drift, exactly
like the BENCH_shard.json / BENCH_granularity.json guards.

The measurement runs in a subprocess that forces 8 XLA host devices before
jax initializes, so the benchmark works from any session.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .harness import emit_json, row

OUT = "BENCH_stream.json"
# shared with benchmarks/smoke.py — the regression guard recomputes with
# exactly the configs that produced the checked-in JSON
SCALE = 9           # R-MAT: 2**9 vertices
EDGE_FACTOR = 8
GRAPH_SEED = 1
STREAM_SEED = 2
BATCHES = 4         # delta batches per stream
BATCH_SIZE = 16     # edge ops per batch (small deltas — the target regime)
WORKERS = 32
PR_EPS = 1e-4
SNAP_EVERY = 2      # rounds between mid-drain snapshots (overhead section)
COMPACT_EVERY = 2   # slotted-CSR re-pack cadence (taskserver --compact-every)
ALGOS = (("bfs", {"source": 0}), ("pagerank", {"eps": PR_EPS}),
         ("coloring", {}))


def _child() -> None:
    import tempfile
    import time

    import numpy as np

    from repro.core import SchedulerConfig
    from repro.graph.generators import edge_delta_stream, rmat
    from repro.runtime import stream_execute

    base = rmat(SCALE, edge_factor=EDGE_FACTOR, seed=GRAPH_SEED)
    deltas = edge_delta_stream(base, BATCHES, BATCH_SIZE, seed=STREAM_SEED)
    cfg = SchedulerConfig(num_workers=WORKERS, topology="single",
                          persistent=False)
    payload: dict = {
        "config": {"scale": SCALE, "edge_factor": EDGE_FACTOR,
                   "batches": BATCHES, "batch_size": BATCH_SIZE,
                   "workers": WORKERS, "eps": PR_EPS},
        "algorithms": {},
    }

    def batch_rows(res):
        return [{"rounds": r.rounds, "work": r.work, "seeds": r.seeds,
                 "eff": r.effective_ops, "touched": r.touched_rows,
                 "overlay": r.overlay, "compacted": r.compacted}
                for r in res.batches]

    m = base.num_edges
    for algo, params in ALGOS:
        entry: dict = {}
        for mode, incr in (("incremental", True), ("full", False)):
            t0 = time.perf_counter()
            res = stream_execute(algo, base, deltas, cfg,
                                 params=dict(params), incremental=incr,
                                 compact_every=COMPACT_EVERY)
            wall = time.perf_counter() - t0
            assert res.info["dropped"] == 0, (algo, mode)
            assert all(r.touched_rows < m for r in res.batches), (algo, mode)
            entry[mode] = {
                "per_batch": batch_rows(res),
                # delta-batch totals only: batch 0 (the cold drain on the
                # base graph) is identical in both modes by construction
                "total_rounds": sum(r.rounds for r in res.batches[1:]),
                "total_work": sum(r.work for r in res.batches[1:]),
                "wall_seconds": wall,
                # O(delta) commit cost (apply + patch wall, rows touched,
                # slotted re-packs) — the tentpole meters
                "commit_seconds": res.info["commit_seconds"],
                "touched_rows": res.info["touched_rows"],
                "compactions": res.info["compactions"],
            }
        iw = entry["incremental"]["total_work"]
        fw = entry["full"]["total_work"]
        assert iw < fw, (algo, iw, fw)
        entry["savings"] = {"work_ratio": iw / fw if fw else 0.0}
        payload["algorithms"][algo] = entry

    # sharded streaming parity: same log over the 8-device mesh
    scfg = SchedulerConfig(num_workers=WORKERS, topology="sharded",
                           num_shards=8, persistent=False)
    t0 = time.perf_counter()
    sres = stream_execute("bfs", base, deltas, scfg, params={"source": 0},
                          compact_every=COMPACT_EVERY)
    swall = time.perf_counter() - t0
    ref = stream_execute("bfs", base, deltas, cfg, params={"source": 0},
                         compact_every=COMPACT_EVERY)
    parity = bool((np.asarray(sres.result) == np.asarray(ref.result)).all())
    assert parity and sres.info["dropped"] == 0
    payload["sharded_bfs"] = {
        "rounds": sres.info["rounds"],
        "work": sres.info["work"],
        "exchanged": sres.info["exchanged"],
        "parity": parity,
        "wall_seconds": swall,
    }

    # snapshot overhead: save-enabled run vs the plain run, plus a resume
    # (the resume replays the log and re-drains from the newest snapshot)
    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        snap_res = stream_execute("bfs", base, deltas, cfg,
                                  params={"source": 0},
                                  snapshot_every=SNAP_EVERY,
                                  checkpoint_dir=d, keep=1000,
                                  compact_every=COMPACT_EVERY)
        snap_wall = time.perf_counter() - t0
        ticks = len([p for p in os.listdir(d) if p.startswith("snap_")])
        t0 = time.perf_counter()
        stream_execute("bfs", base, deltas, cfg, params={"source": 0},
                       snapshot_every=SNAP_EVERY, checkpoint_dir=d,
                       keep=1000, resume=True,
                       compact_every=COMPACT_EVERY)
        resume_wall = time.perf_counter() - t0
        assert (np.asarray(snap_res.result)
                == np.asarray(ref.result)).all()
    plain_wall = payload["algorithms"]["bfs"]["incremental"]["wall_seconds"]
    payload["snapshot"] = {
        "ticks": ticks,
        "snapshot_every": SNAP_EVERY,
        "save_wall_seconds": snap_wall,
        "plain_wall_seconds": plain_wall,
        "resume_wall_seconds": resume_wall,
    }

    payload["findings"] = {
        "incremental_below_full": {
            a: payload["algorithms"][a]["incremental"]["total_work"]
            < payload["algorithms"][a]["full"]["total_work"]
            for a, _ in ALGOS},
        # O(delta) commits: every batch's slab-touched row count stays
        # strictly below m (= full-rebuild cost in rows)
        "commit_touched_below_m": {
            a: all(r["touched"] < m
                   for mode in ("incremental", "full")
                   for r in payload["algorithms"][a][mode]["per_batch"])
            for a, _ in ALGOS},
    }
    print(json.dumps(payload))


def run(out: str = OUT):
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        JAX_PLATFORMS=os.environ.get("JAX_PLATFORMS", "cpu"),
    )
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_stream", "--child"],
        capture_output=True, text=True, env=env, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(
            f"bench_stream child failed:\n{proc.stderr[-3000:]}")
    payload = json.loads(proc.stdout.strip().splitlines()[-1])

    for algo, entry in payload["algorithms"].items():
        inc, full = entry["incremental"], entry["full"]
        row(f"stream/{algo}", inc["wall_seconds"] * 1e6,
            f"inc_work={inc['total_work']} full_work={full['total_work']} "
            f"inc_rounds={inc['total_rounds']} "
            f"full_rounds={full['total_rounds']} "
            f"ratio={entry['savings']['work_ratio']:.3f}")
        row(f"stream/{algo}/commit", inc["commit_seconds"] * 1e6,
            f"touched={inc['touched_rows']} "
            f"compactions={inc['compactions']}")
    s = payload["sharded_bfs"]
    row("stream/bfs_shard", s["wall_seconds"] * 1e6,
        f"rounds={s['rounds']} work={s['work']} "
        f"exchanged={s['exchanged']} parity={s['parity']}")
    sn = payload["snapshot"]
    row("stream/snapshot", sn["save_wall_seconds"] * 1e6,
        f"ticks={sn['ticks']} plain={sn['plain_wall_seconds']:.2f}s "
        f"resume={sn['resume_wall_seconds']:.2f}s")
    emit_json(out, payload)
    return payload


if __name__ == "__main__":
    if "--child" in sys.argv:
        _child()
    else:
        run()
