"""Mixed-tenant serving benchmark: fused wavefronts vs per-job sequential.

  PYTHONPATH=src python -m benchmarks.run server

Submits N concurrent jobs (BFS + PageRank + coloring, mixed over a
scale-free and a mesh graph) and compares

  * **fused**      — one TaskServer, per-job lanes, weighted fair sharing:
    underfilled frontiers from different tenants overlap in one wavefront;
  * **sequential** — each job alone with the full wavefront (what a
    tenant-at-a-time deployment pays).

Emits ``BENCH_server.json`` with total rounds, wall time, occupancy, and
per-job telemetry for both modes.  The paper's small-frontier fixed-cost
analysis predicts fused < sequential in total rounds; the JSON records the
measured ratio.  Note wall time on CPU includes one host dispatch per
granted lane per round, which favors sequential; rounds (device work
launches saved) is the architecture-level metric.

The benchmark also runs the autotuner over the job mix with the kernel
``backend`` axis in the candidate grid (DESIGN.md section 9) and records,
per job, which backend (and launch shape) calibration picked — on CPU that
is jnp (pallas interprets); on TPU the same benchmark reports the
compiled-kernel choice.
"""
from __future__ import annotations

import dataclasses

from repro.core.scheduler import SchedulerConfig
from repro.launch.taskserver import build_registry, mixed_specs
from repro.server import Autotuner, TaskServer, serve_sequential

from .harness import emit_json, row, timeit_host

N_JOBS = 9
SCALE = 8          # R-MAT: 2**8 vertices
GRID_SIDE = 16     # mesh: 16x16
EPS = 1e-4
POLICY = "weighted"
OUT = "BENCH_server.json"


def _run_fused(registry, specs, config, policy, n_lanes):
    server = TaskServer(registry, num_lanes=n_lanes, config=config,
                        policy=policy)
    for spec in specs:
        server.submit(spec)
    return server.run()


def _autotune_backends(registry, specs):
    """Tune each job's (algorithm, graph-class) over the backend axis and
    return ``{job_index: {key, chosen, backend}}``.  A small grid — the
    default launch shape on each backend — keeps calibration cheap while
    still exercising the axis the tentpole added."""
    # warmup=1 so each candidate's timed sample excludes JIT trace+compile —
    # otherwise the recorded backend picks are compile-time noise.
    tuner = Autotuner(
        candidates=[SchedulerConfig(),
                    dataclasses.replace(SchedulerConfig(), backend="pallas")],
        warmup=1, iters=1)
    picks = {}
    for i, spec in enumerate(specs):
        graph = registry.graph(spec.graph)
        chosen = tuner.tune(spec.algorithm, graph)  # cached per (alg, class)
        key = tuner.cache_key(spec.algorithm, graph)
        picks[str(i)] = {"key": key, "backend": chosen.backend,
                         "num_workers": chosen.num_workers,
                         "fetch_size": chosen.fetch_size,
                         "persistent": chosen.persistent}
        row(f"server/autotune_backend/job{i}", 0.0,
            f"{key} -> {chosen.backend}")
    return picks


def run(n_jobs: int = N_JOBS, scale: int = SCALE, grid_side: int = GRID_SIDE,
        policy: str = POLICY, eps: float = EPS, iters: int = 2,
        out: str = OUT, seed: int = 0):
    registry = build_registry(scale, grid_side, seed)
    specs = mixed_specs(n_jobs, registry, eps, seed)
    config = SchedulerConfig()

    autotune_picks = _autotune_backends(registry, specs)

    fused_wall, fused = timeit_host(
        lambda: _run_fused(registry, specs, config, policy, n_jobs),
        warmup=1, iters=iters)
    seq_wall, seq = timeit_host(
        lambda: serve_sequential(registry, specs, config=config),
        warmup=1, iters=iters)

    row("server/fused_rounds", fused.stats.rounds,
        f"occupancy={fused.stats.occupancy:.3f}")
    row("server/sequential_rounds", seq.stats.rounds,
        f"occupancy={seq.stats.occupancy:.3f}")
    row("server/fused_wall_us", fused_wall * 1e6)
    row("server/sequential_wall_us", seq_wall * 1e6)
    ratio = fused.stats.rounds / max(seq.stats.rounds, 1)
    row("server/rounds_ratio", ratio * 100, "fused/sequential x100")

    payload = {
        "workload": {
            "jobs": [
                {"algorithm": s.algorithm, "graph": s.graph,
                 "params": s.params, "weight": s.weight} for s in specs
            ],
            "graphs": {
                name: {"n": registry.graph(name).num_vertices,
                       "m": registry.graph(name).num_edges}
                for name in registry.graph_names
            },
            "config": {"num_workers": config.num_workers,
                       "fetch_size": config.fetch_size,
                       "backend": config.backend,
                       "policy": policy},
        },
        "autotune_backend_per_job": autotune_picks,
        "fused": {
            "rounds": fused.stats.rounds,
            "wall_seconds": fused_wall,
            "occupancy": fused.stats.occupancy,
            "backpressure_events": fused.stats.backpressure_events,
            "jobs": {str(k): t.as_dict()
                     for k, t in fused.telemetry.items()},
        },
        "sequential": {
            "rounds": seq.stats.rounds,
            "wall_seconds": seq_wall,
            "occupancy": seq.stats.occupancy,
            "jobs": {str(k): t.as_dict() for k, t in seq.telemetry.items()},
        },
        "fused_over_sequential_rounds": ratio,
        "fused_over_sequential_wall": fused_wall / max(seq_wall, 1e-12),
    }
    emit_json(out, payload)
    return payload


if __name__ == "__main__":
    run()
