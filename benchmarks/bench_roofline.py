"""Roofline table reader: summarizes experiments/dryrun/*.json.

CSV: name = roofline/<arch>/<shape>/<mesh>, us = wall (max term, us),
derived = dominant;terms;fraction.  This is the per-cell source for
EXPERIMENTS.md section Roofline.
"""
from __future__ import annotations

import glob
import json
import os

from .harness import row

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def run():
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not files:
        row("roofline/NO_DATA", 0.0,
            "run repro.launch.dryrun --all first")
        return
    for path in files:
        with open(path) as f:
            rec = json.load(f)
        if rec.get("skipped"):
            continue
        wall = max(rec["t_compute_s"], rec["t_memory_s"],
                   rec["t_collective_s"])
        name = (f"roofline/{rec['arch']}/{rec['shape']}/"
                f"{rec['mesh']}/{rec.get('tag', 'baseline')}")
        row(name, wall * 1e6,
            f"dom={rec['dominant']};tC={rec['t_compute_s']:.2e};"
            f"tM={rec['t_memory_s']:.2e};tN={rec['t_collective_s']:.2e};"
            f"useful={rec['usefulness']:.2f};"
            f"frac={rec['roofline_fraction']:.4f}")
